//! Table III: LM perplexity under SAFs per grouping configuration.

use super::Table;
use crate::coordinator::Method;
use crate::fault::FaultRates;
use crate::grouping::GroupConfig;
use crate::metrics::mean_std;
use crate::nn::lm::LmEvaluator;
use crate::runtime::Runtime;
use anyhow::Result;
use std::path::Path;

pub struct LmOptions {
    pub configs: Vec<GroupConfig>,
    pub trials: usize,
    pub threads: usize,
    pub max_windows: usize,
    pub include_unprotected: bool,
}

impl Default for LmOptions {
    fn default() -> Self {
        LmOptions {
            configs: vec![GroupConfig::R1C4, GroupConfig::R2C2],
            trials: 3,
            threads: crate::util::pool::default_threads(None),
            max_windows: 60,
            include_unprotected: false,
        }
    }
}

/// Table III: perplexity per stream (jaxsrc/npsrc/pysrc stand in for
/// WikiText-2/PTB/C4), mean over chips.
pub fn table3(rt: &Runtime, art: &Path, opts: &LmOptions) -> Result<Table> {
    let mut t = Table::new(
        "Table III — LM perplexity under SAFs (mean ± std over chips)",
        &["config", "prec.", "jaxsrc", "npsrc", "pysrc"],
    );

    // Fault-free quantized reference.
    {
        let mut ev = LmEvaluator::new(rt, art, GroupConfig::R1C4)?;
        ev.max_windows = opts.max_windows;
        let r = ev.eval(0, FaultRates::none(), Method::Complete, opts.threads)?;
        let mut row = vec!["w/o SAF".to_string(), "8 bit".to_string()];
        for (_, p) in &r.ppl {
            row.push(format!("{p:.2}"));
        }
        t.row(row);
    }

    for cfg in &opts.configs {
        for (method, suffix) in method_rows(opts.include_unprotected) {
            let mut ev = LmEvaluator::new(rt, art, *cfg)?;
            ev.max_windows = opts.max_windows;
            // trials × 3 streams.
            let mut per_stream: Vec<Vec<f64>> = vec![Vec::new(); 3];
            for trial in 0..opts.trials {
                let r = ev.eval(
                    9000 + trial as u64,
                    FaultRates::paper_default(),
                    method,
                    opts.threads,
                )?;
                for (i, (_, p)) in r.ppl.iter().enumerate() {
                    per_stream[i].push(*p);
                }
            }
            let mut row = vec![
                format!("{}{}", cfg.name(), suffix),
                format!("{:.2} bit", cfg.precision_bits()),
            ];
            for s in &per_stream {
                let (m, sd) = mean_std(s);
                row.push(format!("{m:.2} (±{sd:.2})"));
            }
            t.row(row);
        }
    }
    Ok(t)
}

fn method_rows(include_unprotected: bool) -> Vec<(Method, &'static str)> {
    if include_unprotected {
        vec![(Method::Complete, ""), (Method::Unprotected, " raw")]
    } else {
        vec![(Method::Complete, "")]
    }
}
