//! Fig 6 (inconsecutivity probability) and Fig 11 (energy vs array size).

use super::Table;
use crate::arrays::models::by_name;
use crate::arrays::{map_network, ArrayDims, MapperPolicy};
use crate::energy::{network_energy, EnergyParams};
use crate::fault::{FaultRates, GroupFaults};
use crate::grouping::{FaultAnalysis, GroupConfig};
use crate::util::prng::Rng;
use anyhow::{anyhow, Result};

/// Fig 6: Monte-Carlo probability that a sampled fault map yields an
/// inconsecutive representable range, per grouping config.
pub fn fig6(configs: &[GroupConfig], samples: usize, seed: u64) -> Table {
    let mut t = Table::new(
        "Fig 6 — inconsecutivity probability at published fault rates",
        &["config", "P(inconsecutive)", "P(any fault)", "samples"],
    );
    let rates = FaultRates::paper_default();
    for cfg in configs {
        let mut rng = Rng::new(seed);
        let mut inconsec = 0usize;
        let mut any_fault = 0usize;
        for _ in 0..samples {
            let faults = GroupFaults::sample(cfg.cells(), &rates, &mut rng);
            if faults.is_fault_free() {
                continue;
            }
            any_fault += 1;
            let fa = FaultAnalysis::new(cfg, &faults);
            if !fa.consecutive {
                inconsec += 1;
            }
        }
        t.row(vec![
            cfg.name(),
            format!("{:.4}%", 100.0 * inconsec as f64 / samples as f64),
            format!("{:.2}%", 100.0 * any_fault as f64 / samples as f64),
            samples.to_string(),
        ]);
    }
    t
}

/// Fig 11: normalized energy vs array dimension for one network.
pub fn fig11(
    model: &str,
    sizes: &[usize],
    params: &EnergyParams,
    policy: MapperPolicy,
) -> Result<Table> {
    let layers = by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let mut t = Table::new(
        &format!("Fig 11 — normalized energy vs array size ({model}, {policy:?})"),
        &["array", "R1C4", "R2C2", "R2C4", "R1C4 row-util", "R2C2 row-util"],
    );
    for &n in sizes {
        let dims = ArrayDims::square(n);
        let base = network_energy(&layers, dims, &GroupConfig::R1C4, params, policy).0.total();
        let e22 = network_energy(&layers, dims, &GroupConfig::R2C2, params, policy).0.total();
        let e24 = network_energy(&layers, dims, &GroupConfig::R2C4, params, policy).0.total();
        let u14 = crate::arrays::mean_row_utilization(&map_network(
            &layers,
            dims,
            &GroupConfig::R1C4,
            policy,
        ));
        let u22 = crate::arrays::mean_row_utilization(&map_network(
            &layers,
            dims,
            &GroupConfig::R2C2,
            policy,
        ));
        t.row(vec![
            format!("{n}x{n}"),
            "1.000".to_string(),
            format!("{:.3}", e22 / base),
            format!("{:.3}", e24 / base),
            format!("{:.2}", u14),
            format!("{:.2}", u22),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_r1c4_much_more_inconsecutive_than_r2c2() {
        let t = fig6(&[GroupConfig::R1C4, GroupConfig::R2C2], 200_000, 99);
        let parse = |row: &[String]| -> f64 {
            row[1].trim_end_matches('%').parse::<f64>().unwrap()
        };
        let p14 = parse(&t.rows[0]);
        let p22 = parse(&t.rows[1]);
        // Paper: 3.49% vs 0.01% — two orders of magnitude apart.
        assert!(p14 > 1.0, "R1C4 inconsecutivity {p14}% too low");
        assert!(p22 < 0.2, "R2C2 inconsecutivity {p22}% too high");
        assert!(p14 / p22.max(1e-6) > 20.0);
    }

    #[test]
    fn fig11_generates_all_sizes() {
        let t = fig11(
            "resnet20",
            &[64, 128, 256, 512],
            &EnergyParams::default(),
            MapperPolicy::KernelSplit,
        )
        .unwrap();
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            let r22: f64 = row[2].parse().unwrap();
            assert!(r22 < 1.0, "R2C2 should save energy: {r22}");
        }
    }
}
