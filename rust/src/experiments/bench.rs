//! `rchg bench` — the per-PR performance-trajectory harness.
//!
//! Runs a fixed, seeded workload suite — cold/warm compile throughput on
//! ResNet-20-shaped tensors, dedupe ratio, `DiffTable` builds/s (vectorized
//! vs scalar reference), batch-scan throughput (parallel vs sequential
//! reference, plus "RCRG" registry-snapshot codec rates), shard merge
//! time, a localhost fabric round-trip, and the traced-vs-untraced
//! compile overhead (`obs_overhead`) — and emits a schema-stable
//! JSON report. The report for
//! PR *n* is committed at the repo root as `BENCH_<n>.json`, so the perf
//! trajectory across PRs is a diffable artifact; CI runs the same suite
//! with `--quick` on every push and uploads the result.
//!
//! Schema stability contract: a report's **key tree** never changes within
//! one `schema` tag ([`BENCH_SCHEMA`]). [`skeleton`] is the canonical key
//! tree (every leaf `null`); [`validate`] checks any report against it.
//! Leaf *values* split into two classes: timing fields (names ending in
//! `_secs` / `_per_sec`, plus `speedup` — see [`is_timing_field`]) vary
//! run to run, every other field is a deterministic function of the seeded
//! workload and must be identical across runs (pinned by the
//! `bench_harness` integration test).
//!
//! The microbenchmarks under `rust/benches/` share this module's workload
//! definitions ([`seeded_cases`], [`BENCH_MODEL`], [`BENCH_CHIP_SEED`],
//! [`compile_sample`]) so the two never drift apart.

use super::compile_time::synthetic_model_tensors;
use crate::coordinator::compiler::{dedup_ratio_of, scan_batch, scan_batch_reference, TensorJob};
use crate::coordinator::persist::{decode_registry_snapshot, encode_registry_snapshot, CacheKey};
use crate::coordinator::{
    CompileOptions, CompileSession, Method, ServiceOptions, ShardPlan, SolveCache, TableBudget,
};
use crate::decompose::GroupTables;
use crate::fault::bank::ChipFaults;
use crate::fault::{FaultRates, GroupFaults};
use crate::grouping::GroupConfig;
use crate::net::{run_worker, CompileClient, FabricServer, ServeOptions};
use crate::obs;
use crate::store::StoreHandle;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::timer::{bench, black_box, Timer};
use anyhow::{anyhow, Result};
use std::thread;
use std::time::Duration;

/// Schema tag of the report format. Bump only on key-tree changes.
pub const BENCH_SCHEMA: &str = "rchg-bench-v1";

/// Model shape every compile workload uses.
pub const BENCH_MODEL: &str = "resnet20";

/// Chip fault-bank seed shared with `benches/bench_compile.rs`.
pub const BENCH_CHIP_SEED: u64 = 1;

/// Case-pool RNG seed shared with `benches/bench_decompose.rs`.
pub const BENCH_CASE_SEED: u64 = 7;

/// Case-pool size of the decompose/DiffTable microbenchmarks.
pub const BENCH_CASE_POOL: usize = 4096;

/// The two configs every per-config workload runs at.
pub const BENCH_CONFIGS: [GroupConfig; 2] = [GroupConfig::R2C2, GroupConfig::R1C4];

/// Sample size of `bench_compile`'s Table-II rows (shared so the criterion
/// bench and this harness measure the same seeded inputs).
pub fn compile_sample(quick: bool) -> usize {
    if quick {
        50_000
    } else {
        400_000
    }
}

/// The seeded (fault pattern, weight) case pool shared by
/// `benches/bench_decompose.rs` and the harness's DiffTable workload —
/// one generator, no drift between the two measurements.
pub fn seeded_cases(cfg: &GroupConfig, n: usize) -> Vec<(GroupFaults, i64)> {
    let rates = FaultRates::paper_default();
    let mut rng = Rng::new(BENCH_CASE_SEED);
    (0..n)
        .map(|_| {
            (
                GroupFaults::sample(cfg.cells(), &rates, &mut rng),
                rng.range_i64(-cfg.max_per_array(), cfg.max_per_array()),
            )
        })
        .collect()
}

/// Workload sizes for one harness run.
#[derive(Clone, Debug)]
pub struct BenchOptions {
    /// Solver threads for the compile/shard workloads.
    pub threads: usize,
    /// Total weight cap of the cold/warm compile and shard workloads.
    pub compile_limit: usize,
    /// DiffTable case-pool size (≤ [`BENCH_CASE_POOL`]).
    pub difftable_cases: usize,
    /// Minimum timed seconds per DiffTable measurement.
    pub min_time_s: f64,
    /// Shard count of the shard-merge workload.
    pub shards: usize,
    /// Total weight cap of the fabric round-trip workload.
    pub fabric_limit: usize,
    /// Run the localhost fabric round-trip (needs TCP loopback); when
    /// off, the fabric workload's fields are emitted as `null` so the
    /// schema stays identical.
    pub fabric: bool,
}

impl BenchOptions {
    /// Full-size suite (the numbers committed as `BENCH_<n>.json`).
    pub fn full() -> BenchOptions {
        BenchOptions {
            threads: 1,
            compile_limit: 120_000,
            difftable_cases: BENCH_CASE_POOL,
            min_time_s: 0.5,
            shards: 4,
            fabric_limit: 10_000,
            fabric: true,
        }
    }

    /// Reduced suite for the CI smoke step (`rchg bench --quick`).
    pub fn quick() -> BenchOptions {
        BenchOptions {
            threads: 1,
            compile_limit: 20_000,
            difftable_cases: 512,
            min_time_s: 0.1,
            shards: 2,
            fabric_limit: 2_000,
            fabric: true,
        }
    }

    /// Tiny suite for the test harness: seconds, not minutes, and no
    /// sockets inside `cargo test`.
    pub fn tiny() -> BenchOptions {
        BenchOptions {
            threads: 1,
            compile_limit: 1_500,
            difftable_cases: 48,
            min_time_s: 0.0,
            shards: 2,
            fabric_limit: 400,
            fabric: false,
        }
    }
}

/// Is `name` a timing leaf (varies run to run) rather than a
/// deterministic property of the seeded workload?
pub fn is_timing_field(name: &str) -> bool {
    name.ends_with("_secs") || name.ends_with("_per_sec") || name == "speedup"
}

/// A copy of `doc` with every timing leaf (by [`is_timing_field`])
/// nulled — the view the determinism test compares across runs.
pub fn strip_timings(doc: &Json) -> Json {
    match doc {
        Json::Obj(m) => Json::Obj(
            m.iter()
                .map(|(k, v)| {
                    let v = if is_timing_field(k) { Json::Null } else { strip_timings(v) };
                    (k.clone(), v)
                })
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(strip_timings).collect()),
        other => other.clone(),
    }
}

// ---------------------------------------------------------------------
// Workload measurements. Each workload has a measurement struct and one
// `*_fields` function mapping `Option<&M>` to named leaves — called with
// `Some` by the runner and with `None` by `skeleton()`, which is what
// guarantees the two key trees can never drift apart.
// ---------------------------------------------------------------------

struct CompileMeasurement {
    weights: usize,
    tensors: usize,
    unique_patterns: usize,
    unique_pairs: usize,
    pattern_tables_built: usize,
    cold_secs: f64,
    warm_secs: f64,
    warm_fresh_pairs: usize,
    /// Fleet-store lookups during the cold compile (store starts empty,
    /// so the hit rate is 0 by construction — the no-spurious-hit check).
    store_cold_hits: u64,
    store_cold_misses: u64,
    /// Fleet-store lookups when a *second chip* compiles the same model
    /// against the store the first chip populated — the cross-chip reuse
    /// the store exists for.
    store_warm_hits: u64,
    store_warm_misses: u64,
}

fn compile_fields(m: Option<&CompileMeasurement>) -> Vec<(&'static str, Json)> {
    let f = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    vec![
        ("weights", f(m.map(|m| m.weights as f64))),
        ("tensors", f(m.map(|m| m.tensors as f64))),
        ("unique_patterns", f(m.map(|m| m.unique_patterns as f64))),
        ("unique_pairs", f(m.map(|m| m.unique_pairs as f64))),
        ("dedup_ratio", f(m.map(|m| dedup_ratio_of(m.weights, m.unique_pairs)))),
        ("pattern_tables_built", f(m.map(|m| m.pattern_tables_built as f64))),
        ("cold_secs", f(m.map(|m| m.cold_secs))),
        ("cold_weights_per_sec", f(m.map(|m| per_sec(m.weights, m.cold_secs)))),
        ("cold_patterns_per_sec", f(m.map(|m| per_sec(m.unique_patterns, m.cold_secs)))),
        ("warm_secs", f(m.map(|m| m.warm_secs))),
        ("warm_weights_per_sec", f(m.map(|m| per_sec(m.weights, m.warm_secs)))),
        ("warm_fresh_pairs", f(m.map(|m| m.warm_fresh_pairs as f64))),
        ("store_cold_hit_rate", f(m.and_then(|m| hit_rate(m.store_cold_hits, m.store_cold_misses)))),
        ("store_warm_hit_rate", f(m.and_then(|m| hit_rate(m.store_warm_hits, m.store_warm_misses)))),
    ]
}

/// Store hit rate over `hits + misses` lookups; `None` (→ a `null` leaf)
/// when the workload never consulted the store (per-weight tiers).
/// Deterministic: the lookup set is the seeded fresh-pattern set, which
/// does not depend on thread count or timing.
fn hit_rate(hits: u64, misses: u64) -> Option<f64> {
    let n = hits + misses;
    if n == 0 {
        None
    } else {
        Some(hits as f64 / n as f64)
    }
}

struct DiffTableMeasurement {
    cases: usize,
    distinct_tables: usize,
    build_secs: f64,
    reference_secs: f64,
}

fn difftable_fields(m: Option<&DiffTableMeasurement>) -> Vec<(&'static str, Json)> {
    let f = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    vec![
        ("cases", f(m.map(|m| m.cases as f64))),
        ("distinct_tables", f(m.map(|m| m.distinct_tables as f64))),
        ("builds_per_sec", f(m.map(|m| per_sec(m.cases, m.build_secs)))),
        ("reference_builds_per_sec", f(m.map(|m| per_sec(m.cases, m.reference_secs)))),
        ("speedup", f(m.map(|m| m.reference_secs / m.build_secs.max(1e-12)))),
    ]
}

struct ScanMeasurement {
    groups: usize,
    patterns: usize,
    reference_secs: f64,
    parallel_secs: f64,
    /// Threads the parallel side ran with (host parallelism capped at 8;
    /// the reference is sequential by definition).
    scan_threads: usize,
    snapshot_bytes: usize,
    encode_secs: f64,
    decode_secs: f64,
}

fn scan_fields(m: Option<&ScanMeasurement>) -> Vec<(&'static str, Json)> {
    let f = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    let mib = (1usize << 20) as f64;
    vec![
        ("groups", f(m.map(|m| m.groups as f64))),
        ("patterns", f(m.map(|m| m.patterns as f64))),
        ("scan_threads", f(m.map(|m| m.scan_threads as f64))),
        ("reference_groups_per_sec", f(m.map(|m| per_sec(m.groups, m.reference_secs)))),
        ("parallel_groups_per_sec", f(m.map(|m| per_sec(m.groups, m.parallel_secs)))),
        ("speedup", f(m.map(|m| m.reference_secs / m.parallel_secs.max(1e-12)))),
        ("snapshot_bytes", f(m.map(|m| m.snapshot_bytes as f64))),
        ("snapshot_encode_mb_per_sec", f(m.map(|m| per_sec(m.snapshot_bytes, m.encode_secs) / mib))),
        ("snapshot_decode_mb_per_sec", f(m.map(|m| per_sec(m.snapshot_bytes, m.decode_secs) / mib))),
    ]
}

struct ShardMergeMeasurement {
    shards: usize,
    patterns: usize,
    solved_pairs: usize,
    shard_solve_secs: f64,
    merge_secs: f64,
}

fn shard_merge_fields(m: Option<&ShardMergeMeasurement>) -> Vec<(&'static str, Json)> {
    let f = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    vec![
        ("shards", f(m.map(|m| m.shards as f64))),
        ("patterns", f(m.map(|m| m.patterns as f64))),
        ("solved_pairs", f(m.map(|m| m.solved_pairs as f64))),
        ("shard_solve_secs", f(m.map(|m| m.shard_solve_secs))),
        ("merge_secs", f(m.map(|m| m.merge_secs))),
    ]
}

struct FabricMeasurement {
    weights: usize,
    tensors: usize,
    shards: usize,
    workers: usize,
    fresh_solves: u64,
    roundtrip_secs: f64,
}

fn fabric_fields(m: Option<&FabricMeasurement>) -> Vec<(&'static str, Json)> {
    let f = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    vec![
        ("weights", f(m.map(|m| m.weights as f64))),
        ("tensors", f(m.map(|m| m.tensors as f64))),
        ("shards", f(m.map(|m| m.shards as f64))),
        ("workers", f(m.map(|m| m.workers as f64))),
        ("fresh_solves", f(m.map(|m| m.fresh_solves as f64))),
        ("roundtrip_secs", f(m.map(|m| m.roundtrip_secs))),
        ("weights_per_sec", f(m.map(|m| per_sec(m.weights, m.roundtrip_secs)))),
    ]
}

struct ObsOverheadMeasurement {
    weights: usize,
    /// Records the traced run emitted (header + spans). Deterministic:
    /// compile spans come from the sequential driver thread only, so the
    /// count is a pure function of the seeded workload.
    trace_records: u64,
    untraced_secs: f64,
    traced_secs: f64,
}

fn obs_overhead_fields(m: Option<&ObsOverheadMeasurement>) -> Vec<(&'static str, Json)> {
    let f = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    vec![
        ("weights", f(m.map(|m| m.weights as f64))),
        ("trace_records", f(m.map(|m| m.trace_records as f64))),
        ("untraced_secs", f(m.map(|m| m.untraced_secs))),
        ("traced_secs", f(m.map(|m| m.traced_secs))),
    ]
}

fn per_sec(count: usize, secs: f64) -> f64 {
    count as f64 / secs.max(1e-12)
}

fn cfg_key(prefix: &str, cfg: &GroupConfig) -> String {
    format!("{prefix}_{}", cfg.name().to_lowercase())
}

// ---------------------------------------------------------------------
// Workload runners.
// ---------------------------------------------------------------------

/// Cold compile of the seeded model through a fresh session, then a warm
/// recompile of the same tensors through the now-warm session. The
/// session carries an in-memory fleet store (mirroring the batch
/// service, which always attaches one); after the timed runs a *second
/// chip* compiles the same model against that store to measure the
/// cross-chip hit rate.
fn run_compile(cfg: GroupConfig, o: &BenchOptions) -> Result<CompileMeasurement> {
    let tensors = synthetic_model_tensors(BENCH_MODEL, &cfg, o.compile_limit)?;
    let chip = ChipFaults::new(BENCH_CHIP_SEED, FaultRates::paper_default());
    let store = StoreHandle::in_memory();
    let mut session = CompileSession::builder(cfg)
        .method(Method::Complete)
        .threads(o.threads)
        .store(store.clone())
        .chip(&chip);

    let t = Timer::start();
    let cold = session.compile_model(&tensors);
    let cold_secs = t.secs();
    let weights: usize = cold.iter().map(|(_, c, _)| c.stats.weights).sum();
    let unique_pairs: usize = cold.iter().map(|(_, c, _)| c.stats.unique_pairs).sum();
    let pattern_tables_built: usize =
        cold.iter().map(|(_, c, _)| c.stats.pattern_tables_built).sum();

    let t = Timer::start();
    let warm = session.compile_model(&tensors);
    let warm_secs = t.secs();
    let warm_fresh_pairs: usize = warm.iter().map(|(_, c, _)| c.stats.unique_pairs).sum();

    let after_cold = store.counters();
    let mut cross = CompileSession::builder(cfg)
        .method(Method::Complete)
        .threads(o.threads)
        .store(store.clone())
        .chip(&ChipFaults::new(BENCH_CHIP_SEED + 1, FaultRates::paper_default()));
    cross.compile_model(&tensors);
    let after_cross = store.counters();

    Ok(CompileMeasurement {
        weights,
        tensors: tensors.len(),
        unique_patterns: session.pattern_classes(),
        unique_pairs,
        pattern_tables_built,
        cold_secs,
        warm_secs,
        warm_fresh_pairs,
        store_cold_hits: after_cold.hits,
        store_cold_misses: after_cold.misses,
        store_warm_hits: after_cross.hits - after_cold.hits,
        store_warm_misses: after_cross.misses - after_cold.misses,
    })
}

/// DiffTable construction throughput over the seeded case pool:
/// vectorized builder vs the scalar reference, same `GroupTables`.
fn run_difftable(cfg: GroupConfig, o: &BenchOptions) -> DiffTableMeasurement {
    let cases = seeded_cases(&cfg, o.difftable_cases);
    let tables: Vec<GroupTables> =
        cases.iter().map(|(f, _)| GroupTables::build(&cfg, f)).collect();
    let mut distinct = std::collections::BTreeSet::new();
    for (f, _) in &cases {
        distinct.insert(f.pattern_key());
    }
    let built = bench("difftable", 3, o.min_time_s, || {
        for gt in &tables {
            black_box(gt.diff_table());
        }
    });
    let reference = bench("difftable-reference", 3, o.min_time_s, || {
        for gt in &tables {
            black_box(gt.diff_table_reference());
        }
    });
    DiffTableMeasurement {
        cases: tables.len(),
        distinct_tables: distinct.len(),
        build_secs: built.mean_s,
        reference_secs: reference.mean_s,
    }
}

/// Batch-scan throughput over the seeded model: the parallel chunked
/// scan vs the sequential reference (same canonical output — the
/// equivalence is property-tested in `coordinator::compiler`), plus the
/// "RCRG" registry-snapshot codec's encode/decode rates over the
/// registry that scan produced. Every iteration scans cold (fresh
/// `SolveCache`), since a warm scan is pure dedupe and measures nothing.
fn run_scan(cfg: GroupConfig, o: &BenchOptions) -> Result<ScanMeasurement> {
    let tensors = synthetic_model_tensors(BENCH_MODEL, &cfg, o.compile_limit)?;
    let chip = ChipFaults::new(BENCH_CHIP_SEED, FaultRates::paper_default());
    let faults: Vec<Vec<GroupFaults>> = tensors
        .iter()
        .enumerate()
        .map(|(i, (_, ws))| chip.sample_tensor(i as u64, ws.len(), cfg.cells()))
        .collect();
    let jobs: Vec<TensorJob<'_>> = tensors
        .iter()
        .zip(&faults)
        .map(|((_, ws), fs)| TensorJob { weights: ws, faults: fs })
        .collect();
    let groups: usize = tensors.iter().map(|(_, ws)| ws.len()).sum();

    let ref_opts = CompileOptions::new(cfg, Method::Complete);
    let scan_threads = thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    let mut par_opts = ref_opts.clone();
    par_opts.threads = scan_threads;

    let reference = bench("scan-reference", 3, o.min_time_s, || {
        let mut cache = SolveCache::new(cfg);
        black_box(scan_batch_reference(&jobs, &ref_opts, &mut cache, false));
    });
    let parallel = bench("scan-parallel", 3, o.min_time_s, || {
        let mut cache = SolveCache::new(cfg);
        black_box(scan_batch(&jobs, &par_opts, &mut cache, false));
    });

    let mut cache = SolveCache::new(cfg);
    scan_batch_reference(&jobs, &ref_opts, &mut cache, false);
    let patterns = cache.registry.len();
    let key = CacheKey::new(&chip, cfg, ref_opts.pipeline);
    let snapshot = encode_registry_snapshot(&key, &cache.registry);
    let encode = bench("snapshot-encode", 3, o.min_time_s, || {
        black_box(encode_registry_snapshot(&key, &cache.registry));
    });
    let decode = bench("snapshot-decode", 3, o.min_time_s, || {
        black_box(decode_registry_snapshot(&snapshot).expect("snapshot decodes"));
    });
    Ok(ScanMeasurement {
        groups,
        patterns,
        reference_secs: reference.mean_s,
        parallel_secs: parallel.mean_s,
        scan_threads,
        snapshot_bytes: snapshot.len(),
        encode_secs: encode.mean_s,
        decode_secs: decode.mean_s,
    })
}

/// Solve the model in K pattern-range shards, then time reassembling the
/// fragments into one warm session.
fn run_shard_merge(cfg: GroupConfig, o: &BenchOptions) -> Result<ShardMergeMeasurement> {
    let tensors = synthetic_model_tensors(BENCH_MODEL, &cfg, o.compile_limit)?;
    let chip = ChipFaults::new(BENCH_CHIP_SEED, FaultRates::paper_default());
    let plan = ShardPlan::new(o.shards);
    let t = Timer::start();
    let mut fragments = Vec::with_capacity(o.shards);
    for k in 0..o.shards {
        let mut session = CompileSession::builder(cfg)
            .method(Method::Complete)
            .threads(o.threads)
            .chip(&chip);
        for (name, ws) in &tensors {
            session.submit(name, ws.clone());
        }
        fragments.push(session.solve_shard(&plan, k)?);
    }
    let shard_solve_secs = t.secs();
    let t = Timer::start();
    let merged = CompileSession::from_fragments(&fragments)?;
    let merge_secs = t.secs();
    Ok(ShardMergeMeasurement {
        shards: o.shards,
        patterns: merged.pattern_classes(),
        solved_pairs: merged.solved_pairs(),
        shard_solve_secs,
        merge_secs,
    })
}

/// Full fabric round-trip on loopback TCP: coordinator + one worker,
/// client submits the model and streams results back.
fn run_fabric(o: &BenchOptions) -> Result<FabricMeasurement> {
    let cfg = GroupConfig::R2C2;
    let tensors = synthetic_model_tensors(BENCH_MODEL, &cfg, o.fabric_limit)?;
    let mut copts = CompileOptions::new(cfg, Method::Complete);
    copts.threads = o.threads;
    let sopts = ServeOptions {
        service: ServiceOptions {
            opts: copts,
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: None,
            store_dir: None,
        },
        shard_min_weights: 1, // always fan out, so the trip is end-to-end
        max_shards: 8,
        worker_timeout: Duration::from_secs(60),
        snapshot_dispatch: true,
    };
    let server = FabricServer::bind("127.0.0.1:0", sopts)?;
    let addr = server.local_addr().to_string();
    let server_handle = thread::spawn(move || server.run());
    let worker_addr = addr.clone();
    let worker_handle = thread::spawn(move || run_worker(&worker_addr, 1));

    let mut client = CompileClient::connect(&addr)?;
    let mut ready = false;
    for _ in 0..600 {
        if client.info()?.workers >= 1 {
            ready = true;
            break;
        }
        thread::sleep(Duration::from_millis(10));
    }
    if !ready {
        return Err(anyhow!("fabric worker never registered at {addr}"));
    }

    let t = Timer::start();
    let (results, summary) =
        client.compile_model(BENCH_CHIP_SEED, cfg, Method::Complete, &tensors)?;
    let roundtrip_secs = t.secs();
    let weights: usize = results.iter().map(|r| r.decomps.len()).sum();
    client.shutdown_server()?;
    let _ = server_handle.join();
    let _ = worker_handle.join();
    Ok(FabricMeasurement {
        weights,
        tensors: results.len(),
        shards: summary.shards as usize,
        workers: summary.workers as usize,
        fresh_solves: summary.fresh_solves,
        roundtrip_secs,
    })
}

/// Tracing overhead over the cold compile path: the same seeded compile
/// once untraced and once with an in-memory JSON-lines sink installed.
/// The record count is deterministic (spans come from the sequential
/// batch driver only); the wall-clock pair is what `bench_compile`'s
/// criterion bounds. Byte-identity of traced vs untraced outputs is
/// pinned separately in `tests/obs.rs` — this workload only measures.
fn run_obs_overhead(o: &BenchOptions) -> Result<ObsOverheadMeasurement> {
    let cfg = GroupConfig::R2C2;
    let tensors = synthetic_model_tensors(BENCH_MODEL, &cfg, o.compile_limit)?;
    let chip = ChipFaults::new(BENCH_CHIP_SEED, FaultRates::paper_default());
    let run = || {
        let mut session = CompileSession::builder(cfg)
            .method(Method::Complete)
            .threads(o.threads)
            .chip(&chip);
        let t = Timer::start();
        let out = session.compile_model(&tensors);
        let secs = t.secs();
        let weights: usize = out.iter().map(|(_, c, _)| c.stats.weights).sum();
        (weights, secs)
    };
    obs::set_sink(None);
    let (weights, untraced_secs) = run();
    let mem = obs::MemorySink::new(1 << 16);
    obs::set_sink(Some(Box::new(mem)));
    let (traced_weights, traced_secs) = run();
    let trace_records = obs::set_sink(None);
    if weights != traced_weights {
        return Err(anyhow!("traced compile changed the workload size"));
    }
    Ok(ObsOverheadMeasurement { weights, trace_records, untraced_secs, traced_secs })
}

// ---------------------------------------------------------------------
// Report assembly.
// ---------------------------------------------------------------------

fn workload_obj(fields: Vec<(&'static str, Json)>) -> Json {
    Json::obj(fields)
}

fn host_obj() -> Json {
    let cpus = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    Json::obj(vec![
        ("os", Json::Str(std::env::consts::OS.to_string())),
        ("arch", Json::Str(std::env::consts::ARCH.to_string())),
        ("cpus", Json::Num(cpus as f64)),
    ])
}

fn assemble(
    quick: bool,
    pr: usize,
    threads: usize,
    workloads: Vec<(String, Json)>,
) -> Json {
    Json::obj(vec![
        ("schema", Json::Str(BENCH_SCHEMA.to_string())),
        ("pr", Json::Num(pr as f64)),
        ("quick", Json::Bool(quick)),
        ("threads", Json::Num(threads as f64)),
        ("host", host_obj()),
        (
            "workloads",
            Json::Obj(workloads.into_iter().collect()),
        ),
    ])
}

/// Run the whole suite and return the JSON report.
///
/// Suites serialize process-wide: the `obs_overhead` workload installs
/// the process-global trace sink, and a concurrently running suite's
/// compile spans would otherwise leak into its record count (the harness
/// contract tests run several tiny suites in one test binary).
pub fn run(o: &BenchOptions, quick: bool, pr: usize) -> Result<Json> {
    static RUN_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _guard = RUN_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let mut workloads: Vec<(String, Json)> = Vec::new();
    for cfg in BENCH_CONFIGS {
        let m = run_compile(cfg, o)?;
        workloads.push((cfg_key("compile", &cfg), workload_obj(compile_fields(Some(&m)))));
    }
    for cfg in BENCH_CONFIGS {
        let m = run_difftable(cfg, o);
        workloads
            .push((cfg_key("difftable", &cfg), workload_obj(difftable_fields(Some(&m)))));
    }
    for cfg in BENCH_CONFIGS {
        let m = run_scan(cfg, o)?;
        workloads.push((cfg_key("scan", &cfg), workload_obj(scan_fields(Some(&m)))));
    }
    let m = run_shard_merge(GroupConfig::R2C2, o)?;
    workloads.push(("shard_merge_r2c2".to_string(), workload_obj(shard_merge_fields(Some(&m)))));
    let fabric = if o.fabric {
        let m = run_fabric(o)?;
        workload_obj(fabric_fields(Some(&m)))
    } else {
        workload_obj(fabric_fields(None))
    };
    workloads.push(("fabric_roundtrip".to_string(), fabric));
    let m = run_obs_overhead(o)?;
    workloads.push(("obs_overhead".to_string(), workload_obj(obs_overhead_fields(Some(&m)))));
    Ok(assemble(quick, pr, o.threads, workloads))
}

/// The canonical key tree of a report: every structural key present,
/// every leaf `null`. A session authored without a local Rust toolchain
/// commits this skeleton as its `BENCH_<n>.json`; CI regenerates the
/// measured version (schema-identical by construction) as an artifact.
pub fn skeleton(pr: usize) -> Json {
    let mut workloads: Vec<(String, Json)> = Vec::new();
    for cfg in BENCH_CONFIGS {
        workloads.push((cfg_key("compile", &cfg), workload_obj(compile_fields(None))));
    }
    for cfg in BENCH_CONFIGS {
        workloads.push((cfg_key("difftable", &cfg), workload_obj(difftable_fields(None))));
    }
    for cfg in BENCH_CONFIGS {
        workloads.push((cfg_key("scan", &cfg), workload_obj(scan_fields(None))));
    }
    workloads.push(("shard_merge_r2c2".to_string(), workload_obj(shard_merge_fields(None))));
    workloads.push(("fabric_roundtrip".to_string(), workload_obj(fabric_fields(None))));
    workloads.push(("obs_overhead".to_string(), workload_obj(obs_overhead_fields(None))));
    let mut doc = assemble(false, pr, 1, workloads);
    // Run-dependent header scalars are null in the skeleton; `pr` stays,
    // since it names the report regardless of whether anyone measured.
    if let Json::Obj(m) = &mut doc {
        for key in ["quick", "threads"] {
            m.insert(key.to_string(), Json::Null);
        }
        m.insert(
            "host".to_string(),
            Json::obj(vec![("os", Json::Null), ("arch", Json::Null), ("cpus", Json::Null)]),
        );
    }
    doc
}

/// Validate `doc` against the canonical key tree: identical object keys
/// at every level. Leaf values are unconstrained (null or scalar) except
/// `schema`, which must be [`BENCH_SCHEMA`] when present as a string.
pub fn validate(doc: &Json) -> std::result::Result<(), String> {
    if let Json::Str(s) = doc.get("schema") {
        if s != BENCH_SCHEMA {
            return Err(format!("schema tag {s:?} != {BENCH_SCHEMA:?}"));
        }
    }
    same_shape(&skeleton(0), doc, "$")
}

fn same_shape(want: &Json, got: &Json, path: &str) -> std::result::Result<(), String> {
    match (want, got) {
        (Json::Obj(a), Json::Obj(b)) => {
            let ka: Vec<&String> = a.keys().collect();
            let kb: Vec<&String> = b.keys().collect();
            if ka != kb {
                return Err(format!("{path}: keys {kb:?} != expected {ka:?}"));
            }
            for (k, v) in a {
                same_shape(v, &b[k], &format!("{path}.{k}"))?;
            }
            Ok(())
        }
        (Json::Obj(_), other) => {
            Err(format!("{path}: expected an object, got {other:?}"))
        }
        // Leaves: any scalar (or null, for skeleton/unmeasured runs).
        _ => match got {
            Json::Obj(_) | Json::Arr(_) => {
                Err(format!("{path}: expected a scalar leaf, got a container"))
            }
            _ => Ok(()),
        },
    }
}

/// Human-readable rendering of a report (the non-`--json` CLI output).
pub fn render_human(doc: &Json) -> String {
    let mut t = super::Table::new(
        &format!("rchg bench ({})", doc.get("schema").as_str().unwrap_or("?")),
        &["workload", "field", "value"],
    );
    if let Json::Obj(ws) = doc.get("workloads") {
        for (name, fields) in ws {
            if let Json::Obj(fs) = fields {
                for (field, v) in fs {
                    let val = match v {
                        Json::Null => "-".to_string(),
                        Json::Num(x) if x.fract() == 0.0 && x.abs() < 1e15 => {
                            format!("{}", *x as i64)
                        }
                        Json::Num(x) => format!("{x:.3}"),
                        other => format!("{other:?}"),
                    };
                    t.row(vec![name.clone(), field.to_string(), val]);
                }
            }
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skeleton_is_schema_valid() {
        let sk = skeleton(6);
        validate(&sk).expect("skeleton must validate against itself");
        // And it round-trips through the serializer.
        let text = sk.pretty();
        let parsed = Json::parse(&text).expect("skeleton pretty output parses");
        assert_eq!(parsed, sk);
        validate(&parsed).expect("parsed skeleton still validates");
    }

    #[test]
    fn timing_field_classifier() {
        for t in [
            "cold_secs",
            "merge_secs",
            "weights_per_sec",
            "builds_per_sec",
            "speedup",
            "parallel_groups_per_sec",
            "snapshot_encode_mb_per_sec",
        ] {
            assert!(is_timing_field(t), "{t} must be a timing field");
        }
        for d in [
            "weights",
            "dedup_ratio",
            "unique_patterns",
            "shards",
            "fresh_solves",
            "store_cold_hit_rate",
            "store_warm_hit_rate",
            "snapshot_bytes",
            "scan_threads",
        ] {
            assert!(!is_timing_field(d), "{d} must be deterministic");
        }
    }

    #[test]
    fn strip_timings_nulls_only_timing_leaves() {
        let doc = Json::obj(vec![
            ("weights", Json::Num(10.0)),
            ("cold_secs", Json::Num(1.5)),
            (
                "nested",
                Json::obj(vec![("speedup", Json::Num(2.0)), ("shards", Json::Num(4.0))]),
            ),
        ]);
        let s = strip_timings(&doc);
        assert_eq!(s.get("weights"), &Json::Num(10.0));
        assert_eq!(s.get("cold_secs"), &Json::Null);
        assert_eq!(s.get("nested").get("speedup"), &Json::Null);
        assert_eq!(s.get("nested").get("shards"), &Json::Num(4.0));
    }

    #[test]
    fn seeded_cases_are_reproducible() {
        let cfg = GroupConfig::R2C2;
        let a = seeded_cases(&cfg, 64);
        let b = seeded_cases(&cfg, 64);
        assert_eq!(a, b, "case pool must be a pure function of the seed");
    }
}
