//! Table II + Fig 10: compilation-time evaluation.
//!
//! Protocol mirrors the paper: single processor thread (unless asked
//! otherwise), per-chip fault maps at the published rates, real layer
//! shapes for ResNet-20/18/50 and VGG-16 with synthetic quantized weight
//! values (compile time depends on weight values + fault maps, not on
//! trained accuracy).
//!
//! Slow methods (original FF, ILP-only) are measured on a deterministic
//! weight sample and extrapolated linearly to the full model — both the
//! measured sample time and the extrapolation are reported. The complete
//! pipeline is fast enough to run at full scale.
//!
//! For the dedupe-first path the linear extrapolation is *pessimistic*:
//! solve time scales with unique (pattern, weight) pairs, which grow
//! sublinearly in weights (the pair space saturates). `measure` therefore
//! also fits a power law to the sample's unique-pair growth (a cheap
//! scan-only pass) and reports a dedup-aware estimate next to the linear
//! one in the `dedup_report` table.

use super::Table;
use crate::arrays::models::{by_name, total_params};
use crate::coordinator::{CompileOptions, CompileSession, Method, PatternId, PatternRegistry};
use crate::fault::bank::ChipFaults;
use crate::fault::{FaultRates, GroupFaults};
use crate::grouping::GroupConfig;
use crate::store::StoreHandle;
use crate::util::fnv::FnvMap;
use crate::util::prng::Rng;
use crate::util::timer::{fmt_dur, Timer};
use anyhow::{anyhow, Result};

/// Synthetic quantized weights for one model at real layer shapes.
/// Deterministic in (model, cfg). Values roughly bell-shaped like trained
/// weights (sum of two uniforms), clamped to the config's range.
pub fn synthetic_model_weights(model: &str, cfg: &GroupConfig, limit: usize) -> Result<Vec<i64>> {
    let layers = by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let total = total_params(&layers).min(limit);
    let mut rng = Rng::new(0xC0DE ^ crate::util::prop::fnv1a(model.as_bytes()));
    let max = cfg.max_per_array();
    Ok((0..total)
        .map(|_| {
            let a = rng.range_i64(-max, max);
            let b = rng.range_i64(-max, max);
            ((a + b) / 2).clamp(-max, max)
        })
        .collect())
}

/// The same synthetic weights split into per-layer tensors `(name,
/// weights)` — the shape `CompileSession::compile_model` and the batch
/// service consume. Truncated at `limit` total weights (the final layer
/// may be partial; layers past the limit are dropped).
pub fn synthetic_model_tensors(
    model: &str,
    cfg: &GroupConfig,
    limit: usize,
) -> Result<Vec<(String, Vec<i64>)>> {
    let layers = by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let ws = synthetic_model_weights(model, cfg, limit)?;
    let mut out = Vec::new();
    let mut start = 0usize;
    for layer in &layers {
        if start >= ws.len() {
            break;
        }
        let end = (start + layer.params()).min(ws.len());
        out.push((layer.name.clone(), ws[start..end].to_vec()));
        start = end;
    }
    Ok(out)
}

/// Unique (pattern, weight) pair counts at prefix checkpoints of one
/// tensor — a scan-only pass (pattern interning + hashing, no solving)
/// used to fit the sublinear pair-growth curve.
pub fn pair_growth_checkpoints(
    cfg: &GroupConfig,
    weights: &[i64],
    faults: &[GroupFaults],
    points: usize,
) -> Vec<(usize, usize)> {
    debug_assert_eq!(weights.len(), faults.len());
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let points = points.clamp(1, n);
    let mut marks: Vec<usize> = (1..=points).map(|i| n * i / points).collect();
    marks.dedup();
    let mut registry = PatternRegistry::new(*cfg);
    let mut seen: FnvMap<(PatternId, i64), ()> = FnvMap::default();
    let mut out = Vec::with_capacity(marks.len());
    let mut mi = 0;
    for i in 0..n {
        let pid = registry.intern(&faults[i]);
        seen.insert((pid, weights[i]), ());
        if mi < marks.len() && i + 1 == marks[mi] {
            out.push((i + 1, seen.len()));
            mi += 1;
        }
    }
    out
}

/// Least-squares power-law fit `pairs(n) ≈ a·n^b` on log-log axes.
/// Returns `(a, b)`; degenerate inputs fall back to the linear model
/// through the last point (`b = 1`).
pub fn fit_power_law(points: &[(usize, usize)]) -> (f64, f64) {
    let linear_fallback = |points: &[(usize, usize)]| match points.last() {
        Some(&(n, p)) if n > 0 => (p as f64 / n as f64, 1.0),
        _ => (1.0, 1.0),
    };
    let pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|(n, p)| *n > 0 && *p > 0)
        .map(|&(n, p)| ((n as f64).ln(), (p as f64).ln()))
        .collect();
    if pts.len() < 2 {
        return linear_fallback(points);
    }
    let m = pts.len() as f64;
    let sx: f64 = pts.iter().map(|(x, _)| x).sum();
    let sy: f64 = pts.iter().map(|(_, y)| y).sum();
    let sxx: f64 = pts.iter().map(|(x, _)| x * x).sum();
    let sxy: f64 = pts.iter().map(|(x, y)| x * y).sum();
    let denom = m * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return linear_fallback(points);
    }
    let b = (m * sxy - sx * sy) / denom;
    let a = ((sy - b * sx) / m).exp();
    if !a.is_finite() || !b.is_finite() {
        return linear_fallback(points);
    }
    (a, b)
}

#[derive(Clone, Debug)]
pub struct CompileTimeRow {
    pub method: Method,
    pub cfg: GroupConfig,
    pub model: String,
    pub sampled_weights: usize,
    pub total_weights: usize,
    pub measured_secs: f64,
    /// Seconds the measured run spent in the scan + dedupe phases
    /// (from [`crate::coordinator::CompileStats::scan_secs`]) — the part
    /// the parallel batch scan attacks; the rest of `measured_secs` is
    /// solve + scatter.
    pub scan_secs: f64,
    /// Linear extrapolation to the full model.
    pub full_secs: f64,
    /// Dedup-aware extrapolation: solve time scaled by the fitted
    /// unique-pair growth (sublinear), scan/dedupe/scatter overhead
    /// scaled linearly. Equals `full_secs` for non-dedupe rows and
    /// `measured_secs` for full-scale runs.
    pub full_secs_dedup: f64,
    /// Unique pairs the power-law fit predicts at full model scale.
    pub predicted_pairs_full: usize,
    /// Fitted pair-growth exponent `b` in `pairs(n) ≈ a·n^b` (1.0 when
    /// no fit ran).
    pub pair_growth_exp: f64,
    /// Stage-bucket breakdown (cond / fawd / cvm / ff), seconds, measured.
    pub breakdown: Vec<(String, f64)>,
    /// Distinct fault-pattern classes seen in the sample.
    pub unique_patterns: usize,
    /// Unique (pattern, weight) pairs that needed fresh solve work — the
    /// pattern-class dedup makes this ≪ `sampled_weights`.
    pub unique_pairs: usize,
    /// Weights served from the solve cache instead of a fresh solve.
    pub dedup_hits: usize,
    /// Full-range pattern tables batch-solved (`BatchTable` tier solve
    /// sweeps; 0 on per-weight rows).
    pub pattern_tables: usize,
    /// Estimated resident bytes of per-pattern solution tables at the end
    /// of the run.
    pub resident_table_bytes: usize,
    /// Pattern solutions evicted to honor the session memory budget.
    pub table_evictions: u64,
    /// Pattern tables answered by the fleet solution store instead of a
    /// fresh batch solve (0 when no store is attached).
    pub store_hits: usize,
    /// Pattern tables solved fresh while a store was attached (and
    /// published back to it).
    pub store_misses: usize,
}

impl CompileTimeRow {
    /// Weights per solver invocation (1.0 when dedup is off).
    pub fn dedup_ratio(&self) -> f64 {
        crate::coordinator::compiler::dedup_ratio_of(self.sampled_weights, self.unique_pairs)
    }
}

/// Measure one (method, config, model) cell of Table II.
pub fn measure(
    model: &str,
    cfg: GroupConfig,
    method: Method,
    sample: usize,
    threads: usize,
    chip_seed: u64,
) -> Result<CompileTimeRow> {
    measure_with_store(model, cfg, method, sample, threads, chip_seed, None)
}

/// [`measure`] with an optional fleet solution store attached to the
/// session (`rchg compile --store-dir`, and the bench harness's store
/// workload). The store changes *where* tables come from, never their
/// bytes, so timing rows stay comparable; the row's `store_hits` /
/// `store_misses` report what it contributed.
pub fn measure_with_store(
    model: &str,
    cfg: GroupConfig,
    method: Method,
    sample: usize,
    threads: usize,
    chip_seed: u64,
    store: Option<StoreHandle>,
) -> Result<CompileTimeRow> {
    let layers = by_name(model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let total_weights = total_params(&layers);
    let ws = synthetic_model_weights(model, &cfg, sample)?;
    let chip = ChipFaults::new(chip_seed, FaultRates::paper_default());
    let mut opts = CompileOptions::new(cfg, method);
    opts.threads = threads;
    // Baselines (FF, ILP-only, unprotected) reproduce the paper's
    // per-weight protocol; only the complete pipeline — the contribution
    // under measurement — runs the dedupe-first core. Letting baselines
    // dedupe would deflate their sample times and distort the Table II /
    // Fig 10a speedup ratios.
    opts.dedupe = method == Method::Complete;
    // Pure-throughput mode (no per-stage clocks) via RCHG_TIME_STAGES=0.
    if std::env::var("RCHG_TIME_STAGES").as_deref() == Ok("0") {
        opts.time_stages = false;
    }
    let mut builder = CompileSession::builder(cfg).options(opts.clone());
    if let Some(store) = store {
        builder = builder.store(store);
    }
    let mut session = builder.chip(&chip);
    let faults = session.sample_faults(0, ws.len());
    let timer = Timer::start();
    let out = session.compile_with_faults(&ws, &faults);
    let measured = timer.secs();
    let full = measured * total_weights as f64 / ws.len() as f64;

    // Dedup-aware extrapolation (complete pipeline only): solve time
    // scales with unique pairs — fit their sublinear growth over the
    // sample and project to full scale; the linear part (scan, dedupe,
    // scatter) keeps scaling with weights.
    let (full_secs_dedup, predicted_pairs_full, pair_growth_exp) = if !opts.dedupe
        || out.stats.unique_pairs == 0
    {
        (full, out.stats.unique_pairs, 1.0)
    } else if ws.len() >= total_weights {
        (measured, out.stats.unique_pairs, 1.0)
    } else {
        let checkpoints = pair_growth_checkpoints(&cfg, &ws, &faults, 4);
        let (a, b) = fit_power_law(&checkpoints);
        let pred = (a * (total_weights as f64).powf(b))
            .round()
            .clamp(out.stats.unique_pairs as f64, total_weights as f64)
            as usize;
        let solve_secs = out.stats.clock.total().min(measured);
        let overhead = measured - solve_secs;
        let est = overhead * total_weights as f64 / ws.len() as f64
            + solve_secs * pred as f64 / out.stats.unique_pairs as f64;
        (est, pred, b)
    };

    Ok(CompileTimeRow {
        method,
        cfg,
        model: model.to_string(),
        sampled_weights: ws.len(),
        total_weights,
        measured_secs: measured,
        scan_secs: out.stats.scan_secs,
        full_secs: full,
        full_secs_dedup,
        predicted_pairs_full,
        pair_growth_exp,
        breakdown: out
            .stats
            .clock
            .entries()
            .iter()
            .map(|(n, s)| (n.clone(), *s * total_weights as f64 / ws.len() as f64))
            .collect(),
        unique_patterns: out.stats.unique_patterns,
        unique_pairs: out.stats.unique_pairs,
        dedup_hits: out.stats.dedup_hits,
        pattern_tables: out.stats.pattern_tables_built,
        resident_table_bytes: out.stats.resident_table_bytes,
        table_evictions: out.stats.table_evictions,
        store_hits: out.stats.store_hits,
        store_misses: out.stats.store_misses,
    })
}

pub struct CompileTimeOptions {
    pub models: Vec<String>,
    /// Sample sizes per method (full-model times are extrapolated).
    pub sample_complete: usize,
    pub sample_ilp: usize,
    pub sample_ff: usize,
    pub threads: usize,
    pub include_r2c4: bool,
}

impl Default for CompileTimeOptions {
    fn default() -> Self {
        CompileTimeOptions {
            models: vec!["resnet20".into(), "resnet18".into(), "resnet50".into(), "vgg16".into()],
            sample_complete: 400_000,
            sample_ilp: 2_000,
            sample_ff: 2_000,
            threads: 1,
            include_r2c4: false,
        }
    }
}

/// Table II: compilation time (extrapolated full-model, measured sample in
/// parentheses where sampled).
pub fn table2(opts: &CompileTimeOptions) -> Result<(Table, Vec<CompileTimeRow>)> {
    let mut header = vec!["method".to_string(), "config".to_string()];
    header.extend(opts.models.iter().cloned());
    let mut t = Table::new(
        "Table II — compilation time (full-model; '~' = extrapolated from sample)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut all_rows = Vec::new();

    let mut plan: Vec<(Method, GroupConfig, usize, &str)> = vec![
        (Method::OriginalFf, GroupConfig::R1C4, opts.sample_ff, "Fault-Free (FF)"),
        (Method::IlpOnly, GroupConfig::R1C4, opts.sample_ilp, "ILP only"),
        (Method::IlpOnly, GroupConfig::R2C2, opts.sample_ilp, "ILP only"),
        (Method::Complete, GroupConfig::R1C4, opts.sample_complete, "Complete pipeline"),
        (Method::Complete, GroupConfig::R2C2, opts.sample_complete, "Complete pipeline"),
    ];
    if opts.include_r2c4 {
        plan.push((Method::Complete, GroupConfig::R2C4, opts.sample_complete, "Complete pipeline"));
    }

    for (method, cfg, sample, label) in plan {
        let mut row = vec![label.to_string(), cfg.name()];
        for model in &opts.models {
            let r = measure(model, cfg, method, sample, opts.threads, 1)?;
            let approx = if r.sampled_weights < r.total_weights { "~" } else { "" };
            row.push(format!("{approx}{}", fmt_dur(r.full_secs)));
            all_rows.push(r);
        }
        t.row(row);
    }
    Ok((t, all_rows))
}

/// Fig 10a: speedup factors of the complete pipeline vs FF and vs ILP-only.
pub fn fig10a(rows: &[CompileTimeRow], models: &[String]) -> Table {
    let mut header = vec!["model".to_string()];
    header.extend(["FF/complete(R1C4)", "ILP/complete(R1C4)", "FF/complete(R2C2)"].map(String::from));
    let mut t = Table::new(
        "Fig 10a — compile-time speedup of the complete pipeline",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let find = |m: Method, c: GroupConfig, model: &str| -> Option<f64> {
        rows.iter()
            .find(|r| r.method == m && r.cfg == c && r.model == model)
            .map(|r| r.full_secs)
    };
    for model in models {
        let ff = find(Method::OriginalFf, GroupConfig::R1C4, model);
        let ilp = find(Method::IlpOnly, GroupConfig::R1C4, model);
        let c14 = find(Method::Complete, GroupConfig::R1C4, model);
        let c22 = find(Method::Complete, GroupConfig::R2C2, model);
        let fmt = |a: Option<f64>, b: Option<f64>| match (a, b) {
            (Some(x), Some(y)) if y > 0.0 => format!("{:.0}x", x / y),
            _ => "-".to_string(),
        };
        t.row(vec![
            model.clone(),
            fmt(ff, c14),
            fmt(ilp, c14),
            fmt(ff, c22),
        ]);
    }
    t
}

/// Pattern-class dedup report: how far the dedupe-first core collapses
/// each (config, model) cell's workload before the solver ever runs, and
/// what that does to the full-model extrapolation — the linear estimate
/// scales everything with weights; the dedup-aware estimate scales solve
/// time with the fitted (sublinear, exponent `b`) unique-pair growth.
pub fn dedup_report(rows: &[CompileTimeRow]) -> Table {
    let mut t = Table::new(
        "Pattern-class dedup — complete pipeline (sample → full-model extrapolation)",
        &[
            "config",
            "model",
            "weights",
            "patterns",
            "unique pairs",
            "dedup",
            "pred pairs",
            "b",
            "linear est",
            "dedup-aware est",
        ],
    );
    for r in rows.iter().filter(|r| r.method == Method::Complete && r.unique_pairs > 0) {
        t.row(vec![
            r.cfg.name(),
            r.model.clone(),
            r.sampled_weights.to_string(),
            r.unique_patterns.to_string(),
            r.unique_pairs.to_string(),
            format!("{:.1}x", r.dedup_ratio()),
            r.predicted_pairs_full.to_string(),
            format!("{:.2}", r.pair_growth_exp),
            fmt_dur(r.full_secs),
            fmt_dur(r.full_secs_dedup),
        ]);
    }
    t
}

/// Fig 10b: stage breakdown of the complete pipeline per config.
pub fn fig10b(rows: &[CompileTimeRow], model: &str) -> Table {
    let mut t = Table::new(
        &format!("Fig 10b — complete-pipeline stage breakdown ({model}, extrapolated s)"),
        &["config", "cond+fast", "fawd", "cvm", "total"],
    );
    for r in rows.iter().filter(|r| r.method == Method::Complete && r.model == model) {
        let get = |k: &str| {
            r.breakdown
                .iter()
                .find(|(n, _)| n == k)
                .map(|(_, s)| *s)
                .unwrap_or(0.0)
        };
        let (cond, fawd, cvm) = (get("cond"), get("fawd"), get("cvm"));
        t.row(vec![
            r.cfg.name(),
            format!("{:.3}", cond),
            format!("{:.3}", fawd),
            format!("{:.3}", cvm),
            format!("{:.3}", cond + fawd + cvm),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_weights_in_range_and_deterministic() {
        let cfg = GroupConfig::R2C2;
        let a = synthetic_model_weights("resnet20", &cfg, 10_000).unwrap();
        let b = synthetic_model_weights("resnet20", &cfg, 10_000).unwrap();
        assert_eq!(a, b);
        assert!(a.iter().all(|&w| w.abs() <= cfg.max_per_array()));
        // Bell-shaped: more mass near zero than at extremes.
        let near = a.iter().filter(|w| w.abs() <= 10).count();
        let far = a.iter().filter(|w| w.abs() >= 25).count();
        assert!(near > far);
    }

    #[test]
    fn measure_complete_small_sample() {
        let r = measure("resnet20", GroupConfig::R2C2, Method::Complete, 5_000, 1, 1).unwrap();
        assert_eq!(r.sampled_weights, 5_000);
        assert!(r.full_secs >= r.measured_secs);
        assert!(r.total_weights > 250_000);
        // Dedup counters flow through from CompileStats.
        assert!(r.unique_pairs > 0 && r.unique_pairs <= r.sampled_weights);
        assert_eq!(r.unique_pairs + r.dedup_hits, r.sampled_weights);
        assert!(r.unique_patterns > 0);
        assert!(r.dedup_ratio() > 1.0, "R2C2 at 5k weights must dedupe");
        // The scan-phase clock is populated and bounded by the wall.
        assert!(r.scan_secs > 0.0, "scan_secs must be stamped");
        assert!(r.scan_secs <= r.measured_secs + 1e-9);
    }

    #[test]
    fn model_tensors_split_matches_flat_weights() {
        let cfg = GroupConfig::R2C2;
        let limit = 10_000;
        let tensors = synthetic_model_tensors("resnet20", &cfg, limit).unwrap();
        let flat = synthetic_model_weights("resnet20", &cfg, limit).unwrap();
        let total: usize = tensors.iter().map(|(_, w)| w.len()).sum();
        assert_eq!(total, flat.len());
        let rejoined: Vec<i64> = tensors.iter().flat_map(|(_, w)| w.iter().copied()).collect();
        assert_eq!(rejoined, flat, "tensor split must preserve weight order");
        // Layer names are unique (they key chip regions in the service).
        let mut names: Vec<&str> = tensors.iter().map(|(n, _)| n.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), tensors.len());
        // Unlimited split covers every layer exactly.
        let full = synthetic_model_tensors("resnet20", &cfg, usize::MAX).unwrap();
        let full_total: usize = full.iter().map(|(_, w)| w.len()).sum();
        assert_eq!(full_total, total_params(&by_name("resnet20").unwrap()));
    }

    #[test]
    fn power_law_fit_recovers_exponent() {
        let pts: Vec<(usize, usize)> =
            [100usize, 400, 2_500, 10_000].iter().map(|&n| (n, (n as f64).sqrt() as usize)).collect();
        let (a, b) = fit_power_law(&pts);
        assert!((b - 0.5).abs() < 0.05, "fitted b = {b}");
        assert!(a > 0.0);
        // Degenerate inputs fall back to linear.
        assert_eq!(fit_power_law(&[]), (1.0, 1.0));
        assert_eq!(fit_power_law(&[(10, 5)]), (0.5, 1.0));
    }

    #[test]
    fn pair_growth_checkpoints_monotone_and_scan_only() {
        let cfg = GroupConfig::R2C2;
        let ws = synthetic_model_weights("resnet20", &cfg, 8_000).unwrap();
        let chip = ChipFaults::new(1, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let cps = pair_growth_checkpoints(&cfg, &ws, &faults, 4);
        assert_eq!(cps.len(), 4);
        assert_eq!(cps.last().unwrap().0, ws.len());
        assert!(cps.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 <= w[1].1));
        // Final checkpoint agrees with the compiler's own dedup counter.
        let r = measure("resnet20", cfg, Method::Complete, 8_000, 1, 1).unwrap();
        assert_eq!(cps.last().unwrap().1, r.unique_pairs);
    }

    #[test]
    fn dedup_aware_extrapolation_is_sublinear() {
        let r = measure("resnet20", GroupConfig::R2C2, Method::Complete, 20_000, 1, 1).unwrap();
        assert!(r.sampled_weights < r.total_weights);
        // Pair growth saturates, so the fitted exponent is < 1 and the
        // dedup-aware estimate undercuts the linear one.
        assert!(
            r.pair_growth_exp < 1.0,
            "R2C2 pair growth should be sublinear, got b = {}",
            r.pair_growth_exp
        );
        assert!(
            r.full_secs_dedup < r.full_secs,
            "dedup-aware {} not below linear {}",
            r.full_secs_dedup,
            r.full_secs
        );
        assert!(r.predicted_pairs_full >= r.unique_pairs);
        assert!(r.predicted_pairs_full <= r.total_weights);
        // Baseline rows keep the linear estimate.
        let ff = measure("resnet20", GroupConfig::R1C4, Method::OriginalFf, 500, 1, 1).unwrap();
        assert_eq!(ff.full_secs_dedup, ff.full_secs);
        assert_eq!(ff.pair_growth_exp, 1.0);
    }

    #[test]
    fn pipeline_beats_ff_on_same_sample() {
        let ff = measure("resnet20", GroupConfig::R1C4, Method::OriginalFf, 800, 1, 1).unwrap();
        let cp = measure("resnet20", GroupConfig::R1C4, Method::Complete, 800, 1, 1).unwrap();
        assert!(
            cp.measured_secs * 5.0 < ff.measured_secs,
            "complete {} vs ff {}",
            cp.measured_secs,
            ff.measured_secs
        );
    }
}
