//! Experiment drivers: one function per paper table/figure.
//!
//! Each driver returns a formatted report (and machine-readable rows) so
//! the `examples/`, the `rchg` CLI and the bench harnesses all regenerate
//! the same numbers from the same code. EXPERIMENTS.md records the runs.

pub mod accuracy;
pub mod bench;
pub mod compile_time;
pub mod hw;
pub mod lm;

/// Simple fixed-width table formatter shared by the drivers.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("== {}\n", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo"));
        assert!(s.lines().count() >= 4);
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[1].len(), lines[2].len().max(lines[1].len()));
    }
}
