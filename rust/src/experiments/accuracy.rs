//! Table I (CNN accuracy under SAFs), Fig 8 (layer-wise error) and Fig 9
//! (accuracy vs fault rate).

use super::Table;
use crate::coordinator::Method;
use crate::fault::FaultRates;
use crate::grouping::GroupConfig;
use crate::metrics::mean_std;
use crate::nn::cnn::CnnEvaluator;
use crate::runtime::Runtime;
use anyhow::Result;
use std::path::Path;

pub struct AccuracyOptions {
    pub archs: Vec<String>,
    pub configs: Vec<GroupConfig>,
    pub trials: usize,
    pub threads: usize,
    /// Also evaluate the unprotected (no-mitigation) baseline rows.
    pub include_unprotected: bool,
}

impl Default for AccuracyOptions {
    fn default() -> Self {
        AccuracyOptions {
            archs: vec!["cnn_s".into(), "cnn_m".into(), "cnn_d".into(), "vgg_n".into()],
            configs: vec![GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4],
            trials: 3,
            threads: crate::util::pool::default_threads(None),
            include_unprotected: false,
        }
    }
}

/// Table I: accuracy per (grouping config × architecture), mean ± std over
/// chips, plus the fault-free reference row.
pub fn table1(rt: &Runtime, art: &Path, opts: &AccuracyOptions) -> Result<Table> {
    let mut header = vec!["config".to_string(), "prec.".to_string()];
    header.extend(opts.archs.iter().cloned());
    let mut t = Table::new(
        "Table I — accuracy under SAFs (mean ± std, %)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    // Fault-free reference (quantization only, R1C4's 8-bit).
    let mut row = vec!["w/o SAF".to_string(), "8 bit".to_string()];
    for arch in &opts.archs {
        let ev = CnnEvaluator::new(rt, art, arch, GroupConfig::R1C4)?;
        let r = ev.eval(0, FaultRates::none(), Method::Complete, opts.threads)?;
        row.push(format!("{:.2}", 100.0 * r.accuracy));
    }
    t.row(row);

    for cfg in &opts.configs {
        let mut row = vec![cfg.name(), format!("{:.2} bit", cfg.precision_bits())];
        for arch in &opts.archs {
            let ev = CnnEvaluator::new(rt, art, arch, *cfg)?;
            let accs: Vec<f64> = (0..opts.trials)
                .map(|trial| {
                    ev.eval(
                        1000 + trial as u64,
                        FaultRates::paper_default(),
                        Method::Complete,
                        opts.threads,
                    )
                    .map(|r| 100.0 * r.accuracy)
                })
                .collect::<Result<_>>()?;
            let (m, s) = mean_std(&accs);
            row.push(format!("{m:.2} (±{s:.2})"));
        }
        t.row(row);

        if opts.include_unprotected {
            let mut row = vec![format!("{} raw", cfg.name()), "(no mitig.)".to_string()];
            for arch in &opts.archs {
                let ev = CnnEvaluator::new(rt, art, arch, *cfg)?;
                let accs: Vec<f64> = (0..opts.trials)
                    .map(|trial| {
                        ev.eval(
                            1000 + trial as u64,
                            FaultRates::paper_default(),
                            Method::Unprotected,
                            opts.threads,
                        )
                        .map(|r| 100.0 * r.accuracy)
                    })
                    .collect::<Result<_>>()?;
                let (m, s) = mean_std(&accs);
                row.push(format!("{m:.2} (±{s:.2})"));
            }
            t.row(row);
        }
    }
    Ok(t)
}

/// Fig 8: per-layer fault+quantization ℓ1 error for one architecture.
pub fn fig8(rt: &Runtime, art: &Path, arch: &str, threads: usize) -> Result<Table> {
    let mut t = Table::new(
        &format!("Fig 8 — layer-wise fault ℓ1 error ({arch})"),
        &["layer", "R1C4", "R2C2", "R2C4"],
    );
    let mut per_cfg: Vec<Vec<(String, f64)>> = Vec::new();
    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
        let ev = CnnEvaluator::new(rt, art, arch, cfg)?;
        let r = ev.eval(7, FaultRates::paper_default(), Method::Complete, threads)?;
        per_cfg.push(r.layer_l1);
    }
    for i in 0..per_cfg[0].len() {
        t.row(vec![
            per_cfg[0][i].0.clone(),
            format!("{:.2}", per_cfg[0][i].1),
            format!("{:.2}", per_cfg[1][i].1),
            format!("{:.2}", per_cfg[2][i].1),
        ]);
    }
    let sums: Vec<f64> = per_cfg.iter().map(|v| v.iter().map(|(_, e)| e).sum()).collect();
    t.row(vec![
        "TOTAL".into(),
        format!("{:.2}", sums[0]),
        format!("{:.2}", sums[1]),
        format!("{:.2}", sums[2]),
    ]);
    Ok(t)
}

/// Fig 9: accuracy vs total fault rate (SA0:SA1 ratio fixed at 1.75:9.04).
pub fn fig9(
    rt: &Runtime,
    art: &Path,
    arch: &str,
    rates: &[f64],
    trials: usize,
    threads: usize,
) -> Result<Table> {
    let mut t = Table::new(
        &format!("Fig 9 — accuracy vs fault rate ({arch})"),
        &["fault rate", "R1C4", "R2C2", "R2C4"],
    );
    let evs: Vec<CnnEvaluator> = [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4]
        .iter()
        .map(|cfg| CnnEvaluator::new(rt, art, arch, *cfg))
        .collect::<Result<_>>()?;
    for &rate in rates {
        let mut row = vec![format!("{:.1}%", rate * 100.0)];
        for ev in &evs {
            let accs: Vec<f64> = (0..trials)
                .map(|trial| {
                    ev.eval(
                        5000 + trial as u64,
                        FaultRates::scaled_to_total(rate),
                        Method::Complete,
                        threads,
                    )
                    .map(|r| 100.0 * r.accuracy)
                })
                .collect::<Result<_>>()?;
            let (m, s) = mean_std(&accs);
            row.push(format!("{m:.2} (±{s:.2})"));
        }
        t.row(row);
    }
    Ok(t)
}
