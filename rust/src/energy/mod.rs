//! Energy model — the NeuroSIM-flavoured substrate behind Fig 11.
//!
//! Component energies are *relative* units calibrated to the published
//! NeuroSIM/ISAAC breakdowns (ADC dominates analog IMC energy; wordline/
//! DAC drive next; cell read small; digital shift-add/subtract cheap per
//! op but per-column). Absolute joules are not the claim — the paper
//! normalizes against the R1C4 baseline, and so do we.
//!
//! Per array activation (one MVM against one crossbar):
//!   e_fixed(dims)  — precharge/decoder/sense bias, scales with array size
//!   e_row  × rows driven (DAC + wordline)
//!   e_cell × rows×cols used (bit-line current)
//!   e_adc  × columns digitized (dominant)
//!   e_sa   × columns (shift-and-add)
//! plus e_sub per logical output value (pos − neg subtraction).

use crate::arrays::models::LayerShape;
use crate::arrays::{map_network, ArrayDims, LayerMapping, MapperPolicy};
use crate::grouping::GroupConfig;

/// Component energies (pJ, relative calibration).
#[derive(Clone, Copy, Debug)]
pub struct EnergyParams {
    /// ADC energy per conversion.
    pub e_adc: f64,
    /// Wordline + DAC energy per driven row per activation.
    pub e_row: f64,
    /// Per-cell read energy (row×col product term).
    pub e_cell: f64,
    /// Shift-and-add per column per activation.
    pub e_sa: f64,
    /// Subtractor per logical output per pixel.
    pub e_sub: f64,
    /// Fixed activation overhead per (row + col) of the physical array.
    pub e_fixed_per_line: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        // Ratios follow NeuroSIM-style breakdowns for ReRAM + SAR-ADC at
        // ~5-bit: ADC ≈ 2 pJ/conv dominates; row drive ≈ 0.05 pJ; cell
        // read ≈ 0.001 pJ; digital ops ≈ 0.05 pJ; fixed ≈ 0.002 pJ/line.
        EnergyParams {
            e_adc: 2.0,
            e_row: 0.05,
            e_cell: 0.001,
            e_sa: 0.05,
            e_sub: 0.05,
            e_fixed_per_line: 0.002,
        }
    }
}

/// Energy breakdown for one layer (pJ).
#[derive(Clone, Debug, Default)]
pub struct EnergyBreakdown {
    pub adc: f64,
    pub rows: f64,
    pub cells: f64,
    pub digital: f64,
    pub fixed: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.adc + self.rows + self.cells + self.digital + self.fixed
    }
    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.adc += o.adc;
        self.rows += o.rows;
        self.cells += o.cells;
        self.digital += o.digital;
        self.fixed += o.fixed;
    }
}

/// Energy of one mapped layer per inference.
pub fn layer_energy(
    m: &LayerMapping,
    layer: &LayerShape,
    dims: ArrayDims,
    p: &EnergyParams,
) -> EnergyBreakdown {
    let pixels = (layer.oh * layer.ow) as f64;
    EnergyBreakdown {
        adc: p.e_adc * m.adc_conversions as f64,
        rows: p.e_row * m.row_drives as f64,
        cells: p.e_cell * (m.row_drives as f64 / m.activations.max(1) as f64)
            * (m.adc_conversions as f64 / m.activations.max(1) as f64)
            * m.activations as f64,
        digital: p.e_sa * m.adc_conversions as f64
            + p.e_sub * layer.cout as f64 * pixels,
        fixed: p.e_fixed_per_line * (dims.rows + dims.cols) as f64 * m.activations as f64,
    }
}

/// Whole-network energy per inference.
pub fn network_energy(
    layers: &[LayerShape],
    dims: ArrayDims,
    cfg: &GroupConfig,
    p: &EnergyParams,
    policy: MapperPolicy,
) -> (EnergyBreakdown, Vec<LayerMapping>) {
    let mappings = map_network(layers, dims, cfg, policy);
    let mut total = EnergyBreakdown::default();
    for (m, l) in mappings.iter().zip(layers) {
        total.add(&layer_energy(m, l, dims, p));
    }
    (total, mappings)
}

/// Fig 11 datapoint: energy of `cfg` normalized against the R1C4 baseline
/// on the same network and array size (paper's kernel-splitting mapper).
pub fn normalized_energy(
    layers: &[LayerShape],
    dims: ArrayDims,
    cfg: &GroupConfig,
    p: &EnergyParams,
) -> f64 {
    let policy = MapperPolicy::KernelSplit;
    let (e, _) = network_energy(layers, dims, cfg, p, policy);
    let (base, _) = network_energy(layers, dims, &GroupConfig::R1C4, p, policy);
    e.total() / base.total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arrays::models::{resnet18, resnet20};

    #[test]
    fn r1c4_normalizes_to_one() {
        let p = EnergyParams::default();
        let n = normalized_energy(&resnet20(), ArrayDims::square(128), &GroupConfig::R1C4, &p);
        assert!((n - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2c2_saves_energy_fig11() {
        // The headline claim: R2C2 reduces energy by up to ~2× (≈0.5
        // normalized) for both ResNet-20 and ResNet-18, at every array size.
        let p = EnergyParams::default();
        for layers in [resnet20(), resnet18()] {
            let mut best = 1.0f64;
            for n in [64usize, 128, 256, 512] {
                let r = normalized_energy(&layers, ArrayDims::square(n), &GroupConfig::R2C2, &p);
                assert!(r < 0.9, "R2C2 should always save energy, got {r} at {n}");
                best = best.min(r);
            }
            assert!(best < 0.62, "peak saving should approach 2x, got {best}");
        }
    }

    #[test]
    fn adc_dominates_default_params() {
        let p = EnergyParams::default();
        let (e, _) = network_energy(
            &resnet20(),
            ArrayDims::square(256),
            &GroupConfig::R1C4,
            &p,
            MapperPolicy::KernelSplit,
        );
        assert!(e.adc > e.total() * 0.5, "adc {} of {}", e.adc, e.total());
    }

    #[test]
    fn energy_positive_and_finite_across_grid() {
        let p = EnergyParams::default();
        for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
            for n in [64usize, 128, 256, 512] {
                for policy in [MapperPolicy::KernelSplit, MapperPolicy::PackedVertical] {
                    let (e, maps) =
                        network_energy(&resnet20(), ArrayDims::square(n), &cfg, &p, policy);
                    assert!(e.total().is_finite() && e.total() > 0.0);
                    assert!(!maps.is_empty());
                }
            }
        }
    }

    #[test]
    fn r2c4_costs_more_than_r2c2() {
        // R2C4 doubles the columns of R2C2 → more ADC work.
        let p = EnergyParams::default();
        let d = ArrayDims::square(256);
        let e22 = normalized_energy(&resnet20(), d, &GroupConfig::R2C2, &p);
        let e24 = normalized_energy(&resnet20(), d, &GroupConfig::R2C4, &p);
        assert!(e24 > e22);
    }
}
