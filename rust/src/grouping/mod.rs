//! Multi-bit weight representation on IMC arrays: grouping configurations,
//! bitmaps and the fault-analysis theorems (§III–§IV of the paper).

pub mod analysis;
pub mod bitmap;
pub mod config;

pub use analysis::{Array, FaultAnalysis, FreeCell};
pub use bitmap::{Bitmap, Decomposition};
pub use config::GroupConfig;
