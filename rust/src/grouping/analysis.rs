//! Fault analysis of a grouped bitmap pair — Theorems 1 and 2 (§III).
//!
//! Given a `GroupConfig` and a `GroupFaults` map this computes, in closed
//! form (no enumeration):
//!
//! * the constant component `C = (L−1)·(d(F0⁺) − d(F0⁻))` of Eq. (4);
//! * the representable range `[C − N, C + P]` of the faulty weight, where
//!   `P`/`N` are the free-cell capacities of the positive/negative arrays
//!   (Theorem 1 — the *clipping* characterization);
//! * whether the representable set is *consecutive* (gap-free). The paper's
//!   Theorem 2 gives a sufficient condition for inconsecutivity when a
//!   whole significance column is stuck; we implement the exact criterion
//!   (complete-sequence test over free-cell significances), which the
//!   pipeline needs to decide FAWD vs CVM safely, and test both against
//!   brute-force enumeration;
//! * a constructive zero-error solution (greedy digit assignment) whenever
//!   the target is representable and the set is consecutive.

use super::bitmap::{Bitmap, Decomposition};
use super::config::GroupConfig;
use crate::fault::{FaultState, GroupFaults};

/// Which array a free cell lives in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Array {
    Pos,
    Neg,
}

/// One programmable (fault-free) cell of the group.
#[derive(Clone, Copy, Debug)]
pub struct FreeCell {
    pub array: Array,
    /// Flat index within its bitmap.
    pub idx: usize,
    /// Column significance.
    pub sig: i64,
}

/// Closed-form fault analysis for one (config, faultmap) pair.
#[derive(Clone, Debug)]
pub struct FaultAnalysis {
    pub cfg: GroupConfig,
    /// Constant component `C` of Eq. (4).
    pub constant: i64,
    /// Max positive free contribution `max(d(Ẋ⁺))`.
    pub pos_cap: i64,
    /// Max negative free contribution `max(d(Ẋ⁻))`.
    pub neg_cap: i64,
    /// Free cells, sorted by descending significance (for greedy assign).
    pub free: Vec<FreeCell>,
    /// Exact consecutivity of the representable set.
    pub consecutive: bool,
}

impl FaultAnalysis {
    pub fn new(cfg: &GroupConfig, faults: &GroupFaults) -> FaultAnalysis {
        debug_assert_eq!(faults.pos.len(), cfg.cells());
        debug_assert_eq!(faults.neg.len(), cfg.cells());
        let lm1 = cfg.levels as i64 - 1;

        let mut constant = 0i64;
        let mut pos_cap = 0i64;
        let mut neg_cap = 0i64;
        let mut free: Vec<FreeCell> = Vec::with_capacity(2 * cfg.cells());

        for (idx, f) in faults.pos.iter().enumerate() {
            let sig = cfg.sig_of(idx);
            match f {
                FaultState::Free => {
                    pos_cap += sig * lm1;
                    free.push(FreeCell { array: Array::Pos, idx, sig });
                }
                FaultState::Sa0 => constant += sig * lm1,
                FaultState::Sa1 => {}
            }
        }
        for (idx, f) in faults.neg.iter().enumerate() {
            let sig = cfg.sig_of(idx);
            match f {
                FaultState::Free => {
                    neg_cap += sig * lm1;
                    free.push(FreeCell { array: Array::Neg, idx, sig });
                }
                FaultState::Sa0 => constant -= sig * lm1,
                FaultState::Sa1 => {}
            }
        }

        // Sort ascending once; check consecutivity on the ascending order,
        // then reverse in place for the descending-order greedy solver
        // (avoids a second allocation — this is the per-weight hot path).
        free.sort_unstable_by_key(|cell| cell.sig);

        // Exact consecutivity: the achievable variable component, shifted by
        // +neg_cap, is the set of sums Σ v_i·sig_i with v_i ∈ [0, L−1] over
        // *all* free cells (both arrays — a negative-array cell programmed
        // to b contributes (L−1−b)·sig − (L−1)·sig). Such a digit system is
        // gap-free iff, processing significances in increasing order, each
        // item's significance is ≤ 1 + (total capacity of smaller items).
        let mut consecutive = true;
        let mut reach = 0i64; // all of [0, reach] is achievable so far
        for cell in &free {
            if cell.sig > reach + 1 {
                consecutive = false;
                break;
            }
            reach += cell.sig * lm1;
        }
        free.reverse();

        FaultAnalysis { cfg: *cfg, constant, pos_cap, neg_cap, free, consecutive }
    }

    /// Theorem 1 quantities: inclusive faulty-representable range.
    #[inline]
    pub fn range(&self) -> (i64, i64) {
        (self.constant - self.neg_cap, self.constant + self.pos_cap)
    }

    /// Width of the faulty range (Theorem 1 says this strictly shrinks
    /// whenever at least one fault exists).
    pub fn range_width(&self) -> i64 {
        self.pos_cap + self.neg_cap
    }

    /// Does the paper's Theorem-2 *sufficient* condition hold for any
    /// significance column? (All cells of significance `L^{i-1}`, i ≠ MSB,
    /// stuck in both arrays, and `(L^i − 1)/(L^{i−1} − 1) > 2r`.)
    pub fn theorem2_condition(&self, faults: &GroupFaults) -> bool {
        let l = self.cfg.levels as i64;
        for col in 1..self.cfg.cols {
            // col > 0 ⇒ not the MSB; significance index i = cols − col.
            let all_stuck = (0..self.cfg.rows).all(|row| {
                let idx = col * self.cfg.rows + row;
                faults.pos[idx].is_fault() && faults.neg[idx].is_fault()
            });
            if !all_stuck {
                continue;
            }
            let i = (self.cfg.cols - col) as u32; // significance exponent above this column
            let num = l.pow(i) - 1;
            let den = l.pow(i - 1) - 1;
            if den > 0 && num > 2 * self.cfg.rows as i64 * den {
                return true;
            }
        }
        false
    }

    /// Is `w` inside the faulty representable range?
    #[inline]
    pub fn in_range(&self, w: i64) -> bool {
        let (lo, hi) = self.range();
        w >= lo && w <= hi
    }

    /// Clamp `w` to the faulty range — the Theorem-1 trivial solution value.
    #[inline]
    pub fn clamp(&self, w: i64) -> i64 {
        let (lo, hi) = self.range();
        w.clamp(lo, hi)
    }

    /// Build the decomposition whose faulty value is exactly the range
    /// extreme: free cells of one array full, the other zeroed.
    pub fn extreme_solution(&self, hi: bool) -> Decomposition {
        let mut pos = Bitmap::zeros(&self.cfg);
        let mut neg = Bitmap::zeros(&self.cfg);
        for cell in &self.free {
            match (cell.array, hi) {
                (Array::Pos, true) => pos.cells[cell.idx] = self.cfg.levels - 1,
                (Array::Neg, false) => neg.cells[cell.idx] = self.cfg.levels - 1,
                _ => {}
            }
        }
        Decomposition { pos, neg }
    }

    /// Constructive zero-error solution via greedy generalized-digit
    /// assignment. Returns `None` if `w` is out of range, or if the set is
    /// inconsecutive and the greedy residual cannot be closed (the CVM path
    /// handles those cases).
    ///
    /// Transformation: a negative-array free cell programmed to `b`
    /// contributes `−b·sig`; substituting `v = (L−1) − b` makes every free
    /// cell a non-negative digit `v·sig` with target `T = w − C + N ≥ 0`.
    pub fn solve_exact(&self, w: i64) -> Option<Decomposition> {
        if !self.in_range(w) {
            return None;
        }
        let lm1 = (self.cfg.levels - 1) as i64;
        let mut target = w - self.constant + self.neg_cap;
        debug_assert!(target >= 0);

        // Greedy over descending significance with exact remainder guard:
        // keep the remaining lower capacity as a running suffix sum and
        // take v = clamp(ceil((T − lower_cap)/sig), 0, min(L−1, T/sig)).
        // Digits are written straight into the bitmaps — no intermediate
        // allocations (per-weight hot path; see EXPERIMENTS.md §Perf).
        let mut lower = self.pos_cap + self.neg_cap; // capacity of cells i..
        let mut pos = Bitmap::zeros(&self.cfg);
        let mut neg = Bitmap::zeros(&self.cfg);
        for cell in &self.free {
            lower -= cell.sig * lm1; // capacity strictly below cell i
            let max_take = lm1.min(target / cell.sig);
            // Must take at least enough that the rest fits below.
            let need = target - lower;
            let min_take = if need > 0 { (need + cell.sig - 1) / cell.sig } else { 0 };
            if min_take > max_take {
                return None; // unreachable target (inconsecutive gap)
            }
            // Prefer the largest take (keeps remainder smallest — standard
            // complete-sequence greedy; also tends to sparsify pos array).
            let v = max_take;
            target -= v * cell.sig;
            match cell.array {
                Array::Pos => pos.cells[cell.idx] = v as u8,
                Array::Neg => neg.cells[cell.idx] = (lm1 - v) as u8,
            }
        }
        if target != 0 {
            return None;
        }
        Some(Decomposition { pos, neg })
    }

    /// Enumerate every achievable faulty value (exponential in free cells —
    /// test/verification use only).
    pub fn enumerate_values(&self) -> Vec<i64> {
        let lm1 = (self.cfg.levels - 1) as i64;
        let mut vals = vec![0i64];
        for cell in &self.free {
            let signed = match cell.array {
                Array::Pos => cell.sig,
                Array::Neg => -cell.sig,
            };
            let mut next = Vec::with_capacity(vals.len() * (lm1 as usize + 1));
            for v in &vals {
                for d in 0..=lm1 {
                    next.push(v + signed * d);
                }
            }
            next.sort_unstable();
            next.dedup();
            vals = next;
        }
        vals.iter_mut().for_each(|v| *v += self.constant);
        vals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultRates;
    use crate::util::prop::prop_check;
    use crate::{prop_assert, prop_assert_eq};

    fn random_cfg(rng: &mut crate::util::prng::Rng) -> GroupConfig {
        let rows = 1 + rng.index(3);
        let cols = 1 + rng.index(3);
        let levels = [2u8, 4][rng.index(2)];
        GroupConfig::new(rows, cols, levels)
    }

    #[test]
    fn no_faults_full_range_consecutive() {
        for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
            let fa = FaultAnalysis::new(&cfg, &GroupFaults::free(cfg.cells()));
            assert_eq!(fa.range(), (-cfg.max_per_array(), cfg.max_per_array()));
            assert!(fa.consecutive);
            assert_eq!(fa.constant, 0);
        }
    }

    #[test]
    fn theorem1_any_fault_strictly_shrinks_range() {
        prop_check("thm1-clipping", 400, |rng| {
            let cfg = random_cfg(rng);
            let faults = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: 0.2, p_sa1: 0.2 },
                rng,
            );
            let fa = FaultAnalysis::new(&cfg, &faults);
            let ideal_width = 2 * cfg.max_per_array();
            if faults.is_fault_free() {
                prop_assert!(fa.range_width() == ideal_width, "free map lost range");
            } else {
                prop_assert!(
                    fa.range_width() < ideal_width,
                    "faulty map range {} !< ideal {} (cfg {cfg}, faults {faults:?})",
                    fa.range_width(),
                    ideal_width
                );
            }
            Ok(())
        });
    }

    #[test]
    fn range_matches_enumeration() {
        prop_check("range-vs-enum", 200, |rng| {
            let cfg = random_cfg(rng);
            let faults = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: 0.25, p_sa1: 0.25 },
                rng,
            );
            let fa = FaultAnalysis::new(&cfg, &faults);
            let vals = fa.enumerate_values();
            let (lo, hi) = fa.range();
            prop_assert!(*vals.first().unwrap() == lo, "min mismatch");
            prop_assert!(*vals.last().unwrap() == hi, "max mismatch");
            Ok(())
        });
    }

    #[test]
    fn consecutivity_matches_enumeration() {
        prop_check("consec-vs-enum", 300, |rng| {
            let cfg = random_cfg(rng);
            let faults = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: 0.3, p_sa1: 0.3 },
                rng,
            );
            let fa = FaultAnalysis::new(&cfg, &faults);
            let vals = fa.enumerate_values();
            let gap_free = vals.windows(2).all(|w| w[1] - w[0] == 1) || vals.len() <= 1;
            prop_assert!(
                fa.consecutive == gap_free,
                "criterion {} but enumeration gap_free {} (cfg {cfg}, faults {faults:?}, vals {vals:?})",
                fa.consecutive,
                gap_free
            );
            Ok(())
        });
    }

    #[test]
    fn theorem2_sufficient_condition_implies_inconsecutive() {
        // R1C4: stick both LSB cells (pos+neg) at col 3 (sig 1): then
        // significance step 4 with max lower... use col index 1 (sig 16):
        // condition (L^i − 1)/(L^{i−1} − 1) = (4^3−1)/(4^2−1) = 63/15 = 4.2 > 2r = 2.
        let cfg = GroupConfig::R1C4;
        let mut faults = GroupFaults::free(cfg.cells());
        faults.pos[1] = FaultState::Sa1; // col 1 (sig 16)
        faults.neg[1] = FaultState::Sa0;
        let fa = FaultAnalysis::new(&cfg, &faults);
        assert!(fa.theorem2_condition(&faults));
        assert!(!fa.consecutive, "theorem 2 condition must imply inconsecutive");
        let vals = fa.enumerate_values();
        assert!(vals.windows(2).any(|w| w[1] - w[0] > 1));
    }

    #[test]
    fn theorem2_r2c2_needs_all_four_cells() {
        // In R2C2 a single stuck LSB does not trigger inconsecutivity —
        // the redundancy argument from Fig 6.
        let cfg = GroupConfig::R2C2;
        let mut faults = GroupFaults::free(cfg.cells());
        faults.pos[2] = FaultState::Sa1; // one LSB cell of four
        let fa = FaultAnalysis::new(&cfg, &faults);
        assert!(fa.consecutive);
        assert!(!fa.theorem2_condition(&faults));
    }

    #[test]
    fn solve_exact_zero_error_when_consecutive() {
        prop_check("solve-exact", 500, |rng| {
            let cfg = random_cfg(rng);
            let faults = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: 0.15, p_sa1: 0.15 },
                rng,
            );
            let fa = FaultAnalysis::new(&cfg, &faults);
            let (lo, hi) = fa.range();
            if lo > hi {
                return Ok(());
            }
            let w = rng.range_i64(lo, hi);
            match fa.solve_exact(w) {
                Some(d) => {
                    let got = d.faulty_value(&cfg, &faults);
                    prop_assert!(got == w, "solution decodes to {got}, want {w}");
                    // Free-cell-only: stuck cells may hold anything, but our
                    // solution must respect L-1 bounds.
                    for &c in d.pos.cells.iter().chain(&d.neg.cells) {
                        prop_assert!(c < cfg.levels, "cell value {c} out of bounds");
                    }
                }
                None => {
                    prop_assert!(
                        !fa.consecutive,
                        "solve_exact failed on consecutive set (w={w}, cfg={cfg}, faults={faults:?})"
                    );
                    // w must genuinely be unreachable.
                    let vals = fa.enumerate_values();
                    prop_assert!(
                        !vals.contains(&w),
                        "greedy failed but {w} is enumerable (cfg {cfg}, faults {faults:?})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn extreme_solutions_hit_range_ends() {
        prop_check("extremes", 200, |rng| {
            let cfg = random_cfg(rng);
            let faults = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: 0.25, p_sa1: 0.25 },
                rng,
            );
            let fa = FaultAnalysis::new(&cfg, &faults);
            let (lo, hi) = fa.range();
            prop_assert_eq!(fa.extreme_solution(true).faulty_value(&cfg, &faults), hi);
            prop_assert_eq!(fa.extreme_solution(false).faulty_value(&cfg, &faults), lo);
            Ok(())
        });
    }

    #[test]
    fn fig5_clipping_numbers() {
        // Fig 5 narrative: an MSB fault in R1C4 wipes a large share of the
        // range; the same fault in R2C2 wipes much less, because
        // significance is distributed. Quantify both.
        let r1c4 = GroupConfig::R1C4;
        let mut f = GroupFaults::free(r1c4.cells());
        f.pos[0] = FaultState::Sa1; // MSB stuck at 0 in pos array
        let fa = FaultAnalysis::new(&r1c4, &f);
        let loss_r1c4 = 1.0 - fa.range_width() as f64 / (2 * r1c4.max_per_array()) as f64;

        let r2c2 = GroupConfig::R2C2;
        let mut f2 = GroupFaults::free(r2c2.cells());
        f2.pos[0] = FaultState::Sa1; // one of the two MSB cells
        let fa2 = FaultAnalysis::new(&r2c2, &f2);
        let loss_r2c2 = 1.0 - fa2.range_width() as f64 / (2 * r2c2.max_per_array()) as f64;

        // R1C4 loses 192/510 ≈ 38%; R2C2 loses 12/60 = 20%.
        assert!((loss_r1c4 - 192.0 / 510.0).abs() < 1e-9);
        assert!((loss_r2c2 - 12.0 / 60.0).abs() < 1e-9);
        assert!(loss_r2c2 < loss_r1c4);
    }
}
