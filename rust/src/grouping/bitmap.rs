//! Bitmaps: the cell-value matrices `X⁺`, `X⁻` holding one weight.
//!
//! Layout: flat `Vec<u8>`, index `col*rows + row`, column 0 = MSB. The
//! decode function implements the paper's `d(X) = s X 1` (Eq. 2); fault
//! application implements Eq. (1).

use super::config::GroupConfig;
use crate::fault::{FaultState, GroupFaults};

/// Cell values for one array (positive or negative) of one weight group.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bitmap {
    pub cells: Vec<u8>,
}

impl Bitmap {
    pub fn zeros(cfg: &GroupConfig) -> Self {
        Bitmap { cells: vec![0; cfg.cells()] }
    }

    pub fn full(cfg: &GroupConfig) -> Self {
        Bitmap { cells: vec![cfg.levels - 1; cfg.cells()] }
    }

    /// Decode `d(X) = Σ_cells sig(cell)·value(cell)` (Eq. 2's `sXl`).
    pub fn decode(&self, cfg: &GroupConfig) -> i64 {
        self.cells
            .iter()
            .enumerate()
            .map(|(i, &v)| cfg.sig_of(i) * v as i64)
            .sum()
    }

    /// Decode after fault injection: `d(f(X, F0, F1))` per Eq. (1) — SA0
    /// cells read `L-1`, SA1 cells read `0`, free cells read their value.
    pub fn decode_faulty(&self, cfg: &GroupConfig, faults: &[FaultState]) -> i64 {
        debug_assert_eq!(self.cells.len(), faults.len());
        self.cells
            .iter()
            .zip(faults)
            .enumerate()
            .map(|(i, (&v, f))| cfg.sig_of(i) * f.apply(v, cfg.levels) as i64)
            .sum()
    }

    /// The faulty bitmap itself, `X̃ = (1−F0−F1)⊙X + (L−1)F0`.
    pub fn inject(&self, cfg: &GroupConfig, faults: &[FaultState]) -> Bitmap {
        Bitmap {
            cells: self
                .cells
                .iter()
                .zip(faults)
                .map(|(&v, f)| f.apply(v, cfg.levels))
                .collect(),
        }
    }
}

/// A positive/negative bitmap pair representing one signed weight.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Decomposition {
    pub pos: Bitmap,
    pub neg: Bitmap,
}

impl Decomposition {
    /// Ideal (fault-unaware) sign decomposition + base-L digit encoding:
    /// the magnitude goes into the matching array, zero into the other.
    /// Rows are filled greedily: row 0 takes as much of each digit as it
    /// can, overflow cascades into further rows (for r>1 a digit can exceed
    /// L-1 per cell up to r(L-1) per column).
    pub fn encode_ideal(w: i64, cfg: &GroupConfig) -> Decomposition {
        debug_assert!(w.abs() <= cfg.max_per_array(), "weight {w} out of range for {cfg}");
        let mag = w.unsigned_abs() as i64;
        let filled = encode_magnitude(mag, cfg);
        if w >= 0 {
            Decomposition { pos: filled, neg: Bitmap::zeros(cfg) }
        } else {
            Decomposition { pos: Bitmap::zeros(cfg), neg: filled }
        }
    }

    /// The represented (fault-free) weight: `d(X⁺) − d(X⁻)`.
    pub fn value(&self, cfg: &GroupConfig) -> i64 {
        self.pos.decode(cfg) - self.neg.decode(cfg)
    }

    /// The faulty weight `w̃ = d(f(X⁺,…)) − d(f(X⁻,…))` (Eq. 2).
    pub fn faulty_value(&self, cfg: &GroupConfig, faults: &GroupFaults) -> i64 {
        self.pos.decode_faulty(cfg, &faults.pos) - self.neg.decode_faulty(cfg, &faults.neg)
    }

    /// ℓ1 norm of the stored cell values (the ILP-FAWD objective).
    pub fn l1(&self) -> u64 {
        self.pos.cells.iter().chain(&self.neg.cells).map(|&v| v as u64).sum()
    }
}

/// Encode a non-negative magnitude into one array's cells.
///
/// Per column (significance L^j) the digit can reach `r·(L−1)`; we compute
/// generalized base-L digits with that per-column capacity, most
/// significant first, then split each column digit across its `r` rows.
fn encode_magnitude(mut mag: i64, cfg: &GroupConfig) -> Bitmap {
    let mut bm = Bitmap::zeros(cfg);
    let cap_per_col = (cfg.levels as i64 - 1) * cfg.rows as i64;
    for col in 0..cfg.cols {
        let sig = (cfg.levels as i64).pow((cfg.cols - 1 - col) as u32);
        // Take as many units of this significance as available/needed;
        // capacity of all lower columns combined is r·(L−1)·(sig−1)/(L−1)
        // = r·(sig−1).
        let lower_max = cfg.rows as i64 * (sig - 1);
        let mut take = mag / sig;
        if take > cap_per_col {
            take = cap_per_col;
        }
        // Ensure remainder fits in lower columns (always true for generalized
        // base-L with per-column capacity ≥ L-1, but keep the guard exact).
        while mag - take * sig > lower_max {
            take += 1;
        }
        debug_assert!(take <= cap_per_col);
        mag -= take * sig;
        // Split `take` across rows.
        for row in 0..cfg.rows {
            let v = take.min(cfg.levels as i64 - 1);
            bm.cells[col * cfg.rows + row] = v as u8;
            take -= v;
        }
        debug_assert_eq!(take, 0);
    }
    debug_assert_eq!(mag, 0);
    bm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn decode_full_equals_max() {
        for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4, GroupConfig::new(3, 3, 2)] {
            assert_eq!(Bitmap::full(&cfg).decode(&cfg), cfg.max_per_array());
            assert_eq!(Bitmap::zeros(&cfg).decode(&cfg), 0);
        }
    }

    #[test]
    fn paper_fig1b_example() {
        // 52 stored in R1C4 (L=4); SA0 at MSB, SA1 at 2nd-LSB ⇒ reads 240.
        let cfg = GroupConfig::R1C4;
        let d = Decomposition::encode_ideal(52, &cfg);
        assert_eq!(d.pos.cells, vec![0, 3, 1, 0]);
        let mut faults = GroupFaults::free(cfg.cells());
        faults.pos[0] = FaultState::Sa0; // MSB
        faults.pos[2] = FaultState::Sa1; // 2nd LSB
        assert_eq!(d.faulty_value(&cfg, &faults), 240);
    }

    #[test]
    fn encode_decode_roundtrip_all_weights() {
        for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
            for w in -cfg.max_per_array()..=cfg.max_per_array() {
                let d = Decomposition::encode_ideal(w, &cfg);
                assert_eq!(d.value(&cfg), w, "cfg={cfg} w={w}");
                // Sign decomposition: one side must be all zeros.
                if w >= 0 {
                    assert!(d.neg.cells.iter().all(|&c| c == 0));
                } else {
                    assert!(d.pos.cells.iter().all(|&c| c == 0));
                }
            }
        }
    }

    #[test]
    fn prop_encode_roundtrip_random_configs() {
        prop_check("encode-roundtrip", 300, |rng| {
            let rows = 1 + rng.index(3);
            let cols = 1 + rng.index(4);
            let levels = [2u8, 4, 8][rng.index(3)];
            let cfg = GroupConfig::new(rows, cols, levels);
            let w = rng.range_i64(-cfg.max_per_array(), cfg.max_per_array());
            let d = Decomposition::encode_ideal(w, &cfg);
            prop_assert!(d.value(&cfg) == w, "w={w} decoded={} cfg={cfg}", d.value(&cfg));
            for &cell in d.pos.cells.iter().chain(&d.neg.cells) {
                prop_assert!(cell < levels, "cell {cell} exceeds L-1");
            }
            Ok(())
        });
    }

    #[test]
    fn fault_free_faulty_value_equals_value() {
        let cfg = GroupConfig::R2C2;
        let faults = GroupFaults::free(cfg.cells());
        for w in [-30, -1, 0, 17, 30] {
            let d = Decomposition::encode_ideal(w, &cfg);
            assert_eq!(d.faulty_value(&cfg, &faults), w);
        }
    }

    #[test]
    fn inject_matches_decode_faulty() {
        prop_check("inject-consistency", 200, |rng| {
            let cfg = GroupConfig::R2C2;
            let w = rng.range_i64(-30, 30);
            let d = Decomposition::encode_ideal(w, &cfg);
            let faults = GroupFaults::sample(cfg.cells(), &crate::fault::FaultRates { p_sa0: 0.3, p_sa1: 0.3 }, rng);
            let injected = d.pos.inject(&cfg, &faults.pos);
            prop_assert!(
                injected.decode(&cfg) == d.pos.decode_faulty(&cfg, &faults.pos),
                "inject/decode_faulty disagree"
            );
            Ok(())
        });
    }

    #[test]
    fn l1_of_ideal_zero_is_zero() {
        let cfg = GroupConfig::R1C4;
        assert_eq!(Decomposition::encode_ideal(0, &cfg).l1(), 0);
        assert!(Decomposition::encode_ideal(255, &cfg).l1() > 0);
    }

    #[test]
    fn row_overflow_encoding() {
        // R2C2: w=25 needs col digit > L-1 split across rows:
        // 25 = 6*4 + 1 ⇒ col0 digit 6 → rows (3,3), col1 digit 1 → (1,0).
        let cfg = GroupConfig::R2C2;
        let d = Decomposition::encode_ideal(25, &cfg);
        assert_eq!(d.pos.cells, vec![3, 3, 1, 0]);
        assert_eq!(d.value(&cfg), 25);
    }
}
