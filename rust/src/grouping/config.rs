//! Grouping configurations — §IV of the paper.
//!
//! A `GroupConfig` RrCc with cell resolution `L` groups `r × c` cells (per
//! array) to represent one signed weight. Columns carry base-`L`
//! significance `[L^{c-1}, …, L, 1]`; the `r` rows of a group share the
//! same input voltage, so their conductances add — each significance is
//! backed by `r` interchangeable cells. The representable magnitude per
//! array is `r·(L^c − 1)`; with positive/negative sign decomposition the
//! weight range is `[−r(L^c−1), +r(L^c−1)]`.
//!
//! Paper configurations (L = 4, i.e. 2-bit cells):
//!   R1C4 → range ±255, 8.00 bits (conventional column grouping baseline)
//!   R2C2 → range ±30,  4.95 bits
//!   R2C4 → range ±510, 8.99 bits

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GroupConfig {
    /// Grouped rows per weight (shared input voltage).
    pub rows: usize,
    /// Grouped columns per weight (bit slices).
    pub cols: usize,
    /// Cell conductance levels (2 = 1-bit cell, 4 = 2-bit cell).
    pub levels: u8,
}

impl GroupConfig {
    pub const fn new(rows: usize, cols: usize, levels: u8) -> Self {
        GroupConfig { rows, cols, levels }
    }

    /// The paper's baseline: conventional column grouping, 2-bit cells.
    pub const R1C4: GroupConfig = GroupConfig::new(1, 4, 4);
    /// Hybrid grouping, 2 rows × 2 cols, 2-bit cells (the headline config).
    pub const R2C2: GroupConfig = GroupConfig::new(2, 2, 4);
    /// Hybrid grouping, 2 rows × 4 cols (higher precision than R1C4).
    pub const R2C4: GroupConfig = GroupConfig::new(2, 4, 4);

    /// Parse "r2c2" / "R2C2" style names, optionally with "@L" suffix
    /// ("r2c2@2" = 1-bit cells).
    pub fn parse(s: &str) -> Option<GroupConfig> {
        let t = s.trim().to_ascii_lowercase();
        let (body, levels) = match t.split_once('@') {
            Some((b, l)) => (b.to_string(), l.parse().ok()?),
            None => (t, 4u8),
        };
        let rest = body.strip_prefix('r')?;
        let (r, c) = rest.split_once('c')?;
        let rows: usize = r.parse().ok()?;
        let cols: usize = c.parse().ok()?;
        if rows == 0 || cols == 0 || levels < 2 {
            return None;
        }
        Some(GroupConfig { rows, cols, levels })
    }

    /// Cells per array for one weight.
    #[inline]
    pub fn cells(&self) -> usize {
        self.rows * self.cols
    }

    /// Column significance vector, MSB first: `[L^{c-1}, …, L, 1]`.
    pub fn significances(&self) -> Vec<i64> {
        let l = self.levels as i64;
        (0..self.cols).rev().map(|j| l.pow(j as u32)).collect()
    }

    /// Significance of the cell at flat index `idx` (layout
    /// `idx = col*rows + row`, column 0 = MSB).
    #[inline]
    pub fn sig_of(&self, idx: usize) -> i64 {
        let col = idx / self.rows;
        (self.levels as i64).pow((self.cols - 1 - col) as u32)
    }

    /// Maximum decoded magnitude of one array: `r·(L^c − 1)`.
    pub fn max_per_array(&self) -> i64 {
        self.rows as i64 * ((self.levels as i64).pow(self.cols as u32) - 1)
    }

    /// Effective precision in bits: `log2(r(L^c−1) + 1)` — the paper's
    /// "Prec." column (R2C2 → 4.95, R2C4 → 8.99).
    pub fn precision_bits(&self) -> f64 {
        ((self.max_per_array() + 1) as f64).log2()
    }

    /// Number of representable signed integer weights.
    pub fn num_weight_levels(&self) -> i64 {
        2 * self.max_per_array() + 1
    }

    pub fn name(&self) -> String {
        if self.levels == 4 {
            format!("R{}C{}", self.rows, self.cols)
        } else {
            format!("R{}C{}@{}", self.rows, self.cols, self.levels)
        }
    }
}

impl fmt::Display for GroupConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_ranges() {
        assert_eq!(GroupConfig::R1C4.max_per_array(), 255);
        assert_eq!(GroupConfig::R2C2.max_per_array(), 30);
        assert_eq!(GroupConfig::R2C4.max_per_array(), 510);
    }

    #[test]
    fn paper_precisions() {
        assert!((GroupConfig::R1C4.precision_bits() - 8.00).abs() < 0.01);
        assert!((GroupConfig::R2C2.precision_bits() - 4.95).abs() < 0.01);
        assert!((GroupConfig::R2C4.precision_bits() - 8.99).abs() < 0.01);
    }

    #[test]
    fn significances_msb_first() {
        assert_eq!(GroupConfig::R1C4.significances(), vec![64, 16, 4, 1]);
        assert_eq!(GroupConfig::R2C2.significances(), vec![4, 1]);
        assert_eq!(GroupConfig::new(1, 3, 2).significances(), vec![4, 2, 1]);
    }

    #[test]
    fn sig_of_matches_layout() {
        let cfg = GroupConfig::R2C2;
        // layout: [col0row0, col0row1, col1row0, col1row1]
        assert_eq!(cfg.sig_of(0), 4);
        assert_eq!(cfg.sig_of(1), 4);
        assert_eq!(cfg.sig_of(2), 1);
        assert_eq!(cfg.sig_of(3), 1);
    }

    #[test]
    fn parse_names() {
        assert_eq!(GroupConfig::parse("r1c4"), Some(GroupConfig::R1C4));
        assert_eq!(GroupConfig::parse("R2C2"), Some(GroupConfig::R2C2));
        assert_eq!(
            GroupConfig::parse("r4c8@2"),
            Some(GroupConfig::new(4, 8, 2))
        );
        assert_eq!(GroupConfig::parse("x2c2"), None);
        assert_eq!(GroupConfig::parse("r0c2"), None);
    }

    #[test]
    fn name_roundtrip() {
        for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::new(3, 2, 2)] {
            assert_eq!(GroupConfig::parse(&cfg.name()), Some(cfg));
        }
    }
}
