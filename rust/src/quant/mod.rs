//! Post-training quantization to the grouping configuration's integer
//! range.
//!
//! The paper quantizes CNNs with AnyPrecision QAT and LMs with GPTQ; both
//! produce integer weights in the representable range of the grouping
//! config — which is the only contract the compiler needs. We implement
//! symmetric per-output-channel PTQ (the python side mirrors it in
//! `packing.quantize_sym`), plus an optional greedy error-compensating
//! variant (`gptq_lite`) in the spirit of GPTQ's column-by-column residual
//! correction for the LM head.

use crate::grouping::GroupConfig;

/// A per-output-column symmetric quantized matrix.
///
/// Layout: `w_int[k * n + j]` for input row `k`, output column `j`;
/// `dequant(k, j) = w_int[k,j] * scale[j]`.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub k: usize,
    pub n: usize,
    pub w_int: Vec<i64>,
    pub scale: Vec<f32>,
    pub max_int: i64,
}

impl QuantizedMatrix {
    /// Symmetric per-column quantization of a row-major `[k, n]` matrix.
    pub fn quantize(w: &[f32], k: usize, n: usize, cfg: &GroupConfig) -> QuantizedMatrix {
        assert_eq!(w.len(), k * n);
        let max_int = cfg.max_per_array();
        let mut absmax = vec![0f32; n];
        for row in 0..k {
            for col in 0..n {
                absmax[col] = absmax[col].max(w[row * n + col].abs());
            }
        }
        let scale: Vec<f32> = absmax
            .iter()
            .map(|&m| if m > 0.0 { m / max_int as f32 } else { 1.0 })
            .collect();
        let mut w_int = vec![0i64; k * n];
        for row in 0..k {
            for col in 0..n {
                let q = (w[row * n + col] / scale[col]).round() as i64;
                w_int[row * n + col] = q.clamp(-max_int, max_int);
            }
        }
        QuantizedMatrix { k, n, w_int, scale, max_int }
    }

    /// GPTQ-flavoured quantization: process input rows in order; after
    /// rounding a row, push its rounding residual into the next row
    /// (weighted by a decaying factor), which reduces the *accumulated*
    /// output error for correlated inputs. A lightweight stand-in for
    /// GPTQ's Hessian-weighted update that needs no calibration data.
    pub fn quantize_gptq_lite(w: &[f32], k: usize, n: usize, cfg: &GroupConfig) -> QuantizedMatrix {
        assert_eq!(w.len(), k * n);
        let max_int = cfg.max_per_array();
        let mut absmax = vec![0f32; n];
        for row in 0..k {
            for col in 0..n {
                absmax[col] = absmax[col].max(w[row * n + col].abs());
            }
        }
        let scale: Vec<f32> = absmax
            .iter()
            .map(|&m| if m > 0.0 { m / max_int as f32 } else { 1.0 })
            .collect();
        let mut w_int = vec![0i64; k * n];
        let mut carry = vec![0f32; n];
        for row in 0..k {
            for col in 0..n {
                let target = w[row * n + col] + carry[col] * 0.5;
                let q = (target / scale[col]).round() as i64;
                let q = q.clamp(-max_int, max_int);
                w_int[row * n + col] = q;
                carry[col] = target - q as f32 * scale[col];
            }
        }
        QuantizedMatrix { k, n, w_int, scale, max_int }
    }

    /// Dequantize arbitrary integer values with this matrix's scales.
    pub fn dequant_values(&self, ints: &[i64]) -> Vec<f32> {
        assert_eq!(ints.len(), self.k * self.n);
        let mut out = vec![0f32; ints.len()];
        for row in 0..self.k {
            for col in 0..self.n {
                out[row * self.n + col] = ints[row * self.n + col] as f32 * self.scale[col];
            }
        }
        out
    }

    /// The ideal dequantized weights (quantization error only, no faults).
    pub fn dequant(&self) -> Vec<f32> {
        self.dequant_values(&self.w_int)
    }

    /// Max |w − dequant| over all entries (quantization error bound check).
    pub fn quant_error_linf(&self, w: &[f32]) -> f32 {
        let dq = self.dequant();
        w.iter().zip(&dq).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        prop_check("quant-halfstep", 100, |rng| {
            let (k, n) = (1 + rng.index(20), 1 + rng.index(8));
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.3).collect();
            let cfg = GroupConfig::R1C4;
            let q = QuantizedMatrix::quantize(&w, k, n, &cfg);
            for col in 0..n {
                let half = q.scale[col] * 0.5 + 1e-7;
                for row in 0..k {
                    let err = (w[row * n + col] - q.w_int[row * n + col] as f32 * q.scale[col]).abs();
                    prop_assert!(err <= half, "err {err} > half-step {half}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ints_within_config_range() {
        prop_check("quant-range", 100, |rng| {
            let cfg = [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4][rng.index(3)];
            let (k, n) = (1 + rng.index(10), 1 + rng.index(5));
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 2.0).collect();
            let q = QuantizedMatrix::quantize(&w, k, n, &cfg);
            prop_assert!(
                q.w_int.iter().all(|&v| v.abs() <= cfg.max_per_array()),
                "int out of range"
            );
            Ok(())
        });
    }

    #[test]
    fn higher_precision_configs_quantize_better() {
        let mut rng = crate::util::prng::Rng::new(5);
        let (k, n) = (64, 16);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let e_r2c2 = QuantizedMatrix::quantize(&w, k, n, &GroupConfig::R2C2).quant_error_linf(&w);
        let e_r1c4 = QuantizedMatrix::quantize(&w, k, n, &GroupConfig::R1C4).quant_error_linf(&w);
        let e_r2c4 = QuantizedMatrix::quantize(&w, k, n, &GroupConfig::R2C4).quant_error_linf(&w);
        assert!(e_r2c4 < e_r1c4 && e_r1c4 < e_r2c2, "{e_r2c4} < {e_r1c4} < {e_r2c2}");
    }

    #[test]
    fn zero_column_safe() {
        let w = vec![0.0f32; 12];
        let q = QuantizedMatrix::quantize(&w, 4, 3, &GroupConfig::R2C2);
        assert!(q.w_int.iter().all(|&v| v == 0));
        assert!(q.scale.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn gptq_lite_reduces_column_sum_error() {
        // The carry trick should shrink the accumulated per-column error
        // |Σ_k (w - dq)| relative to plain rounding (it compensates
        // residuals along k).
        let mut rng = crate::util::prng::Rng::new(17);
        let (k, n) = (256, 8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.2).collect();
        let cfg = GroupConfig::R2C2; // coarse quantization → visible effect
        let plain = QuantizedMatrix::quantize(&w, k, n, &cfg);
        let lite = QuantizedMatrix::quantize_gptq_lite(&w, k, n, &cfg);
        let colsum = |q: &QuantizedMatrix| -> f64 {
            let dq = q.dequant();
            (0..n)
                .map(|j| {
                    (0..k)
                        .map(|i| (w[i * n + j] - dq[i * n + j]) as f64)
                        .sum::<f64>()
                        .abs()
                })
                .sum()
        };
        assert!(
            colsum(&lite) < colsum(&plain),
            "gptq-lite {} !< plain {}",
            colsum(&lite),
            colsum(&plain)
        );
    }
}
