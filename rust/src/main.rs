//! `rchg` — the L3 coordinator CLI.
//!
//! Subcommands map to the paper's experiments and to operational tasks:
//!
//!   rchg tables                 regenerate every paper table/figure (fast set)
//!   rchg compile …              compile a model's weights for a chip
//!   rchg serve-batch …          batched compile service over many chips
//!   rchg serve …                compile-fabric coordinator daemon (TCP)
//!   rchg worker …               fabric worker: solve shard jobs for a coordinator
//!   rchg submit …               send a compile job to a fabric coordinator
//!   rchg top …                  scrape a coordinator's live metrics registry
//!   rchg trace-check …          validate a --trace-out JSON-lines dump
//!   rchg shard-solve …          solve shard k/K of one chip's compile
//!   rchg merge-shards …         reassemble shard fragments into a warm cache
//!   rchg chaos …                seeded fault-injection soak of a localhost fleet
//!                               (requires a `--features failpoints` build)
//!   rchg eval-cnn …             CNN accuracy under SAFs   (Table I/Fig 8/9)
//!   rchg eval-lm …              LM perplexity under SAFs  (Table III)
//!   rchg compile-time …         compilation-time study    (Table II/Fig 10)
//!   rchg bench …                per-PR perf harness → BENCH_<n>.json
//!   rchg energy …               energy sweep              (Fig 11)
//!   rchg inconsecutivity …      Monte-Carlo Theorem-2 study (Fig 6)
//!   rchg info                   runtime + artifact info

use rchg::arrays::MapperPolicy;
use rchg::coordinator::{
    CompileOptions, CompileService, CompileSession, CompileStats, Method, ServiceOptions,
    ShardFragment, ShardPlan, TableBudget,
};
use rchg::energy::EnergyParams;
use rchg::experiments::accuracy::{fig8, fig9, table1, AccuracyOptions};
use rchg::experiments::bench::{self, BenchOptions};
use rchg::experiments::compile_time::{
    dedup_report, fig10a, fig10b, measure_with_store, synthetic_model_tensors, table2,
    CompileTimeOptions,
};
use rchg::experiments::hw::{fig6, fig11};
use rchg::experiments::lm::{table3, LmOptions};
use rchg::experiments::Table;
use rchg::fault::FaultRates;
use rchg::grouping::GroupConfig;
use rchg::net::{run_worker, CompileClient, FabricServer, ServeOptions as FabricServeOptions};
use rchg::obs;
use rchg::runtime::{artifacts_dir, Runtime};
use rchg::store::StoreHandle;
use rchg::util::cli::Cli;
use rchg::util::timer::{fmt_dur, Timer};
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let sub = argv.get(1).map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = std::iter::once(format!("rchg {sub}"))
        .chain(argv.iter().skip(2).cloned())
        .collect();

    match sub {
        "info" => {
            let art = artifacts_dir();
            println!("artifacts dir: {}", art.display());
            if art.join("manifest.json").exists() {
                let rt = Runtime::new(&art)?;
                println!("platform: {}", rt.platform());
                println!("executables:");
                for n in rt.executables() {
                    println!("  {n}");
                }
            } else {
                println!("artifacts not built — run `make artifacts`");
            }
        }
        "tables" => {
            // A fast regeneration of every table/figure (reduced trials).
            let art = artifacts_dir();
            let rt = Runtime::new(&art)?;
            let aopts = AccuracyOptions { trials: 2, ..Default::default() };
            println!("{}", table1(&rt, &art, &aopts)?.render());
            println!("{}", fig8(&rt, &art, "cnn_s", 1)?.render());
            println!("{}", fig9(&rt, &art, "cnn_s", &[0.05, 0.1079, 0.2], 2, 1)?.render());
            let ctopts = CompileTimeOptions {
                models: vec!["resnet20".into(), "resnet18".into()],
                sample_complete: 100_000,
                sample_ilp: 1_000,
                sample_ff: 1_000,
                threads: 1,
                include_r2c4: false,
            };
            let (t2, rows) = table2(&ctopts)?;
            println!("{}", t2.render());
            println!("{}", fig10a(&rows, &ctopts.models).render());
            println!("{}", fig10b(&rows, "resnet18").render());
            println!("{}", dedup_report(&rows).render());
            let lopts = LmOptions { trials: 2, max_windows: 40, ..Default::default() };
            println!("{}", table3(&rt, &art, &lopts)?.render());
            println!(
                "{}",
                fig6(&[GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4], 500_000, 99)
                    .render()
            );
            println!(
                "{}",
                fig11(
                    "resnet20",
                    &[64, 128, 256, 512],
                    &EnergyParams::default(),
                    MapperPolicy::KernelSplit
                )?
                .render()
            );
        }
        "eval-cnn" => {
            let cli = Cli::new("CNN accuracy under SAFs")
                .opt("archs", "architectures", Some("cnn_s,cnn_m,cnn_d,vgg_n"))
                .opt("configs", "grouping configs", Some("r1c4,r2c2,r2c4"))
                .opt("trials", "chips per cell", Some("3"))
                .opt("threads", "worker threads (0 = auto-detect)", Some("0"))
                .opt("layerwise", "Fig 8 output", None)
                .opt("sweep", "Fig 9 output", None)
                .opt("unprotected", "no-mitigation rows", None);
            let args = cli.parse(rest);
            let art = artifacts_dir();
            let rt = Runtime::new(&art)?;
            let opts = AccuracyOptions {
                archs: args.get_list("archs"),
                configs: args
                    .get_list("configs")
                    .iter()
                    .filter_map(|s| GroupConfig::parse(s))
                    .collect(),
                trials: args.get_usize("trials", 3),
                threads: args.get_threads("threads"),
                include_unprotected: args.get_bool("unprotected"),
            };
            println!("{}", table1(&rt, &art, &opts)?.render());
            if args.get_bool("layerwise") {
                println!("{}", fig8(&rt, &art, &opts.archs[0], opts.threads)?.render());
            }
            if args.get_bool("sweep") {
                println!(
                    "{}",
                    fig9(
                        &rt,
                        &art,
                        &opts.archs[0],
                        &[0.02, 0.05, 0.1079, 0.15, 0.2],
                        opts.trials,
                        opts.threads
                    )?
                    .render()
                );
            }
        }
        "eval-lm" => {
            let cli = Cli::new("LM perplexity under SAFs")
                .opt("configs", "grouping configs", Some("r1c4,r2c2"))
                .opt("trials", "chips", Some("3"))
                .opt("windows", "eval windows per stream", Some("60"))
                .opt("threads", "worker threads (0 = auto-detect)", Some("0"))
                .opt("unprotected", "no-mitigation rows", None);
            let args = cli.parse(rest);
            let art = artifacts_dir();
            let rt = Runtime::new(&art)?;
            let opts = LmOptions {
                configs: args
                    .get_list("configs")
                    .iter()
                    .filter_map(|s| GroupConfig::parse(s))
                    .collect(),
                trials: args.get_usize("trials", 3),
                threads: args.get_threads("threads"),
                max_windows: args.get_usize("windows", 60),
                include_unprotected: args.get_bool("unprotected"),
            };
            println!("{}", table3(&rt, &art, &opts)?.render());
        }
        "compile-time" => {
            let cli = Cli::new("compilation time study")
                .opt("models", "models", Some("resnet20,resnet18,resnet50,vgg16"))
                .opt("sample-complete", "complete-pipeline sample", Some("400000"))
                .opt("sample-ilp", "ILP-only sample", Some("2000"))
                .opt("sample-ff", "FF sample", Some("2000"))
                .opt("threads", "worker threads (1 = paper protocol, 0 = auto)", Some("1"))
                .opt("r2c4", "include R2C4", None);
            let args = cli.parse(rest);
            let opts = CompileTimeOptions {
                models: args.get_list("models"),
                sample_complete: args.get_usize("sample-complete", 400_000),
                sample_ilp: args.get_usize("sample-ilp", 2_000),
                sample_ff: args.get_usize("sample-ff", 2_000),
                threads: args.get_threads("threads"),
                include_r2c4: args.get_bool("r2c4"),
            };
            let (t, rows) = table2(&opts)?;
            println!("{}", t.render());
            println!("{}", fig10a(&rows, &opts.models).render());
            println!("{}", fig10b(&rows, opts.models.last().unwrap()).render());
            println!("{}", dedup_report(&rows).render());
        }
        "bench" => {
            let cli = Cli::new("per-PR perf harness: seeded workload suite → schema-stable JSON")
                .opt("json", "print the JSON report instead of the human-readable table", None)
                .opt("quick", "reduced workload sizes (the CI smoke configuration)", None)
                .opt("threads", "solver threads for the compile/shard workloads", Some("1"))
                .opt("no-fabric", "skip the localhost fabric round-trip workload", None)
                .opt("out", "also write the JSON report to this path", None)
                .opt("pr", "PR number stamped into the report", Some("10"))
                .opt("check", "validate an existing report file against the schema, then exit", None);
            let args = cli.parse(rest);
            if let Some(path) = args.get("check") {
                let text = std::fs::read_to_string(path)?;
                let doc = rchg::util::json::Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("{path}: invalid JSON: {e}"))?;
                bench::validate(&doc)
                    .map_err(|e| anyhow::anyhow!("{path}: schema mismatch: {e}"))?;
                println!("{path}: schema ok ({})", bench::BENCH_SCHEMA);
                return Ok(());
            }
            let quick = args.get_bool("quick");
            let mut o = if quick { BenchOptions::quick() } else { BenchOptions::full() };
            o.threads = args.get_usize("threads", 1).max(1);
            if args.get_bool("no-fabric") {
                o.fabric = false;
            }
            let doc = bench::run(&o, quick, args.get_usize("pr", 10))?;
            if let Some(path) = args.get("out") {
                std::fs::write(path, doc.pretty() + "\n")?;
                eprintln!("bench report written to {path}");
            }
            if args.get_bool("json") {
                println!("{}", doc.pretty());
            } else {
                println!("{}", bench::render_human(&doc));
            }
        }
        "compile" => {
            let cli = Cli::new("compile a synthetic model for one chip")
                .opt("model", "layer-shape model", Some("resnet20"))
                .opt("config", "grouping config", Some("r2c2"))
                .opt("method", "complete|ilp|ff|unprotected", Some("complete"))
                .opt("chip", "chip seed", Some("1"))
                .opt("threads", "worker threads (0 = auto-detect)", Some("0"))
                .opt("limit", "max weights", None)
                .opt(
                    "store-dir",
                    "fleet solution store directory (reuse pattern tables across chips/runs)",
                    None,
                )
                .opt("trace-out", "write a JSON-lines span trace to this path", None);
            let args = cli.parse(rest);
            let cfg = GroupConfig::parse(args.get_str("config", "r2c2"))
                .ok_or_else(|| anyhow::anyhow!("bad config"))?;
            let method = Method::parse(args.get_str("method", "complete"))
                .ok_or_else(|| anyhow::anyhow!("bad method"))?;
            let store = match args.get("store-dir") {
                Some(dir) => Some(StoreHandle::with_dir(std::path::Path::new(&dir))?),
                None => None,
            };
            let trace_out = args.get("trace-out");
            if let Some(path) = &trace_out {
                install_trace_sink(path)?;
            }
            let r = measure_with_store(
                args.get_str("model", "resnet20"),
                cfg,
                method,
                args.get_usize("limit", usize::MAX),
                args.get_threads("threads"),
                args.get_u64("chip", 1),
                store,
            )?;
            println!(
                "compiled {} weights of {} ({}) in {} — full model {} weights ≈ {} linear, \
                 ≈ {} dedup-aware",
                r.sampled_weights,
                r.model,
                cfg.name(),
                fmt_dur(r.measured_secs),
                r.total_weights,
                fmt_dur(r.full_secs),
                fmt_dur(r.full_secs_dedup)
            );
            if r.unique_pairs > 0 {
                println!(
                    "pattern classes: {} — {} fresh (pattern, weight) requests \
                     ({:.1}x dedup); fitted pair growth n^{:.2} → {} pairs at full scale",
                    r.unique_patterns,
                    r.unique_pairs,
                    r.dedup_ratio(),
                    r.pair_growth_exp,
                    r.predicted_pairs_full
                );
                println!(
                    "pattern tables: {} batch-solved — resident {:.1} MiB, {} evicted \
                     (bounded session cache)",
                    r.pattern_tables,
                    r.resident_table_bytes as f64 / (1 << 20) as f64,
                    r.table_evictions
                );
            }
            if r.store_hits + r.store_misses > 0 {
                println!(
                    "solution store: {} table(s) served from the store, {} solved fresh \
                     and published",
                    r.store_hits, r.store_misses
                );
            }
            if let Some(path) = &trace_out {
                finish_trace_sink(path);
            }
        }
        "serve-batch" => {
            let cli = Cli::new("batched compile service: many chips, one warm session each")
                .opt("chips", "chip seeds", Some("1,2,3,4"))
                .opt("model", "layer-shape model", Some("resnet20"))
                .opt("config", "grouping config", Some("r2c2"))
                .opt("method", "complete|ilp|ff|unprotected", Some("complete"))
                .opt("limit", "max weights per chip", Some("60000"))
                .opt("threads", "total worker threads (0 = auto-detect)", Some("0"))
                .opt("cache-dir", "persist per-chip session caches (cross-run warm-start)", None)
                .opt(
                    "store-dir",
                    "fleet solution store directory (default <cache-dir>/store when caching)",
                    None,
                )
                .opt(
                    "table-budget",
                    "pattern-table memory: per-session | auto | fleet bytes (suffix k/m/g ok)",
                    Some("per-session"),
                )
                .opt("rounds", "batch rounds; round 2+ recompiles warm", Some("2"));
            let args = cli.parse(rest);
            let cfg = GroupConfig::parse(args.get_str("config", "r2c2"))
                .ok_or_else(|| anyhow::anyhow!("bad config"))?;
            let method = Method::parse(args.get_str("method", "complete"))
                .ok_or_else(|| anyhow::anyhow!("bad method"))?;
            let seeds: Vec<u64> =
                args.get_list("chips").iter().filter_map(|s| s.parse().ok()).collect();
            if seeds.is_empty() {
                anyhow::bail!("no chip seeds given");
            }
            let table_budget = parse_table_budget(args.get_str("table-budget", "per-session"))?;
            let tensors = synthetic_model_tensors(
                args.get_str("model", "resnet20"),
                &cfg,
                args.get_usize("limit", 60_000),
            )?;
            let mut opts = CompileOptions::new(cfg, method);
            opts.threads = args.get_threads("threads");
            let mut service = CompileService::new(ServiceOptions {
                opts,
                rates: FaultRates::paper_default(),
                table_budget,
                cache_dir: args.get("cache-dir").map(std::path::PathBuf::from),
                store_dir: args.get("store-dir").map(std::path::PathBuf::from),
            });
            for round in 1..=args.get_usize("rounds", 2).max(1) {
                for &seed in &seeds {
                    for (name, ws) in &tensors {
                        service.enqueue(seed, name, ws.clone());
                    }
                }
                let timer = Timer::start();
                let results = service.run()?;
                let secs = timer.secs();
                for e in service.persist_errors() {
                    eprintln!("warning: session cache not persisted — {e}");
                }
                let mut per_chip: BTreeMap<u64, CompileStats> = BTreeMap::new();
                for r in &results {
                    per_chip.entry(r.chip_seed).or_default().merge_with_wall(&r.tensor.stats);
                }
                let fresh: usize = per_chip.values().map(|s| s.unique_pairs).sum();
                let mut t = Table::new(
                    &format!(
                        "serve-batch round {round} — {} jobs / {} chips in {}{}",
                        results.len(),
                        per_chip.len(),
                        fmt_dur(secs),
                        if fresh == 0 { " (warm: every solve cached)" } else { "" },
                    ),
                    &["chip", "tensors", "weights", "classes", "fresh solves", "cache hits", "dedup"],
                );
                for (seed, st) in &per_chip {
                    t.row(vec![
                        seed.to_string(),
                        tensors.len().to_string(),
                        st.weights.to_string(),
                        st.unique_patterns.to_string(),
                        st.unique_pairs.to_string(),
                        st.dedup_hits.to_string(),
                        format!("{:.1}x", st.dedup_ratio()),
                    ]);
                }
                println!("{}", t.render());
                let store_hits: usize = per_chip.values().map(|s| s.store_hits).sum();
                let store_misses: usize = per_chip.values().map(|s| s.store_misses).sum();
                let sc = service.store().counters();
                if store_hits + store_misses > 0 || sc.rejected_blobs + sc.io_errors > 0 {
                    println!(
                        "solution store: {store_hits} pattern table(s) served from the \
                         fleet store, {store_misses} solved fresh and published \
                         ({} corrupt blob(s) rejected, {} I/O error(s))",
                        sc.rejected_blobs, sc.io_errors
                    );
                }
                let persist_failures = service.persist_errors().len();
                if persist_failures > 0 {
                    println!(
                        "persist: {persist_failures} session cache write(s) FAILED this round \
                         (see warnings above; warm state is retained in memory and retried \
                         next round)"
                    );
                }
                if let Some(total) = service.applied_table_budget() {
                    let shares: Vec<usize> = service
                        .sessions()
                        .filter_map(|(s, _)| service.session_table_budget(*s))
                        .collect();
                    let mib = |b: usize| b as f64 / (1 << 20) as f64;
                    let lo = shares.iter().copied().min().unwrap_or(0);
                    let hi = shares.iter().copied().max().unwrap_or(0);
                    println!(
                        "fleet table budget: {:.1} MiB across {} sessions \
                         (per-chip {:.1}–{:.1} MiB, split ∝ interned pattern count)",
                        mib(total),
                        shares.len(),
                        mib(lo),
                        mib(hi),
                    );
                }
            }
        }
        "serve" => {
            let cli = Cli::new("compile-fabric coordinator: accept jobs, schedule shard-solves on workers")
                .opt("listen", "listen address", Some("127.0.0.1:7077"))
                .opt("config", "grouping config", Some("r2c2"))
                .opt("method", "complete|ilp|ff|unprotected", Some("complete"))
                .opt("threads", "local worker threads (0 = auto-detect)", Some("0"))
                .opt("cache-dir", "persist per-chip session caches (cross-run warm-start)", None)
                .opt(
                    "store-dir",
                    "fleet solution store directory (default <cache-dir>/store when caching)",
                    None,
                )
                .opt(
                    "table-budget",
                    "pattern-table memory: per-session | auto | fleet bytes (suffix k/m/g ok)",
                    Some("per-session"),
                )
                .opt(
                    "shard-min-weights",
                    "fan a job out to workers only at/above this many weights",
                    Some("50000"),
                )
                .opt("max-shards", "max shard ranges per distributed job", Some("8"))
                .opt(
                    "worker-timeout-secs",
                    "seconds before a silent worker's range is reassigned",
                    Some("600"),
                )
                .opt(
                    "tensor-jobs",
                    "ship tensor sets to workers instead of sealed registry snapshots",
                    None,
                )
                .opt("trace-out", "write a JSON-lines span trace to this path", None);
            let args = cli.parse(rest);
            let cfg = GroupConfig::parse(args.get_str("config", "r2c2"))
                .ok_or_else(|| anyhow::anyhow!("bad config"))?;
            let method = Method::parse(args.get_str("method", "complete"))
                .ok_or_else(|| anyhow::anyhow!("bad method"))?;
            let mut opts = CompileOptions::new(cfg, method);
            opts.threads = args.get_threads("threads");
            let sopts = FabricServeOptions {
                service: ServiceOptions {
                    opts,
                    rates: FaultRates::paper_default(),
                    table_budget: parse_table_budget(args.get_str("table-budget", "per-session"))?,
                    cache_dir: args.get("cache-dir").map(std::path::PathBuf::from),
                    store_dir: args.get("store-dir").map(std::path::PathBuf::from),
                },
                shard_min_weights: args.get_usize("shard-min-weights", 50_000),
                max_shards: args.get_usize("max-shards", 8).max(1),
                worker_timeout: std::time::Duration::from_secs(
                    args.get_u64("worker-timeout-secs", 600).max(1),
                ),
                snapshot_dispatch: !args.get_bool("tensor-jobs"),
            };
            let trace_out = args.get("trace-out");
            if let Some(path) = &trace_out {
                install_trace_sink(path)?;
            }
            let server = FabricServer::bind(args.get_str("listen", "127.0.0.1:7077"), sopts)?;
            println!(
                "rchg fabric: listening on {} ({} {:?}) — add workers with \
                 `rchg worker --connect {0}`, submit with `rchg submit --connect {0}`, \
                 stop with `rchg submit --connect {0} --shutdown`",
                server.local_addr(),
                cfg,
                method,
            );
            // `run` consumes the server; keep a store handle for the
            // shutdown summary (the handle shares the live counters).
            let store = server.store();
            let stats = server.run()?;
            println!(
                "fabric stopped: {} jobs ({} distributed, {} via registry snapshots), \
                 {} workers joined, {} shard ranges dispatched, {} reassigned after worker loss",
                stats.jobs,
                stats.distributed_jobs,
                stats.snapshot_rounds,
                stats.workers_joined,
                stats.shards_dispatched,
                stats.reassignments,
            );
            let sc = store.counters();
            if sc.hits + sc.misses + sc.publishes + sc.rejected_blobs + sc.io_errors > 0 {
                println!(
                    "solution store: {} hit(s) / {} miss(es), {} published, {} evicted, \
                     {} corrupt blob(s) rejected, {} I/O error(s)",
                    sc.hits, sc.misses, sc.publishes, sc.evictions, sc.rejected_blobs, sc.io_errors
                );
            }
            if let Some(path) = &trace_out {
                finish_trace_sink(path);
            }
        }
        "worker" => {
            let cli = Cli::new("fabric worker: solve shard jobs handed down by a coordinator")
                .opt("connect", "coordinator address", Some("127.0.0.1:7077"))
                .opt("threads", "solve threads (0 = auto-detect)", Some("0"));
            let args = cli.parse(rest);
            let addr = args.get_str("connect", "127.0.0.1:7077");
            println!("rchg worker: connecting to coordinator {addr}");
            let report = run_worker(addr, args.get_threads("threads"))?;
            println!(
                "worker done: {} shard job(s) solved ({} pattern classes, {} store hit(s), \
                 {} table(s) published); coordinator hung up",
                report.jobs, report.patterns_solved, report.store_hits, report.store_published,
            );
            if !report.metrics.is_empty() {
                print!("{}", report.metrics.render());
            }
        }
        "chaos" => {
            let cli = Cli::new(
                "seeded chaos soak: run randomized failpoint schedules against localhost fleets \
                 and check every job ends byte-identical or with a typed error",
            )
            .opt("seed", "base schedule seed (each seed replays exactly)", Some("1"))
            .opt("seeds", "number of consecutive seeds to run", Some("1"))
            .opt("scenarios", "random scenarios per seed", Some("4"))
            .opt("weights", "synthetic model size per job", Some("900"));
            let args = cli.parse(rest);
            run_chaos(
                args.get_u64("seed", 1),
                args.get_u64("seeds", 1),
                args.get_usize("scenarios", 4),
                args.get_usize("weights", 900),
            )?;
        }
        "submit" => {
            let cli = Cli::new("send a compile job to a fabric coordinator")
                .opt("connect", "coordinator address", Some("127.0.0.1:7077"))
                .opt("model", "layer-shape model", Some("resnet20"))
                .opt("config", "grouping config (must match the coordinator)", Some("r2c2"))
                .opt("method", "complete|ilp|ff|unprotected", Some("complete"))
                .opt("chip", "chip seed", Some("1"))
                .opt("limit", "max weights", Some("60000"))
                .opt("fetch-session", "also download the chip's warm RCSS cache to this path", None)
                .opt("info", "print fabric status instead of compiling", None)
                .opt("stats", "print the coordinator's live metrics instead of compiling", None)
                .opt("shutdown", "stop the coordinator when done", None);
            let args = cli.parse(rest);
            let addr = args.get_str("connect", "127.0.0.1:7077");
            let mut client = CompileClient::connect(addr)?;
            if args.get_bool("info") {
                let i = client.info()?;
                println!(
                    "fabric {addr}: {} idle worker(s), {} warm session(s), {} job(s) served \
                     ({} distributed, {} shard reassignments)",
                    i.workers, i.sessions, i.jobs, i.distributed_jobs, i.reassignments,
                );
            } else if args.get_bool("stats") {
                print!("{}", client.stats()?.render());
            } else {
                let cfg = GroupConfig::parse(args.get_str("config", "r2c2"))
                    .ok_or_else(|| anyhow::anyhow!("bad config"))?;
                let method = Method::parse(args.get_str("method", "complete"))
                    .ok_or_else(|| anyhow::anyhow!("bad method"))?;
                let seed = args.get_u64("chip", 1);
                let tensors = synthetic_model_tensors(
                    args.get_str("model", "resnet20"),
                    &cfg,
                    args.get_usize("limit", 60_000),
                )?;
                let timer = Timer::start();
                let (results, summary) = client.compile_model(seed, cfg, method, &tensors)?;
                let secs = timer.secs();
                println!(
                    "chip {seed}: {} tensors / {} weights compiled in {} — {} fresh solve(s){}",
                    summary.tensors,
                    summary.weights,
                    fmt_dur(secs),
                    summary.fresh_solves,
                    if summary.shards > 0 {
                        format!(
                            ", fanned out as {} shard range(s) over {} worker(s) \
                             ({} reassigned after loss)",
                            summary.shards, summary.workers, summary.reassigned
                        )
                    } else {
                        " (compiled on the coordinator)".to_string()
                    },
                );
                let imperfect: usize = results
                    .iter()
                    .flat_map(|r| r.errors.iter())
                    .filter(|&&e| e != 0)
                    .count();
                println!(
                    "residual: {imperfect} of {} weights imperfect ({:.4}%)",
                    summary.weights,
                    100.0 * imperfect as f64 / (summary.weights.max(1)) as f64,
                );
                if let Some(path) = args.get("fetch-session") {
                    let bytes = client.fetch_session(seed)?;
                    let path = std::path::PathBuf::from(path);
                    if let Some(parent) = path.parent() {
                        std::fs::create_dir_all(parent).ok();
                    }
                    std::fs::write(&path, &bytes)?;
                    println!(
                        "fetched warm session cache: {} bytes → {}",
                        bytes.len(),
                        path.display()
                    );
                }
            }
            if args.get_bool("shutdown") {
                client.shutdown_server()?;
                println!("fabric {addr}: shutdown requested");
            }
        }
        "top" => {
            let cli = Cli::new("scrape a fabric coordinator's live metrics registry")
                .opt("connect", "coordinator address", Some("127.0.0.1:7077"))
                .opt("watch", "keep scraping until interrupted", None)
                .opt("interval-secs", "seconds between scrapes with --watch", Some("2"));
            let args = cli.parse(rest);
            let addr = args.get_str("connect", "127.0.0.1:7077");
            let interval =
                std::time::Duration::from_secs(args.get_u64("interval-secs", 2).max(1));
            loop {
                // One connection per scrape, so a coordinator that stops
                // mid-watch ends the loop with a clean connect error.
                let mut client = CompileClient::connect(addr)?;
                let snap = client.stats()?;
                println!("fabric {addr} — {} metric(s)", snap.len());
                print!("{}", snap.render());
                if !args.get_bool("watch") {
                    break;
                }
                std::thread::sleep(interval);
                println!();
            }
        }
        "trace-check" => {
            let cli = Cli::new("validate a --trace-out JSON-lines trace dump")
                .opt("file", "trace path", Some("trace.jsonl"));
            let args = cli.parse(rest);
            let path = args.get_str("file", "trace.jsonl");
            let text = std::fs::read_to_string(path)?;
            let n = obs::validate_trace(&text)
                .map_err(|e| anyhow::anyhow!("{path}: invalid trace: {e}"))?;
            println!("{path}: {} ok ({n} record(s))", obs::TRACE_SCHEMA);
        }
        "shard-solve" => {
            let cli = Cli::new("solve shard k/K of one chip's compile (fan one chip out)")
                .opt("model", "layer-shape model", Some("resnet20"))
                .opt("config", "grouping config", Some("r2c2"))
                .opt("method", "complete|ilp|ff|unprotected", Some("complete"))
                .opt("chip", "chip seed", Some("1"))
                .opt("limit", "max weights", Some("60000"))
                .opt("threads", "worker threads (0 = auto-detect)", Some("0"))
                .opt("shard", "shard index as k/K, 1-based (e.g. 2/4)", Some("1/1"))
                .opt("out", "fragment path (default shards/chip-<seed>-<k>of<K>.rcsf)", None);
            let args = cli.parse(rest);
            let cfg = GroupConfig::parse(args.get_str("config", "r2c2"))
                .ok_or_else(|| anyhow::anyhow!("bad config"))?;
            let method = Method::parse(args.get_str("method", "complete"))
                .ok_or_else(|| anyhow::anyhow!("bad method"))?;
            let (k, total) = parse_shard_spec(args.get_str("shard", "1/1"))?;
            let seed = args.get_u64("chip", 1);
            let tensors = synthetic_model_tensors(
                args.get_str("model", "resnet20"),
                &cfg,
                args.get_usize("limit", 60_000),
            )?;
            let chip = rchg::fault::bank::ChipFaults::new(seed, FaultRates::paper_default());
            let mut session = CompileSession::builder(cfg)
                .method(method)
                .threads(args.get_threads("threads"))
                .chip(&chip);
            for (name, ws) in &tensors {
                session.submit(name, ws.clone());
            }
            let plan = ShardPlan::new(total);
            let timer = Timer::start();
            let fragment = session.solve_shard(&plan, k - 1)?;
            let path = args
                .get("out")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(|| {
                    std::path::PathBuf::from(format!("shards/chip-{seed}-{k}of{total}.rcsf"))
                });
            fragment.save(&path)?;
            println!(
                "shard {k}/{total} of chip {seed}: solved {} of {} pattern classes \
                 (ids {:?} of {}) in {} → {}",
                fragment.solved_patterns(),
                fragment.range().len(),
                fragment.range(),
                fragment.total_patterns(),
                fmt_dur(timer.secs()),
                path.display(),
            );
        }
        "merge-shards" => {
            let cli = Cli::new("reassemble shard fragments into one warm session cache")
                .opt("frags", "comma-separated fragment paths (all K shards)", None)
                .opt("out", "merged session cache path", Some("shards/merged.rcs"))
                .opt("verify-model", "recompile this model after merging; must solve nothing", None)
                .opt("limit", "max weights for --verify-model", Some("60000"));
            let args = cli.parse(rest);
            let paths = args.get_list("frags");
            if paths.is_empty() {
                anyhow::bail!("no fragments given — pass --frags a.rcsf,b.rcsf,…");
            }
            let fragments: Vec<ShardFragment> = paths
                .iter()
                .map(|p| ShardFragment::load(std::path::Path::new(p)))
                .collect::<anyhow::Result<_>>()?;
            // The fragment key carries the whole session identity, so the
            // merge coordinator needs no model/config flags at all.
            let mut session = CompileSession::from_fragments(&fragments)?;
            let out = std::path::PathBuf::from(args.get_str("out", "shards/merged.rcs"));
            session.save(&out)?;
            println!(
                "merged {} fragments: {} pattern classes, {} solved pairs → {}",
                fragments.len(),
                session.pattern_classes(),
                session.solved_pairs(),
                out.display(),
            );
            if let Some(model) = args.get("verify-model") {
                let cfg = session.options().cfg;
                let tensors =
                    synthetic_model_tensors(model, &cfg, args.get_usize("limit", 60_000))?;
                for (name, ws) in &tensors {
                    session.submit(name, ws.clone());
                }
                let compiled = session.drain();
                let fresh: usize =
                    compiled.iter().map(|(_, t)| t.stats.unique_pairs).sum();
                let weights: usize = compiled.iter().map(|(_, t)| t.decomps.len()).sum();
                println!(
                    "verify: {} tensors / {} weights recompiled with {} fresh solves{}",
                    compiled.len(),
                    weights,
                    fresh,
                    if fresh == 0 { " (fully warm)" } else { " — fragments did not cover the model!" },
                );
                if fresh > 0 {
                    anyhow::bail!("merged cache was not warm for {model}");
                }
            }
        }
        "energy" => {
            let cli = Cli::new("energy sweep (Fig 11)")
                .opt("model", "network", Some("resnet20"))
                .opt("sizes", "array sizes", Some("64,128,256,512"))
                .opt("packed", "packed mapper ablation", None);
            let args = cli.parse(rest);
            let policy = if args.get_bool("packed") {
                MapperPolicy::PackedVertical
            } else {
                MapperPolicy::KernelSplit
            };
            let sizes: Vec<usize> =
                args.get_list("sizes").iter().filter_map(|s| s.parse().ok()).collect();
            println!(
                "{}",
                fig11(args.get_str("model", "resnet20"), &sizes, &EnergyParams::default(), policy)?
                    .render()
            );
        }
        "inconsecutivity" => {
            let cli = Cli::new("Fig 6 Monte-Carlo")
                .opt("samples", "samples", Some("1000000"))
                .opt("seed", "seed", Some("99"));
            let args = cli.parse(rest);
            println!(
                "{}",
                fig6(
                    &[GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4],
                    args.get_usize("samples", 1_000_000),
                    args.get_u64("seed", 99)
                )
                .render()
            );
        }
        _ => {
            println!(
                "rchg — row-column hybrid grouping compiler + IMC fault simulator\n\n\
                 subcommands:\n\
                 \x20 info             runtime + artifact info\n\
                 \x20 tables           regenerate all paper tables/figures (fast set)\n\
                 \x20 compile          compile a model for one chip (timing)\n\
                 \x20 serve-batch      batched compile service over many chips (warm sessions)\n\
                 \x20 serve            compile-fabric coordinator daemon (schedules shard-solves on workers)\n\
                 \x20 worker           fabric worker: solve shard jobs for a coordinator\n\
                 \x20 submit           send a compile job to a fabric coordinator\n\
                 \x20 top              scrape a coordinator's live metrics registry (--watch to follow)\n\
                 \x20 trace-check      validate a --trace-out JSON-lines trace dump\n\
                 \x20 shard-solve      solve shard k/K of one chip's compile (fan one chip out)\n\
                 \x20 merge-shards     reassemble shard fragments into a warm session cache\n\
                 \x20 chaos            seeded fault-injection soak (needs --features failpoints)\n\
                 \x20 eval-cnn         Table I / Fig 8 / Fig 9\n\
                 \x20 eval-lm          Table III\n\
                 \x20 compile-time     Table II / Fig 10\n\
                 \x20 bench            per-PR perf harness: seeded workloads → BENCH_<n>.json\n\
                 \x20 energy           Fig 11\n\
                 \x20 inconsecutivity  Fig 6\n\n\
                 run `rchg <subcommand> --help` for options"
            );
        }
    }
    Ok(())
}

/// Install the JSON-lines trace sink behind `--trace-out`.
fn install_trace_sink(path: &str) -> anyhow::Result<()> {
    let sink = obs::FileSink::create(std::path::Path::new(path))
        .map_err(|e| anyhow::anyhow!("create trace file {path}: {e}"))?;
    obs::set_sink(Some(Box::new(sink)));
    Ok(())
}

/// Remove the trace sink (flushing the file) and report what was written.
fn finish_trace_sink(path: &str) {
    let n = obs::set_sink(None);
    eprintln!("trace: {n} record(s) written to {path}");
}

/// Parse the `--table-budget` policy shared by `serve-batch` and `serve`:
/// `per-session`, `auto`, or a fleet byte size (k/m/g suffixes ok).
fn parse_table_budget(s: &str) -> anyhow::Result<TableBudget> {
    Ok(match s {
        "per-session" => TableBudget::PerSession,
        "auto" => TableBudget::Auto,
        s => TableBudget::Fleet(rchg::util::mem::parse_size_bytes(s).ok_or_else(|| {
            anyhow::anyhow!("bad --table-budget {s:?} (per-session | auto | bytes)")
        })?),
    })
}

/// `rchg chaos` soak loop: randomized failpoint schedules against
/// throwaway localhost fleets, one report line per seed. Every scenario
/// must end byte-identical to a fault-free compile or with a typed error
/// — the first violation aborts with the failing `(seed, scenario)` so
/// the run can be replayed exactly.
#[cfg(feature = "failpoints")]
fn run_chaos(seed: u64, seeds: u64, scenarios: usize, weights: usize) -> anyhow::Result<()> {
    use rchg::net::chaos;
    let t = Timer::start();
    let mut completed = 0usize;
    let mut typed_errors = 0usize;
    for s in seed..seed + seeds.max(1) {
        let report = chaos::run_seed(s, scenarios, weights)?;
        println!(
            "chaos seed {s}: {} scenario(s), {} completed byte-identical, {} typed error(s)",
            report.scenarios, report.completed, report.typed_errors
        );
        completed += report.completed;
        typed_errors += report.typed_errors;
    }
    println!(
        "chaos: invariant held across {} scenario(s) ({completed} completed, {typed_errors} \
         typed errors) in {}",
        completed + typed_errors,
        fmt_dur(t.secs()),
    );
    Ok(())
}

/// Feature-off stub for `rchg chaos`: the hooks compile to no-ops in
/// this binary, so there is nothing to inject.
#[cfg(not(feature = "failpoints"))]
fn run_chaos(_seed: u64, _seeds: u64, _scenarios: usize, _weights: usize) -> anyhow::Result<()> {
    anyhow::bail!(
        "this rchg was built without the `failpoints` feature; rebuild with \
         `cargo build --release --features failpoints` to run the chaos soak"
    )
}

/// Parse the `--shard k/K` spec (1-based index, e.g. `2/4`).
fn parse_shard_spec(s: &str) -> anyhow::Result<(usize, usize)> {
    let bad = || anyhow::anyhow!("bad --shard {s:?}: expected k/K with 1 <= k <= K, e.g. 2/4");
    let (k, total) = s.split_once('/').ok_or_else(bad)?;
    let k: usize = k.trim().parse().map_err(|_| bad())?;
    let total: usize = total.trim().parse().map_err(|_| bad())?;
    if k == 0 || total == 0 || k > total {
        return Err(bad());
    }
    Ok((k, total))
}
