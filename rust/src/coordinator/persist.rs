//! Shared on-disk framing for the coordinator's warm-state files.
//!
//! Two file formats are built from the codecs here and share every byte of
//! their framing:
//!
//! * the **session cache** ("RCSS" v2, [`super::session`]) — one chip's
//!   full warm solve state;
//! * the **shard fragment** ("RCSF" v1, [`super::shard`]) — one shard's
//!   partial solve state from a [`super::ShardPlan`]-partitioned solve,
//!   mergeable back into a session cache byte-identical to an unsharded
//!   compile.
//!
//! Both are versioned little-endian binaries that open with the same
//! **cache key** ([`write_key`]/[`read_key`]: chip seed + fault rates,
//! [`GroupConfig`], pipeline fingerprint), carry per-pattern
//! [`PatternSolution`]s in pattern-id order, and close with a trailing
//! FNV-1a checksum over everything before it ([`seal`]/[`unseal`]). The
//! checksum is verified *before* any parsing, so a truncated or corrupted
//! file is rejected without ever touching the decoder.
//!
//! The network fabric's wire protocol ("RCWP" v1, [`crate::net`]) is the
//! third consumer of these codecs: shard-job payloads open with the same
//! cache-key layout, shard results travel as verbatim RCSF fragment
//! bytes, and session fetches as verbatim RCSS files — one codec across
//! disk and wire.
//!
//! Everything here is `pub(crate)`: the public surface is
//! `CompileSession::{save,load,to_bytes,from_bytes}`,
//! `ShardFragment::{save,load,to_bytes,from_bytes}`, and the
//! [`crate::net::protocol`] payload codecs built on top.

use super::classes::PatternSolution;
use super::pipeline::{Method, Outcome, PipelineOptions, Stage};
use crate::fault::bank::ChipFaults;
use crate::fault::{FaultRates, FaultState, GroupFaults};
use crate::grouping::{Bitmap, Decomposition, GroupConfig};
use crate::util::fnv::FnvMap;
use crate::util::prop::fnv1a;
use anyhow::{anyhow, bail, Result};

/// Per-pattern solution tags shared by the RCSS v2 and RCSF formats.
pub(crate) const TAG_TABLE: u8 = 0;
pub(crate) const TAG_PAIRS: u8 = 1;
/// Fragment-only tag: a pattern in the shard's range with no solution in
/// this fragment (already resident before the shard solved, or empty).
pub(crate) const TAG_EMPTY: u8 = 2;

pub(crate) fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
pub(crate) fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}
pub(crate) fn push_i64(buf: &mut Vec<u8>, v: i64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append the trailing FNV-1a checksum, sealing the payload.
pub(crate) fn seal(mut buf: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a(&buf);
    push_u64(&mut buf, sum);
    buf
}

/// Verify the trailing checksum and return the payload it covers. This
/// runs before any parsing: corruption anywhere in the file is caught
/// here, never inside the decoder.
pub(crate) fn unseal(bytes: &[u8]) -> Result<&[u8]> {
    if bytes.len() < 16 {
        bail!("truncated cache file ({} bytes)", bytes.len());
    }
    let (payload, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    if fnv1a(payload) != stored {
        bail!("cache checksum mismatch (corrupted or truncated file)");
    }
    Ok(payload)
}

/// The identity a warm-state file is keyed by: the chip (seed + fault
/// rates), the grouping configuration, and the pipeline fingerprint
/// (method + table limit + sparsest). Two files with equal keys hold
/// interchangeable solve state; everything else must be rebuilt, never
/// silently adopted.
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct CacheKey {
    pub chip: ChipFaults,
    pub cfg: GroupConfig,
    pub pipeline: PipelineOptions,
}

impl CacheKey {
    pub(crate) fn new(chip: &ChipFaults, cfg: GroupConfig, pipeline: PipelineOptions) -> CacheKey {
        CacheKey { chip: chip.clone(), cfg, pipeline }
    }

    pub(crate) fn cells(&self) -> usize {
        self.cfg.cells()
    }

    /// Human-readable mismatch description, or `None` when keys agree —
    /// the error message of every "wrong file for this session" rejection.
    pub(crate) fn mismatch(&self, other: &CacheKey) -> Option<String> {
        if self.chip.chip_seed != other.chip.chip_seed {
            return Some(format!(
                "chip seed {} != {}",
                other.chip.chip_seed, self.chip.chip_seed
            ));
        }
        if self.chip.rates != other.chip.rates {
            return Some("fault rates differ".into());
        }
        if self.cfg != other.cfg {
            return Some(format!("grouping config {} != {}", other.cfg, self.cfg));
        }
        if self.pipeline != other.pipeline {
            return Some("pipeline fingerprint (method/table limit/sparsest) differs".into());
        }
        None
    }
}

/// Serialize the cache key. Byte layout (all little-endian) is shared by
/// RCSS v2 and RCSF v1 and must never be reordered:
/// `chip_seed u64 · p_sa0 u64 · p_sa1 u64 · rows u32 · cols u32 ·
/// levels u32 · method u8 · sparsest u8 · table_value_limit i64 ·
/// cells u32`.
pub(crate) fn write_key(buf: &mut Vec<u8>, key: &CacheKey) {
    push_u64(buf, key.chip.chip_seed);
    push_u64(buf, key.chip.rates.p_sa0.to_bits());
    push_u64(buf, key.chip.rates.p_sa1.to_bits());
    push_u32(buf, key.cfg.rows as u32);
    push_u32(buf, key.cfg.cols as u32);
    push_u32(buf, key.cfg.levels as u32);
    buf.push(key.pipeline.method.code());
    buf.push(key.pipeline.sparsest as u8);
    push_i64(buf, key.pipeline.table_value_limit);
    push_u32(buf, key.cfg.cells() as u32);
}

/// Parse and validate a cache key (see [`write_key`] for the layout). A
/// corrupt header must not overflow `max_per_array` or provoke absurd
/// table allocations, so the weight range is recomputed with checked
/// arithmetic and bounded.
pub(crate) fn read_key(r: &mut Reader<'_>) -> Result<CacheKey> {
    let chip_seed = r.u64()?;
    let p_sa0 = f64::from_bits(r.u64()?);
    let p_sa1 = f64::from_bits(r.u64()?);
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let levels = r.u32()?;
    if rows == 0 || cols == 0 || !(2..=255).contains(&levels) {
        bail!("bad grouping config R{rows}C{cols}@{levels} in cache file");
    }
    let cfg = GroupConfig::new(rows, cols, levels as u8);
    let method =
        Method::from_code(r.u8()?).ok_or_else(|| anyhow!("bad method code in cache file"))?;
    let sparsest = r.u8()? != 0;
    let table_value_limit = r.i64()?;
    let pipeline = PipelineOptions { method, table_value_limit, sparsest };
    let cells = r.u32()? as usize;
    if cells != cfg.cells() || cells == 0 || cells > 16 {
        bail!("cell count {cells} disagrees with config {cfg}");
    }
    (levels as i64)
        .checked_pow(cols as u32)
        .and_then(|p| p.checked_sub(1))
        .and_then(|p| p.checked_mul(rows as i64))
        .filter(|&m| m > 0 && m <= (1 << 24))
        .ok_or_else(|| anyhow!("unreasonable weight range in cache file"))?;
    let chip = ChipFaults::new(chip_seed, FaultRates { p_sa0, p_sa1 });
    Ok(CacheKey { chip, cfg, pipeline })
}

/// Dense-table length of one full-range pattern solution under `cfg`.
pub(crate) fn table_len(cfg: &GroupConfig) -> usize {
    (2 * cfg.max_per_array() + 1) as usize
}

/// Serialized size of one [`Outcome`]: error i64 + stage u8 + two cell
/// bitmaps.
pub(crate) fn outcome_len(cells: usize) -> usize {
    9 + 2 * cells
}

pub(crate) fn push_outcome(buf: &mut Vec<u8>, out: &Outcome) {
    push_i64(buf, out.error);
    buf.push(out.stage.code());
    buf.extend_from_slice(&out.decomposition.pos.cells);
    buf.extend_from_slice(&out.decomposition.neg.cells);
}

pub(crate) fn read_outcome(r: &mut Reader<'_>, cells: usize, levels: u8) -> Result<Outcome> {
    let error = r.i64()?;
    let stage =
        Stage::from_code(r.u8()?).ok_or_else(|| anyhow!("bad stage code in cache file"))?;
    let pos = Bitmap { cells: r.bytes(cells)?.to_vec() };
    let neg = Bitmap { cells: r.bytes(cells)?.to_vec() };
    if pos.cells.iter().chain(&neg.cells).any(|&v| v >= levels) {
        bail!("cell value exceeds {levels} levels in cache file");
    }
    Ok(Outcome { decomposition: Decomposition { pos, neg }, error, stage })
}

/// Serialize one pattern's fault bytes followed by its solution. The
/// solution body is tagged: [`TAG_TABLE`] is a dense full-range table with
/// implicit length ([`table_len`]) and the weight implicit in the index;
/// [`TAG_PAIRS`] is a count followed by (weight, outcome) entries sorted
/// by weight. `None` writes [`TAG_EMPTY`] (fragment files only).
pub(crate) fn write_pattern_solution(
    buf: &mut Vec<u8>,
    pattern: &GroupFaults,
    solution: Option<&PatternSolution>,
) {
    for f in pattern.pos.iter().chain(&pattern.neg) {
        buf.push(*f as u8);
    }
    match solution {
        Some(PatternSolution::Table(t)) => {
            buf.push(TAG_TABLE);
            for out in t {
                push_outcome(buf, out);
            }
        }
        Some(PatternSolution::Pairs(m)) => {
            buf.push(TAG_PAIRS);
            push_u32(buf, m.len() as u32);
            let mut ws: Vec<i64> = m.keys().copied().collect();
            ws.sort_unstable();
            for w in ws {
                push_i64(buf, w);
                push_outcome(buf, &m[&w]);
            }
        }
        None => buf.push(TAG_EMPTY),
    }
}

/// Parse one pattern + solution written by [`write_pattern_solution`].
/// `allow_empty` admits [`TAG_EMPTY`] (fragments); the session cache
/// rejects it — a saved session never carries unsolved patterns.
pub(crate) fn read_pattern_solution(
    r: &mut Reader<'_>,
    key: &CacheKey,
    allow_empty: bool,
) -> Result<(GroupFaults, Option<PatternSolution>)> {
    let cells = key.cells();
    let levels = key.cfg.levels;
    let pos = r.fault_states(cells)?;
    let neg = r.fault_states(cells)?;
    let pattern = GroupFaults { pos, neg };
    let o_len = outcome_len(cells);
    let solution = match r.u8()? {
        TAG_TABLE => {
            let t_len = table_len(&key.cfg);
            if r.remaining() < t_len * o_len {
                bail!("cache file truncated inside a pattern table");
            }
            let mut outcomes = Vec::with_capacity(t_len);
            for _ in 0..t_len {
                outcomes.push(read_outcome(r, cells, levels)?);
            }
            Some(PatternSolution::Table(outcomes))
        }
        TAG_PAIRS => {
            let n = r.u32()? as usize;
            if n == 0 {
                bail!("empty pattern solution in cache file");
            }
            if r.remaining() < n * o_len {
                bail!("cache file truncated inside pattern pairs");
            }
            let mut m: FnvMap<i64, Outcome> = FnvMap::default();
            for _ in 0..n {
                let w = r.i64()?;
                let out = read_outcome(r, cells, levels)?;
                if m.insert(w, out).is_some() {
                    bail!("duplicate solved weight {w} in cache file");
                }
            }
            Some(PatternSolution::Pairs(m))
        }
        TAG_EMPTY if allow_empty => None,
        t => bail!("bad pattern solution tag {t} in cache file"),
    };
    Ok((pattern, solution))
}

/// Registry snapshot magic, "RCRG" big-endian.
pub(crate) const SNAPSHOT_MAGIC: u32 = 0x5243_5247;
pub(crate) const SNAPSHOT_VERSION: u32 = 1;

/// Serialize a post-scan pattern registry ("RCRG" v1): canonical pattern
/// fault bytes in id order under the shared cache-key header, sealed with
/// the trailing FNV-1a checksum. Layout: `magic u32 · version u32 ·
/// cache key ([`write_key`]) · n_patterns u32 · n × (pos cells · neg
/// cells, one [`FaultState`] byte each) · checksum u64`. Re-interning the
/// decoded patterns in order reproduces the coordinator's pattern ids
/// exactly — that contract is what lets a fabric worker rebuild the
/// registry without the tensor set or a re-scan
/// ([`super::CompileSession::solve_shard_from_snapshot`]).
pub(crate) fn encode_registry_snapshot(
    key: &CacheKey,
    registry: &super::classes::PatternRegistry,
) -> Vec<u8> {
    debug_assert_eq!(*registry.cfg(), key.cfg);
    let cells = key.cells();
    let n = registry.len();
    let mut buf = Vec::with_capacity(58 + 4 + n * 2 * cells + 8);
    push_u32(&mut buf, SNAPSHOT_MAGIC);
    push_u32(&mut buf, SNAPSHOT_VERSION);
    write_key(&mut buf, key);
    push_u32(&mut buf, n as u32);
    for p in registry.patterns() {
        debug_assert_eq!(p.pos.len(), cells);
        for f in p.pos.iter().chain(&p.neg) {
            buf.push(*f as u8);
        }
    }
    seal(buf)
}

/// Parse and validate an "RCRG" v1 registry snapshot (see
/// [`encode_registry_snapshot`]). The checksum is verified before any
/// parsing; the byte count must agree exactly with the declared pattern
/// count. Duplicate patterns are not rejected here — the re-interning
/// consumer catches them as a non-sequential id.
pub(crate) fn decode_registry_snapshot(bytes: &[u8]) -> Result<(CacheKey, Vec<GroupFaults>)> {
    let payload = unseal(bytes)?;
    let mut r = Reader::new(payload);
    if r.u32()? != SNAPSHOT_MAGIC {
        bail!("not a registry snapshot (bad magic)");
    }
    let version = r.u32()?;
    if version != SNAPSHOT_VERSION {
        bail!("unsupported registry snapshot version {version} (expected {SNAPSHOT_VERSION})");
    }
    let key = read_key(&mut r)?;
    let cells = key.cells();
    let n = r.u32()? as usize;
    if r.remaining() != n * 2 * cells {
        bail!("registry snapshot size disagrees with its pattern count");
    }
    let mut patterns = Vec::with_capacity(n);
    for _ in 0..n {
        let pos = r.fault_states(cells)?;
        let neg = r.fault_states(cells)?;
        patterns.push(GroupFaults { pos, neg });
    }
    Ok((key, patterns))
}

/// Bounds-checked little-endian reader over a sealed payload.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("truncated cache file");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub(crate) fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub(crate) fn fault_states(&mut self, n: usize) -> Result<Vec<FaultState>> {
        self.bytes(n)?
            .iter()
            .map(|&b| FaultState::from_u8(b).ok_or_else(|| anyhow!("bad fault state byte {b}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seal_unseal_roundtrip_and_rejection() {
        let payload = vec![1u8, 2, 3, 4, 5, 6, 7, 8, 9];
        let sealed = seal(payload.clone());
        assert_eq!(unseal(&sealed).unwrap(), &payload[..]);
        // Any flipped byte (payload or checksum) is caught before parsing.
        for i in 0..sealed.len() {
            let mut bad = sealed.clone();
            bad[i] ^= 0x40;
            assert!(unseal(&bad).is_err(), "flip at {i} must be rejected");
        }
        assert!(unseal(&sealed[..sealed.len() - 1]).is_err());
        assert!(unseal(&[]).is_err());
    }

    #[test]
    fn registry_snapshot_roundtrip_and_rejection() {
        use super::super::classes::PatternRegistry;

        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(9, FaultRates::paper_default());
        let key = CacheKey::new(&chip, cfg, PipelineOptions::default());
        let mut registry = PatternRegistry::new(cfg);
        let faults = chip.sample_tensor(0, 400, cfg.cells());
        registry.intern_all(&faults);
        assert!(registry.len() > 1);

        let bytes = encode_registry_snapshot(&key, &registry);
        let (back_key, patterns) = decode_registry_snapshot(&bytes).unwrap();
        assert_eq!(back_key, key);
        assert_eq!(patterns.len(), registry.len());
        assert!(registry.patterns().eq(patterns.iter()), "id order must round-trip");
        // Re-interning reproduces the same ids.
        let mut rebuilt = PatternRegistry::new(cfg);
        for (i, p) in patterns.iter().enumerate() {
            assert_eq!(rebuilt.intern(p) as usize, i);
        }

        // Corruption anywhere (including the checksum) is rejected.
        for i in (0..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x20;
            assert!(decode_registry_snapshot(&bad).is_err(), "flip at {i} must be rejected");
        }
        // Truncation at every prefix is rejected.
        for len in [0, 8, 15, 16, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_registry_snapshot(&bytes[..len]).is_err());
        }
        // Wrong magic / unsupported version (re-sealed so the checksum
        // passes and the header check itself fires).
        let payload = unseal(&bytes).unwrap().to_vec();
        let mut wrong_magic = payload.clone();
        wrong_magic[0] ^= 1;
        let err = decode_registry_snapshot(&seal(wrong_magic)).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        let mut wrong_version = payload.clone();
        wrong_version[4] = 99;
        let err = decode_registry_snapshot(&seal(wrong_version)).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // Declared count disagreeing with the byte count is rejected.
        let mut short_count = payload;
        let count_at = 8 + 50; // magic + version + cache key
        short_count[count_at] = short_count[count_at].wrapping_sub(1);
        assert!(decode_registry_snapshot(&seal(short_count)).is_err());
    }

    #[test]
    fn golden_rcrg_snapshot_matches_the_blessed_bytes() {
        use super::super::classes::PatternRegistry;

        // Generated independently of this encoder by
        // `tests/fixtures/make_fixtures.py`: chip 7, paper rates, R2C2,
        // default pipeline, two patterns (all-free; pos[0]=SA0,
        // neg[3]=SA1). Pins the RCRG v1 byte layout itself, not just the
        // round-trip.
        const RCRG: &[u8] = include_bytes!("../../tests/fixtures/rcrg_v1_snapshot.bin");

        let (key, patterns) = decode_registry_snapshot(RCRG).expect("golden snapshot must parse");
        assert_eq!(key.chip.chip_seed, 7);
        assert_eq!(key.chip.rates, FaultRates::paper_default());
        assert_eq!(key.cfg, GroupConfig::R2C2);
        assert_eq!(key.pipeline, PipelineOptions::default());
        assert_eq!(patterns.len(), 2);
        assert_eq!(patterns[0], GroupFaults::free(4));
        assert_eq!(patterns[1].pos[0], FaultState::Sa0);
        assert_eq!(patterns[1].neg[3], FaultState::Sa1);

        // Re-interning the decoded patterns and re-encoding must land on
        // the exact golden bytes.
        let mut registry = PatternRegistry::new(key.cfg);
        for (i, p) in patterns.iter().enumerate() {
            assert_eq!(registry.intern(p) as usize, i);
        }
        assert_eq!(
            encode_registry_snapshot(&key, &registry),
            RCRG,
            "the snapshot encoder no longer produces the golden RCRG bytes"
        );

        // Corruption anywhere is rejected before parsing.
        for i in 0..RCRG.len() {
            let mut bad = RCRG.to_vec();
            bad[i] ^= 0xff;
            assert!(decode_registry_snapshot(&bad).is_err(), "flip at {i} must be rejected");
        }
    }

    #[test]
    fn key_roundtrip_and_mismatch_reporting() {
        let chip = ChipFaults::new(42, FaultRates::paper_default());
        let key = CacheKey::new(&chip, GroupConfig::R2C2, PipelineOptions::default());
        let mut buf = Vec::new();
        write_key(&mut buf, &key);
        let mut r = Reader::new(&buf);
        let back = read_key(&mut r).unwrap();
        assert_eq!(back, key);
        assert_eq!(r.remaining(), 0);
        assert!(key.mismatch(&back).is_none());

        let other = CacheKey::new(
            &ChipFaults::new(43, FaultRates::paper_default()),
            GroupConfig::R2C2,
            PipelineOptions::default(),
        );
        assert!(key.mismatch(&other).unwrap().contains("chip seed"));
        let other_cfg =
            CacheKey::new(&chip, GroupConfig::R1C4, PipelineOptions::default());
        assert!(key.mismatch(&other_cfg).unwrap().contains("config"));
    }
}
