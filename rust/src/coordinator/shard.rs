//! Shard-solve: partition one chip's solve phase into mergeable
//! pattern-range shards.
//!
//! `CompileService` already fans *chips* out across workers, but one big
//! chip's compile was still a single-process job. Sharding splits the
//! expensive phase — the solve — across processes or machines while
//! keeping every byte of the output identical to an unsharded compile:
//!
//! 1. Every shard runs the same deterministic **scan**: it interns the
//!    full tensor set's fault patterns, so all shards agree on the
//!    pattern registry (ids are first-seen scan order, independent of
//!    thread count). The scan is cheap — solve time dominates.
//! 2. A [`ShardPlan`] deterministically partitions the pattern-id space
//!    `0..n_patterns` into K contiguous ranges; shard `k` solves **only**
//!    the fresh work whose pattern id falls in range `k` and serializes
//!    the result as a [`ShardFragment`] ("RCSF", the same framing and
//!    checksum as the RCSS session cache).
//! 3. A coordinator calls [`CompileSession::merge_fragments`] with all K
//!    fragments: the ranges tile the registry exactly, so the reassembled
//!    [`super::SolveCache`] — and therefore the RCSS file saved from it,
//!    and every tensor compiled against it — is **byte-identical** to
//!    what a single process would have produced, for any K.
//!
//! Fragments are keyed by the same chip/config/pipeline fingerprint as
//! the session cache; a fragment from the wrong chip, grouping config, or
//! pipeline is rejected, never silently merged.
//!
//! The CLI surface is `rchg shard-solve --shard k/K` (run K times,
//! anywhere) and `rchg merge-shards` (reassemble + save the warm RCSS).
//!
//! ```
//! use rchg::coordinator::{CompileSession, ShardPlan};
//! use rchg::fault::bank::ChipFaults;
//! use rchg::fault::FaultRates;
//! use rchg::grouping::GroupConfig;
//!
//! let cfg = GroupConfig::R2C2;
//! let chip = ChipFaults::new(3, FaultRates::paper_default());
//! let weights: Vec<i64> = (0..256).map(|i| (i % 61) - 30).collect();
//!
//! // Unsharded reference: one process does everything.
//! let mut solo = CompileSession::builder(cfg).chip(&chip);
//! let want = solo.compile_tensor("fc", &weights);
//!
//! // Sharded: two independent sessions each scan everything but solve
//! // only their half of the pattern-id space…
//! let plan = ShardPlan::new(2);
//! let fragments: Vec<_> = (0..2)
//!     .map(|k| {
//!         let mut shard = CompileSession::builder(cfg).chip(&chip);
//!         shard.submit("fc", weights.clone());
//!         shard.solve_shard(&plan, k).unwrap()
//!     })
//!     .collect();
//!
//! // …and a coordinator merges the fragments back into a warm session
//! // that compiles the model without a single fresh solve.
//! let mut merged = CompileSession::builder(cfg).chip(&chip);
//! merged.merge_fragments(&fragments).unwrap();
//! let got = merged.compile_tensor("fc", &weights);
//! assert_eq!(got.stats.unique_pairs, 0, "merged cache answers everything");
//! assert_eq!(got.decomps, want.decomps);
//! assert_eq!(got.errors, want.errors);
//! assert_eq!(merged.to_bytes().unwrap(), solo.to_bytes().unwrap());
//! ```

use super::classes::{PatternId, PatternSolution};
use super::compiler::{scan_batch, solve_fresh, BatchScan, CompileStats, TensorJob};
use super::persist::{
    decode_registry_snapshot, encode_registry_snapshot, push_u32, read_key,
    read_pattern_solution, seal, table_len, unseal, write_key, write_pattern_solution, CacheKey,
    Reader,
};
use super::pipeline::SolveTier;
use super::session::CompileSession;
use crate::fault::GroupFaults;
use anyhow::{anyhow, bail, Context, Result};
use std::ops::Range;
use std::path::Path;

/// Magic marker of the shard fragment format ("RCSF").
pub const FRAGMENT_MAGIC: u32 = 0x5243_5346;
/// Current shard fragment format version.
pub const FRAGMENT_VERSION: u32 = 1;

/// Deterministic K-way partition of a chip's pattern-id space.
///
/// The plan is just the shard count: the concrete ranges depend only on
/// `(shards, n_patterns)`, so independent processes that scanned the same
/// tensor set derive identical partitions without coordinating. Ranges
/// are contiguous, near-equal (the first `n % K` shards get one extra
/// pattern) and tile `0..n_patterns` exactly; with more shards than
/// patterns the surplus shards get empty ranges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    shards: usize,
}

impl ShardPlan {
    /// A plan with `shards` shards (clamped to at least 1).
    pub fn new(shards: usize) -> ShardPlan {
        ShardPlan { shards: shards.max(1) }
    }

    /// Number of shards in the plan.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The contiguous pattern-id range shard `shard` solves out of
    /// `n_patterns` interned patterns.
    ///
    /// ```
    /// use rchg::coordinator::ShardPlan;
    /// let plan = ShardPlan::new(4);
    /// let ranges: Vec<_> = (0..4).map(|k| plan.range(k, 10)).collect();
    /// assert_eq!(ranges, vec![0..3, 3..6, 6..8, 8..10]);
    /// ```
    pub fn range(&self, shard: usize, n_patterns: usize) -> Range<usize> {
        assert!(shard < self.shards, "shard {shard} out of 0..{}", self.shards);
        let base = n_patterns / self.shards;
        let extra = n_patterns % self.shards;
        let start = shard * base + shard.min(extra);
        start..start + base + usize::from(shard < extra)
    }
}

/// One shard's solved slice of a chip's pattern space: an RCSS-compatible
/// *partial* solve cache, keyed by the same chip/config/pipeline
/// fingerprint, carrying every pattern of its range in id order (with or
/// without a solution) so K fragments concatenate back into the full
/// registry. Produced by [`CompileSession::solve_shard`], consumed by
/// [`CompileSession::merge_fragments`].
#[derive(Clone, Debug)]
pub struct ShardFragment {
    pub(super) key: CacheKey,
    pub(super) shard: u32,
    pub(super) shards: u32,
    /// Patterns in the full registry after the scan (shared by all
    /// fragments of one plan).
    pub(super) n_patterns: u32,
    /// First pattern id of this fragment's range.
    pub(super) start: u32,
    /// Every in-range pattern in id order; `None` marks a pattern this
    /// shard did not solve (already resident before the batch, or never
    /// requested).
    pub(super) parts: Vec<(GroupFaults, Option<PatternSolution>)>,
}

impl ShardFragment {
    /// The chip/config/pipeline fingerprint this fragment belongs to —
    /// the fabric coordinator's scheduling hook for validating a
    /// worker-returned fragment *before* attempting a merge.
    pub(crate) fn cache_key(&self) -> &CacheKey {
        &self.key
    }

    /// Shard index within the plan (0-based).
    pub fn shard(&self) -> usize {
        self.shard as usize
    }

    /// Total shards in the plan this fragment belongs to.
    pub fn shards(&self) -> usize {
        self.shards as usize
    }

    /// Chip seed of the session this fragment was solved for.
    pub fn chip_seed(&self) -> u64 {
        self.key.chip.chip_seed
    }

    /// The pattern-id range this fragment covers.
    pub fn range(&self) -> Range<usize> {
        self.start as usize..self.start as usize + self.parts.len()
    }

    /// Patterns in the full registry the plan was derived from.
    pub fn total_patterns(&self) -> usize {
        self.n_patterns as usize
    }

    /// In-range patterns that carry a solution in this fragment.
    pub fn solved_patterns(&self) -> usize {
        self.parts.iter().filter(|(_, s)| s.is_some()).count()
    }

    /// In-range (pattern, solution) parts in pattern-id order. The
    /// fabric worker walks these to publish freshly solved full-range
    /// tables to the fleet store (see [`crate::store`]).
    pub fn parts(&self) -> impl Iterator<Item = (&GroupFaults, Option<&PatternSolution>)> {
        self.parts.iter().map(|(p, s)| (p, s.as_ref()))
    }

    /// Serialize to the RCSF v1 format: the RCSS cache-key header, the
    /// shard framing (`shard · shards · n_patterns · start · len`), the
    /// per-pattern solutions in id order (same byte layout as RCSS v2,
    /// plus an *empty* tag for unsolved patterns), and the trailing
    /// FNV-1a checksum.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        push_u32(&mut buf, FRAGMENT_MAGIC);
        push_u32(&mut buf, FRAGMENT_VERSION);
        write_key(&mut buf, &self.key);
        push_u32(&mut buf, self.shard);
        push_u32(&mut buf, self.shards);
        push_u32(&mut buf, self.n_patterns);
        push_u32(&mut buf, self.start);
        push_u32(&mut buf, self.parts.len() as u32);
        for (pattern, solution) in &self.parts {
            write_pattern_solution(&mut buf, pattern, solution.as_ref());
        }
        seal(buf)
    }

    /// Parse a fragment, verifying the trailing checksum first and
    /// rejecting malformed input — wrong magic/version, inconsistent
    /// shard framing, or a range that disagrees with the deterministic
    /// [`ShardPlan`] — with an error.
    pub fn from_bytes(bytes: &[u8]) -> Result<ShardFragment> {
        let payload = unseal(bytes)?;
        let mut r = Reader::new(payload);
        let magic = r.u32()?;
        if magic != FRAGMENT_MAGIC {
            bail!("bad shard fragment magic {magic:#010x}");
        }
        let version = r.u32()?;
        if version != FRAGMENT_VERSION {
            bail!("unsupported shard fragment version {version} (this build reads {FRAGMENT_VERSION})");
        }
        let key = read_key(&mut r)?;
        let shard = r.u32()?;
        let shards = r.u32()?;
        let n_patterns = r.u32()?;
        let start = r.u32()?;
        let len = r.u32()? as usize;
        if shards == 0 || shard >= shards {
            bail!("bad shard index {shard} of {shards} in fragment");
        }
        let plan = ShardPlan::new(shards as usize);
        let want = plan.range(shard as usize, n_patterns as usize);
        if start as usize != want.start || len != want.len() {
            bail!(
                "fragment covers patterns {start}..{} but a {shards}-way plan over \
                 {n_patterns} patterns assigns {want:?} to shard {shard}",
                start as usize + len
            );
        }
        // Sanity cap before allocating: every pattern costs at least its
        // fault bytes plus a tag.
        if r.remaining() < len * (2 * key.cells() + 1) {
            bail!("shard fragment truncated ({len} patterns declared)");
        }
        let mut parts = Vec::with_capacity(len);
        for _ in 0..len {
            parts.push(read_pattern_solution(&mut r, &key, true)?);
        }
        if r.remaining() != 0 {
            bail!("shard fragment has {} trailing bytes", r.remaining());
        }
        Ok(ShardFragment { key, shard, shards, n_patterns, start, parts })
    }

    /// Write the fragment to `path` (creating parent directories).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write shard fragment {}", path.display()))
    }

    /// Read a fragment written by [`ShardFragment::save`].
    pub fn load(path: &Path) -> Result<ShardFragment> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read shard fragment {}", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("parse shard fragment {}", path.display()))
    }
}

impl CompileSession {
    /// Build a warm session directly from a complete fragment set: the
    /// session identity (chip, grouping config, pipeline) comes from the
    /// fragment key, so a merge coordinator needs no configuration beyond
    /// the fragments themselves. Equivalent to building a matching
    /// session and calling [`CompileSession::merge_fragments`].
    pub fn from_fragments(fragments: &[ShardFragment]) -> Result<CompileSession> {
        let first = fragments
            .first()
            .ok_or_else(|| anyhow!("no shard fragments to merge"))?;
        let mut session = CompileSession::for_key(&first.key);
        session.merge_fragments(fragments)?;
        Ok(session)
    }

    /// Run shard `shard` of `plan` over every tensor queued via
    /// [`CompileSession::submit`]: scan + intern the **full** tensor set
    /// (so all shards derive the identical pattern registry), then solve
    /// only the fresh work whose pattern id falls in this shard's range.
    /// Consumes the queue, like [`CompileSession::drain`], but returns a
    /// [`ShardFragment`] instead of compiled tensors — sharding
    /// distributes the solve phase; compilation output comes from
    /// [`CompileSession::drain`] on a session that merged all K fragments
    /// (or from this session itself, which keeps its shard's solutions
    /// warm).
    ///
    /// Session statistics account the shard's own work: `unique_pairs`
    /// counts only in-range fresh requests and `weights` stays 0 (no
    /// tensor outputs are produced here).
    pub fn solve_shard(&mut self, plan: &ShardPlan, shard: usize) -> Result<ShardFragment> {
        if shard >= plan.shards() {
            bail!("shard {shard} out of range for a {}-way plan", plan.shards());
        }
        let chip = self
            .chip
            .clone()
            .ok_or_else(|| anyhow!("detached session has no chip to shard-solve for"))?;
        if self.cache.is_none() {
            bail!("legacy (dedupe = off) session cannot shard-solve");
        }
        let cells = self.opts.cfg.cells();
        if cells == 0 || cells > 16 {
            bail!(
                "config {} has {cells} cells per array; shard fragments support at most 16",
                self.opts.cfg
            );
        }
        if self.queue.is_empty() {
            bail!("no tensors queued — submit() the tensor set before solve_shard()");
        }
        let queue = std::mem::take(&mut self.queue);
        let all_faults: Vec<Vec<GroupFaults>> = queue
            .iter()
            .map(|q| chip.sample_tensor(q.tensor_id, q.weights.len(), cells))
            .collect();
        let jobs: Vec<TensorJob<'_>> = queue
            .iter()
            .zip(&all_faults)
            .map(|(q, f)| TensorJob { weights: &q.weights, faults: f })
            .collect();
        let cache = self.cache.as_mut().expect("checked above");
        let mut scan = scan_batch(&jobs, &self.opts, cache, true);
        let n_patterns = cache.registry.len();
        let range = plan.range(shard, n_patterns);

        // Keep only this shard's slice of the fresh work, and re-count the
        // per-tensor fresh-request stats to match what is actually solved.
        let in_range = |pid: PatternId| range.contains(&(pid as usize));
        for st in &mut scan.per_tensor {
            st.unique_pairs = 0;
        }
        scan.fresh_patterns.retain(|&(pid, _)| in_range(pid));
        scan.fresh_pairs.retain(|&(pid, _, _)| in_range(pid));
        for &(_, _, ti) in &scan.fresh_pairs {
            scan.per_tensor[ti].unique_pairs += 1;
        }
        let solve_secs = solve_fresh(&mut scan, &self.opts, cache);

        let pipeline = cache.pipeline().copied().unwrap_or(self.opts.pipeline);
        let parts: Vec<(GroupFaults, Option<PatternSolution>)> = range
            .clone()
            .map(|pid| {
                let pid = pid as PatternId;
                let pattern = cache.registry.ctx(pid).faults.clone();
                (pattern, cache.solution_if_current(pid).cloned())
            })
            .collect();
        for (ti, mut st) in scan.per_tensor.into_iter().enumerate() {
            st.wall_secs = solve_secs[ti];
            self.stats.merge_with_wall(&st);
        }
        Ok(ShardFragment {
            key: CacheKey::new(&chip, self.opts.cfg, pipeline),
            shard: shard as u32,
            shards: plan.shards() as u32,
            n_patterns: n_patterns as u32,
            start: range.start as u32,
            parts,
        })
    }

    /// Scan + intern every queued tensor — **without** consuming the
    /// queue — and serialize the resulting pattern registry as a sealed
    /// "RCRG" v1 snapshot (see
    /// [`CompileSession::solve_shard_from_snapshot`] for the consuming
    /// side). This is the coordinator half of the snapshot shard path:
    /// one scan here replaces K per-worker re-scans of the full tensor
    /// set, and the snapshot (a few bytes per distinct pattern) replaces
    /// the tensor set in every shard-job payload. The session keeps its
    /// queue so the same tensors can be [`CompileSession::drain`]ed after
    /// the shard fragments merge back in.
    pub fn scan_to_snapshot(&mut self) -> Result<Vec<u8>> {
        let chip = self
            .chip
            .clone()
            .ok_or_else(|| anyhow!("detached session has no chip to snapshot"))?;
        if self.cache.is_none() {
            bail!("legacy (dedupe = off) session cannot snapshot its registry");
        }
        let cells = self.opts.cfg.cells();
        if cells == 0 || cells > 16 {
            bail!(
                "config {} has {cells} cells per array; registry snapshots support at most 16",
                self.opts.cfg
            );
        }
        if self.queue.is_empty() {
            bail!("no tensors queued — submit() the tensor set before scan_to_snapshot()");
        }
        let all_faults: Vec<Vec<GroupFaults>> = self
            .queue
            .iter()
            .map(|q| chip.sample_tensor(q.tensor_id, q.weights.len(), cells))
            .collect();
        let jobs: Vec<TensorJob<'_>> = self
            .queue
            .iter()
            .zip(&all_faults)
            .map(|(q, f)| TensorJob { weights: &q.weights, faults: f })
            .collect();
        let cache = self.cache.as_mut().expect("checked above");
        scan_batch(&jobs, &self.opts, cache, false);
        let pipeline = cache.pipeline().copied().unwrap_or(self.opts.pipeline);
        let key = CacheKey::new(&chip, self.opts.cfg, pipeline);
        Ok(encode_registry_snapshot(&key, &cache.registry))
    }

    /// Run shard `shard` of `plan` from a registry snapshot instead of
    /// the tensor set: rebuild the coordinator's pattern registry by
    /// re-interning the snapshot's patterns in id order (reproducing the
    /// exact ids the coordinator assigned), then batch-solve every
    /// pattern in this shard's range. Per-shard cost is O(in-range
    /// patterns) — no tensors shipped, no full re-scan — and on a cold
    /// session the fragment is byte-identical to what
    /// [`CompileSession::solve_shard`] produces from the full tensor set
    /// (pinned by `tests/sharding.rs` and the fabric e2e suite).
    ///
    /// Only the [`SolveTier::BatchTable`] tier is supported: per-weight
    /// fresh work is (pattern, weight) pairs, which a registry snapshot
    /// deliberately does not carry.
    ///
    /// ```
    /// use rchg::coordinator::{CompileSession, ShardPlan};
    /// use rchg::fault::bank::ChipFaults;
    /// use rchg::fault::FaultRates;
    /// use rchg::grouping::GroupConfig;
    ///
    /// let cfg = GroupConfig::R2C2;
    /// let chip = ChipFaults::new(3, FaultRates::paper_default());
    /// let weights: Vec<i64> = (0..256).map(|i| (i % 61) - 30).collect();
    ///
    /// // The coordinator scans once and ships the registry, not the tensors.
    /// let mut coord = CompileSession::builder(cfg).chip(&chip);
    /// coord.submit("fc", weights.clone());
    /// let snapshot = coord.scan_to_snapshot().unwrap();
    ///
    /// let plan = ShardPlan::new(2);
    /// let fragments: Vec<_> = (0..2)
    ///     .map(|k| {
    ///         // Workers never see `weights`.
    ///         let mut worker = CompileSession::builder(cfg).chip(&chip);
    ///         worker.solve_shard_from_snapshot(&snapshot, &plan, k).unwrap()
    ///     })
    ///     .collect();
    /// let mut merged = CompileSession::from_fragments(&fragments).unwrap();
    /// let got = merged.compile_tensor("fc", &weights);
    /// assert_eq!(got.stats.unique_pairs, 0, "merged cache answers everything");
    /// ```
    pub fn solve_shard_from_snapshot(
        &mut self,
        snapshot: &[u8],
        plan: &ShardPlan,
        shard: usize,
    ) -> Result<ShardFragment> {
        if shard >= plan.shards() {
            bail!("shard {shard} out of range for a {}-way plan", plan.shards());
        }
        let chip = self
            .chip
            .clone()
            .ok_or_else(|| anyhow!("detached session cannot shard-solve from a snapshot"))?;
        let cache = self.cache.as_mut().ok_or_else(|| {
            anyhow!("legacy (dedupe = off) session cannot shard-solve from a snapshot")
        })?;
        if self.opts.effective_tier() != SolveTier::BatchTable {
            bail!(
                "snapshot shard-solve requires the full-range table tier \
                 (per-weight fresh work needs the tensor set — use solve_shard)"
            );
        }
        let (key, patterns) = decode_registry_snapshot(snapshot)?;
        let pipeline = cache.pipeline().copied().unwrap_or(self.opts.pipeline);
        let own = CacheKey::new(&chip, self.opts.cfg, pipeline);
        if let Some(why) = own.mismatch(&key) {
            bail!("registry snapshot does not belong to this session: {why}");
        }
        // Start the batch exactly like a scan would, then rebuild the
        // registry in snapshot id order (the codec's re-intern contract).
        cache.bind_pipeline(&self.opts.pipeline);
        cache.set_table_memory_bytes(self.opts.table_memory_bytes);
        cache.begin_batch();
        for (i, p) in patterns.iter().enumerate() {
            if cache.registry.intern(p) as usize != i {
                bail!("registry snapshot pattern {i} is a duplicate");
            }
        }
        let n_patterns = patterns.len();
        let range = plan.range(shard, n_patterns);

        // Every in-range pattern is this shard's fresh work: snapshots
        // are shipped for cold rounds, where the tensor path would mark
        // each of them fresh too. All solve work is charged to one
        // pseudo-tensor — there are no per-tensor stats without tensors.
        let mut scan = BatchScan {
            per_tensor: vec![CompileStats::default()],
            tensor_pids: Vec::new(),
            fresh_patterns: range.clone().map(|pid| (pid as PatternId, 0)).collect(),
            fresh_pairs: Vec::new(),
            tier: SolveTier::BatchTable,
        };
        let solve_secs = solve_fresh(&mut scan, &self.opts, cache);
        let parts: Vec<(GroupFaults, Option<PatternSolution>)> = range
            .clone()
            .map(|pid| {
                let pid = pid as PatternId;
                let pattern = cache.registry.ctx(pid).faults.clone();
                (pattern, cache.solution_if_current(pid).cloned())
            })
            .collect();
        let mut st = scan.per_tensor.pop().expect("one pseudo-tensor");
        st.wall_secs = solve_secs[0];
        self.stats.merge_with_wall(&st);
        Ok(ShardFragment {
            key: own,
            shard: shard as u32,
            shards: plan.shards() as u32,
            n_patterns: n_patterns as u32,
            start: range.start as u32,
            parts,
        })
    }

    /// Merge a complete K-shard fragment set into this session's solve
    /// cache, reassembling a warm cache **byte-identical** to what a
    /// single-process compile of the same tensor set would hold — the
    /// registry is rebuilt in fragment order (= scan order), every
    /// solution is installed, and a subsequent [`CompileSession::save`]
    /// writes the same RCSS bytes an unsharded session would.
    ///
    /// Returns the number of pattern solutions installed. Fails — without
    /// touching half-merged state where detectable up front — when a
    /// fragment's chip/config/pipeline fingerprint does not match this
    /// session, the set is incomplete or duplicated, fragments disagree on
    /// the plan, or the pattern order disagrees with this session's
    /// registry.
    pub fn merge_fragments(&mut self, fragments: &[ShardFragment]) -> Result<usize> {
        let chip = self
            .chip
            .clone()
            .ok_or_else(|| anyhow!("detached session cannot merge shard fragments"))?;
        let cache = self
            .cache
            .as_mut()
            .ok_or_else(|| anyhow!("legacy (dedupe = off) session cannot merge shard fragments"))?;
        let first = match fragments.first() {
            Some(f) => f,
            None => bail!("no shard fragments to merge"),
        };
        let pipeline = cache.pipeline().copied().unwrap_or(self.opts.pipeline);
        let key = CacheKey::new(&chip, self.opts.cfg, pipeline);
        let (shards, n_patterns) = (first.shards, first.n_patterns);
        // Size check before the plan-sized allocation: a corrupt or
        // hostile `shards` header must produce a clean error, not a
        // multi-gigabyte `vec![None; shards]`.
        if fragments.len() != shards as usize {
            bail!(
                "incomplete shard set: {} fragment(s) for a {shards}-way plan \
                 (missing or duplicated shards)",
                fragments.len()
            );
        }
        let mut by_shard: Vec<Option<&ShardFragment>> = vec![None; shards as usize];
        for f in fragments {
            if let Some(why) = key.mismatch(&f.key) {
                bail!(
                    "shard fragment {}/{} does not belong to this session: {why}",
                    f.shard + 1,
                    f.shards
                );
            }
            if f.shards != shards || f.n_patterns != n_patterns {
                bail!(
                    "fragments disagree on the shard plan: {}-way over {} patterns vs \
                     {shards}-way over {n_patterns}",
                    f.shards,
                    f.n_patterns
                );
            }
            let slot = &mut by_shard[f.shard as usize];
            if slot.replace(f).is_some() {
                bail!("duplicate fragment for shard {}/{shards}", f.shard + 1);
            }
        }
        // At this point the set is complete: the count matched the plan
        // and duplicates bailed above, so every slot is filled.
        let plan = ShardPlan::new(shards as usize);
        cache.bind_pipeline(&pipeline);
        let t_len = table_len(&self.opts.cfg);
        let mut installed = 0usize;
        let mut expected: PatternId = 0;
        for (k, f) in by_shard.iter().enumerate() {
            let f = f.expect("completeness checked above");
            let want = plan.range(k, n_patterns as usize);
            if f.range() != want {
                bail!(
                    "fragment {}/{shards} covers patterns {:?} but the plan assigns {want:?}",
                    k + 1,
                    f.range()
                );
            }
            for (pattern, solution) in &f.parts {
                let pid = cache.registry.intern(pattern);
                if pid != expected {
                    bail!(
                        "fragment pattern {expected} interned as id {pid}: the fragment \
                         set disagrees with this session's registry (different tensor \
                         set or duplicate patterns)"
                    );
                }
                expected += 1;
                match solution {
                    Some(PatternSolution::Table(t)) => {
                        if t.len() != t_len {
                            bail!(
                                "pattern {pid} table has {} entries, config {} needs {t_len}",
                                t.len(),
                                self.opts.cfg
                            );
                        }
                        cache.install_table(pid, t.clone());
                        installed += 1;
                    }
                    Some(PatternSolution::Pairs(m)) => {
                        let mut entries: Vec<_> =
                            m.iter().map(|(&w, o)| (pid, w, o.clone())).collect();
                        entries.sort_unstable_by_key(|&(_, w, _)| w);
                        cache.install_pairs(entries);
                        installed += 1;
                    }
                    None => {}
                }
            }
        }
        Ok(installed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_ranges_tile_exactly() {
        for shards in 1..=9usize {
            let plan = ShardPlan::new(shards);
            for n in [0usize, 1, 2, 7, 64, 1000] {
                let mut next = 0usize;
                for k in 0..shards {
                    let r = plan.range(k, n);
                    assert_eq!(r.start, next, "gap/overlap at shard {k} of {shards}, n={n}");
                    assert!(r.len() <= n / shards + 1);
                    next = r.end;
                }
                assert_eq!(next, n, "{shards} shards must tile 0..{n}");
            }
        }
    }

    #[test]
    fn plan_clamps_zero_shards() {
        let plan = ShardPlan::new(0);
        assert_eq!(plan.shards(), 1);
        assert_eq!(plan.range(0, 5), 0..5);
    }
}
