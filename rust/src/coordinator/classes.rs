//! Pattern-class registry: the dedupe-first compiler core.
//!
//! At realistic SAF rates most groups are fault-free or share a
//! low-cardinality fault pattern, so the compiler's unit of work is not a
//! weight but a **pattern class**: the set of weights whose groups carry
//! the same `GroupFaults` pattern. This module interns patterns by their
//! dense [`crate::fault::PatternKey`] and attaches one shared
//! [`PatternCtx`] per class — the `FaultAnalysis` and `GroupTables` that
//! the legacy per-weight pipeline rebuilt for every single weight are now
//! built at most once per class, lazily, and shared across worker threads.
//!
//! [`SolveCache`] extends the dedup one level further: a chip-wide
//! pattern → [`PatternSolution`] store. On the `BatchTable` tier a
//! pattern is solved **once for its entire weight range** (dense table
//! indexed by shifted weight — every weight of every later tensor is an
//! O(1) lookup); on the `PerWeight` tier individually solved (weight →
//! outcome) entries accumulate per pattern. Resident solution memory is
//! bounded: [`SolveCache::begin_batch`] evicts least-recently-used
//! pattern solutions (deterministically — by last-used batch epoch, then
//! pattern id) until the configured byte budget fits. Everything is
//! deterministic: pattern ids are assigned in first-seen scan order,
//! independent of thread count, and eviction only ever costs re-solves,
//! never changes an output byte.

use super::pipeline::{Outcome, PipelineOptions};
use crate::decompose::GroupTables;
use crate::fault::{GroupFaults, PatternKey};
use crate::grouping::{FaultAnalysis, GroupConfig};
use crate::store::StoreHandle;
use crate::util::fnv::FnvMap;
use std::sync::OnceLock;

/// Index of an interned pattern within its [`PatternRegistry`].
pub type PatternId = u32;

/// Shared solve context for one fault-pattern class: the fault map itself
/// plus its analysis and decomposition tables, built at most once and
/// shared by every weight (and every worker thread) in the class.
#[derive(Clone, Debug)]
pub struct PatternCtx {
    pub cfg: GroupConfig,
    pub faults: GroupFaults,
    /// Dense interning key (see [`GroupFaults::pattern_key`]).
    pub key: PatternKey,
    fault_free: bool,
    analysis: OnceLock<FaultAnalysis>,
    tables: OnceLock<GroupTables>,
}

impl PatternCtx {
    pub fn new(cfg: GroupConfig, faults: GroupFaults) -> PatternCtx {
        let key = faults.pattern_key();
        PatternCtx::with_key(cfg, faults, key)
    }

    /// Construct with a precomputed interning key. The registry already
    /// computed the key to probe its map; recomputing it here would double
    /// the per-fresh-pattern key-derivation work on the scan path.
    pub fn with_key(cfg: GroupConfig, faults: GroupFaults, key: PatternKey) -> PatternCtx {
        debug_assert_eq!(key, faults.pattern_key());
        let fault_free = faults.is_fault_free();
        PatternCtx {
            cfg,
            faults,
            key,
            fault_free,
            analysis: OnceLock::new(),
            tables: OnceLock::new(),
        }
    }

    #[inline]
    pub fn is_fault_free(&self) -> bool {
        self.fault_free
    }

    /// Theorem-1/2 analysis for this class (built on first use).
    pub fn analysis(&self) -> &FaultAnalysis {
        self.analysis.get_or_init(|| FaultAnalysis::new(&self.cfg, &self.faults))
    }

    /// Decomposition tables for this class (built on first use; threads
    /// block on the single builder rather than re-running the DP).
    pub fn tables(&self) -> &GroupTables {
        self.tables.get_or_init(|| GroupTables::build(&self.cfg, &self.faults))
    }

    /// Whether the (expensive) tables were ever materialized.
    pub fn tables_built(&self) -> bool {
        self.tables.get().is_some()
    }
}

/// Patterns per arena chunk. 256 contexts ≈ a few hundred KB per chunk
/// once analyses/tables materialize inline — big enough to amortize the
/// chunk allocation, small enough that a mostly-fault-free chip (a
/// handful of classes) does not over-commit.
const CTX_CHUNK: usize = 256;

/// Chunked arena backing [`PatternCtx`] storage.
///
/// `PatternCtx` is a wide struct (fault map plus two inline `OnceLock`
/// payloads once the lazy analysis/tables materialize). A plain
/// `Vec<PatternCtx>` re-copies every context on each capacity doubling as
/// a scan discovers new classes; the arena allocates fixed-size chunks
/// instead, so a push never moves previously interned contexts and
/// interning cost stays flat regardless of registry size. Every chunk
/// holds exactly `CTX_CHUNK` contexts (the last one partially), which
/// makes indexing a shift-and-mask-free div/mod pair.
#[derive(Debug)]
struct CtxArena {
    chunks: Vec<Vec<PatternCtx>>,
    len: usize,
}

impl CtxArena {
    fn new() -> CtxArena {
        CtxArena { chunks: Vec::new(), len: 0 }
    }

    #[inline]
    fn get(&self, i: usize) -> &PatternCtx {
        &self.chunks[i / CTX_CHUNK][i % CTX_CHUNK]
    }

    fn push(&mut self, ctx: PatternCtx) {
        if self.len % CTX_CHUNK == 0 {
            self.chunks.push(Vec::with_capacity(CTX_CHUNK));
        }
        self.chunks.last_mut().expect("chunk pushed above").push(ctx);
        self.len += 1;
    }

    fn iter(&self) -> impl Iterator<Item = &PatternCtx> {
        self.chunks.iter().flat_map(|c| c.iter())
    }
}

impl Clone for CtxArena {
    fn clone(&self) -> CtxArena {
        // Rebuild through `push` so every clone restores the full-capacity
        // chunk invariant (a derived clone would shrink chunk capacity to
        // its length and the next push into the tail chunk would
        // reallocate it).
        let mut out = CtxArena::new();
        out.chunks.reserve(self.chunks.len());
        for ctx in self.iter() {
            out.push(ctx.clone());
        }
        out
    }
}

/// Interning registry of fault-pattern classes for one grouping config.
///
/// Pattern ids are assigned in first-intern order, so a registry filled by
/// a deterministic scan is itself deterministic. Contexts live in a
/// chunked [`CtxArena`]; the interning fast path (pattern already seen —
/// the overwhelmingly common case on a realistic chip) is one key
/// derivation plus one map probe, with no allocation.
#[derive(Clone, Debug)]
pub struct PatternRegistry {
    cfg: GroupConfig,
    by_key: FnvMap<PatternKey, PatternId>,
    ctxs: CtxArena,
}

impl PatternRegistry {
    pub fn new(cfg: GroupConfig) -> PatternRegistry {
        PatternRegistry { cfg, by_key: FnvMap::default(), ctxs: CtxArena::new() }
    }

    pub fn cfg(&self) -> &GroupConfig {
        &self.cfg
    }

    /// Intern one pattern, returning its class id. The key is derived
    /// once and handed down rather than recomputed inside the context
    /// constructor.
    pub fn intern(&mut self, faults: &GroupFaults) -> PatternId {
        self.intern_with_key(faults, faults.pattern_key())
    }

    /// Intern one pattern whose key the caller already derived. The
    /// parallel scan's merge path goes through here: thread-local scans
    /// computed every key once, so the merge must not pay the derivation
    /// again per distinct pattern.
    pub fn intern_with_key(&mut self, faults: &GroupFaults, key: PatternKey) -> PatternId {
        debug_assert_eq!(key, faults.pattern_key());
        if let Some(&id) = self.by_key.get(&key) {
            return id;
        }
        let id = self.ctxs.len as PatternId;
        self.by_key.insert(key, id);
        self.ctxs.push(PatternCtx::with_key(self.cfg, faults.clone(), key));
        id
    }

    /// Scan a tensor's fault maps, interning every pattern. Returns one
    /// class id per group, aligned with the input. The output vector is
    /// sized up front — for a million-group tensor this is the only
    /// allocation the scan performs besides the (rare) fresh-pattern
    /// inserts.
    pub fn intern_all(&mut self, faults: &[GroupFaults]) -> Vec<PatternId> {
        let mut out = Vec::with_capacity(faults.len());
        out.extend(faults.iter().map(|f| self.intern(f)));
        out
    }

    /// Interned fault patterns in id order (the session cache serializer
    /// walks these; re-interning them in order reproduces the same ids).
    pub fn patterns(&self) -> impl Iterator<Item = &GroupFaults> {
        self.ctxs.iter().map(|c| &c.faults)
    }

    pub fn ctx(&self, id: PatternId) -> &PatternCtx {
        self.ctxs.get(id as usize)
    }

    /// Number of distinct pattern classes interned so far.
    pub fn len(&self) -> usize {
        self.ctxs.len
    }

    pub fn is_empty(&self) -> bool {
        self.ctxs.len == 0
    }

    /// How many classes materialized their decomposition tables.
    pub fn tables_built(&self) -> usize {
        self.ctxs.iter().filter(|c| c.tables_built()).count()
    }
}

/// Default resident-memory budget for per-pattern solution tables
/// (`CompileOptions::table_memory_bytes`): comfortably holds every
/// pattern a paper-scale model produces on R1C4/R2C2/R2C4 while bounding
/// pathological fleets (huge weight ranges × many chips) — the ROADMAP's
/// "cache grows without limit" item.
pub const DEFAULT_TABLE_MEMORY_BYTES: usize = 256 << 20;

/// Estimated resident bytes of one cached [`Outcome`] (two cell vectors
/// plus error/stage). An estimate, not an allocator measurement — the
/// budget is a guard rail, not an accounting ledger.
fn outcome_bytes(cells: usize) -> usize {
    2 * (24 + cells) + 16
}

/// Solved outcomes of one pattern class.
///
/// `Table` is the `BatchTable` tier's unit: dense full-range solutions
/// indexed by shifted weight (`w + max_per_array`), built by one batch
/// solve — every representable weight is an O(1) lookup forever after.
/// `Pairs` is the `PerWeight` tier's unit: individually solved entries
/// for methods/configs where full enumeration is the wrong trade (ILP
/// methods, >16-cell or huge-range configs).
#[derive(Clone, Debug)]
pub enum PatternSolution {
    /// Dense full-range table, `outcomes[w + max_per_array]`.
    Table(Vec<Outcome>),
    /// Individually solved weight → outcome entries.
    Pairs(FnvMap<i64, Outcome>),
}

impl PatternSolution {
    /// Number of solved entries resident in this solution.
    pub fn len(&self) -> usize {
        match self {
            PatternSolution::Table(t) => t.len(),
            PatternSolution::Pairs(m) => m.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn estimated_bytes(&self, cells: usize) -> usize {
        match self {
            PatternSolution::Table(t) => 24 + t.len() * outcome_bytes(cells),
            PatternSolution::Pairs(m) => 48 + m.len() * (outcome_bytes(cells) + 16),
        }
    }
}

/// One pattern's resident solution plus its cache bookkeeping.
#[derive(Clone, Debug)]
struct SolutionSlot {
    solution: PatternSolution,
    /// Batch epoch of the last lookup or install — the LRU eviction key.
    last_used: u64,
    /// Served or freshly solved at least once in this cache's lifetime.
    /// Entries loaded from a warm-start file start `false`; the session
    /// serializer skips never-hit slots so cache files stop growing
    /// monotonically across model revisions.
    hit: bool,
    /// Estimated resident bytes of the solution payload.
    bytes: usize,
}

/// Chip-wide pattern → [`PatternSolution`] solve cache with a bounded
/// memory footprint.
///
/// One `SolveCache` per chip: every tensor compiled through it shares the
/// pattern registry and the solutions of all tensors before it. A weight
/// whose pattern already carries a full-range table costs a dense-vector
/// read — even if that exact weight was never compiled before. Eviction
/// (LRU by batch epoch, ties by pattern id) keeps resident solution bytes
/// under [`SolveCache::table_memory_bytes`]; an evicted pattern is simply
/// re-solved on next use, bit-for-bit identically.
#[derive(Clone, Debug)]
pub struct SolveCache {
    pub registry: PatternRegistry,
    /// Per-pattern solutions, indexed by [`PatternId`].
    slots: Vec<Option<SolutionSlot>>,
    /// Pipeline options the cached outcomes were solved under; set on
    /// first use. Outcomes are keyed by (pattern, weight) only, so mixing
    /// pipelines in one cache would silently serve stale solutions.
    pipeline: Option<PipelineOptions>,
    /// `cfg.max_per_array()` — the shift that indexes full-range tables.
    max_w: i64,
    /// Current batch epoch (see [`SolveCache::begin_batch`]).
    epoch: u64,
    resident_bytes: usize,
    table_memory_bytes: usize,
    evictions: u64,
    /// Optional fleet-global solution store (see [`crate::store`]): the
    /// solve phase consults it for fresh full-range patterns before
    /// fanning out local solves, and publishes what it solved. Shared
    /// across chips; never serialized with the chip-scoped session.
    store: Option<StoreHandle>,
}

impl SolveCache {
    pub fn new(cfg: GroupConfig) -> SolveCache {
        SolveCache {
            registry: PatternRegistry::new(cfg),
            slots: Vec::new(),
            pipeline: None,
            max_w: cfg.max_per_array(),
            epoch: 0,
            resident_bytes: 0,
            table_memory_bytes: DEFAULT_TABLE_MEMORY_BYTES,
            evictions: 0,
            store: None,
        }
    }

    /// Attach a fleet-global solution store. The solve phase will consult
    /// it for fresh `BatchTable` patterns (installing byte-identical hits
    /// instead of solving) and publish freshly solved tables back.
    pub fn set_store(&mut self, store: StoreHandle) {
        self.store = Some(store);
    }

    /// The attached fleet-global solution store, if any.
    pub fn store(&self) -> Option<&StoreHandle> {
        self.store.as_ref()
    }

    /// Bind the cache to one set of pipeline options (first caller wins;
    /// later callers must match or the cached outcomes would be invalid).
    pub fn bind_pipeline(&mut self, p: &PipelineOptions) {
        match self.pipeline {
            None => self.pipeline = Some(*p),
            Some(bound) => assert_eq!(
                bound, *p,
                "solve cache reused with different pipeline options"
            ),
        }
    }

    /// Pipeline options the cached outcomes were solved under (set on the
    /// first compilation through this cache).
    pub fn pipeline(&self) -> Option<&PipelineOptions> {
        self.pipeline.as_ref()
    }

    /// Resident-memory budget for pattern solutions, in (estimated) bytes.
    pub fn table_memory_bytes(&self) -> usize {
        self.table_memory_bytes
    }

    /// Adjust the memory budget; takes effect at the next
    /// [`SolveCache::begin_batch`].
    pub fn set_table_memory_bytes(&mut self, bytes: usize) {
        self.table_memory_bytes = bytes.max(1);
    }

    /// Start a compilation batch: advance the LRU epoch and evict
    /// least-recently-used pattern solutions until the resident estimate
    /// fits the budget. Called once per `compile_batch_with_cache` round,
    /// so everything touched *within* a batch stays resident through its
    /// scatter phase (a single batch may therefore overshoot the budget;
    /// it is trimmed at the next batch boundary).
    pub fn begin_batch(&mut self) {
        self.epoch += 1;
        // The fleet store rides the same batch cadence: its LRU epoch
        // advances (and its budget is enforced) at batch boundaries too.
        if let Some(store) = &self.store {
            store.begin_epoch();
        }
        if self.resident_bytes <= self.table_memory_bytes {
            return;
        }
        // Deterministic LRU: (last-used epoch, pattern id) ascending. Only
        // slots from earlier epochs are candidates; at this point (epoch
        // just advanced) that is every slot.
        let mut cands: Vec<(u64, u32)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(pid, s)| {
                s.as_ref()
                    .filter(|s| s.last_used < self.epoch)
                    .map(|s| (s.last_used, pid as u32))
            })
            .collect();
        cands.sort_unstable();
        for (_, pid) in cands {
            if self.resident_bytes <= self.table_memory_bytes {
                break;
            }
            if let Some(slot) = self.slots[pid as usize].take() {
                self.resident_bytes -= slot.bytes.min(self.resident_bytes);
                self.evictions += 1;
            }
        }
    }

    fn ensure_slots(&mut self) {
        if self.slots.len() < self.registry.len() {
            self.slots.resize_with(self.registry.len(), || None);
        }
    }

    /// Mark pattern `pid` used in the current epoch and report whether
    /// weight `w` already has a resident solution. The scan/dedupe phase
    /// calls this once per weight; `false` means the pair needs fresh
    /// solve work this batch.
    pub fn touch(&mut self, pid: PatternId, w: i64) -> bool {
        self.ensure_slots();
        match &mut self.slots[pid as usize] {
            Some(slot) => {
                slot.hit = true;
                slot.last_used = self.epoch;
                match &slot.solution {
                    PatternSolution::Table(t) => {
                        debug_assert_eq!(t.len() as i64, 2 * self.max_w + 1);
                        w.abs() <= self.max_w
                    }
                    PatternSolution::Pairs(m) => m.contains_key(&w),
                }
            }
            None => false,
        }
    }

    /// The resident outcome for (pattern, weight), if any.
    pub fn get(&self, pid: PatternId, w: i64) -> Option<&Outcome> {
        let slot = self.slots.get(pid as usize)?.as_ref()?;
        match &slot.solution {
            PatternSolution::Table(t) => {
                let i = w + self.max_w;
                if (0..t.len() as i64).contains(&i) {
                    Some(&t[i as usize])
                } else {
                    None
                }
            }
            PatternSolution::Pairs(m) => m.get(&w),
        }
    }

    /// Install a freshly batch-solved full-range table for `pid`
    /// (replacing any sparse entries — the outcomes are identical, the
    /// table strictly supersedes them).
    pub fn install_table(&mut self, pid: PatternId, outcomes: Vec<Outcome>) {
        debug_assert_eq!(outcomes.len() as i64, 2 * self.max_w + 1);
        self.ensure_slots();
        let cells = self.registry.cfg().cells();
        let solution = PatternSolution::Table(outcomes);
        let bytes = solution.estimated_bytes(cells);
        if let Some(old) = self.slots[pid as usize].take() {
            self.resident_bytes -= old.bytes.min(self.resident_bytes);
        }
        self.resident_bytes += bytes;
        self.slots[pid as usize] =
            Some(SolutionSlot { solution, last_used: self.epoch, hit: true, bytes });
    }

    /// Install freshly solved per-weight entries (the `PerWeight` tier's
    /// absorb step).
    pub fn install_pairs(&mut self, entries: Vec<(PatternId, i64, Outcome)>) {
        self.ensure_slots();
        let cells = self.registry.cfg().cells();
        let per_entry = outcome_bytes(cells) + 16;
        for (pid, w, out) in entries {
            if self.slots[pid as usize].is_none() {
                // Account for the fresh slot's base footprint so eviction
                // (which subtracts the full slot.bytes) stays in balance.
                self.resident_bytes += 48;
            }
            let slot = self.slots[pid as usize].get_or_insert_with(|| SolutionSlot {
                solution: PatternSolution::Pairs(FnvMap::default()),
                last_used: self.epoch,
                hit: true,
                bytes: 48,
            });
            slot.hit = true;
            slot.last_used = self.epoch;
            match &mut slot.solution {
                PatternSolution::Pairs(m) => {
                    if m.insert(w, out).is_none() {
                        slot.bytes += per_entry;
                        self.resident_bytes += per_entry;
                    }
                }
                PatternSolution::Table(_) => {
                    unreachable!("a full table is never a solve miss")
                }
            }
        }
    }

    /// Immutable per-pattern view of every resident solution, indexed by
    /// [`PatternId`] over the full registry. The batch scatter phase
    /// resolves millions of weights; borrowing the slot vector once hoists
    /// the per-weight bounds/`Option` probes of [`SolveCache::get`] out of
    /// the hot loop.
    pub fn solution_views(&self) -> Vec<Option<&PatternSolution>> {
        (0..self.registry.len())
            .map(|pid| self.slots.get(pid).and_then(|s| s.as_ref()).map(|s| &s.solution))
            .collect()
    }

    /// The resident solution of pattern `pid` **if it was touched in the
    /// current batch epoch** (scanned, served, or freshly solved since the
    /// last [`SolveCache::begin_batch`]). This is the shard-fragment
    /// extractor's view: a shard ships exactly the solutions the current
    /// batch produced or re-used, never stale residents from earlier
    /// batches.
    pub fn solution_if_current(&self, pid: PatternId) -> Option<&PatternSolution> {
        let slot = self.slots.get(pid as usize)?.as_ref()?;
        (slot.last_used == self.epoch).then_some(&slot.solution)
    }

    /// Total solved entries resident across every pattern (full-range
    /// table entries count individually).
    pub fn solved_pairs(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|s| s.as_ref())
            .map(|s| s.solution.len())
            .sum()
    }

    /// Estimated resident bytes of all pattern solutions.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Pattern solutions evicted so far to honor the memory budget.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The serializable warm state, in pattern-id order: (fault pattern,
    /// solution) for every slot that is non-empty **and was hit** in this
    /// cache's lifetime. Entries loaded from an earlier file but never
    /// used since are dropped — that is what keeps warm-start files from
    /// growing monotonically across model revisions.
    pub fn save_parts(&self) -> Vec<(&GroupFaults, &PatternSolution)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(pid, s)| {
                let slot = s.as_ref()?;
                if !slot.hit || slot.solution.is_empty() {
                    return None;
                }
                Some((&self.registry.ctx(pid as PatternId).faults, &slot.solution))
            })
            .collect()
    }

    /// Rebuild a cache from serialized parts. Returns `None` when the
    /// parts are internally inconsistent (duplicate patterns, empty
    /// solutions, or full-range tables of the wrong length for `cfg`).
    /// Rehydrated slots start with `hit = false` (see
    /// [`SolveCache::save_parts`]).
    pub fn from_parts(
        cfg: GroupConfig,
        parts: Vec<(GroupFaults, PatternSolution)>,
        pipeline: Option<PipelineOptions>,
    ) -> Option<SolveCache> {
        let mut cache = SolveCache::new(cfg);
        cache.pipeline = pipeline;
        let cells = cfg.cells();
        for (i, (pattern, solution)) in parts.into_iter().enumerate() {
            if cache.registry.intern(&pattern) as usize != i {
                return None; // duplicate pattern in the stream
            }
            if solution.is_empty() {
                return None;
            }
            if let PatternSolution::Table(t) = &solution {
                if t.len() as i64 != 2 * cache.max_w + 1 {
                    return None;
                }
            }
            let bytes = solution.estimated_bytes(cells);
            cache.resident_bytes += bytes;
            cache.slots.push(Some(SolutionSlot {
                solution,
                last_used: 0,
                hit: false,
                bytes,
            }));
        }
        Some(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::Stage;
    use crate::fault::{FaultRates, FaultState};
    use crate::grouping::Decomposition;
    use crate::util::prng::Rng;

    #[test]
    fn interning_dedupes_by_key() {
        let cfg = GroupConfig::R2C2;
        let mut reg = PatternRegistry::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let mut faulty = GroupFaults::free(cfg.cells());
        faulty.pos[1] = FaultState::Sa1;
        let a = reg.intern(&free);
        let b = reg.intern(&faulty);
        let c = reg.intern(&free);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ctx(b).faults, faulty);
    }

    #[test]
    fn ctx_lazy_builds_are_consistent() {
        let cfg = GroupConfig::R1C4;
        let mut rng = Rng::new(3);
        let faults = GroupFaults::sample(cfg.cells(), &FaultRates::paper_default(), &mut rng);
        let ctx = PatternCtx::new(cfg, faults.clone());
        assert!(!ctx.tables_built());
        let fresh = FaultAnalysis::new(&cfg, &faults);
        assert_eq!(ctx.analysis().range(), fresh.range());
        assert_eq!(ctx.analysis().consecutive, fresh.consecutive);
        let t = ctx.tables();
        assert!(ctx.tables_built());
        let fresh_t = GroupTables::build(&cfg, &faults);
        assert_eq!(t.pos.values(), fresh_t.pos.values());
        assert_eq!(t.neg.values(), fresh_t.neg.values());
    }

    #[test]
    fn registry_ids_are_scan_order_deterministic() {
        let cfg = GroupConfig::R2C2;
        let mut rng = Rng::new(11);
        let maps: Vec<GroupFaults> = (0..500)
            .map(|_| GroupFaults::sample(cfg.cells(), &FaultRates::paper_default(), &mut rng))
            .collect();
        let mut r1 = PatternRegistry::new(cfg);
        let mut r2 = PatternRegistry::new(cfg);
        let ids1 = r1.intern_all(&maps);
        let ids2 = r2.intern_all(&maps);
        assert_eq!(ids1, ids2);
        assert_eq!(r1.len(), r2.len());
        // Every id resolves back to a pattern with the same key.
        for (f, id) in maps.iter().zip(&ids1) {
            assert_eq!(r1.ctx(*id).key, f.pattern_key());
        }
    }

    #[test]
    fn arena_survives_chunk_boundaries() {
        // Fill the registry well past two arena chunks and verify ids,
        // keys, iteration order and clones all stay consistent.
        let cfg = GroupConfig::R2C2;
        let mut reg = PatternRegistry::new(cfg);
        let mut rng = Rng::new(99);
        let mut seen: Vec<(PatternId, GroupFaults)> = Vec::new();
        while reg.len() < 2 * CTX_CHUNK + 7 {
            let f =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.4, p_sa1: 0.4 }, &mut rng);
            let id = reg.intern(&f);
            seen.push((id, f));
        }
        for (id, f) in &seen {
            assert_eq!(reg.ctx(*id).key, f.pattern_key());
            assert_eq!(&reg.ctx(*id).faults, f);
        }
        // patterns() walks ids in order across chunk boundaries; a rebuild
        // from that walk reproduces identical ids (the serializer contract).
        let mut rebuilt = PatternRegistry::new(cfg);
        for (expect, f) in reg.patterns().enumerate() {
            assert_eq!(rebuilt.intern(f) as usize, expect);
        }
        assert_eq!(rebuilt.len(), reg.len());
        let cloned = reg.clone();
        for (id, f) in &seen {
            assert_eq!(&cloned.ctx(*id).faults, f);
        }
    }

    fn ideal_outcome(cfg: &GroupConfig, w: i64) -> Outcome {
        Outcome {
            decomposition: Decomposition::encode_ideal(w, cfg),
            error: 0,
            stage: Stage::FastPath,
        }
    }

    fn full_table(cfg: &GroupConfig) -> Vec<Outcome> {
        let maxv = cfg.max_per_array();
        (-maxv..=maxv).map(|w| ideal_outcome(cfg, w)).collect()
    }

    #[test]
    fn table_install_makes_every_weight_resident() {
        let cfg = GroupConfig::R2C2;
        let mut cache = SolveCache::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let pid = cache.registry.intern(&free);
        cache.begin_batch();
        assert!(!cache.touch(pid, 3), "nothing resident before install");
        assert!(cache.get(pid, 3).is_none());
        cache.install_table(pid, full_table(&cfg));
        // EVERY representable weight is now an O(1) hit — including ones
        // never requested before.
        for w in [-30i64, -7, 0, 3, 30] {
            assert!(cache.touch(pid, w), "w={w} must be resident");
            assert_eq!(
                cache.get(pid, w).unwrap().decomposition,
                Decomposition::encode_ideal(w, &cfg)
            );
        }
        assert_eq!(cache.solved_pairs(), 61);
        assert!(cache.resident_bytes() > 0);
    }

    #[test]
    fn pairs_install_is_per_weight() {
        let cfg = GroupConfig::R2C2;
        let mut cache = SolveCache::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let pid = cache.registry.intern(&free);
        cache.begin_batch();
        cache.install_pairs(vec![(pid, 3, ideal_outcome(&cfg, 3)), (pid, 7, ideal_outcome(&cfg, 7))]);
        assert!(cache.touch(pid, 3));
        assert!(cache.touch(pid, 7));
        assert!(!cache.touch(pid, 9), "unsolved weight is not resident on the pairs tier");
        assert_eq!(cache.solved_pairs(), 2);
        // Duplicate install of the same weight does not double-count.
        let before = cache.resident_bytes();
        cache.install_pairs(vec![(pid, 3, ideal_outcome(&cfg, 3))]);
        assert_eq!(cache.resident_bytes(), before);
        assert_eq!(cache.solved_pairs(), 2);
    }

    #[test]
    fn lru_eviction_honors_budget_deterministically() {
        let cfg = GroupConfig::R2C2;
        let mut cache = SolveCache::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let mut f1 = GroupFaults::free(cfg.cells());
        f1.pos[0] = FaultState::Sa1;
        let mut f2 = GroupFaults::free(cfg.cells());
        f2.neg[1] = FaultState::Sa0;
        let a = cache.registry.intern(&free);
        let b = cache.registry.intern(&f1);
        let c = cache.registry.intern(&f2);

        cache.begin_batch();
        cache.install_table(a, full_table(&cfg));
        cache.begin_batch();
        cache.install_table(b, full_table(&cfg));
        cache.begin_batch();
        // Touch `a` so it is the most recently used despite oldest install.
        assert!(cache.touch(a, 0));
        cache.install_table(c, full_table(&cfg));
        let one_table = cache.resident_bytes() / 3;

        // Budget for two tables: the LRU victim must be `b` (oldest
        // last-used epoch), not `a` (touched) or `c` (newest).
        cache.set_table_memory_bytes(2 * one_table + one_table / 2);
        cache.begin_batch();
        assert_eq!(cache.evictions(), 1);
        assert!(cache.get(b, 0).is_none(), "LRU victim must be b");
        assert!(cache.get(a, 0).is_some());
        assert!(cache.get(c, 0).is_some());
        assert!(cache.resident_bytes() <= 2 * one_table + one_table / 2);
        // A re-install after eviction works (re-solve path).
        cache.install_table(b, full_table(&cfg));
        assert!(cache.get(b, 0).is_some());
    }

    #[test]
    fn save_parts_skips_never_hit_and_roundtrips() {
        let cfg = GroupConfig::R2C2;
        let mut cache = SolveCache::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let mut faulty = GroupFaults::free(cfg.cells());
        faulty.pos[0] = FaultState::Sa1;
        let a = cache.registry.intern(&free);
        let b = cache.registry.intern(&faulty);
        cache.begin_batch();
        cache.install_table(a, full_table(&cfg));
        cache.install_pairs(vec![(b, 5, ideal_outcome(&cfg, 5))]);

        let parts: Vec<(GroupFaults, PatternSolution)> = cache
            .save_parts()
            .into_iter()
            .map(|(p, s)| (p.clone(), s.clone()))
            .collect();
        assert_eq!(parts.len(), 2, "both freshly solved patterns are saved");

        let mut warm = SolveCache::from_parts(cfg, parts, cache.pipeline().copied())
            .expect("consistent parts must rebuild");
        assert_eq!(warm.solved_pairs(), cache.solved_pairs());
        let pid_a = warm.registry.intern(&free);
        assert_eq!(warm.get(pid_a, 3).unwrap().decomposition, Decomposition::encode_ideal(3, &cfg));

        // Never-hit slots are dropped at the next save: only the table we
        // actually touched after reload survives.
        warm.begin_batch();
        assert!(warm.touch(pid_a, 3));
        let second = warm.save_parts();
        assert_eq!(second.len(), 1, "never-hit warm entries must be skipped");
        assert_eq!(second[0].0, &free);

        // Inconsistent parts are rejected, not mis-assembled.
        let dup = vec![
            (free.clone(), PatternSolution::Table(full_table(&cfg))),
            (free.clone(), PatternSolution::Table(full_table(&cfg))),
        ];
        assert!(SolveCache::from_parts(cfg, dup, None).is_none());
        let short = vec![(free.clone(), PatternSolution::Table(vec![ideal_outcome(&cfg, 0)]))];
        assert!(SolveCache::from_parts(cfg, short, None).is_none());
        let empty = vec![(free, PatternSolution::Pairs(crate::util::fnv::FnvMap::default()))];
        assert!(SolveCache::from_parts(cfg, empty, None).is_none());
    }
}
