//! Pattern-class registry: the dedupe-first compiler core.
//!
//! At realistic SAF rates most groups are fault-free or share a
//! low-cardinality fault pattern, so the compiler's unit of work is not a
//! weight but a **pattern class**: the set of weights whose groups carry
//! the same `GroupFaults` pattern. This module interns patterns by their
//! dense [`crate::fault::PatternKey`] and attaches one shared
//! [`PatternCtx`] per class — the `FaultAnalysis` and `GroupTables` that
//! the legacy per-weight pipeline rebuilt for every single weight are now
//! built at most once per class, lazily, and shared across worker threads.
//!
//! [`SolveCache`] extends the dedup one level further: a chip-wide
//! (pattern, weight) → [`Outcome`] cache. Tensors compiled through the
//! same cache (see `compile_model`) reuse each other's solved pairs, so a
//! pattern+weight combination recurring in layer 17 of a model costs a
//! hash lookup, not a solve. Both structures are deterministic: pattern
//! ids and solve slots are assigned in first-seen scan order, independent
//! of thread count.

use super::pipeline::{Outcome, PipelineOptions};
use crate::decompose::GroupTables;
use crate::fault::{GroupFaults, PatternKey};
use crate::grouping::{FaultAnalysis, GroupConfig};
use crate::util::fnv::FnvMap;
use std::sync::OnceLock;

/// Index of an interned pattern within its [`PatternRegistry`].
pub type PatternId = u32;

/// Shared solve context for one fault-pattern class: the fault map itself
/// plus its analysis and decomposition tables, built at most once and
/// shared by every weight (and every worker thread) in the class.
#[derive(Clone, Debug)]
pub struct PatternCtx {
    pub cfg: GroupConfig,
    pub faults: GroupFaults,
    /// Dense interning key (see [`GroupFaults::pattern_key`]).
    pub key: PatternKey,
    fault_free: bool,
    analysis: OnceLock<FaultAnalysis>,
    tables: OnceLock<GroupTables>,
}

impl PatternCtx {
    pub fn new(cfg: GroupConfig, faults: GroupFaults) -> PatternCtx {
        let key = faults.pattern_key();
        let fault_free = faults.is_fault_free();
        PatternCtx {
            cfg,
            faults,
            key,
            fault_free,
            analysis: OnceLock::new(),
            tables: OnceLock::new(),
        }
    }

    #[inline]
    pub fn is_fault_free(&self) -> bool {
        self.fault_free
    }

    /// Theorem-1/2 analysis for this class (built on first use).
    pub fn analysis(&self) -> &FaultAnalysis {
        self.analysis.get_or_init(|| FaultAnalysis::new(&self.cfg, &self.faults))
    }

    /// Decomposition tables for this class (built on first use; threads
    /// block on the single builder rather than re-running the DP).
    pub fn tables(&self) -> &GroupTables {
        self.tables.get_or_init(|| GroupTables::build(&self.cfg, &self.faults))
    }

    /// Whether the (expensive) tables were ever materialized.
    pub fn tables_built(&self) -> bool {
        self.tables.get().is_some()
    }
}

/// Interning registry of fault-pattern classes for one grouping config.
///
/// Pattern ids are assigned in first-intern order, so a registry filled by
/// a deterministic scan is itself deterministic.
#[derive(Clone, Debug)]
pub struct PatternRegistry {
    cfg: GroupConfig,
    by_key: FnvMap<PatternKey, PatternId>,
    ctxs: Vec<PatternCtx>,
}

impl PatternRegistry {
    pub fn new(cfg: GroupConfig) -> PatternRegistry {
        PatternRegistry { cfg, by_key: FnvMap::default(), ctxs: Vec::new() }
    }

    pub fn cfg(&self) -> &GroupConfig {
        &self.cfg
    }

    /// Intern one pattern, returning its class id.
    pub fn intern(&mut self, faults: &GroupFaults) -> PatternId {
        let key = faults.pattern_key();
        if let Some(&id) = self.by_key.get(&key) {
            return id;
        }
        let id = self.ctxs.len() as PatternId;
        self.by_key.insert(key, id);
        self.ctxs.push(PatternCtx::new(self.cfg, faults.clone()));
        id
    }

    /// Scan a tensor's fault maps, interning every pattern. Returns one
    /// class id per group, aligned with the input.
    pub fn intern_all(&mut self, faults: &[GroupFaults]) -> Vec<PatternId> {
        faults.iter().map(|f| self.intern(f)).collect()
    }

    /// Interned fault patterns in id order (the session cache serializer
    /// walks these; re-interning them in order reproduces the same ids).
    pub fn patterns(&self) -> impl Iterator<Item = &GroupFaults> {
        self.ctxs.iter().map(|c| &c.faults)
    }

    pub fn ctx(&self, id: PatternId) -> &PatternCtx {
        &self.ctxs[id as usize]
    }

    /// Number of distinct pattern classes interned so far.
    pub fn len(&self) -> usize {
        self.ctxs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ctxs.is_empty()
    }

    /// How many classes materialized their decomposition tables.
    pub fn tables_built(&self) -> usize {
        self.ctxs.iter().filter(|c| c.tables_built()).count()
    }
}

/// Chip-wide (pattern, weight) → [`Outcome`] solve cache.
///
/// One `SolveCache` per chip: every tensor compiled through it shares the
/// pattern registry and the solved pairs of all tensors before it. Slots
/// are assigned in first-seen order, so the cache contents — and every
/// compilation drawing on them — are byte-deterministic regardless of
/// thread count.
#[derive(Clone, Debug)]
pub struct SolveCache {
    pub registry: PatternRegistry,
    index: FnvMap<(PatternId, i64), u32>,
    solved: Vec<Outcome>,
    /// Pipeline options the cached outcomes were solved under; set on
    /// first use. Outcomes are keyed by (pattern, weight) only, so mixing
    /// pipelines in one cache would silently serve stale solutions.
    pipeline: Option<PipelineOptions>,
}

impl SolveCache {
    pub fn new(cfg: GroupConfig) -> SolveCache {
        SolveCache {
            registry: PatternRegistry::new(cfg),
            index: FnvMap::default(),
            solved: Vec::new(),
            pipeline: None,
        }
    }

    /// Bind the cache to one set of pipeline options (first caller wins;
    /// later callers must match or the cached outcomes would be invalid).
    pub fn bind_pipeline(&mut self, p: &PipelineOptions) {
        match self.pipeline {
            None => self.pipeline = Some(*p),
            Some(bound) => assert_eq!(
                bound, *p,
                "solve cache reused with different pipeline options"
            ),
        }
    }

    /// Map every (pattern-id, weight) to a solve slot, collecting the
    /// pairs not yet solved. Returns the per-weight slot assignment plus
    /// the fresh pairs in slot order; the caller must solve them and pass
    /// the outcomes to [`SolveCache::absorb`] before resolving slots.
    pub fn dedupe(
        &mut self,
        pids: &[PatternId],
        weights: &[i64],
    ) -> (Vec<u32>, Vec<(PatternId, i64)>) {
        let mut fresh: Vec<(PatternId, i64)> = Vec::new();
        let slots = self.dedupe_pending(pids, weights, &mut fresh);
        (slots, fresh)
    }

    /// Batched variant of [`SolveCache::dedupe`]: fresh pairs accumulate
    /// into a caller-owned `pending` list so several tensors can be
    /// deduped back-to-back before a single solve + [`SolveCache::absorb`]
    /// round. Slot numbering continues past both the solved pairs and the
    /// pending tail, so slots from consecutive calls never collide.
    pub fn dedupe_pending(
        &mut self,
        pids: &[PatternId],
        weights: &[i64],
        pending: &mut Vec<(PatternId, i64)>,
    ) -> Vec<u32> {
        debug_assert_eq!(pids.len(), weights.len());
        let mut slots = Vec::with_capacity(weights.len());
        for (&pid, &w) in pids.iter().zip(weights.iter()) {
            let next = (self.solved.len() + pending.len()) as u32;
            let slot = match self.index.get(&(pid, w)) {
                Some(&s) => s,
                None => {
                    self.index.insert((pid, w), next);
                    pending.push((pid, w));
                    next
                }
            };
            slots.push(slot);
        }
        slots
    }

    /// Append outcomes for the pairs returned by the latest
    /// [`SolveCache::dedupe`], in the same order.
    pub fn absorb(&mut self, outcomes: Vec<Outcome>) {
        self.solved.extend(outcomes);
    }

    pub fn outcome(&self, slot: u32) -> &Outcome {
        &self.solved[slot as usize]
    }

    /// Total unique (pattern, weight) pairs solved through this cache.
    pub fn solved_pairs(&self) -> usize {
        self.solved.len()
    }

    /// Pipeline options the cached outcomes were solved under (set on the
    /// first compilation through this cache).
    pub fn pipeline(&self) -> Option<&PipelineOptions> {
        self.pipeline.as_ref()
    }

    /// Solved (pattern-id, weight) pairs in slot order — the serialization
    /// counterpart of the outcomes returned by [`SolveCache::outcome`].
    pub fn pairs(&self) -> Vec<(PatternId, i64)> {
        debug_assert_eq!(self.index.len(), self.solved.len());
        let mut out = vec![(0 as PatternId, 0i64); self.solved.len()];
        for (&(pid, w), &slot) in &self.index {
            out[slot as usize] = (pid, w);
        }
        out
    }

    /// Rebuild a cache from serialized parts: patterns in id order, solved
    /// pairs in slot order with their outcomes, and the pipeline options
    /// the outcomes were solved under. Returns `None` when the parts are
    /// internally inconsistent (duplicate patterns or pairs, pair counts
    /// disagreeing with outcomes, pattern ids out of range).
    pub fn from_parts(
        cfg: GroupConfig,
        patterns: &[GroupFaults],
        pairs: Vec<(PatternId, i64)>,
        outcomes: Vec<Outcome>,
        pipeline: Option<PipelineOptions>,
    ) -> Option<SolveCache> {
        if pairs.len() != outcomes.len() {
            return None;
        }
        let mut registry = PatternRegistry::new(cfg);
        for (i, p) in patterns.iter().enumerate() {
            if registry.intern(p) as usize != i {
                return None; // duplicate pattern in the stream
            }
        }
        let mut index: FnvMap<(PatternId, i64), u32> = FnvMap::default();
        for (slot, &(pid, w)) in pairs.iter().enumerate() {
            if (pid as usize) >= registry.len() {
                return None;
            }
            if index.insert((pid, w), slot as u32).is_some() {
                return None; // duplicate (pattern, weight) pair
            }
        }
        Some(SolveCache { registry, index, solved: outcomes, pipeline })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::Stage;
    use crate::fault::{FaultRates, FaultState};
    use crate::grouping::Decomposition;
    use crate::util::prng::Rng;

    #[test]
    fn interning_dedupes_by_key() {
        let cfg = GroupConfig::R2C2;
        let mut reg = PatternRegistry::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let mut faulty = GroupFaults::free(cfg.cells());
        faulty.pos[1] = FaultState::Sa1;
        let a = reg.intern(&free);
        let b = reg.intern(&faulty);
        let c = reg.intern(&free);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ctx(b).faults, faulty);
    }

    #[test]
    fn ctx_lazy_builds_are_consistent() {
        let cfg = GroupConfig::R1C4;
        let mut rng = Rng::new(3);
        let faults = GroupFaults::sample(cfg.cells(), &FaultRates::paper_default(), &mut rng);
        let ctx = PatternCtx::new(cfg, faults.clone());
        assert!(!ctx.tables_built());
        let fresh = FaultAnalysis::new(&cfg, &faults);
        assert_eq!(ctx.analysis().range(), fresh.range());
        assert_eq!(ctx.analysis().consecutive, fresh.consecutive);
        let t = ctx.tables();
        assert!(ctx.tables_built());
        let fresh_t = GroupTables::build(&cfg, &faults);
        assert_eq!(t.pos.values(), fresh_t.pos.values());
        assert_eq!(t.neg.values(), fresh_t.neg.values());
    }

    #[test]
    fn registry_ids_are_scan_order_deterministic() {
        let cfg = GroupConfig::R2C2;
        let mut rng = Rng::new(11);
        let maps: Vec<GroupFaults> = (0..500)
            .map(|_| GroupFaults::sample(cfg.cells(), &FaultRates::paper_default(), &mut rng))
            .collect();
        let mut r1 = PatternRegistry::new(cfg);
        let mut r2 = PatternRegistry::new(cfg);
        let ids1 = r1.intern_all(&maps);
        let ids2 = r2.intern_all(&maps);
        assert_eq!(ids1, ids2);
        assert_eq!(r1.len(), r2.len());
        // Every id resolves back to a pattern with the same key.
        for (f, id) in maps.iter().zip(&ids1) {
            assert_eq!(r1.ctx(*id).key, f.pattern_key());
        }
    }

    #[test]
    fn solve_cache_slots_and_absorb_roundtrip() {
        let cfg = GroupConfig::R2C2;
        let mut cache = SolveCache::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let pids = vec![cache.registry.intern(&free); 4];
        let weights = [3i64, 7, 3, 7];
        let (slots, fresh) = cache.dedupe(&pids, &weights);
        assert_eq!(fresh, vec![(0, 3), (0, 7)]);
        assert_eq!(slots, vec![0, 1, 0, 1]);
        let outcomes: Vec<Outcome> = fresh
            .iter()
            .map(|&(_, w)| Outcome {
                decomposition: Decomposition::encode_ideal(w, &cfg),
                error: 0,
                stage: Stage::FastPath,
            })
            .collect();
        cache.absorb(outcomes);
        assert_eq!(cache.solved_pairs(), 2);
        // Second tensor through the same cache: all hits.
        let (slots2, fresh2) = cache.dedupe(&pids[..2], &[7, 3]);
        assert!(fresh2.is_empty());
        assert_eq!(slots2, vec![1, 0]);
        assert_eq!(
            cache.outcome(slots2[1]).decomposition,
            Decomposition::encode_ideal(3, &cfg)
        );
    }

    #[test]
    fn dedupe_pending_spans_tensors_without_slot_collisions() {
        let cfg = GroupConfig::R2C2;
        let mut cache = SolveCache::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let pid = cache.registry.intern(&free);
        let mut pending = Vec::new();
        // Two tensors deduped back-to-back before any absorb.
        let s1 = cache.dedupe_pending(&[pid, pid], &[3, 7], &mut pending);
        let s2 = cache.dedupe_pending(&[pid, pid, pid], &[7, 9, 3], &mut pending);
        assert_eq!(s1, vec![0, 1]);
        assert_eq!(s2, vec![1, 2, 0], "second tensor must reuse pending slots");
        assert_eq!(pending, vec![(pid, 3), (pid, 7), (pid, 9)]);
        let outcomes: Vec<Outcome> = pending
            .iter()
            .map(|&(_, w)| Outcome {
                decomposition: Decomposition::encode_ideal(w, &cfg),
                error: 0,
                stage: Stage::FastPath,
            })
            .collect();
        cache.absorb(outcomes);
        assert_eq!(
            cache.outcome(s2[1]).decomposition,
            Decomposition::encode_ideal(9, &cfg)
        );
    }

    #[test]
    fn cache_pairs_and_from_parts_roundtrip() {
        let cfg = GroupConfig::R2C2;
        let mut cache = SolveCache::new(cfg);
        let free = GroupFaults::free(cfg.cells());
        let mut faulty = GroupFaults::free(cfg.cells());
        faulty.pos[0] = FaultState::Sa1;
        let a = cache.registry.intern(&free);
        let b = cache.registry.intern(&faulty);
        let (slots, fresh) = cache.dedupe(&[a, b, a], &[5, 5, 2]);
        let outcomes: Vec<Outcome> = fresh
            .iter()
            .map(|&(_, w)| Outcome {
                decomposition: Decomposition::encode_ideal(w, &cfg),
                error: 0,
                stage: Stage::FastPath,
            })
            .collect();
        cache.absorb(outcomes);
        let pairs = cache.pairs();
        assert_eq!(pairs, vec![(a, 5), (b, 5), (a, 2)]);

        let patterns: Vec<GroupFaults> = cache.registry.patterns().cloned().collect();
        let saved: Vec<Outcome> =
            (0..pairs.len() as u32).map(|s| cache.outcome(s).clone()).collect();
        let mut rebuilt =
            SolveCache::from_parts(cfg, &patterns, pairs, saved, cache.pipeline().copied())
                .expect("consistent parts must rebuild");
        assert_eq!(rebuilt.solved_pairs(), cache.solved_pairs());
        // The rebuilt cache resolves the same pairs to the same slots.
        let pids = rebuilt.registry.intern_all(&[free.clone(), faulty, free.clone()]);
        let (slots2, fresh2) = rebuilt.dedupe(&pids, &[5, 5, 2]);
        assert!(fresh2.is_empty(), "rebuilt cache must already hold every pair");
        assert_eq!(slots2, slots);

        // Inconsistent parts are rejected, not mis-assembled.
        assert!(SolveCache::from_parts(cfg, &[free.clone(), free.clone()], vec![], vec![], None)
            .is_none());
        let one = Outcome {
            decomposition: Decomposition::encode_ideal(1, &cfg),
            error: 0,
            stage: Stage::FastPath,
        };
        assert!(SolveCache::from_parts(cfg, &[free], vec![(7, 1)], vec![one], None).is_none());
    }
}
