//! `CompileSession` — the chip-scoped compiler API.
//!
//! A physical chip has one fixed SAF pattern, and compilation is a
//! *recurring* per-chip operation: every model revision deployed to the
//! chip is recompiled against the same fault maps. The session is the
//! object that makes this cheap. It owns the chip identity
//! ([`ChipFaults`]), the compile options, and the chip-wide pattern-class
//! state ([`SolveCache`]: interned fault patterns + solved (pattern,
//! weight) pairs), so every tensor compiled through it reuses everything
//! solved before — within a tensor, across tensors, and (via
//! [`CompileSession::save`]/[`CompileSession::load`]) across process
//! lifetimes.
//!
//! ```text
//! let chip = ChipFaults::new(seed, FaultRates::paper_default());
//! let mut session = CompileSession::builder(GroupConfig::R2C2)
//!     .method(Method::Complete)
//!     .threads(8)
//!     .chip(&chip);
//! let compiled = session.compile_tensor("conv1", &weights); // cold
//! session.save(path)?;                                      // persist
//! // …later, possibly another process, same chip…
//! let mut warm = CompileSession::load(path)?;
//! let again = warm.compile_tensor("conv1", &weights);       // zero solves
//! ```
//!
//! ## Migration from the free-function API (removed)
//!
//! | old entry point                              | session method            |
//! |----------------------------------------------|---------------------------|
//! | `compile_tensor(ws, faults, opts)`           | `session.compile_with_faults(ws, faults)` (`.detached()` when there is no chip) |
//! | `compile_tensor_with_cache(ws, f, opts, c)`  | same — the session owns the cache |
//! | `compile_model(tensors, chip, opts)`         | `session.compile_model(tensors)` |
//! | `nn::ChipCompiler::new(chip, opts)`          | unchanged (thin adapter over a session) |
//!
//! ## Tensor identity
//!
//! A tensor's chip region (and therefore its fault maps) is keyed by a
//! `tensor_id`. [`CompileSession::compile_tensor`] derives it from the
//! tensor *name* (FNV-1a), so recompiling `"conv1"` in any later session
//! of the same chip hits the same fault maps — that is what makes
//! warm-start recompiles exact. [`CompileSession::compile_model`] uses
//! sequential ids `0..n` (the historical protocol), and
//! [`CompileSession::compile_tensor_at`] takes an explicit id.
//!
//! ## Persistence format ("RCSS" v2)
//!
//! `save` writes a versioned little-endian binary: magic/version header,
//! the cache key (chip seed + fault rates, [`GroupConfig`], pipeline
//! fingerprint = method + table limit + sparsest), then **per-pattern
//! solutions** — for each saved pattern its fault bytes, a tier tag, and
//! either a dense full-range table (one entry per representable weight,
//! the weight implicit in the index) or the individually solved (weight,
//! outcome) entries sorted by weight — and a trailing FNV-1a checksum
//! over everything before it. Patterns with no solved entries, and
//! entries loaded from an earlier file but never hit since, are skipped,
//! so warm-start files do not grow monotonically across model revisions.
//! `load` verifies the checksum before parsing and rejects truncated,
//! corrupted, version-mismatched (including v1), or internally
//! inconsistent files with an error — never a silently wrong cache.
//! The framing codecs are shared with the shard-fragment format (the
//! crate-internal `persist` module); `docs/ARCHITECTURE.md` documents the
//! exact byte layouts.
//!
//! ## Distributed solve (sharding)
//!
//! One big chip's solve phase can fan out across processes or machines:
//! [`CompileSession::solve_shard`] runs the full scan but solves only one
//! [`super::ShardPlan`] pattern-id range, returning a mergeable
//! [`super::ShardFragment`]; [`CompileSession::merge_fragments`] (or
//! [`CompileSession::from_fragments`]) reassembles the complete warm
//! cache byte-identically to an unsharded compile. See [`super::shard`].

use super::classes::SolveCache;
use super::compiler::{
    compile_batch_with_cache, compile_tensor_per_weight, CompileOptions, CompileStats,
    CompiledTensor, TensorJob,
};
use super::persist::{
    push_u32, read_key, read_pattern_solution, seal, unseal, write_key, write_pattern_solution,
    CacheKey, Reader,
};
use super::pipeline::{Method, PipelineOptions, SolveTier};
use crate::fault::bank::ChipFaults;
use crate::fault::GroupFaults;
use crate::grouping::GroupConfig;
use crate::obs;
use crate::store::StoreHandle;
use crate::util::fnv::FnvMap;
use crate::util::prop::fnv1a;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Magic marker of the session cache format ("RCSS").
pub const SESSION_MAGIC: u32 = 0x5243_5353;
/// Current session cache format version (v2 = per-pattern solution
/// tables; v1 pair files are rejected with a clean version error).
pub const SESSION_VERSION: u32 = 2;

/// A tensor queued via [`CompileSession::submit`], compiled on
/// [`CompileSession::drain`] (or scanned by
/// [`CompileSession::solve_shard`]).
pub(super) struct QueuedTensor {
    pub(super) name: String,
    pub(super) tensor_id: u64,
    pub(super) weights: Vec<i64>,
}

/// Chip-scoped compiler session: one per (chip, grouping config,
/// pipeline). See the module docs for the full story.
///
/// ```
/// use rchg::coordinator::CompileSession;
/// use rchg::fault::bank::ChipFaults;
/// use rchg::fault::FaultRates;
/// use rchg::grouping::GroupConfig;
///
/// let chip = ChipFaults::new(7, FaultRates::paper_default());
/// let mut session = CompileSession::builder(GroupConfig::R2C2).chip(&chip);
/// let weights: Vec<i64> = (-15..=15).collect();
/// let compiled = session.compile_tensor("conv1", &weights);
/// assert_eq!(compiled.decomps.len(), weights.len());
///
/// // Recompiling the same tensor is pure cache hits: zero fresh solves.
/// let again = session.compile_tensor("conv1", &weights);
/// assert_eq!(again.stats.unique_pairs, 0);
/// assert_eq!(again.decomps, compiled.decomps);
/// ```
pub struct CompileSession {
    pub(super) opts: CompileOptions,
    /// `None` for detached sessions (explicit fault maps only).
    pub(super) chip: Option<ChipFaults>,
    /// `None` on the legacy per-weight path (`dedupe = false`).
    pub(super) cache: Option<SolveCache>,
    pub(super) stats: CompileStats,
    pub(super) tensors: usize,
    pub(super) queue: Vec<QueuedTensor>,
}

/// Builder for [`CompileSession`] — finish with
/// [`SessionBuilder::chip`] (chip-scoped) or [`SessionBuilder::detached`]
/// (explicit fault maps only).
///
/// ```
/// use rchg::coordinator::{CompileSession, Method, SolveTier};
/// use rchg::fault::bank::ChipFaults;
/// use rchg::fault::FaultRates;
/// use rchg::grouping::GroupConfig;
///
/// let chip = ChipFaults::new(1, FaultRates::paper_default());
/// let session = CompileSession::builder(GroupConfig::R2C2)
///     .method(Method::Complete)
///     .threads(4)
///     .solve_tier(SolveTier::BatchTable)
///     .table_memory_bytes(64 << 20)
///     .chip(&chip);
/// assert_eq!(session.options().threads, 4);
/// assert!(session.persistable());
/// ```
pub struct SessionBuilder {
    opts: CompileOptions,
    store: Option<StoreHandle>,
}

impl SessionBuilder {
    /// Decomposition method (default [`Method::Complete`]).
    pub fn method(mut self, m: Method) -> SessionBuilder {
        self.opts.pipeline.method = m;
        self
    }

    /// Worker threads for the solve fan-out (default 1, the paper's
    /// single-thread protocol). Thread count never changes results.
    pub fn threads(mut self, t: usize) -> SessionBuilder {
        self.opts.threads = t.max(1);
        self
    }

    /// Full pipeline tunables (method, table limit, sparsest mode).
    pub fn pipeline(mut self, p: PipelineOptions) -> SessionBuilder {
        self.opts.pipeline = p;
        self
    }

    /// Toggle the dedupe-first pattern-class core (default on). Off
    /// selects the legacy per-weight path — no cache, no persistence.
    pub fn dedupe(mut self, on: bool) -> SessionBuilder {
        self.opts.dedupe = on;
        self
    }

    /// Solve-backend tier (default [`SolveTier::BatchTable`]: one solve
    /// per pattern for its whole weight range). The tier never changes
    /// outputs, only where solve time is spent — see
    /// [`CompileOptions::effective_tier`] for the gate.
    pub fn solve_tier(mut self, tier: SolveTier) -> SessionBuilder {
        self.opts.tier = tier;
        self
    }

    /// Resident-memory budget for per-pattern solution tables, in bytes
    /// (default [`super::classes::DEFAULT_TABLE_MEMORY_BYTES`]).
    /// Least-recently-used patterns are evicted at batch boundaries once
    /// the estimate exceeds it; eviction costs re-solves, never changes
    /// outputs.
    pub fn table_memory_bytes(mut self, bytes: usize) -> SessionBuilder {
        self.opts.table_memory_bytes = bytes.max(1);
        self
    }

    /// Charge wall time to per-stage buckets (default on; see
    /// [`CompileOptions::time_stages`]).
    pub fn time_stages(mut self, on: bool) -> SessionBuilder {
        self.opts.time_stages = on;
        self
    }

    /// Replace the options wholesale (migration helper for callers that
    /// already carry a [`CompileOptions`]).
    pub fn options(mut self, opts: CompileOptions) -> SessionBuilder {
        self.opts = opts;
        self
    }

    /// Attach a fleet-global solution store (see [`crate::store`]): the
    /// solve phase consults it for fresh full-range patterns before
    /// solving locally, and publishes everything it solved. One
    /// [`StoreHandle`] clone can be shared across any number of
    /// sessions — that sharing is the whole point (solutions depend
    /// only on pattern + config + pipeline, never on the chip).
    /// Ignored by legacy (`dedupe = false`) sessions.
    pub fn store(mut self, store: StoreHandle) -> SessionBuilder {
        self.store = Some(store);
        self
    }

    /// Bind the session to a chip: tensors compiled by name/id sample
    /// their fault maps from this chip's fault universe.
    pub fn chip(self, chip: &ChipFaults) -> CompileSession {
        CompileSession::from_opts(self.opts, Some(chip.clone()), self.store)
    }

    /// A session without a chip binding — only
    /// [`CompileSession::compile_with_faults`] works; `save` is refused
    /// (there is no chip identity to key the cache by).
    pub fn detached(self) -> CompileSession {
        CompileSession::from_opts(self.opts, None, self.store)
    }
}

impl CompileSession {
    /// Start building a session for one grouping configuration.
    pub fn builder(cfg: GroupConfig) -> SessionBuilder {
        SessionBuilder { opts: CompileOptions::new(cfg, Method::Complete), store: None }
    }

    /// Session matching a warm-state cache key — the shared constructor
    /// of everything that rebuilds a session from a serialized identity:
    /// the shard merge ([`CompileSession::from_fragments`]), the network
    /// fabric's workers (a wire-delivered shard job), and the fabric
    /// coordinator. Execution knobs (threads, tier, budget) stay at their
    /// defaults; adjust with the `set_*` methods.
    pub(crate) fn for_key(key: &CacheKey) -> CompileSession {
        let mut opts = CompileOptions::new(key.cfg, key.pipeline.method);
        opts.pipeline = key.pipeline;
        CompileSession::builder(key.cfg).options(opts).chip(&key.chip)
    }

    fn from_opts(
        opts: CompileOptions,
        chip: Option<ChipFaults>,
        store: Option<StoreHandle>,
    ) -> CompileSession {
        let cache = opts.dedupe.then(|| {
            let mut cache = SolveCache::new(opts.cfg);
            if let Some(store) = store {
                cache.set_store(store);
            }
            cache
        });
        CompileSession {
            opts,
            chip,
            cache,
            stats: CompileStats::default(),
            tensors: 0,
            queue: Vec::new(),
        }
    }

    /// The chip this session compiles for (`None` when detached).
    pub fn chip(&self) -> Option<&ChipFaults> {
        self.chip.as_ref()
    }

    pub fn options(&self) -> &CompileOptions {
        &self.opts
    }

    /// Statistics accumulated over every compilation in this session
    /// (wall time summed across compiles — `merge_with_wall` semantics).
    pub fn stats(&self) -> &CompileStats {
        &self.stats
    }

    /// Tensors compiled so far (including drained batches).
    pub fn tensors_compiled(&self) -> usize {
        self.tensors
    }

    /// Unique (pattern, weight) pairs solved through this session's cache.
    pub fn solved_pairs(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.solved_pairs())
    }

    /// Distinct fault-pattern classes interned so far.
    pub fn pattern_classes(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.registry.len())
    }

    /// Adjust worker threads (never changes results, only wall clock).
    pub fn set_threads(&mut self, threads: usize) {
        self.opts.threads = threads.max(1);
    }

    /// Toggle per-stage wall-time accounting.
    pub fn set_time_stages(&mut self, on: bool) {
        self.opts.time_stages = on;
    }

    /// Adjust the solve-backend tier (never changes outputs, only where
    /// solve time is spent).
    pub fn set_solve_tier(&mut self, tier: SolveTier) {
        self.opts.tier = tier;
    }

    /// Adjust the pattern-solution memory budget (applies from the next
    /// compilation batch; eviction never changes outputs).
    pub fn set_table_memory_bytes(&mut self, bytes: usize) {
        self.opts.table_memory_bytes = bytes.max(1);
    }

    /// Attach (or replace) the fleet-global solution store on a live
    /// session — e.g. one rehydrated via [`CompileSession::load`] or
    /// [`CompileSession::from_bytes`], which always start store-less
    /// (the store is fleet state, never part of the chip-scoped RCSS
    /// bytes). No-op on legacy (`dedupe = false`) sessions, which have
    /// no cache for the store to serve.
    pub fn set_store(&mut self, store: StoreHandle) {
        if let Some(cache) = self.cache.as_mut() {
            cache.set_store(store);
        }
    }

    /// The attached fleet store, if any.
    pub fn store(&self) -> Option<&StoreHandle> {
        self.cache.as_ref().and_then(|c| c.store())
    }

    /// Whether this session's cache key matches (chip seed + rates,
    /// grouping config, pipeline fingerprint, dedupe mode). Used to
    /// validate loaded caches before reusing them — a legacy
    /// (`dedupe = false`) configuration must never adopt a pattern-class
    /// cache, or baseline timings would silently run warm.
    pub fn matches(&self, chip: &ChipFaults, opts: &CompileOptions) -> bool {
        match &self.chip {
            Some(c) => {
                c.chip_seed == chip.chip_seed
                    && c.rates == chip.rates
                    && self.opts.cfg == opts.cfg
                    && self.opts.pipeline == opts.pipeline
                    && self.opts.dedupe == opts.dedupe
            }
            None => false,
        }
    }

    /// Whether this session carries a persistable cache (a chip identity
    /// plus the pattern-class cache; legacy `dedupe = false` sessions and
    /// detached sessions have nothing to save).
    pub fn persistable(&self) -> bool {
        self.chip.is_some() && self.cache.is_some() && self.opts.cfg.cells() <= 16
    }

    /// Deterministic tensor id of a named tensor — FNV-1a of the name, so
    /// the same name addresses the same chip region in every session.
    pub fn tensor_id_of(name: &str) -> u64 {
        fnv1a(name.as_bytes())
    }

    /// Fault maps of tensor `tensor_id` on this session's chip.
    ///
    /// Panics on a detached session (no chip to sample from).
    pub fn sample_faults(&self, tensor_id: u64, n_groups: usize) -> Vec<GroupFaults> {
        let chip = self.chip.as_ref().expect("detached session has no chip to sample faults");
        chip.sample_tensor(tensor_id, n_groups, self.opts.cfg.cells())
    }

    /// Compile one tensor against caller-supplied fault maps. This is the
    /// core every other compile method funnels into; it is also the
    /// migration target of the removed `compile_tensor` /
    /// `compile_tensor_with_cache` free functions.
    pub fn compile_with_faults(
        &mut self,
        weights: &[i64],
        faults: &[GroupFaults],
    ) -> CompiledTensor {
        let out = match self.cache.as_mut() {
            Some(cache) => compile_batch_with_cache(&[TensorJob { weights, faults }], &self.opts, cache)
                .pop()
                .expect("batch of one yields one result"),
            None => compile_tensor_per_weight(weights, faults, &self.opts),
        };
        self.stats.merge_with_wall(&out.stats);
        self.tensors += 1;
        out
    }

    /// Compile a named tensor: the name keys the chip region (see
    /// [`CompileSession::tensor_id_of`]), so recompiling the same name in
    /// a warm session reuses every previously solved pair.
    pub fn compile_tensor(&mut self, name: &str, weights: &[i64]) -> CompiledTensor {
        self.compile_tensor_at(Self::tensor_id_of(name), weights)
    }

    /// Compile a tensor at an explicit chip tensor id.
    pub fn compile_tensor_at(&mut self, tensor_id: u64, weights: &[i64]) -> CompiledTensor {
        let faults = self.sample_faults(tensor_id, weights.len());
        self.compile_with_faults(weights, &faults)
    }

    /// Compile a whole model; tensor `i` occupies chip region `i` (the
    /// historical `compile_model` protocol, so results are byte-identical
    /// to it). Returns `(name, compiled, fault maps)` in input order.
    pub fn compile_model(
        &mut self,
        tensors: &[(String, Vec<i64>)],
    ) -> Vec<(String, CompiledTensor, Vec<GroupFaults>)> {
        tensors
            .iter()
            .enumerate()
            .map(|(i, (name, ws))| {
                let faults = self.sample_faults(i as u64, ws.len());
                let compiled = self.compile_with_faults(ws, &faults);
                (name.clone(), compiled, faults)
            })
            .collect()
    }

    /// Queue a named tensor for the next [`CompileSession::drain`].
    pub fn submit(&mut self, name: &str, weights: Vec<i64>) {
        self.queue.push(QueuedTensor {
            tensor_id: Self::tensor_id_of(name),
            name: name.to_string(),
            weights,
        });
    }

    /// Tensors queued and not yet drained.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Distinct fault patterns the queued tensors will intern, in scan
    /// order (first occurrence wins), without touching any session
    /// state. This is the fabric worker's pre-solve peek: before
    /// running [`CompileSession::solve_shard`] it asks the
    /// coordinator's fleet store for exactly these patterns, so
    /// already-solved classes never fan out locally.
    ///
    /// Panics on a detached session (no chip to sample faults from).
    pub fn queued_patterns(&self) -> Vec<GroupFaults> {
        let cells = self.opts.cfg.cells();
        let chip = self.chip.as_ref().expect("detached session has no chip to sample faults");
        let mut seen: FnvMap<u64, ()> = FnvMap::default();
        let mut out = Vec::new();
        for q in &self.queue {
            for f in chip.sample_tensor(q.tensor_id, q.weights.len(), cells) {
                if seen.insert(f.pattern_key(), ()).is_none() {
                    out.push(f);
                }
            }
        }
        out
    }

    /// Compile every queued tensor in submit order as **one batch**: one
    /// scan/dedupe pass per tensor against the shared cache, then a single
    /// work-stealing solve over the union of fresh pairs, then per-tensor
    /// scatter. Results are byte-identical to compiling the tensors one at
    /// a time in the same order — batching only widens the solve phase.
    pub fn drain(&mut self) -> Vec<(String, CompiledTensor)> {
        let queue = std::mem::take(&mut self.queue);
        if queue.is_empty() {
            return Vec::new();
        }
        let cells = self.opts.cfg.cells();
        let chip = self.chip.as_ref().expect("detached session cannot drain (no chip)");
        let all_faults: Vec<Vec<GroupFaults>> = queue
            .iter()
            .map(|q| chip.sample_tensor(q.tensor_id, q.weights.len(), cells))
            .collect();
        let results = match self.cache.as_mut() {
            Some(cache) => {
                let jobs: Vec<TensorJob<'_>> = queue
                    .iter()
                    .zip(&all_faults)
                    .map(|(q, f)| TensorJob { weights: &q.weights, faults: f })
                    .collect();
                compile_batch_with_cache(&jobs, &self.opts, cache)
            }
            None => queue
                .iter()
                .zip(&all_faults)
                .map(|(q, f)| compile_tensor_per_weight(&q.weights, f, &self.opts))
                .collect(),
        };
        for t in &results {
            self.stats.merge_with_wall(&t.stats);
        }
        self.tensors += results.len();
        queue.into_iter().zip(results).map(|(q, t)| (q.name, t)).collect()
    }

    // ---- persistence ----------------------------------------------------

    /// Serialize the session's warm state (interned patterns + solved
    /// pairs, keyed by chip seed, grouping config, and pipeline
    /// fingerprint) to a versioned, checksummed binary file.
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes()?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, &bytes)
            .with_context(|| format!("write session cache {}", path.display()))
    }

    /// Serialize to the session cache format v2 (see module docs). Only
    /// non-empty, hit pattern solutions are written — warm entries loaded
    /// from an earlier file but never used since are dropped, so files do
    /// not grow monotonically across model revisions.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        // Covers both file saves and fabric session fetches; the span is
        // rooted because serialization runs outside any compile batch.
        let mut sp = obs::span("session.save");
        let chip = self
            .chip
            .as_ref()
            .ok_or_else(|| anyhow!("detached session has no chip identity to persist"))?;
        let cache = self
            .cache
            .as_ref()
            .ok_or_else(|| anyhow!("legacy (dedupe = off) session has no cache to persist"))?;
        let cells = self.opts.cfg.cells();
        // Mirror of the load-side bound: `pattern_key` interning supports
        // at most 16 cells per array (2 arrays × 2 bits each in a u64), so
        // refuse to write a file the reader would reject.
        if cells == 0 || cells > 16 {
            bail!("config {} has {cells} cells per array; the session cache supports at most 16", self.opts.cfg);
        }
        let pipeline = cache.pipeline().copied().unwrap_or(self.opts.pipeline);
        let key = CacheKey::new(chip, self.opts.cfg, pipeline);
        let parts = cache.save_parts();
        sp.field_u64("patterns", parts.len() as u64);

        let entries: usize = parts.iter().map(|(_, s)| s.len()).sum();
        let mut buf: Vec<u8> =
            Vec::with_capacity(80 + parts.len() * (2 * cells + 5) + entries * (17 + 2 * cells));
        push_u32(&mut buf, SESSION_MAGIC);
        push_u32(&mut buf, SESSION_VERSION);
        write_key(&mut buf, &key);
        push_u32(&mut buf, parts.len() as u32);
        for (pattern, solution) in parts {
            // Per-pattern framing: fault bytes, then the tagged solution —
            // for tables the length is implicit (2·max_per_array + 1
            // entries, the weight implicit in the index), smaller and
            // faster than v1's per-pair (pid, w) framing.
            write_pattern_solution(&mut buf, pattern, Some(solution));
        }
        let sealed = seal(buf);
        sp.field_u64("bytes", sealed.len() as u64);
        obs::metrics().inc("session.saves", 1);
        Ok(sealed)
    }

    /// Load a previously saved session. The rehydrated session starts
    /// warm: every (pattern, weight) pair solved before saving is a cache
    /// hit. Threads default to 1 — tune with
    /// [`CompileSession::set_threads`] (thread count never changes
    /// results).
    pub fn load(path: &Path) -> Result<CompileSession> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read session cache {}", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("parse session cache {}", path.display()))
    }

    /// Parse the session cache format, verifying the trailing checksum
    /// first and rejecting any malformed input — including v1 pair-cache
    /// files — with an error.
    pub fn from_bytes(bytes: &[u8]) -> Result<CompileSession> {
        let mut sp = obs::span("session.load");
        sp.field_u64("bytes", bytes.len() as u64);
        let payload = unseal(bytes)?;
        let mut r = Reader::new(payload);
        let magic = r.u32()?;
        if magic != SESSION_MAGIC {
            bail!("bad session cache magic {magic:#010x}");
        }
        let version = r.u32()?;
        if version != SESSION_VERSION {
            bail!(
                "unsupported session cache version {version} (this build reads \
                 {SESSION_VERSION}; v1 pair caches must be rebuilt)"
            );
        }
        let key = read_key(&mut r)?;
        let cells = key.cells();
        let n_patterns = r.u32()? as usize;
        // Sanity cap before allocating: every pattern costs at least its
        // fault bytes plus a tag.
        if r.remaining() < n_patterns * (2 * cells + 1) {
            bail!("session cache truncated ({n_patterns} patterns declared)");
        }
        let mut parts = Vec::with_capacity(n_patterns);
        for _ in 0..n_patterns {
            let (pattern, solution) = read_pattern_solution(&mut r, &key, false)?;
            parts.push((pattern, solution.expect("session entries are never empty")));
        }
        if r.remaining() != 0 {
            bail!("session cache has {} trailing bytes", r.remaining());
        }
        let cache = SolveCache::from_parts(key.cfg, parts, Some(key.pipeline)).ok_or_else(|| {
            anyhow!("inconsistent session cache (duplicate patterns or malformed solutions)")
        })?;
        let mut opts = CompileOptions::new(key.cfg, key.pipeline.method);
        opts.pipeline = key.pipeline;
        sp.field_u64("patterns", n_patterns as u64);
        obs::metrics().inc("session.loads", 1);
        Ok(CompileSession {
            opts,
            chip: Some(key.chip),
            cache: Some(cache),
            stats: CompileStats::default(),
            tensors: 0,
            queue: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultRates;
    use crate::util::prng::Rng;

    fn random_weights(n: usize, max: i64, seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.range_i64(-max, max)).collect()
    }

    #[test]
    fn session_equals_one_shot_compiles() {
        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(5, FaultRates::paper_default());
        let ws = random_weights(2_000, cfg.max_per_array(), 3);
        let mut session = CompileSession::builder(cfg).method(Method::Complete).chip(&chip);
        let a = session.compile_tensor_at(0, &ws);
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let b = CompileSession::builder(cfg)
            .method(Method::Complete)
            .detached()
            .compile_with_faults(&ws, &faults);
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
        assert_eq!(session.tensors_compiled(), 1);
        assert_eq!(session.stats().weights, ws.len());
    }

    #[test]
    fn named_tensors_are_region_stable() {
        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(9, FaultRates::paper_default());
        let ws = random_weights(1_200, cfg.max_per_array(), 8);
        let mut s1 = CompileSession::builder(cfg).chip(&chip);
        let a = s1.compile_tensor("conv1", &ws);
        // A brand-new session of the same chip sees the same region.
        let mut s2 = CompileSession::builder(cfg).chip(&chip);
        let b = s2.compile_tensor("conv1", &ws);
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
        // Recompiling the same name in-session is pure cache hits.
        let again = s1.compile_tensor("conv1", &ws);
        assert_eq!(again.stats.unique_pairs, 0);
        assert_eq!(again.stats.dedup_hits, ws.len());
        assert_eq!(again.decomps, a.decomps);
    }

    #[test]
    fn bytes_roundtrip_preserves_cache() {
        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(21, FaultRates::paper_default());
        let ws = random_weights(3_000, cfg.max_per_array(), 4);
        let mut cold = CompileSession::builder(cfg).chip(&chip);
        let first = cold.compile_tensor("t0", &ws);
        let bytes = cold.to_bytes().unwrap();
        let mut warm = CompileSession::from_bytes(&bytes).unwrap();
        assert!(warm.matches(&chip, cold.options()));
        assert_eq!(warm.solved_pairs(), cold.solved_pairs());
        assert_eq!(warm.pattern_classes(), cold.pattern_classes());
        let again = warm.compile_tensor("t0", &ws);
        assert_eq!(again.stats.unique_pairs, 0, "warm recompile must not solve");
        assert_eq!(again.decomps, first.decomps);
        assert_eq!(again.errors, first.errors);
    }

    #[test]
    fn v2_cache_answers_never_compiled_weights_with_zero_solves() {
        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(31, FaultRates::paper_default());
        let base = random_weights(2_000, cfg.max_per_array(), 6);
        let neg: Vec<i64> = base.iter().map(|w| -w.abs()).collect();
        let pos: Vec<i64> = base.iter().map(|w| w.abs()).collect();
        let mut cold = CompileSession::builder(cfg).chip(&chip);
        let _ = cold.compile_tensor("t", &neg);
        let bytes = cold.to_bytes().unwrap();
        let mut warm = CompileSession::from_bytes(&bytes).unwrap();
        // Same chip region, weight values never compiled before: the
        // per-pattern tables answer them without a single fresh solve —
        // the v1 pair cache would have re-solved every one.
        let out = warm.compile_tensor("t", &pos);
        assert_eq!(out.stats.unique_pairs, 0);
        let mut check = CompileSession::builder(cfg).chip(&chip);
        let want = check.compile_tensor("t", &pos);
        assert_eq!(out.decomps, want.decomps);
        assert_eq!(out.errors, want.errors);
    }

    #[test]
    fn save_drops_entries_never_hit_since_load() {
        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(32, FaultRates::paper_default());
        let ws = random_weights(3_000, cfg.max_per_array(), 7);
        let mut gen0 = CompileSession::builder(cfg).chip(&chip);
        let _ = gen0.compile_tensor("a", &ws);
        let _ = gen0.compile_tensor("b", &ws);
        let full = gen0.to_bytes().unwrap();
        // Reload and touch only tensor "a": the next save keeps a's
        // patterns (hit since load) and drops anything exclusive to "b" —
        // warm files shrink back to what is actually used instead of
        // growing monotonically across revisions.
        let mut gen1 = CompileSession::from_bytes(&full).unwrap();
        let _ = gen1.compile_tensor("a", &ws);
        let pruned = gen1.to_bytes().unwrap();
        assert!(
            pruned.len() < full.len(),
            "stale warm entries must be dropped ({} vs {} bytes)",
            pruned.len(),
            full.len()
        );
        // The pruned file still warm-starts tensor "a" with zero solves.
        let mut warm = CompileSession::from_bytes(&pruned).unwrap();
        let again = warm.compile_tensor("a", &ws);
        assert_eq!(again.stats.unique_pairs, 0);
    }

    #[test]
    fn detached_and_legacy_sessions_refuse_to_persist() {
        let cfg = GroupConfig::R1C4;
        let detached = CompileSession::builder(cfg).detached();
        assert!(!detached.persistable());
        assert!(detached.to_bytes().is_err());
        let chip = ChipFaults::new(1, FaultRates::paper_default());
        let legacy = CompileSession::builder(cfg).dedupe(false).chip(&chip);
        assert!(!legacy.persistable());
        assert!(legacy.to_bytes().is_err());
        // A legacy session is also never mistaken for a warm pattern-class
        // cache of the same chip.
        let mut pattern_opts = CompileOptions::new(cfg, Method::Complete);
        pattern_opts.dedupe = true;
        assert!(!legacy.matches(&chip, &pattern_opts));
        // Save/load symmetry: configs the cache format cannot represent
        // (> 16 cells per array) are refused at save time, not at load.
        let big = GroupConfig::new(4, 8, 4);
        let wide = CompileSession::builder(big).chip(&chip);
        assert!(!wide.persistable());
        assert!(wide.to_bytes().is_err());
    }
}
