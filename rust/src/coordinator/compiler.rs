//! Per-chip, per-model compilation driver — solve-once-per-pattern.
//!
//! This is the L3 coordinator proper; the public face of it is the
//! chip-scoped [`super::CompileSession`] (see [`super::session`]). The
//! pattern-class core runs four phases per batch
//! ([`compile_batch_with_cache`]):
//!
//! 1. **Scan** — intern every group's fault pattern into the chip's
//!    [`super::PatternRegistry`]; each class gets one shared
//!    [`super::PatternCtx`] (lazy `FaultAnalysis` + `GroupTables`).
//! 2. **Dedupe** — resolve every (pattern, weight) request against the
//!    chip-wide [`SolveCache`]; anything already resident (solved by an
//!    earlier tensor, batch, or session generation) is reused outright.
//! 3. **Solve** — on the [`SolveTier::BatchTable`] tier the fresh work
//!    unit is a **pattern**: each missing pattern is solved once for its
//!    whole weight range ([`super::pipeline::solve_full_range`]) and
//!    installed as a dense table; on [`SolveTier::PerWeight`] (the paper
//!    baselines' cost model, and intractable configs) each missing pair
//!    is solved individually. Both fan out over the atomic-counter
//!    work-stealing scheduler ([`crate::util::pool::parallel_work_steal`]);
//!    work order is fixed by the scan, so results are byte-deterministic
//!    at any thread count.
//! 4. **Scatter** — O(1) cache lookups map every weight back to its
//!    outcome and aggregate stage counts/timings for the Table II /
//!    Fig 10 reports.
//!
//! The legacy per-weight path (contiguous ranges + thread-local memo) is
//! retained behind `CompileOptions::dedupe = false` as the equivalence
//! baseline for tests and ablation benches. The old free-function entry
//! points (`compile_tensor`, `compile_tensor_with_cache`, `compile_model`)
//! are gone — build a [`super::CompileSession`] (see its module docs for
//! the migration table).

use super::classes::{PatternId, PatternSolution, SolveCache, DEFAULT_TABLE_MEMORY_BYTES};
use super::pipeline::{
    decompose_one, decompose_with_ctx, solve_full_range, Method, Outcome, PipelineOptions,
    SolveTier, Stage, ALL_STAGES,
};
use crate::fault::{GroupFaults, PatternKey};
use crate::grouping::{Decomposition, GroupConfig};
use crate::ilp::IlpStats;
use crate::obs;
use crate::util::fnv::FnvMap;
use crate::util::pool::{parallel_map_ranges, parallel_work_steal, split_ranges};
use crate::util::timer::{StageClock, Timer};
use std::collections::HashMap;

/// Work-stealing chunk size for the solve phase: large enough to amortize
/// the atomic fetch, small enough to balance skewed pattern classes.
const SOLVE_CHUNK: usize = 64;

/// Weights per solver invocation; `unique_pairs == 0` (legacy path or an
/// empty tensor) counts as no dedup.
pub fn dedup_ratio_of(weights: usize, unique_pairs: usize) -> f64 {
    if unique_pairs == 0 {
        1.0
    } else {
        weights as f64 / unique_pairs as f64
    }
}

/// Options for a compilation run.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    pub cfg: GroupConfig,
    pub pipeline: PipelineOptions,
    /// Worker threads (1 reproduces the paper's single-thread protocol).
    pub threads: usize,
    /// Use the dedupe-first pattern-class core (default). `false` selects
    /// the legacy per-weight path, kept as the equivalence baseline.
    pub dedupe: bool,
    /// Legacy path only: memoize (fault-pattern, weight) → decomposition
    /// per worker thread. The pattern-class core subsumes this globally.
    pub memoize: bool,
    /// Charge wall time to per-stage buckets (Fig 10b). Two clock reads per
    /// solve; disable for pure-throughput runs (§Perf).
    pub time_stages: bool,
    /// Requested solve-backend tier (see [`CompileOptions::effective_tier`]
    /// for the gate that actually applies it). Default
    /// [`SolveTier::BatchTable`]: solve each fault pattern once for its
    /// whole weight range.
    pub tier: SolveTier,
    /// Resident-memory budget (estimated bytes) for per-pattern solution
    /// tables in the chip's [`SolveCache`]; least-recently-used patterns
    /// are evicted at batch boundaries once the estimate exceeds it.
    /// Eviction costs re-solves, never correctness.
    pub table_memory_bytes: usize,
}

impl CompileOptions {
    pub fn new(cfg: GroupConfig, method: Method) -> Self {
        CompileOptions {
            cfg,
            pipeline: PipelineOptions { method, ..Default::default() },
            threads: 1,
            dedupe: true,
            memoize: true,
            time_stages: true,
            tier: SolveTier::default(),
            table_memory_bytes: DEFAULT_TABLE_MEMORY_BYTES,
        }
    }

    /// The tier this compilation actually runs. [`SolveTier::BatchTable`]
    /// applies only where enumerating the whole weight range per pattern
    /// is the right trade: the Complete method on table-tractable configs
    /// (range within the pipeline's table limit, ≤ 16 cells per array).
    /// Everything else — the paper-protocol baselines (FF, ILP-only,
    /// unprotected) and intractable configs — keeps the per-weight cost
    /// model, cached in bounded per-pattern maps.
    pub fn effective_tier(&self) -> SolveTier {
        if self.tier == SolveTier::BatchTable
            && self.pipeline.method == Method::Complete
            && self.cfg.max_per_array() <= self.pipeline.table_value_limit
            && self.cfg.cells() <= 16
        {
            SolveTier::BatchTable
        } else {
            SolveTier::PerWeight
        }
    }
}

/// Aggregated statistics of one tensor/model compilation.
#[derive(Clone, Debug, Default)]
pub struct CompileStats {
    pub weights: usize,
    /// Weights routed to each stage.
    pub stage_counts: Vec<(&'static str, usize)>,
    /// Wall time charged to each stage bucket (cond/fawd/cvm/…). On the
    /// pattern-class path each unique pair is charged once.
    pub clock: StageClock,
    /// Legacy path: thread-local memo hits.
    pub memo_hits: usize,
    /// Distinct fault-pattern classes interned (chip-wide when tensors are
    /// compiled through a shared cache).
    pub unique_patterns: usize,
    /// Unique (pattern, weight) pairs this compilation actually solved —
    /// the number of solver invocations.
    pub unique_pairs: usize,
    /// Weights served from the shared solve cache instead of a fresh
    /// solve (within-tensor repeats + cross-tensor cache hits).
    pub dedup_hits: usize,
    /// Pattern classes that materialized decomposition tables (chip-wide
    /// snapshot at the end of this compilation).
    pub tables_built: usize,
    /// Full-range pattern solution tables batch-solved by this compilation
    /// — the number of solve *sweeps* on the `BatchTable` tier (the
    /// pair-cache baseline sweeps once per unique pair instead).
    pub pattern_tables_built: usize,
    /// Fresh patterns answered by the fleet-global solution store
    /// ([`crate::store`]) instead of a local solve — `BatchTable` tier
    /// only; always 0 when no store is attached. A store hit installs a
    /// byte-identical table, so it trades solve time for nothing else.
    pub store_hits: usize,
    /// Fresh patterns an attached store could not answer: solved locally,
    /// then published back for the rest of the fleet. Always 0 when no
    /// store is attached (`pattern_tables_built` keeps counting local
    /// builds either way).
    pub store_misses: usize,
    /// Pattern solutions evicted so far to honor the memory budget
    /// (chip-wide gauge).
    pub table_evictions: u64,
    /// Estimated resident bytes of pattern solutions (chip-wide gauge at
    /// the end of this compilation).
    pub resident_table_bytes: usize,
    pub ilp: IlpStats,
    /// Σ |w − w̃| over all weights (integer domain).
    pub total_abs_error: u64,
    /// Number of weights with non-zero residual error.
    pub imperfect: usize,
    pub wall_secs: f64,
    /// Wall seconds spent in the scan + dedupe phases (1+2), attributed
    /// proportionally to tensor size. Unlike `wall_secs` this is a phase
    /// bucket charged per batch, so both merge flavors sum it (like
    /// solve-clock time, not like the compilation's own wall clock).
    pub scan_secs: f64,
}

impl CompileStats {
    pub fn count_of(&self, stage: Stage) -> usize {
        self.stage_counts
            .iter()
            .find(|(n, _)| *n == stage.name())
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }

    /// Weights per solver invocation — the pattern-class dedup factor
    /// (1.0 on the legacy path, which solves every weight).
    pub fn dedup_ratio(&self) -> f64 {
        dedup_ratio_of(self.weights, self.unique_pairs)
    }

    /// Merge statistics of separate compilations, summing wall time too.
    /// This is the aggregator for **cross-compilation** roll-ups — the
    /// CNN/LM per-trial totals, session-level stats, and the service's
    /// per-chip report all use it.
    pub fn merge_with_wall(&mut self, other: &CompileStats) {
        self.merge(other);
        self.wall_secs += other.wall_secs;
    }

    /// Merge **intra-compilation** partials (per-range worker stats on
    /// the legacy path). Wall time is deliberately not summed — the
    /// compiler stamps it once from its own timer; anything aggregating
    /// across separate compilations must use
    /// [`CompileStats::merge_with_wall`] instead.
    pub fn merge(&mut self, other: &CompileStats) {
        self.weights += other.weights;
        for (name, c) in &other.stage_counts {
            if let Some(e) = self.stage_counts.iter_mut().find(|(n, _)| n == name) {
                e.1 += c;
            } else {
                self.stage_counts.push((name, *c));
            }
        }
        self.clock.merge(&other.clock);
        self.memo_hits += other.memo_hits;
        // Chip-wide gauges: tensors sharing a cache all see the same
        // (growing) registry, so the merged value is the latest snapshot.
        self.unique_patterns = self.unique_patterns.max(other.unique_patterns);
        self.tables_built = self.tables_built.max(other.tables_built);
        self.table_evictions = self.table_evictions.max(other.table_evictions);
        self.resident_table_bytes = self.resident_table_bytes.max(other.resident_table_bytes);
        self.pattern_tables_built += other.pattern_tables_built;
        self.store_hits += other.store_hits;
        self.store_misses += other.store_misses;
        self.unique_pairs += other.unique_pairs;
        self.dedup_hits += other.dedup_hits;
        self.ilp.nodes += other.ilp.nodes;
        self.ilp.lp_solves += other.ilp.lp_solves;
        self.total_abs_error += other.total_abs_error;
        self.imperfect += other.imperfect;
        self.scan_secs += other.scan_secs;
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "weights={} wall={:.3}s scan={:.3}s imperfect={} ({:.4}%) total|err|={} memo_hits={}\n",
            self.weights,
            self.wall_secs,
            self.scan_secs,
            self.imperfect,
            100.0 * self.imperfect as f64 / self.weights.max(1) as f64,
            self.total_abs_error,
            self.memo_hits,
        );
        if self.unique_pairs > 0 || self.dedup_hits > 0 {
            s.push_str(&format!(
                "patterns={} unique_pairs={} dedup_hits={} ({:.1}x dedup) tables_built={}\n",
                self.unique_patterns,
                self.unique_pairs,
                self.dedup_hits,
                self.dedup_ratio(),
                self.tables_built,
            ));
            s.push_str(&format!(
                "pattern_tables={} resident_table_bytes={} evictions={}\n",
                self.pattern_tables_built, self.resident_table_bytes, self.table_evictions,
            ));
        }
        if self.store_hits > 0 || self.store_misses > 0 {
            s.push_str(&format!(
                "store_hits={} store_misses={} ({:.1}% served by the fleet store)\n",
                self.store_hits,
                self.store_misses,
                100.0 * self.store_hits as f64
                    / (self.store_hits + self.store_misses).max(1) as f64,
            ));
        }
        for (name, c) in &self.stage_counts {
            if *c > 0 {
                s.push_str(&format!("  stage {name:<13} {c:>10}\n"));
            }
        }
        for (bucket, secs) in self.clock.entries() {
            s.push_str(&format!("  time  {bucket:<13} {:>10.3}s\n", secs));
        }
        s
    }
}

/// A compiled tensor: one decomposition per weight plus its residual error.
#[derive(Clone, Debug)]
pub struct CompiledTensor {
    pub cfg: GroupConfig,
    pub decomps: Vec<Decomposition>,
    pub errors: Vec<i64>,
    pub stats: CompileStats,
}

impl CompiledTensor {
    /// Reconstruct the faulty integer weights `w̃` this compilation yields.
    pub fn faulty_weights(&self, faults: &[GroupFaults]) -> Vec<i64> {
        self.decomps
            .iter()
            .zip(faults)
            .map(|(d, f)| d.faulty_value(&self.cfg, f))
            .collect()
    }
}

/// One tensor's input to a batched compilation: parallel slices of weights
/// and their per-group fault maps.
#[derive(Clone, Copy, Debug)]
pub struct TensorJob<'a> {
    pub weights: &'a [i64],
    pub faults: &'a [GroupFaults],
}

/// Compile a batch of tensors against one chip-wide cache in a single
/// scan → dedupe → solve → scatter round: every tensor is scanned and
/// deduped first (in batch order), then **one** work-stealing fan-out
/// solves the union of fresh work, then results are scattered per tensor
/// by O(1) cache lookups. Batching widens the solve phase — work shared
/// by two queued tensors is solved once, and small tensors no longer
/// leave workers idle between solve phases.
///
/// The fresh work unit depends on [`CompileOptions::effective_tier`]:
/// `BatchTable` fans out one [`solve_full_range`] build per missing
/// *pattern* (every weight of that pattern — requested now or by any
/// later tensor — becomes a table read); `PerWeight` fans out one
/// [`decompose_with_ctx`] per missing *pair* (the paper baselines' cost
/// model). Work order is fixed by the scan (batch order), so results are
/// byte-identical to compiling the same tensors one at a time through the
/// same cache, at any thread count — and identical across tiers.
///
/// Per-tensor statistics: solve time, table builds and ILP work are
/// charged to the tensor that first introduced each fresh pattern/pair;
/// the residual batch wall time (scan/dedupe/scatter) is attributed
/// proportionally to tensor size, so summing per-tensor `wall_secs`
/// recovers the batch wall at `threads == 1`. `unique_pairs` counts the
/// distinct (pattern, weight) requests that were not already resident —
/// on a warm cache it is 0 even for weight values never compiled before,
/// because their pattern's table already answers them.
pub fn compile_batch_with_cache(
    jobs: &[TensorJob<'_>],
    opts: &CompileOptions,
    cache: &mut SolveCache,
) -> Vec<CompiledTensor> {
    if jobs.is_empty() {
        return Vec::new();
    }
    compile_batch_inner(jobs, opts, cache)
}

/// Result of the scan + dedupe phases of one batch: per-tensor partial
/// stats, every weight's interned pattern id, and the fresh work this
/// batch must solve. Shared by the full compile
/// ([`compile_batch_with_cache`]) and the sharded solve
/// ([`super::CompileSession::solve_shard`]), which filters the fresh work
/// to its pattern-id range before solving.
pub(crate) struct BatchScan {
    pub(super) per_tensor: Vec<CompileStats>,
    pub(super) tensor_pids: Vec<Vec<PatternId>>,
    /// Missing patterns in first-seen scan order, with the tensor index
    /// that introduced each ([`SolveTier::BatchTable`] work units).
    pub(super) fresh_patterns: Vec<(PatternId, usize)>,
    /// Missing (pattern, weight) requests in scan order, with the tensor
    /// index that introduced each ([`SolveTier::PerWeight`] work units).
    /// On the `BatchTable` tier this is filled only when the caller asked
    /// for it (`collect_pairs`, the shard path's in-range re-count) — the
    /// normal compile never materializes per-pair entries there.
    pub(super) fresh_pairs: Vec<(PatternId, i64, usize)>,
    pub(super) tier: SolveTier,
}

/// Scan-phase work-stealing granularity: groups per stolen chunk. Large
/// enough that per-chunk interner setup amortizes, small enough to keep
/// threads balanced across tensors of uneven size. Chunk boundaries never
/// affect output — the merge re-derives global first-seen order from
/// stream order — so this is a pure throughput knob.
const SCAN_CHUNK: usize = 4096;

/// Stamp the scan phase's wall time into the per-tensor partial stats,
/// attributed proportionally to tensor size (the same attribution rule
/// the batch wall uses for non-solve overhead).
fn stamp_scan_secs(per_tensor: &mut [CompileStats], jobs: &[TensorJob<'_>], secs: f64) {
    let total: usize = jobs.iter().map(|j| j.weights.len()).sum();
    if total == 0 {
        return;
    }
    for (st, j) in per_tensor.iter_mut().zip(jobs) {
        st.scan_secs = secs * j.weights.len() as f64 / total as f64;
    }
}

/// Phases 1+2 per tensor, in batch order — scan: intern each group's
/// fault pattern; dedupe: mark resident requests as hits, collect the
/// fresh work (patterns or pairs, by tier) with the tensor that
/// introduced each unit. Also starts the batch (pipeline binding, memory
/// budget, LRU epoch) on the cache. `collect_pairs` forces per-pair
/// collection on the `BatchTable` tier too (see [`BatchScan::fresh_pairs`]).
///
/// The scan itself is parallel: pattern-key derivation and interning —
/// the part that dominates on realistic fault maps — runs as chunk-local
/// scans over [`parallel_work_steal`], and a sequential merge remaps
/// chunk-local ids onto the canonical registry. Because the merge walks
/// chunks in stream order and each chunk's distinct patterns are recorded
/// in chunk-local first-seen order, canonical ids land in **global**
/// first-seen order — so registry order, `fresh_patterns`/`fresh_pairs`
/// order, and every stat are byte-identical to the sequential loop
/// ([`scan_batch_reference`], property-pinned) at any thread count. The
/// epoch-stateful dedupe against the [`SolveCache`] is inherently
/// order-dependent and stays in the sequential tail.
pub(crate) fn scan_batch(
    jobs: &[TensorJob<'_>],
    opts: &CompileOptions,
    cache: &mut SolveCache,
    collect_pairs: bool,
) -> BatchScan {
    let threads = opts.threads.max(1);
    let total: usize = jobs.iter().map(|j| j.faults.len()).sum();
    if threads == 1 || total < 2 * SCAN_CHUNK {
        // No parallelism to exploit — the reference loop *is* the scan.
        return scan_batch_reference(jobs, opts, cache, collect_pairs);
    }
    let timer = Timer::start();
    for j in jobs {
        assert_eq!(j.weights.len(), j.faults.len(), "one fault map per weight group");
    }
    assert_eq!(*cache.registry.cfg(), opts.cfg, "solve cache bound to a different config");
    cache.bind_pipeline(&opts.pipeline);
    cache.set_table_memory_bytes(opts.table_memory_bytes);
    cache.begin_batch();
    let tier = opts.effective_tier();
    let want_pairs = collect_pairs || tier == SolveTier::PerWeight;

    // Phase 1a (parallel): each chunk derives its groups' pattern keys and
    // interns them into a chunk-local table, recording each distinct
    // pattern's key and first flat index — no allocation per group, no
    // clone per pattern.
    let flat: Vec<&GroupFaults> = jobs.iter().flat_map(|j| j.faults.iter()).collect();
    struct ChunkScan {
        /// Chunk-local pattern id per group, in stream order.
        ids: Vec<u32>,
        /// Distinct patterns in chunk-local first-seen order: derived key
        /// plus the flat index of the first occurrence.
        fresh: Vec<(PatternKey, usize)>,
    }
    let n_chunks = total.div_ceil(SCAN_CHUNK);
    let chunks: Vec<ChunkScan> = parallel_work_steal(n_chunks, threads, 1, |c| {
        let range = c * SCAN_CHUNK..((c + 1) * SCAN_CHUNK).min(total);
        let mut local: FnvMap<PatternKey, u32> = FnvMap::default();
        let mut ids = Vec::with_capacity(range.len());
        let mut fresh: Vec<(PatternKey, usize)> = Vec::new();
        for i in range {
            let key = flat[i].pattern_key();
            let next = fresh.len() as u32;
            let id = *local.entry(key).or_insert_with(|| {
                fresh.push((key, i));
                next
            });
            ids.push(id);
        }
        ChunkScan { ids, fresh }
    });

    // Phase 1b (sequential merge): walk chunks in stream order, intern
    // each chunk's distinct patterns into the canonical registry, then
    // remap its chunk-local ids. Chunk-local first-seen order nested in
    // chunk order *is* global stream first-seen order, so canonical ids
    // match the sequential scan's exactly.
    let mut pids: Vec<PatternId> = Vec::with_capacity(total);
    let mut remap: Vec<PatternId> = Vec::new();
    for c in &chunks {
        remap.clear();
        remap.extend(
            c.fresh.iter().map(|&(key, i)| cache.registry.intern_with_key(flat[i], key)),
        );
        pids.extend(c.ids.iter().map(|&l| remap[l as usize]));
    }
    let mut tensor_pids: Vec<Vec<PatternId>> = Vec::with_capacity(jobs.len());
    let mut off = 0;
    for j in jobs {
        let n = j.weights.len();
        tensor_pids.push(pids[off..off + n].to_vec());
        off += n;
    }

    // Phase 2 (sequential, order-dependent): the reference dedupe loop
    // over the canonical ids, verbatim.
    let mut per_tensor: Vec<CompileStats> = vec![CompileStats::default(); jobs.len()];
    let mut batch_seen: FnvMap<(PatternId, i64), ()> = FnvMap::default();
    let mut queued_patterns: FnvMap<PatternId, ()> = FnvMap::default();
    let mut fresh_patterns: Vec<(PatternId, usize)> = Vec::new();
    let mut fresh_pairs: Vec<(PatternId, i64, usize)> = Vec::new();
    for (ti, j) in jobs.iter().enumerate() {
        let st = &mut per_tensor[ti];
        for (&pid, &w) in tensor_pids[ti].iter().zip(j.weights.iter()) {
            if cache.touch(pid, w) || batch_seen.insert((pid, w), ()).is_some() {
                st.dedup_hits += 1;
                continue;
            }
            st.unique_pairs += 1;
            if want_pairs {
                fresh_pairs.push((pid, w, ti));
            }
            if tier == SolveTier::BatchTable && queued_patterns.insert(pid, ()).is_none() {
                fresh_patterns.push((pid, ti));
            }
        }
    }
    stamp_scan_secs(&mut per_tensor, jobs, timer.secs());
    BatchScan { per_tensor, tensor_pids, fresh_patterns, fresh_pairs, tier }
}

/// The sequential scan loop — the equivalence baseline [`scan_batch`] is
/// property-tested against (same pattern as `diff_table_reference`), and
/// the path small batches and single-thread runs take outright.
pub(crate) fn scan_batch_reference(
    jobs: &[TensorJob<'_>],
    opts: &CompileOptions,
    cache: &mut SolveCache,
    collect_pairs: bool,
) -> BatchScan {
    let timer = Timer::start();
    for j in jobs {
        assert_eq!(j.weights.len(), j.faults.len(), "one fault map per weight group");
    }
    assert_eq!(*cache.registry.cfg(), opts.cfg, "solve cache bound to a different config");
    cache.bind_pipeline(&opts.pipeline);
    cache.set_table_memory_bytes(opts.table_memory_bytes);
    cache.begin_batch();
    let tier = opts.effective_tier();
    let want_pairs = collect_pairs || tier == SolveTier::PerWeight;

    let mut per_tensor: Vec<CompileStats> = vec![CompileStats::default(); jobs.len()];
    let mut tensor_pids: Vec<Vec<PatternId>> = Vec::with_capacity(jobs.len());
    let mut batch_seen: FnvMap<(PatternId, i64), ()> = FnvMap::default();
    let mut queued_patterns: FnvMap<PatternId, ()> = FnvMap::default();
    let mut fresh_patterns: Vec<(PatternId, usize)> = Vec::new();
    let mut fresh_pairs: Vec<(PatternId, i64, usize)> = Vec::new();
    for (ti, j) in jobs.iter().enumerate() {
        let pids = cache.registry.intern_all(j.faults);
        let st = &mut per_tensor[ti];
        for (&pid, &w) in pids.iter().zip(j.weights.iter()) {
            if cache.touch(pid, w) || batch_seen.insert((pid, w), ()).is_some() {
                st.dedup_hits += 1;
                continue;
            }
            st.unique_pairs += 1;
            if want_pairs {
                fresh_pairs.push((pid, w, ti));
            }
            if tier == SolveTier::BatchTable && queued_patterns.insert(pid, ()).is_none() {
                fresh_patterns.push((pid, ti));
            }
        }
        tensor_pids.push(pids);
    }
    stamp_scan_secs(&mut per_tensor, jobs, timer.secs());
    BatchScan { per_tensor, tensor_pids, fresh_patterns, fresh_pairs, tier }
}

/// Phase 3 — solve the scan's fresh work exactly once and install the
/// results into the cache (work-stealing fan-out; work order was fixed by
/// the scan, so output is thread-count independent). Solve wall time and
/// table/ILP work are charged to the per-tensor stats of the tensor that
/// introduced each unit; returns solve seconds per tensor.
pub(super) fn solve_fresh(
    scan: &mut BatchScan,
    opts: &CompileOptions,
    cache: &mut SolveCache,
) -> Vec<f64> {
    let threads = opts.threads.max(1);
    let per_tensor = &mut scan.per_tensor;
    let mut solve_secs = vec![0f64; per_tensor.len()];
    match scan.tier {
        SolveTier::BatchTable => {
            // Fleet-store consult before the fan-out: any fresh pattern
            // the store already holds is installed verbatim (the store's
            // determinism contract makes the table byte-identical to a
            // local solve), and only the remainder is solved locally —
            // then published back for the rest of the fleet. Store hits
            // charge no solve time and build no local table; work order
            // stays fixed by the scan either way.
            let store = cache.store().cloned();
            let sctx = crate::store::StoreCtx::new(opts.cfg, opts.pipeline);
            let mut hits: Vec<(usize, Vec<Outcome>)> = Vec::new();
            let mut misses: Vec<usize> = Vec::new();
            if let Some(store) = &store {
                // Rooted (not parented) because this sequential consult
                // loop is shared by the local batch and the shard-solve
                // paths, which trace under different parents.
                let mut csp = obs::span("compile.store_consult");
                for (i, &(pid, _)) in scan.fresh_patterns.iter().enumerate() {
                    match store.lookup_table(&sctx, &cache.registry.ctx(pid).faults) {
                        Some(t) => hits.push((i, t)),
                        None => misses.push(i),
                    }
                }
                csp.field_u64("hits", hits.len() as u64);
                csp.field_u64("misses", misses.len() as u64);
            } else {
                misses.extend(0..scan.fresh_patterns.len());
            }
            for (i, outs) in hits {
                let (pid, ti) = scan.fresh_patterns[i];
                per_tensor[ti].store_hits += 1;
                cache.install_table(pid, outs);
            }
            let fresh_patterns = &scan.fresh_patterns;
            let registry = &cache.registry;
            let built: Vec<(Vec<Outcome>, StageClock, f64)> =
                parallel_work_steal(misses.len(), threads, 1, |j| {
                    let (pid, _) = fresh_patterns[misses[j]];
                    let t = opts.time_stages.then(Timer::start);
                    let (outs, clock) =
                        solve_full_range(registry.ctx(pid), &opts.pipeline, opts.time_stages);
                    let secs = t.map(|t| t.secs()).unwrap_or(0.0);
                    (outs, clock, secs)
                });
            for (&j, (outs, clock, secs)) in misses.iter().zip(built) {
                let (pid, ti) = fresh_patterns[j];
                let st = &mut per_tensor[ti];
                st.clock.merge(&clock);
                st.pattern_tables_built += 1;
                solve_secs[ti] += secs;
                if let Some(store) = &store {
                    st.store_misses += 1;
                    store.publish_table(&sctx, &cache.registry.ctx(pid).faults, &outs);
                }
                cache.install_table(pid, outs);
            }
        }
        SolveTier::PerWeight => {
            let fresh_pairs = &scan.fresh_pairs;
            let registry = &cache.registry;
            let solved: Vec<(Outcome, IlpStats, f64)> =
                parallel_work_steal(fresh_pairs.len(), threads, SOLVE_CHUNK, |i| {
                    let (pid, w, _) = fresh_pairs[i];
                    let ctx = registry.ctx(pid);
                    let mut ist = IlpStats::default();
                    let t = opts.time_stages.then(Timer::start);
                    let out = decompose_with_ctx(ctx, w, &opts.pipeline, &mut ist);
                    let secs = t.map(|t| t.secs()).unwrap_or(0.0);
                    (out, ist, secs)
                });
            let mut entries = Vec::with_capacity(solved.len());
            for (&(pid, w, ti), (out, ist, secs)) in fresh_pairs.iter().zip(solved) {
                let st = &mut per_tensor[ti];
                st.clock.add(out.stage.bucket(), secs);
                st.ilp.nodes += ist.nodes;
                st.ilp.lp_solves += ist.lp_solves;
                solve_secs[ti] += secs;
                entries.push((pid, w, out));
            }
            cache.install_pairs(entries);
        }
    }
    solve_secs
}

fn compile_batch_inner(
    jobs: &[TensorJob<'_>],
    opts: &CompileOptions,
    cache: &mut SolveCache,
) -> Vec<CompiledTensor> {
    let timer = Timer::start();
    // One span tree per batch, opened on the (sequential) driver thread:
    // the parallel solve fan-out carries no spans of its own, so the
    // record stream's deterministic skeleton is identical at any thread
    // count (pinned by `tests/obs.rs`). Phase timings subsume what
    // `StageClock` reports per stage bucket.
    let mut bspan = obs::span("compile.batch");
    bspan.field_u64("tensors", jobs.len() as u64);
    let mut scan = {
        let mut ssp = obs::child_span("compile.scan", bspan.handle());
        let scan = scan_batch(jobs, opts, cache, false);
        ssp.field_u64("unique_patterns", cache.registry.len() as u64);
        ssp.field_u64("fresh_patterns", scan.fresh_patterns.len() as u64);
        scan
    };
    let solve_secs = {
        let mut vsp = obs::child_span("compile.solve", bspan.handle());
        vsp.field_str("tier", if scan.tier == SolveTier::BatchTable { "table" } else { "pairs" });
        solve_fresh(&mut scan, opts, cache)
    };
    let BatchScan { mut per_tensor, tensor_pids, .. } = scan;
    let scatter_span = obs::child_span("compile.scatter", bspan.handle());

    // Phase 4 — scatter: map every weight to its outcome. The per-pattern
    // solution views are borrowed once for the whole batch (hoisting the
    // per-weight slot/`Option` probes of `SolveCache::get` out of the hot
    // loop); decompositions stream into an exact-capacity buffer through
    // `extend` rather than per-weight pushes; stage tallies use a flat
    // array indexed by `Stage::code` instead of a per-weight hash probe.
    // Output bytes are identical to the per-weight formulation — the
    // byte-determinism suites pin this.
    let views = cache.solution_views();
    let max_w = opts.cfg.max_per_array();
    let mut results = Vec::with_capacity(jobs.len());
    for (ti, j) in jobs.iter().enumerate() {
        let n = j.weights.len();
        let mut stats = std::mem::take(&mut per_tensor[ti]);
        let mut decomps: Vec<Decomposition> = Vec::with_capacity(n);
        let mut errors: Vec<i64> = Vec::with_capacity(n);
        let mut counts = [0usize; ALL_STAGES.len()];
        decomps.extend(tensor_pids[ti].iter().zip(j.weights.iter()).map(|(&pid, &w)| {
            let out = resolve_outcome(&views, pid, w, max_w);
            counts[out.stage.code() as usize] += 1;
            if out.error != 0 {
                stats.imperfect += 1;
                stats.total_abs_error += out.error.unsigned_abs();
            }
            errors.push(out.error);
            out.decomposition.clone()
        }));
        stats.weights = n;
        debug_assert_eq!(stats.unique_pairs + stats.dedup_hits, n);
        stats.unique_patterns = cache.registry.len();
        stats.tables_built = cache.registry.tables_built();
        stats.table_evictions = cache.evictions();
        stats.resident_table_bytes = cache.resident_bytes();
        stats.stage_counts = ALL_STAGES
            .iter()
            .filter(|s| counts[s.code() as usize] > 0)
            .map(|s| (s.name(), counts[s.code() as usize]))
            .collect();
        results.push(CompiledTensor { cfg: opts.cfg, decomps, errors, stats });
    }

    drop(scatter_span);

    let wall = timer.secs();
    let total_weights: usize = jobs.iter().map(|j| j.weights.len()).sum();
    let total_solve: f64 = solve_secs.iter().sum();
    let overhead = (wall - total_solve).max(0.0);
    for (ti, r) in results.iter_mut().enumerate() {
        r.stats.wall_secs = if total_weights == 0 {
            0.0
        } else {
            solve_secs[ti] + overhead * r.stats.weights as f64 / total_weights as f64
        };
    }

    // Mirror the batch's deltas into the global registry — this is the
    // single choke point every session/service/fabric compile flows
    // through, so `compile.*` counters unify what the per-tensor
    // `CompileStats` structs report piecemeal. Metrics never feed an
    // output byte (the legacy `dedupe = false` path is uninstrumented by
    // design: it exists as an equivalence baseline, not a product path).
    let mut fresh_pairs = 0u64;
    let mut dedup_hits = 0u64;
    let mut tables = 0u64;
    let mut store_hits = 0u64;
    let mut store_misses = 0u64;
    for r in &results {
        fresh_pairs += r.stats.unique_pairs as u64;
        dedup_hits += r.stats.dedup_hits as u64;
        tables += r.stats.pattern_tables_built as u64;
        store_hits += r.stats.store_hits as u64;
        store_misses += r.stats.store_misses as u64;
    }
    let m = obs::metrics();
    m.inc("compile.batches", 1);
    m.inc("compile.weights", total_weights as u64);
    m.inc("compile.fresh_pairs", fresh_pairs);
    m.inc("compile.dedup_hits", dedup_hits);
    m.inc("compile.pattern_tables_built", tables);
    m.inc("compile.store_hits", store_hits);
    m.inc("compile.store_misses", store_misses);
    m.observe("compile.batch_us", (wall * 1e6) as u64);
    bspan.field_u64("weights", total_weights as u64);
    bspan.field_u64("fresh_pairs", fresh_pairs);
    bspan.field_u64("dedup_hits", dedup_hits);
    bspan.field_u64("pattern_tables_built", tables);
    results
}

/// Resolve one (pattern, weight) request against the batch's hoisted
/// solution views — the scatter phase's inner step. Panics (like the
/// `expect` it replaces) when the request was neither resident nor solved
/// this batch, which the scan phase rules out.
#[inline]
fn resolve_outcome<'a>(
    views: &[Option<&'a PatternSolution>],
    pid: PatternId,
    w: i64,
    max_w: i64,
) -> &'a Outcome {
    match views[pid as usize] {
        Some(PatternSolution::Table(t)) => {
            let i = w + max_w;
            debug_assert!((0..t.len() as i64).contains(&i), "table-tier weight out of range");
            &t[i as usize]
        }
        Some(PatternSolution::Pairs(m)) => {
            m.get(&w).expect("every request was resident or solved this batch")
        }
        None => panic!("every request was resident or solved this batch"),
    }
}

/// Legacy per-weight compilation: contiguous ranges across threads with
/// thread-local memoization. Kept as the equivalence baseline for the
/// pattern-class core (`CompileOptions::dedupe = false`).
pub(crate) fn compile_tensor_per_weight(
    weights: &[i64],
    faults: &[GroupFaults],
    opts: &CompileOptions,
) -> CompiledTensor {
    assert_eq!(weights.len(), faults.len(), "one fault map per weight group");
    let timer = Timer::start();
    let n = weights.len();
    let threads = opts.threads.max(1);

    // Each worker produces (outcomes for its range, local stats).
    let ranges = split_ranges(n, threads);
    let results: Vec<(Vec<(Decomposition, i64)>, CompileStats)> =
        parallel_map_ranges(ranges.len(), ranges.len(), |rr| {
            rr.map(|i| compile_range(weights, faults, opts, ranges[i].clone()))
                .collect()
        });

    let mut decomps = Vec::with_capacity(n);
    let mut errors = Vec::with_capacity(n);
    let mut stats = CompileStats::default();
    for (chunk, st) in results {
        for (d, e) in chunk {
            decomps.push(d);
            errors.push(e);
        }
        stats.merge(&st);
    }
    stats.wall_secs = timer.secs();
    CompiledTensor { cfg: opts.cfg, decomps, errors, stats }
}

/// Serial compilation of one index range with local memoization.
fn compile_range(
    weights: &[i64],
    faults: &[GroupFaults],
    opts: &CompileOptions,
    range: std::ops::Range<usize>,
) -> (Vec<(Decomposition, i64)>, CompileStats) {
    let mut out = Vec::with_capacity(range.len());
    let mut stats = CompileStats::default();
    let mut counts: HashMap<&'static str, usize> = HashMap::new();
    let mut memo: FnvMap<(u64, i64), (Decomposition, i64, Stage)> = FnvMap::default();
    // Memoizing the fault-free pattern would just duplicate encode_ideal;
    // skip it so the memo holds only interesting patterns.
    let free_key = crate::fault::FREE_PATTERN_KEY;

    for i in range.clone() {
        let w = weights[i];
        let f = &faults[i];
        let key = (f.pattern_key(), w);
        let cached = opts.memoize && key.0 != free_key;
        if cached {
            if let Some((d, e, st)) = memo.get(&key) {
                stats.memo_hits += 1;
                *counts.entry(st.name()).or_insert(0) += 1;
                stats.clock.add(st.bucket(), 0.0);
                if *e != 0 {
                    stats.imperfect += 1;
                    stats.total_abs_error += e.unsigned_abs();
                }
                out.push((d.clone(), *e));
                continue;
            }
        }
        let t = opts.time_stages.then(Timer::start);
        let Outcome { decomposition, error, stage } =
            decompose_one(&opts.cfg, f, w, &opts.pipeline, &mut stats.ilp);
        if let Some(t) = t {
            stats.clock.add(stage.bucket(), t.secs());
        }
        *counts.entry(stage.name()).or_insert(0) += 1;
        if error != 0 {
            stats.imperfect += 1;
            stats.total_abs_error += error.unsigned_abs();
        }
        // Selective memoization: after the dense-table §Perf work the
        // cheap stages (fast path / trivial / greedy) cost less than a
        // memo insert + clone, so only the expensive CVM/ILP/table
        // outcomes are worth caching (ablation: bench_ablation).
        let expensive = matches!(
            stage,
            Stage::TableFawd | Stage::IlpFawd | Stage::TableCvm | Stage::IlpCvm | Stage::FfSearch
        );
        if cached && expensive {
            memo.insert(key, (decomposition.clone(), error, stage));
        }
        out.push((decomposition, error));
    }
    stats.weights = range.len();
    stats.stage_counts = ALL_STAGES
        .iter()
        .filter_map(|s| counts.get(s.name()).map(|c| (s.name(), *c)))
        .collect();
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::CompileSession;
    use crate::fault::bank::ChipFaults;
    use crate::fault::FaultRates;
    use crate::util::prng::Rng;

    fn random_weights(n: usize, max: i64, seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.range_i64(-max, max)).collect()
    }

    /// One-shot compile against explicit fault maps (the old free-function
    /// surface, now a detached throwaway session).
    fn compile_tensor(
        weights: &[i64],
        faults: &[GroupFaults],
        opts: &CompileOptions,
    ) -> CompiledTensor {
        CompileSession::builder(opts.cfg)
            .options(opts.clone())
            .detached()
            .compile_with_faults(weights, faults)
    }

    /// One-shot model compile against a chip (the old `compile_model`
    /// surface, now a throwaway chip session).
    fn compile_model(
        tensors: &[(String, Vec<i64>)],
        chip: &ChipFaults,
        opts: &CompileOptions,
    ) -> Vec<(String, CompiledTensor, Vec<GroupFaults>)> {
        CompileSession::builder(opts.cfg).options(opts.clone()).chip(chip).compile_model(tensors)
    }

    #[test]
    fn compile_tensor_end_to_end() {
        let cfg = GroupConfig::R2C2;
        let opts = CompileOptions::new(cfg, Method::Complete);
        let ws = random_weights(2000, cfg.max_per_array(), 42);
        let chip = ChipFaults::new(7, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let out = compile_tensor(&ws, &faults, &opts);
        assert_eq!(out.decomps.len(), ws.len());
        // Every reported error matches the decomposition's actual error.
        let rec = out.faulty_weights(&faults);
        for ((w, r), e) in ws.iter().zip(&rec).zip(&out.errors) {
            assert_eq!((w - r).abs(), *e);
        }
        assert_eq!(out.stats.weights, ws.len());
        let total: usize = out.stats.stage_counts.iter().map(|(_, c)| c).sum();
        assert_eq!(total, ws.len());
        // Dedup accounting is consistent.
        assert_eq!(out.stats.unique_pairs + out.stats.dedup_hits, ws.len());
        assert!(out.stats.unique_patterns > 0);
        assert!(out.stats.unique_pairs < ws.len(), "R2C2 at scale must dedupe");
    }

    #[test]
    fn pattern_class_path_matches_legacy() {
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(6_000, cfg.max_per_array(), 17);
        let chip = ChipFaults::new(5, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let mut legacy = CompileOptions::new(cfg, Method::Complete);
        legacy.dedupe = false;
        let a = compile_tensor(&ws, &faults, &legacy);
        let b = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
        // Stage routing is identical per weight, so the censuses agree.
        assert_eq!(a.stats.stage_counts, b.stats.stage_counts);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(1500, cfg.max_per_array(), 11);
        let chip = ChipFaults::new(3, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let mut o1 = CompileOptions::new(cfg, Method::Complete);
        o1.threads = 1;
        let mut o4 = o1.clone();
        o4.threads = 4;
        let a = compile_tensor(&ws, &faults, &o1);
        let b = compile_tensor(&ws, &faults, &o4);
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.stats.unique_pairs, b.stats.unique_pairs);
    }

    #[test]
    fn legacy_memoization_preserves_results() {
        // The legacy path's selective memo (expensive stages only) must not
        // change results; use R1C4 at scale where CVM patterns repeat.
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(30_000, cfg.max_per_array(), 5);
        let chip = ChipFaults::new(9, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let mut with = CompileOptions::new(cfg, Method::Complete);
        with.dedupe = false;
        with.memoize = true;
        let mut without = with.clone();
        without.memoize = false;
        let a = compile_tensor(&ws, &faults, &with);
        let b = compile_tensor(&ws, &faults, &without);
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.decomps, b.decomps);
        assert!(a.stats.memo_hits > 0, "memo should hit on 30k R1C4 weights");
        assert_eq!(b.stats.memo_hits, 0);
    }

    #[test]
    fn shared_cache_across_tensors_dedupes_chip_wide() {
        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(4, FaultRates::paper_default());
        let opts = CompileOptions::new(cfg, Method::Complete);
        let ws0 = random_weights(3_000, cfg.max_per_array(), 21);
        let ws1 = random_weights(3_000, cfg.max_per_array(), 22);
        let f0 = chip.sample_tensor(0, ws0.len(), cfg.cells());
        let f1 = chip.sample_tensor(1, ws1.len(), cfg.cells());
        let mut cache = SolveCache::new(cfg);
        let a = compile_batch_with_cache(&[TensorJob { weights: &ws0, faults: &f0 }], &opts, &mut cache)
            .pop()
            .unwrap();
        let b = compile_batch_with_cache(&[TensorJob { weights: &ws1, faults: &f1 }], &opts, &mut cache)
            .pop()
            .unwrap();
        // The second tensor reuses the first tensor's pattern tables: it
        // needs far fewer fresh solves than it has weights, and builds far
        // fewer tables than the first.
        assert!(b.stats.unique_pairs < ws1.len() / 2, "cross-tensor reuse missing");
        assert!(b.stats.pattern_tables_built < a.stats.pattern_tables_built);
        // And results are identical to standalone compilation.
        let standalone = compile_tensor(&ws1, &f1, &opts);
        assert_eq!(b.decomps, standalone.decomps);
        assert_eq!(b.errors, standalone.errors);
    }

    #[test]
    fn tiers_are_byte_identical_and_tables_amortize() {
        let cfg = GroupConfig::R2C2;
        let ws = random_weights(4_000, cfg.max_per_array(), 33);
        let chip = ChipFaults::new(6, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let batch = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
        let mut pw = CompileOptions::new(cfg, Method::Complete);
        pw.tier = SolveTier::PerWeight;
        let per_weight = compile_tensor(&ws, &faults, &pw);
        assert_eq!(batch.decomps, per_weight.decomps);
        assert_eq!(batch.errors, per_weight.errors);
        assert_eq!(batch.stats.stage_counts, per_weight.stats.stage_counts);
        assert_eq!(batch.stats.unique_pairs, per_weight.stats.unique_pairs);
        // Fresh solve sweeps: one table build per pattern vs one
        // value-table sweep per unique pair — ≥2x fewer on R2C2.
        assert!(batch.stats.pattern_tables_built > 0);
        assert!(
            batch.stats.pattern_tables_built * 2 <= per_weight.stats.unique_pairs,
            "table builds {} not ≥2x below pair sweeps {}",
            batch.stats.pattern_tables_built,
            per_weight.stats.unique_pairs
        );
        assert_eq!(per_weight.stats.pattern_tables_built, 0);
        // Baselines are gated off the BatchTable tier automatically.
        let ilp = CompileOptions::new(cfg, Method::IlpOnly);
        assert_eq!(ilp.effective_tier(), SolveTier::PerWeight);
        assert_eq!(
            CompileOptions::new(cfg, Method::Complete).effective_tier(),
            SolveTier::BatchTable
        );
    }

    #[test]
    fn warm_table_serves_never_seen_weights_without_solving() {
        // The tentpole payoff over pair caching: once a pattern's table is
        // resident, weight values never compiled before are pure lookups.
        let cfg = GroupConfig::R2C2;
        let chip = ChipFaults::new(12, FaultRates::paper_default());
        let opts = CompileOptions::new(cfg, Method::Complete);
        let f = chip.sample_tensor(0, 3_000, cfg.cells());
        let base = random_weights(3_000, cfg.max_per_array(), 9);
        let neg: Vec<i64> = base.iter().map(|w| -w.abs()).collect();
        let pos: Vec<i64> = base.iter().map(|w| w.abs()).collect();
        let mut cache = SolveCache::new(cfg);
        let a = compile_batch_with_cache(&[TensorJob { weights: &neg, faults: &f }], &opts, &mut cache)
            .pop()
            .unwrap();
        assert!(a.stats.unique_pairs > 0);
        let b = compile_batch_with_cache(&[TensorJob { weights: &pos, faults: &f }], &opts, &mut cache)
            .pop()
            .unwrap();
        assert_eq!(b.stats.unique_pairs, 0, "pattern tables must answer never-seen weights");
        assert_eq!(b.stats.pattern_tables_built, 0);
        let standalone = compile_tensor(&pos, &f, &opts);
        assert_eq!(b.decomps, standalone.decomps);
        assert_eq!(b.errors, standalone.errors);
    }

    #[test]
    fn fault_free_chip_compiles_perfectly() {
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(500, cfg.max_per_array(), 2);
        let chip = ChipFaults::new(1, FaultRates::none());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let out = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
        assert_eq!(out.stats.imperfect, 0);
        assert_eq!(out.stats.total_abs_error, 0);
        assert_eq!(out.stats.count_of(Stage::FastPath), 500);
        // One pattern class: the fault-free one; no tables ever built.
        assert_eq!(out.stats.unique_patterns, 1);
        assert_eq!(out.stats.tables_built, 0);
    }

    #[test]
    fn compile_model_multi_tensor() {
        let cfg = GroupConfig::R2C2;
        let tensors = vec![
            ("layer0".to_string(), random_weights(800, cfg.max_per_array(), 21)),
            ("layer1".to_string(), random_weights(400, cfg.max_per_array(), 22)),
        ];
        let chip = ChipFaults::new(4, FaultRates::paper_default());
        let opts = CompileOptions::new(cfg, Method::Complete);
        let out = compile_model(&tensors, &chip, &opts);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.decomps.len(), 800);
        assert_eq!(out[1].1.decomps.len(), 400);
        // Reconstructed weights respect per-tensor fault maps: each
        // reported error matches the decomposition's actual residual.
        for ((_, ws), (_, compiled, faults)) in tensors.iter().zip(&out) {
            let rec = compiled.faulty_weights(faults);
            for ((w, r), e) in ws.iter().zip(&rec).zip(&compiled.errors) {
                assert_eq!((w - r).abs(), *e);
            }
        }
        // Chip-wide dedup: identical to legacy per-tensor compilation.
        let mut legacy = CompileOptions::new(cfg, Method::Complete);
        legacy.dedupe = false;
        let base = compile_model(&tensors, &chip, &legacy);
        for ((_, c_new, f_new), (_, c_old, f_old)) in out.iter().zip(&base) {
            assert_eq!(f_new, f_old);
            assert_eq!(c_new.decomps, c_old.decomps);
            assert_eq!(c_new.errors, c_old.errors);
        }
    }

    /// Tentpole property: the parallel scan is byte-identical to the
    /// sequential reference — registry order, per-group ids, fresh-work
    /// order, and dedupe stats — at every thread count, on both tiers,
    /// cold and warm.
    #[test]
    fn parallel_scan_matches_reference_at_any_thread_count() {
        for cfg in [GroupConfig::R2C2, GroupConfig::R1C4] {
            let chip = ChipFaults::new(31, FaultRates::paper_default());
            let ws0 = random_weights(9_000, cfg.max_per_array(), 101);
            let ws1 = random_weights(5_000, cfg.max_per_array(), 102);
            let ws2 = random_weights(9_000, cfg.max_per_array(), 103);
            let f0 = chip.sample_tensor(0, ws0.len(), cfg.cells());
            let f1 = chip.sample_tensor(1, ws1.len(), cfg.cells());
            let f2 = chip.sample_tensor(2, ws2.len(), cfg.cells());
            let jobs = [
                TensorJob { weights: &ws0, faults: &f0 },
                TensorJob { weights: &ws1, faults: &f1 },
            ];
            let jobs2 = [TensorJob { weights: &ws2, faults: &f2 }];
            for tier in [SolveTier::BatchTable, SolveTier::PerWeight] {
                for collect_pairs in [false, true] {
                    for threads in [1usize, 4, 8] {
                        let mut ropts = CompileOptions::new(cfg, Method::Complete);
                        ropts.threads = 1;
                        ropts.tier = tier;
                        let mut popts = ropts.clone();
                        popts.threads = threads;
                        let mut rcache = SolveCache::new(cfg);
                        let mut pcache = SolveCache::new(cfg);
                        // Cold batch, then a second batch over the now
                        // warm registry/epoch state.
                        for jb in [&jobs[..], &jobs2[..]] {
                            let r = scan_batch_reference(jb, &ropts, &mut rcache, collect_pairs);
                            let p = scan_batch(jb, &popts, &mut pcache, collect_pairs);
                            let why = format!(
                                "cfg={cfg:?} tier={tier:?} pairs={collect_pairs} threads={threads}"
                            );
                            assert_eq!(p.tensor_pids, r.tensor_pids, "{why}");
                            assert_eq!(p.fresh_patterns, r.fresh_patterns, "{why}");
                            assert_eq!(p.fresh_pairs, r.fresh_pairs, "{why}");
                            assert_eq!(p.tier, r.tier, "{why}");
                            for (a, b) in p.per_tensor.iter().zip(&r.per_tensor) {
                                assert_eq!(a.unique_pairs, b.unique_pairs, "{why}");
                                assert_eq!(a.dedup_hits, b.dedup_hits, "{why}");
                            }
                            assert_eq!(pcache.registry.len(), rcache.registry.len(), "{why}");
                            assert!(
                                pcache.registry.patterns().eq(rcache.registry.patterns()),
                                "registry first-seen order diverged: {why}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn complete_beats_unprotected_in_aggregate() {
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(4000, cfg.max_per_array(), 77);
        let chip = ChipFaults::new(13, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let a = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
        let b = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Unprotected));
        assert!(
            a.stats.total_abs_error * 2 < b.stats.total_abs_error,
            "pipeline {} vs unprotected {}",
            a.stats.total_abs_error,
            b.stats.total_abs_error
        );
    }
}
