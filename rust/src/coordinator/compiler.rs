//! Per-chip, per-model compilation driver.
//!
//! This is the L3 coordinator proper: it walks a model's weight tensors,
//! samples the chip's fault maps, fans the per-weight decomposition
//! problems out across worker threads, memoizes repeated
//! (fault-pattern, weight) pairs, and aggregates stage counts/timings for
//! the Table II / Fig 10 reports.

use super::pipeline::{decompose_one, Method, Outcome, PipelineOptions, Stage, ALL_STAGES};
use crate::fault::bank::ChipFaults;
use crate::fault::GroupFaults;
use crate::grouping::{Decomposition, GroupConfig};
use crate::ilp::IlpStats;
use crate::util::pool::{parallel_map_ranges, split_ranges};
use crate::util::timer::{StageClock, Timer};
use crate::util::fnv::FnvMap;
use std::collections::HashMap;

/// Options for a compilation run.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    pub cfg: GroupConfig,
    pub pipeline: PipelineOptions,
    /// Worker threads (1 reproduces the paper's single-thread protocol).
    pub threads: usize,
    /// Memoize (fault-pattern, weight) → decomposition.
    pub memoize: bool,
    /// Charge wall time to per-stage buckets (Fig 10b). Two clock reads per
    /// weight; disable for pure-throughput runs (§Perf).
    pub time_stages: bool,
}

impl CompileOptions {
    pub fn new(cfg: GroupConfig, method: Method) -> Self {
        CompileOptions {
            cfg,
            pipeline: PipelineOptions { method, ..Default::default() },
            threads: 1,
            memoize: true,
            time_stages: true,
        }
    }
}

/// Aggregated statistics of one tensor/model compilation.
#[derive(Clone, Debug, Default)]
pub struct CompileStats {
    pub weights: usize,
    /// Weights routed to each stage.
    pub stage_counts: Vec<(&'static str, usize)>,
    /// Wall time charged to each stage bucket (cond/fawd/cvm/…).
    pub clock: StageClock,
    pub memo_hits: usize,
    pub ilp: IlpStats,
    /// Σ |w − w̃| over all weights (integer domain).
    pub total_abs_error: u64,
    /// Number of weights with non-zero residual error.
    pub imperfect: usize,
    pub wall_secs: f64,
}

impl CompileStats {
    pub fn count_of(&self, stage: Stage) -> usize {
        self.stage_counts
            .iter()
            .find(|(n, _)| *n == stage.name())
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }

    fn merge(&mut self, other: &CompileStats) {
        self.weights += other.weights;
        for (name, c) in &other.stage_counts {
            if let Some(e) = self.stage_counts.iter_mut().find(|(n, _)| n == name) {
                e.1 += c;
            } else {
                self.stage_counts.push((name, *c));
            }
        }
        self.clock.merge(&other.clock);
        self.memo_hits += other.memo_hits;
        self.ilp.nodes += other.ilp.nodes;
        self.ilp.lp_solves += other.ilp.lp_solves;
        self.total_abs_error += other.total_abs_error;
        self.imperfect += other.imperfect;
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "weights={} wall={:.3}s imperfect={} ({:.4}%) total|err|={} memo_hits={}\n",
            self.weights,
            self.wall_secs,
            self.imperfect,
            100.0 * self.imperfect as f64 / self.weights.max(1) as f64,
            self.total_abs_error,
            self.memo_hits,
        );
        for (name, c) in &self.stage_counts {
            if *c > 0 {
                s.push_str(&format!("  stage {name:<13} {c:>10}\n"));
            }
        }
        for (bucket, secs) in self.clock.entries() {
            s.push_str(&format!("  time  {bucket:<13} {:>10.3}s\n", secs));
        }
        s
    }
}

/// A compiled tensor: one decomposition per weight plus its residual error.
#[derive(Clone, Debug)]
pub struct CompiledTensor {
    pub cfg: GroupConfig,
    pub decomps: Vec<Decomposition>,
    pub errors: Vec<i64>,
    pub stats: CompileStats,
}

impl CompiledTensor {
    /// Reconstruct the faulty integer weights `w̃` this compilation yields.
    pub fn faulty_weights(&self, faults: &[GroupFaults]) -> Vec<i64> {
        self.decomps
            .iter()
            .zip(faults)
            .map(|(d, f)| d.faulty_value(&self.cfg, f))
            .collect()
    }
}

/// Compile one tensor of quantized integer weights against per-group fault
/// maps. `weights.len() == faults.len()`.
pub fn compile_tensor(
    weights: &[i64],
    faults: &[GroupFaults],
    opts: &CompileOptions,
) -> CompiledTensor {
    assert_eq!(weights.len(), faults.len(), "one fault map per weight group");
    let timer = Timer::start();
    let n = weights.len();
    let threads = opts.threads.max(1);

    // Each worker produces (outcomes for its range, local stats).
    let ranges = split_ranges(n, threads);
    let results: Vec<(Vec<(Decomposition, i64)>, CompileStats)> =
        parallel_map_ranges(ranges.len(), ranges.len(), |rr| {
            rr.map(|i| compile_range(weights, faults, opts, ranges[i].clone()))
                .collect()
        });

    let mut decomps = Vec::with_capacity(n);
    let mut errors = Vec::with_capacity(n);
    let mut stats = CompileStats::default();
    for (chunk, st) in results {
        for (d, e) in chunk {
            decomps.push(d);
            errors.push(e);
        }
        stats.merge(&st);
    }
    stats.wall_secs = timer.secs();
    CompiledTensor { cfg: opts.cfg, decomps, errors, stats }
}

/// Serial compilation of one index range with local memoization.
fn compile_range(
    weights: &[i64],
    faults: &[GroupFaults],
    opts: &CompileOptions,
    range: std::ops::Range<usize>,
) -> (Vec<(Decomposition, i64)>, CompileStats) {
    let mut out = Vec::with_capacity(range.len());
    let mut stats = CompileStats::default();
    let mut counts: HashMap<&'static str, usize> = HashMap::new();
    let mut memo: FnvMap<(u64, i64), (Decomposition, i64, Stage)> = FnvMap::default();
    // Memoizing the fault-free pattern would just duplicate encode_ideal;
    // skip it so the memo holds only interesting patterns.
    let free_key = GroupFaults::free(opts.cfg.cells()).pattern_key();

    for i in range.clone() {
        let w = weights[i];
        let f = &faults[i];
        let key = (f.pattern_key(), w);
        let cached = opts.memoize && key.0 != free_key;
        if cached {
            if let Some((d, e, st)) = memo.get(&key) {
                stats.memo_hits += 1;
                *counts.entry(st.name()).or_insert(0) += 1;
                stats.clock.add(st.bucket(), 0.0);
                if *e != 0 {
                    stats.imperfect += 1;
                    stats.total_abs_error += e.unsigned_abs();
                }
                out.push((d.clone(), *e));
                continue;
            }
        }
        let t = opts.time_stages.then(Timer::start);
        let Outcome { decomposition, error, stage } =
            decompose_one(&opts.cfg, f, w, &opts.pipeline, &mut stats.ilp);
        if let Some(t) = t {
            stats.clock.add(stage.bucket(), t.secs());
        }
        *counts.entry(stage.name()).or_insert(0) += 1;
        if error != 0 {
            stats.imperfect += 1;
            stats.total_abs_error += error.unsigned_abs();
        }
        // Selective memoization: after the dense-table §Perf work the
        // cheap stages (fast path / trivial / greedy) cost less than a
        // memo insert + clone, so only the expensive CVM/ILP/table
        // outcomes are worth caching (ablation: bench_ablation).
        let expensive = matches!(
            stage,
            Stage::TableFawd | Stage::IlpFawd | Stage::TableCvm | Stage::IlpCvm | Stage::FfSearch
        );
        if cached && expensive {
            memo.insert(key, (decomposition.clone(), error, stage));
        }
        out.push((decomposition, error));
    }
    stats.weights = range.len();
    stats.stage_counts = ALL_STAGES
        .iter()
        .filter_map(|s| counts.get(s.name()).map(|c| (s.name(), *c)))
        .collect();
    (out, stats)
}

/// Compile a whole model (a list of named integer-weight tensors) against a
/// chip's fault bank. Returns per-tensor results in input order.
pub fn compile_model(
    tensors: &[(String, Vec<i64>)],
    chip: &ChipFaults,
    opts: &CompileOptions,
) -> Vec<(String, CompiledTensor, Vec<GroupFaults>)> {
    tensors
        .iter()
        .enumerate()
        .map(|(ti, (name, ws))| {
            let faults = chip.sample_tensor(ti as u64, ws.len(), opts.cfg.cells());
            let compiled = compile_tensor(ws, &faults, opts);
            (name.clone(), compiled, faults)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultRates;
    use crate::util::prng::Rng;

    fn random_weights(n: usize, max: i64, seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.range_i64(-max, max)).collect()
    }

    #[test]
    fn compile_tensor_end_to_end() {
        let cfg = GroupConfig::R2C2;
        let opts = CompileOptions::new(cfg, Method::Complete);
        let ws = random_weights(2000, cfg.max_per_array(), 42);
        let chip = ChipFaults::new(7, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let out = compile_tensor(&ws, &faults, &opts);
        assert_eq!(out.decomps.len(), ws.len());
        // Every reported error matches the decomposition's actual error.
        let rec = out.faulty_weights(&faults);
        for ((w, r), e) in ws.iter().zip(&rec).zip(&out.errors) {
            assert_eq!((w - r).abs(), *e);
        }
        assert_eq!(out.stats.weights, ws.len());
        let total: usize = out.stats.stage_counts.iter().map(|(_, c)| c).sum();
        assert_eq!(total, ws.len());
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(1500, cfg.max_per_array(), 11);
        let chip = ChipFaults::new(3, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let mut o1 = CompileOptions::new(cfg, Method::Complete);
        o1.threads = 1;
        let mut o4 = o1.clone();
        o4.threads = 4;
        let a = compile_tensor(&ws, &faults, &o1);
        let b = compile_tensor(&ws, &faults, &o4);
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
    }

    #[test]
    fn memoization_preserves_results() {
        // Memoization is selective (expensive stages only), so use R1C4 at
        // scale where CVM patterns repeat.
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(30_000, cfg.max_per_array(), 5);
        let chip = ChipFaults::new(9, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let mut with = CompileOptions::new(cfg, Method::Complete);
        with.memoize = true;
        let mut without = with.clone();
        without.memoize = false;
        let a = compile_tensor(&ws, &faults, &with);
        let b = compile_tensor(&ws, &faults, &without);
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.decomps, b.decomps);
        assert!(a.stats.memo_hits > 0, "memo should hit on 30k R1C4 weights");
        assert_eq!(b.stats.memo_hits, 0);
    }

    #[test]
    fn fault_free_chip_compiles_perfectly() {
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(500, cfg.max_per_array(), 2);
        let chip = ChipFaults::new(1, FaultRates::none());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let out = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
        assert_eq!(out.stats.imperfect, 0);
        assert_eq!(out.stats.total_abs_error, 0);
        assert_eq!(out.stats.count_of(Stage::FastPath), 500);
    }

    #[test]
    fn compile_model_multi_tensor() {
        let cfg = GroupConfig::R2C2;
        let tensors = vec![
            ("layer0".to_string(), random_weights(800, cfg.max_per_array(), 21)),
            ("layer1".to_string(), random_weights(400, cfg.max_per_array(), 22)),
        ];
        let chip = ChipFaults::new(4, FaultRates::paper_default());
        let opts = CompileOptions::new(cfg, Method::Complete);
        let out = compile_model(&tensors, &chip, &opts);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.decomps.len(), 800);
        assert_eq!(out[1].1.decomps.len(), 400);
        // Reconstructed weights respect per-tensor fault maps.
        for (_, compiled, faults) in &out {
            let rec = compiled.faulty_weights(faults);
            for (e, (w_rec, err)) in rec.iter().zip(compiled.errors.iter()).enumerate().map(|(i, p)| (i, p)) {
                let _ = (e, w_rec, err);
            }
        }
    }

    #[test]
    fn complete_beats_unprotected_in_aggregate() {
        let cfg = GroupConfig::R1C4;
        let ws = random_weights(4000, cfg.max_per_array(), 77);
        let chip = ChipFaults::new(13, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let a = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
        let b = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Unprotected));
        assert!(
            a.stats.total_abs_error * 2 < b.stats.total_abs_error,
            "pipeline {} vs unprotected {}",
            a.stats.total_abs_error,
            b.stats.total_abs_error
        );
    }
}
