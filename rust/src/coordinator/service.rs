//! `CompileService` — a batched compile front-end over many chips.
//!
//! A deployment fleet compiles the same (or revised) models for many
//! physical chips, each with its own fault pattern. The service queues
//! jobs from any number of chips behind a single
//! [`CompileService::enqueue`]/[`CompileService::run`] API, keeps **one
//! warm [`CompileSession`] per chip seed**, and shards chips across the
//! existing work-stealing pool on `run` — each chip's jobs drain through
//! its session as one batch (single solve fan-out over the union of fresh
//! pairs), and chips run concurrently.
//!
//! With a `cache_dir` configured, sessions are loaded from / saved to
//! per-chip cache files around every `run`, so a service restarted on the
//! same fleet starts warm: recompiling an unchanged model performs zero
//! fresh solves. Cache files whose key (chip seed, fault rates, grouping
//! config, pipeline fingerprint) does not match the service configuration
//! are ignored and rebuilt, never silently reused.
//!
//! Resident pattern-table memory is budgeted fleet-wide via
//! [`TableBudget`]: one global cap (fixed, or auto-sized from system RAM)
//! split across live sessions **proportionally to each session's interned
//! pattern count** (a chip with 10× the fault-pattern diversity gets 10×
//! the table budget), re-derived on every run as chips join; when no
//! session has interned anything yet the split degrades to even shares.
//! So a service over a thousand chips does not hold a thousand full-size
//! caches, and the cap lands where the patterns are. Budget pressure only
//! ever costs re-solves, never output bytes.
//!
//! Results are byte-deterministic: job results come back in enqueue
//! order, and neither the thread count nor the chip sharding changes a
//! single output byte (per-chip slot order is fixed by enqueue order).

use super::compiler::{CompileOptions, CompiledTensor};
use super::session::CompileSession;
use crate::fault::bank::ChipFaults;
use crate::fault::FaultRates;
use crate::store::StoreHandle;
use crate::util::pool::parallel_work_steal;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// How the service budgets resident pattern-table memory across chips.
///
/// One warm session per chip means N chips hold N solve caches; a cap
/// that is correct for one session (`CompileOptions::table_memory_bytes`)
/// multiplies by the fleet size. `Fleet` and `Auto` instead treat the cap
/// as a **global** budget split across live sessions proportionally to
/// each session's interned pattern count (even shares when no counts
/// exist yet), re-derived on every [`CompileService::run`] as chips
/// join. Shrinking a session's budget only ever costs re-solves (LRU
/// eviction at batch boundaries), never a single output byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableBudget {
    /// Every session keeps its own `CompileOptions::table_memory_bytes`
    /// (the historical behavior; total memory grows with the fleet).
    PerSession,
    /// One fleet-wide cap in bytes, split across live sessions in
    /// proportion to their interned pattern counts (at least 1 byte each
    /// — a degenerate budget degrades to re-solving, not to failure).
    Fleet(usize),
    /// Fleet-wide cap sized from the machine: half of physical RAM when
    /// detectable ([`crate::util::mem::system_memory_bytes`]), else the
    /// per-session default
    /// [`crate::coordinator::DEFAULT_TABLE_MEMORY_BYTES`].
    Auto,
}

impl TableBudget {
    /// The fleet-wide cap this policy implies, or `None` for
    /// [`TableBudget::PerSession`].
    pub fn fleet_bytes(&self) -> Option<usize> {
        match self {
            TableBudget::PerSession => None,
            TableBudget::Fleet(bytes) => Some((*bytes).max(1)),
            TableBudget::Auto => Some(
                crate::util::mem::system_memory_bytes()
                    .map(|ram| (ram / 2).max(1))
                    .unwrap_or(super::classes::DEFAULT_TABLE_MEMORY_BYTES),
            ),
        }
    }
}

/// Service configuration: compile options shared by every chip (threads =
/// total worker budget across chips), the fleet's fault rates, the
/// pattern-table memory policy, and an optional directory for persistent
/// per-chip session caches.
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    pub opts: CompileOptions,
    pub rates: FaultRates,
    /// Resident pattern-table memory policy across the fleet (default
    /// behavior of older services: [`TableBudget::PerSession`]).
    pub table_budget: TableBudget,
    pub cache_dir: Option<PathBuf>,
    /// Directory for the fleet-global solution store's RCPS file tier
    /// (see [`crate::store`]). `None` defaults to `<cache_dir>/store`
    /// when a cache dir is configured; with neither, the store runs
    /// memory-only. The store itself is always on — it is what lets a
    /// second chip with overlapping fault patterns skip the solves the
    /// first chip already paid for.
    pub store_dir: Option<PathBuf>,
}

struct QueuedJob {
    job_id: u64,
    chip_seed: u64,
    name: String,
    weights: Vec<i64>,
}

/// One compiled job, tagged with its identity.
pub struct JobResult {
    pub job_id: u64,
    pub chip_seed: u64,
    pub name: String,
    pub tensor: CompiledTensor,
}

/// Multi-chip batching compile service. See the module docs.
///
/// ```
/// use rchg::coordinator::{CompileOptions, CompileService, Method, ServiceOptions, TableBudget};
/// use rchg::fault::FaultRates;
/// use rchg::grouping::GroupConfig;
///
/// let mut service = CompileService::new(ServiceOptions {
///     opts: CompileOptions::new(GroupConfig::R2C2, Method::Complete),
///     rates: FaultRates::paper_default(),
///     table_budget: TableBudget::Fleet(64 << 20),
///     cache_dir: None,
///     store_dir: None,
/// });
/// let weights: Vec<i64> = (-10..=10).collect();
/// let job_a = service.enqueue(1, "conv1", weights.clone()); // chip 1
/// let job_b = service.enqueue(2, "conv1", weights);         // chip 2
/// let results = service.run()?;
/// assert_eq!(results.len(), 2);
/// assert_eq!(results[0].job_id, job_a);
/// assert_eq!(results[1].job_id, job_b);
/// // The fleet cap is in force, split across the two live sessions
/// // (evenly here: neither had interned patterns when the run began).
/// assert_eq!(service.applied_table_budget(), Some(64 << 20));
/// assert_eq!(service.session_table_budget(1), Some(32 << 20));
/// assert_eq!(service.session_table_budget(2), Some(32 << 20));
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct CompileService {
    sopts: ServiceOptions,
    sessions: BTreeMap<u64, CompileSession>,
    queue: Vec<QueuedJob>,
    next_job: u64,
    persist_errors: Vec<String>,
    fleet_cap: Option<usize>,
    applied_budgets: BTreeMap<u64, usize>,
    /// The fleet-global solution store every session compiles through.
    store: StoreHandle,
}

impl CompileService {
    pub fn new(sopts: ServiceOptions) -> CompileService {
        // One store for the whole fleet: RCPS file tier under
        // `store_dir` (else `<cache_dir>/store`), memory-only when the
        // service has no disk at all. An uncreatable directory degrades
        // to memory-only rather than failing the service — the store is
        // an accelerator, never a correctness dependency.
        let store_dir = sopts
            .store_dir
            .clone()
            .or_else(|| sopts.cache_dir.as_ref().map(|d| d.join("store")));
        let store = store_dir
            .as_deref()
            .and_then(|dir| StoreHandle::with_dir(dir).ok())
            .unwrap_or_else(StoreHandle::in_memory);
        CompileService {
            sopts,
            sessions: BTreeMap::new(),
            queue: Vec::new(),
            next_job: 0,
            persist_errors: Vec::new(),
            fleet_cap: None,
            applied_budgets: BTreeMap::new(),
            store,
        }
    }

    /// The fleet-global solution store shared by every session this
    /// service compiles through. Clone the handle to share the same
    /// store with sessions managed outside the service (the network
    /// fabric's shard workers do exactly that).
    pub fn store(&self) -> &StoreHandle {
        &self.store
    }

    /// The fleet-wide pattern-table cap the latest
    /// [`CompileService::run`] applied under a fleet-wide
    /// [`TableBudget`], or `None` before the first run / under
    /// [`TableBudget::PerSession`]. Per-chip shares are reported by
    /// [`CompileService::session_table_budget`].
    pub fn applied_table_budget(&self) -> Option<usize> {
        self.fleet_cap
    }

    /// The pattern-table budget the latest split derived for one chip's
    /// session: the fleet cap weighted by the session's interned pattern
    /// count when the split was last re-derived (on every
    /// [`CompileService::run`] — after cache-dir warm-starts load, so a
    /// disk-warm chip weighs its real count — and on every
    /// [`CompileService::install_session`]). A chip with nothing
    /// interned yet is weighted as one pattern, which also makes the
    /// all-new fleet split exactly even. `None` before the first split,
    /// under [`TableBudget::PerSession`], or for an unknown chip.
    pub fn session_table_budget(&self, chip_seed: u64) -> Option<usize> {
        self.applied_budgets.get(&chip_seed).copied()
    }

    /// Queue one named tensor for `chip_seed`; returns the job id its
    /// [`JobResult`] will carry. The name keys the tensor's chip region
    /// (see [`CompileSession::tensor_id_of`]), so re-enqueueing the same
    /// name on a warm chip is pure cache hits.
    pub fn enqueue(&mut self, chip_seed: u64, name: &str, weights: Vec<i64>) -> u64 {
        let job_id = self.next_job;
        self.next_job += 1;
        self.queue.push(QueuedJob { job_id, chip_seed, name: name.to_string(), weights });
        job_id
    }

    /// Jobs queued and not yet run.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The warm session of one chip, if it exists yet.
    pub fn session(&self, chip_seed: u64) -> Option<&CompileSession> {
        self.sessions.get(&chip_seed)
    }

    /// Warm sessions currently held, keyed by chip seed.
    pub fn sessions(&self) -> impl Iterator<Item = (&u64, &CompileSession)> {
        self.sessions.iter()
    }

    /// Cache file of one chip under `dir`, keyed by the full session cache
    /// key — chip seed, grouping config, method, plus a fingerprint of the
    /// fault rates and remaining pipeline tunables — so differently
    /// configured services over one directory never clobber each other's
    /// warm state.
    fn cache_path(dir: &Path, opts: &CompileOptions, rates: &FaultRates, chip_seed: u64) -> PathBuf {
        let mut key = Vec::with_capacity(26);
        key.extend_from_slice(&rates.p_sa0.to_bits().to_le_bytes());
        key.extend_from_slice(&rates.p_sa1.to_bits().to_le_bytes());
        key.extend_from_slice(&opts.pipeline.table_value_limit.to_le_bytes());
        key.push(opts.pipeline.sparsest as u8);
        key.push(opts.cfg.levels);
        let fingerprint = crate::util::prop::fnv1a(&key);
        let name = format!(
            "chip-{chip_seed}-{}-{:?}-{fingerprint:016x}.rcs",
            opts.cfg.name(),
            opts.pipeline.method
        );
        dir.join(name.to_ascii_lowercase())
    }

    /// Rehydrate one chip's session from the cache dir, if a file with a
    /// matching key exists. Execution knobs are not part of the cache
    /// key, so the service's configuration is applied to the loaded
    /// session.
    fn load_from_cache_dir(&self, chip_seed: u64) -> Option<CompileSession> {
        let dir = self.sopts.cache_dir.as_ref()?;
        let chip = ChipFaults::new(chip_seed, self.sopts.rates);
        let path = Self::cache_path(dir, &self.sopts.opts, &self.sopts.rates, chip_seed);
        let mut s = CompileSession::load(&path).ok()?;
        if !s.matches(&chip, &self.sopts.opts) {
            return None;
        }
        s.set_time_stages(self.sopts.opts.time_stages);
        s.set_solve_tier(self.sopts.opts.tier);
        s.set_table_memory_bytes(self.sopts.opts.table_memory_bytes);
        Some(s)
    }

    /// A session for `chip_seed`: warm from the in-memory map, else warm
    /// from the cache dir (if the stored key matches), else cold. Every
    /// path leaves the session attached to the fleet store (RCSS bytes
    /// never carry the store, so disk-loaded sessions re-attach here).
    fn obtain_session(&mut self, chip_seed: u64) -> CompileSession {
        let mut s = if let Some(s) = self.sessions.remove(&chip_seed) {
            s
        } else if let Some(s) = self.load_from_cache_dir(chip_seed) {
            s
        } else {
            let chip = ChipFaults::new(chip_seed, self.sopts.rates);
            CompileSession::builder(self.sopts.opts.cfg)
                .options(self.sopts.opts.clone())
                .chip(&chip)
        };
        s.set_store(self.store.clone());
        s
    }

    /// Verbatim RCSS bytes of `chip_seed`'s cache-dir file, when one
    /// exists and is keyed for this service's configuration
    /// (parse-validated, so a stale or corrupt file reads as absent
    /// rather than being served). This — not
    /// [`CompileSession::to_bytes`] on a freshly loaded session, whose
    /// save semantics drop entries never hit since load — is how a
    /// restarted service serves a chip's warm cache it has not compiled
    /// with yet.
    pub fn cached_session_bytes(&self, chip_seed: u64) -> Option<Vec<u8>> {
        let dir = self.sopts.cache_dir.as_ref()?;
        let chip = ChipFaults::new(chip_seed, self.sopts.rates);
        let path = Self::cache_path(dir, &self.sopts.opts, &self.sopts.rates, chip_seed);
        let bytes = std::fs::read(&path).ok()?;
        let s = CompileSession::from_bytes(&bytes).ok()?;
        s.matches(&chip, &self.sopts.opts).then_some(bytes)
    }

    /// Compile every queued job. Jobs are grouped per chip (one warm
    /// session per chip seed), chips are sharded across the work-stealing
    /// pool, and each chip's jobs drain as one batch. Results come back
    /// in enqueue order; outputs are independent of thread count and
    /// sharding. With a `cache_dir`, every touched session is persisted
    /// after the batch.
    pub fn run(&mut self) -> Result<Vec<JobResult>> {
        let queue = std::mem::take(&mut self.queue);
        if queue.is_empty() {
            return Ok(Vec::new());
        }
        // Group jobs by chip, chips ordered by first appearance.
        let mut order: Vec<u64> = Vec::new();
        let mut by_chip: BTreeMap<u64, Vec<QueuedJob>> = BTreeMap::new();
        for job in queue {
            if !by_chip.contains_key(&job.chip_seed) {
                order.push(job.chip_seed);
            }
            by_chip.entry(job.chip_seed).or_default().push(job);
        }
        let n_chips = order.len();
        let total_threads = self.sopts.opts.threads.max(1);
        let outer = total_threads.min(n_chips);
        let inner = (total_threads / outer).max(1);

        // Obtain every participating session *before* deriving the fleet
        // budget split, so a session warm-started from the cache dir
        // carries its real interned pattern count into the weighting
        // instead of being treated as empty.
        let mut obtained: Vec<(u64, CompileSession, Vec<QueuedJob>)> = order
            .iter()
            .map(|seed| (*seed, self.obtain_session(*seed), by_chip.remove(seed).unwrap()))
            .collect();
        let joining: Vec<(u64, usize)> =
            obtained.iter().map(|(seed, s, _)| (*seed, s.pattern_classes())).collect();
        self.rederive_budgets(&joining);

        for (seed, session, _) in obtained.iter_mut() {
            session.set_threads(inner);
            if let Some(&budget) = self.applied_budgets.get(seed) {
                session.set_table_memory_bytes(budget);
            }
        }
        // Move each chip's session + jobs into a cell the pool can claim;
        // every cell is taken by exactly one worker.
        let cells: Vec<Mutex<Option<(u64, CompileSession, Vec<QueuedJob>)>>> =
            obtained.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let done: Vec<(u64, CompileSession, Vec<JobResult>)> =
            parallel_work_steal(n_chips, outer, 1, |i| {
                let (seed, mut session, jobs) = cells[i]
                    .lock()
                    .expect("service cell lock poisoned")
                    .take()
                    .expect("each service cell is claimed once");
                let mut metas = Vec::with_capacity(jobs.len());
                for job in jobs {
                    let QueuedJob { job_id, name, weights, .. } = job;
                    session.submit(&name, weights);
                    metas.push((job_id, name));
                }
                let compiled = session.drain();
                let results = metas
                    .into_iter()
                    .zip(compiled)
                    .map(|((job_id, name), (_, tensor))| JobResult {
                        job_id,
                        chip_seed: seed,
                        name,
                        tensor,
                    })
                    .collect();
                (seed, session, results)
            });

        // Reinsert every session and assemble the results first, THEN
        // persist best-effort: a full disk or unwritable cache dir must
        // never throw away a batch of compiled results (the warm sessions
        // stay in memory either way). Failures are reported via
        // [`CompileService::persist_errors`]; legacy (`dedupe = false`)
        // sessions have nothing to persist and are skipped silently.
        let mut results: Vec<JobResult> = Vec::new();
        self.persist_errors.clear();
        for (seed, mut session, rs) in done {
            session.set_threads(total_threads);
            if let Some(dir) = &self.sopts.cache_dir {
                if session.persistable() {
                    let path = Self::cache_path(dir, &self.sopts.opts, &self.sopts.rates, seed);
                    if let Err(e) = session.save(&path) {
                        self.persist_errors.push(format!("chip {seed}: {e:#}"));
                    }
                }
            }
            self.sessions.insert(seed, session);
            results.extend(rs);
        }
        results.sort_by_key(|r| r.job_id);
        Ok(results)
    }

    /// Re-derive the fleet-wide budget split over every retained session
    /// plus the `joining` (chip, interned pattern count) pairs currently
    /// held outside the map, and apply the new shares to the retained
    /// sessions (joining sessions are the caller's to size). Shares are
    /// proportional to interned pattern counts — the cap lands where the
    /// fault-pattern diversity is — with a floor weight of one pattern,
    /// which also makes an all-new fleet split exactly even. A no-op
    /// beyond clearing state under [`TableBudget::PerSession`].
    fn rederive_budgets(&mut self, joining: &[(u64, usize)]) {
        self.fleet_cap = self.sopts.table_budget.fleet_bytes();
        self.applied_budgets.clear();
        let Some(total) = self.fleet_cap else { return };
        let mut pattern_weight: BTreeMap<u64, u128> = self
            .sessions
            .iter()
            .map(|(seed, s)| (*seed, s.pattern_classes().max(1) as u128))
            .collect();
        for (seed, count) in joining {
            pattern_weight.insert(*seed, (*count).max(1) as u128);
        }
        let weight_sum: u128 = pattern_weight.values().sum::<u128>().max(1);
        for (seed, w) in pattern_weight {
            let share = ((total as u128 * w) / weight_sum) as usize;
            self.applied_budgets.insert(seed, share.max(1));
        }
        // Retained sessions (idle or not) adopt their shares now; a
        // shrinking split takes effect at their next batch boundary.
        for (seed, session) in self.sessions.iter_mut() {
            if let Some(&budget) = self.applied_budgets.get(seed) {
                session.set_table_memory_bytes(budget);
            }
        }
    }

    /// Whether a warm session for `chip_seed` is already available —
    /// retained in memory, or present as a cache file under the
    /// configured `cache_dir` (existence check only; a stale or
    /// key-mismatched file is detected and rebuilt at load time). The
    /// network fabric uses this to route repeat jobs down the warm local
    /// path instead of re-solving them distributed.
    pub fn has_cached_session(&self, chip_seed: u64) -> bool {
        if self.sessions.contains_key(&chip_seed) {
            return true;
        }
        match &self.sopts.cache_dir {
            Some(dir) => {
                Self::cache_path(dir, &self.sopts.opts, &self.sopts.rates, chip_seed).exists()
            }
            None => false,
        }
    }

    /// Adopt `session` as the retained warm session of `chip_seed`,
    /// replacing any existing one. This is the scheduling hook the
    /// network fabric uses to hand a shard-merged session back to the
    /// service so subsequent jobs for the chip run warm and local. With
    /// a `cache_dir` configured the adopted session is persisted
    /// best-effort (a failure is appended to
    /// [`CompileService::persist_errors`], never raised), and under a
    /// fleet-wide [`TableBudget`] the split is re-derived over the new
    /// live set immediately, so adopted sessions join the memory cap
    /// instead of keeping their build-time budget.
    pub fn install_session(&mut self, chip_seed: u64, mut session: CompileSession) {
        session.set_store(self.store.clone());
        if let Some(dir) = &self.sopts.cache_dir {
            if session.persistable() {
                let path = Self::cache_path(dir, &self.sopts.opts, &self.sopts.rates, chip_seed);
                if let Err(e) = session.save(&path) {
                    self.persist_errors.push(format!("chip {chip_seed}: {e:#}"));
                }
            }
        }
        self.sessions.insert(chip_seed, session);
        self.rederive_budgets(&[]);
    }

    /// Cache files the latest [`CompileService::run`] (plus any
    /// [`CompileService::install_session`] since) failed to write —
    /// empty on a clean run. Warm state is still held in memory, so a
    /// later `run` retries persisting automatically.
    pub fn persist_errors(&self) -> &[String] {
        &self.persist_errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;
    use crate::grouping::GroupConfig;
    use crate::util::prng::Rng;

    fn random_weights(n: usize, max: i64, seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.range_i64(-max, max)).collect()
    }

    #[test]
    fn service_results_in_enqueue_order_and_match_sessions() {
        let cfg = GroupConfig::R2C2;
        let mut opts = CompileOptions::new(cfg, Method::Complete);
        opts.threads = 4;
        let mut service = CompileService::new(ServiceOptions {
            opts: opts.clone(),
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: None,
            store_dir: None,
        });
        let w0 = random_weights(1_500, cfg.max_per_array(), 1);
        let w1 = random_weights(900, cfg.max_per_array(), 2);
        // Interleaved enqueue across two chips.
        let j0 = service.enqueue(7, "a", w0.clone());
        let j1 = service.enqueue(8, "a", w0.clone());
        let j2 = service.enqueue(7, "b", w1.clone());
        assert_eq!(service.pending(), 3);
        let results = service.run().unwrap();
        assert_eq!(service.pending(), 0);
        assert_eq!(
            results.iter().map(|r| r.job_id).collect::<Vec<_>>(),
            vec![j0, j1, j2]
        );
        // Each result equals a standalone per-chip session compile.
        for r in &results {
            let chip = ChipFaults::new(r.chip_seed, FaultRates::paper_default());
            let mut solo = CompileSession::builder(cfg).chip(&chip);
            // Replay this chip's jobs in order up to r.
            for pre in results.iter().filter(|p| p.chip_seed == r.chip_seed) {
                let ws = if pre.name == "a" { &w0 } else { &w1 };
                let out = solo.compile_tensor(&pre.name, ws);
                if pre.job_id == r.job_id {
                    assert_eq!(out.decomps, r.tensor.decomps);
                    assert_eq!(out.errors, r.tensor.errors);
                    break;
                }
            }
        }
        // Warm sessions are retained: re-running the same jobs solves nothing.
        service.enqueue(7, "a", w0.clone());
        service.enqueue(8, "a", w0);
        service.enqueue(7, "b", w1);
        let warm = service.run().unwrap();
        assert!(warm.iter().all(|r| r.tensor.stats.unique_pairs == 0));
        for (a, b) in results.iter().zip(&warm) {
            assert_eq!(a.tensor.decomps, b.tensor.decomps);
        }
        // Historical policy: no fleet budget was derived or applied.
        assert_eq!(service.applied_table_budget(), None);
    }

    #[test]
    fn fleet_budget_splits_proportionally_to_pattern_counts() {
        let cfg = GroupConfig::R2C2;
        let opts = CompileOptions::new(cfg, Method::Complete);
        let total = 64 << 20;
        let mut service = CompileService::new(ServiceOptions {
            opts,
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::Fleet(total),
            cache_dir: None,
            store_dir: None,
        });
        // Chip 1 compiles 8x the weights of chip 2, so it interns far
        // more fault-pattern classes.
        let big = random_weights(8_000, cfg.max_per_array(), 5);
        let small = random_weights(1_000, cfg.max_per_array(), 6);
        service.enqueue(1, "a", big.clone());
        service.enqueue(2, "a", small.clone());
        let _ = service.run().unwrap();
        // Both sessions were new when the run began (no interned patterns
        // yet), so the first split is exactly even — the fallback.
        assert_eq!(service.applied_table_budget(), Some(total));
        assert_eq!(service.session_table_budget(1), Some(total / 2));
        assert_eq!(service.session_table_budget(2), Some(total / 2));

        // A third chip joining re-derives the split over all live
        // sessions, now weighted by interned pattern counts.
        let c1 = service.session(1).unwrap().pattern_classes();
        let c2 = service.session(2).unwrap().pattern_classes();
        assert!(c1 > c2, "8x the weights must intern more patterns ({c1} vs {c2})");
        service.enqueue(3, "a", small);
        let _ = service.run().unwrap();
        let sum = (c1 + c2 + 1) as u128;
        let share = |w: usize| ((total as u128 * w as u128 / sum) as usize).max(1);
        assert_eq!(service.session_table_budget(1), Some(share(c1)));
        assert_eq!(service.session_table_budget(2), Some(share(c2)));
        assert_eq!(service.session_table_budget(3), Some(share(1)));
        // The shares are applied to the sessions themselves (idle or not)
        // and never exceed the fleet cap in total.
        for (seed, s) in service.sessions() {
            assert_eq!(Some(s.options().table_memory_bytes), service.session_table_budget(*seed));
        }
        let applied: usize = [1u64, 2, 3]
            .iter()
            .map(|s| service.session_table_budget(*s).unwrap())
            .sum();
        assert!(applied <= total, "shares must fit the cap ({applied} vs {total})");
        assert!(
            service.session_table_budget(1) > service.session_table_budget(3),
            "the pattern-heavy chip must get the bigger share"
        );
        assert_eq!(service.sessions().count(), 3);

        // The auto policy always derives *some* positive fleet cap.
        assert!(TableBudget::Auto.fleet_bytes().unwrap() > 0);
        assert_eq!(TableBudget::PerSession.fleet_bytes(), None);
    }

    #[test]
    fn install_session_adopts_and_persists_warm_state() {
        let cfg = GroupConfig::R2C2;
        let opts = CompileOptions::new(cfg, Method::Complete);
        let dir = std::env::temp_dir().join(format!("rchg-install-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut service = CompileService::new(ServiceOptions {
            opts: opts.clone(),
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: Some(dir.clone()),
            store_dir: None,
        });
        // Warm a session outside the service (as the fabric's shard-merge
        // path does) and hand it over.
        let chip = ChipFaults::new(11, FaultRates::paper_default());
        let ws = random_weights(1_200, cfg.max_per_array(), 9);
        let mut session = CompileSession::builder(cfg).options(opts).chip(&chip);
        let _ = session.compile_tensor("a", &ws);
        service.install_session(11, session);
        assert!(service.persist_errors().is_empty());
        assert!(service.session(11).is_some());
        // The adopted session serves the next run warm…
        service.enqueue(11, "a", ws.clone());
        let results = service.run().unwrap();
        assert_eq!(results[0].tensor.stats.unique_pairs, 0, "adopted session must be warm");
        // …and was persisted at install time: a fresh service over the
        // same cache dir also starts warm.
        let mut restarted = CompileService::new(ServiceOptions {
            opts: CompileOptions::new(cfg, Method::Complete),
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: Some(dir.clone()),
            store_dir: None,
        });
        restarted.enqueue(11, "a", ws);
        let warm = restarted.run().unwrap();
        assert_eq!(warm[0].tensor.stats.unique_pairs, 0, "cache file must warm-start");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
