//! `CompileService` — a batched compile front-end over many chips.
//!
//! A deployment fleet compiles the same (or revised) models for many
//! physical chips, each with its own fault pattern. The service queues
//! jobs from any number of chips behind a single
//! [`CompileService::enqueue`]/[`CompileService::run`] API, keeps **one
//! warm [`CompileSession`] per chip seed**, and shards chips across the
//! existing work-stealing pool on `run` — each chip's jobs drain through
//! its session as one batch (single solve fan-out over the union of fresh
//! pairs), and chips run concurrently.
//!
//! With a `cache_dir` configured, sessions are loaded from / saved to
//! per-chip cache files around every `run`, so a service restarted on the
//! same fleet starts warm: recompiling an unchanged model performs zero
//! fresh solves. Cache files whose key (chip seed, fault rates, grouping
//! config, pipeline fingerprint) does not match the service configuration
//! are ignored and rebuilt, never silently reused.
//!
//! Resident pattern-table memory is budgeted fleet-wide via
//! [`TableBudget`]: one global cap (fixed, or auto-sized from system RAM)
//! split evenly across live sessions and re-derived as chips join — so a
//! service over a thousand chips does not hold a thousand full-size
//! caches. Budget pressure only ever costs re-solves, never output bytes.
//!
//! Results are byte-deterministic: job results come back in enqueue
//! order, and neither the thread count nor the chip sharding changes a
//! single output byte (per-chip slot order is fixed by enqueue order).

use super::compiler::{CompileOptions, CompiledTensor};
use super::session::CompileSession;
use crate::fault::bank::ChipFaults;
use crate::fault::FaultRates;
use crate::util::pool::parallel_work_steal;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// How the service budgets resident pattern-table memory across chips.
///
/// One warm session per chip means N chips hold N solve caches; a cap
/// that is correct for one session (`CompileOptions::table_memory_bytes`)
/// multiplies by the fleet size. `Fleet` and `Auto` instead treat the cap
/// as a **global** budget split evenly across live sessions, re-derived
/// on every [`CompileService::run`] as chips join. Shrinking a session's
/// budget only ever costs re-solves (LRU eviction at batch boundaries),
/// never a single output byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableBudget {
    /// Every session keeps its own `CompileOptions::table_memory_bytes`
    /// (the historical behavior; total memory grows with the fleet).
    PerSession,
    /// One fleet-wide cap in bytes, split evenly across live sessions
    /// (at least 1 byte each — a degenerate budget degrades to
    /// re-solving, not to failure).
    Fleet(usize),
    /// Fleet-wide cap sized from the machine: half of physical RAM when
    /// detectable ([`crate::util::mem::system_memory_bytes`]), else the
    /// per-session default
    /// [`crate::coordinator::DEFAULT_TABLE_MEMORY_BYTES`].
    Auto,
}

impl TableBudget {
    /// The fleet-wide cap this policy implies, or `None` for
    /// [`TableBudget::PerSession`].
    pub fn fleet_bytes(&self) -> Option<usize> {
        match self {
            TableBudget::PerSession => None,
            TableBudget::Fleet(bytes) => Some((*bytes).max(1)),
            TableBudget::Auto => Some(
                crate::util::mem::system_memory_bytes()
                    .map(|ram| (ram / 2).max(1))
                    .unwrap_or(super::classes::DEFAULT_TABLE_MEMORY_BYTES),
            ),
        }
    }
}

/// Service configuration: compile options shared by every chip (threads =
/// total worker budget across chips), the fleet's fault rates, the
/// pattern-table memory policy, and an optional directory for persistent
/// per-chip session caches.
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    pub opts: CompileOptions,
    pub rates: FaultRates,
    /// Resident pattern-table memory policy across the fleet (default
    /// behavior of older services: [`TableBudget::PerSession`]).
    pub table_budget: TableBudget,
    pub cache_dir: Option<PathBuf>,
}

struct QueuedJob {
    job_id: u64,
    chip_seed: u64,
    name: String,
    weights: Vec<i64>,
}

/// One compiled job, tagged with its identity.
pub struct JobResult {
    pub job_id: u64,
    pub chip_seed: u64,
    pub name: String,
    pub tensor: CompiledTensor,
}

/// Multi-chip batching compile service. See the module docs.
///
/// ```
/// use rchg::coordinator::{CompileOptions, CompileService, Method, ServiceOptions, TableBudget};
/// use rchg::fault::FaultRates;
/// use rchg::grouping::GroupConfig;
///
/// let mut service = CompileService::new(ServiceOptions {
///     opts: CompileOptions::new(GroupConfig::R2C2, Method::Complete),
///     rates: FaultRates::paper_default(),
///     table_budget: TableBudget::Fleet(64 << 20),
///     cache_dir: None,
/// });
/// let weights: Vec<i64> = (-10..=10).collect();
/// let job_a = service.enqueue(1, "conv1", weights.clone()); // chip 1
/// let job_b = service.enqueue(2, "conv1", weights);         // chip 2
/// let results = service.run()?;
/// assert_eq!(results.len(), 2);
/// assert_eq!(results[0].job_id, job_a);
/// assert_eq!(results[1].job_id, job_b);
/// // The fleet cap was split across the two live chip sessions.
/// assert_eq!(service.applied_table_budget(), Some(32 << 20));
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct CompileService {
    sopts: ServiceOptions,
    sessions: BTreeMap<u64, CompileSession>,
    queue: Vec<QueuedJob>,
    next_job: u64,
    persist_errors: Vec<String>,
    per_chip_budget: Option<usize>,
}

impl CompileService {
    pub fn new(sopts: ServiceOptions) -> CompileService {
        CompileService {
            sopts,
            sessions: BTreeMap::new(),
            queue: Vec::new(),
            next_job: 0,
            persist_errors: Vec::new(),
            per_chip_budget: None,
        }
    }

    /// The per-chip pattern-table budget the latest
    /// [`CompileService::run`] applied under a fleet-wide
    /// [`TableBudget`], or `None` before the first run / under
    /// [`TableBudget::PerSession`].
    pub fn applied_table_budget(&self) -> Option<usize> {
        self.per_chip_budget
    }

    /// Queue one named tensor for `chip_seed`; returns the job id its
    /// [`JobResult`] will carry. The name keys the tensor's chip region
    /// (see [`CompileSession::tensor_id_of`]), so re-enqueueing the same
    /// name on a warm chip is pure cache hits.
    pub fn enqueue(&mut self, chip_seed: u64, name: &str, weights: Vec<i64>) -> u64 {
        let job_id = self.next_job;
        self.next_job += 1;
        self.queue.push(QueuedJob { job_id, chip_seed, name: name.to_string(), weights });
        job_id
    }

    /// Jobs queued and not yet run.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The warm session of one chip, if it exists yet.
    pub fn session(&self, chip_seed: u64) -> Option<&CompileSession> {
        self.sessions.get(&chip_seed)
    }

    /// Warm sessions currently held, keyed by chip seed.
    pub fn sessions(&self) -> impl Iterator<Item = (&u64, &CompileSession)> {
        self.sessions.iter()
    }

    /// Cache file of one chip under `dir`, keyed by the full session cache
    /// key — chip seed, grouping config, method, plus a fingerprint of the
    /// fault rates and remaining pipeline tunables — so differently
    /// configured services over one directory never clobber each other's
    /// warm state.
    fn cache_path(dir: &Path, opts: &CompileOptions, rates: &FaultRates, chip_seed: u64) -> PathBuf {
        let mut key = Vec::with_capacity(26);
        key.extend_from_slice(&rates.p_sa0.to_bits().to_le_bytes());
        key.extend_from_slice(&rates.p_sa1.to_bits().to_le_bytes());
        key.extend_from_slice(&opts.pipeline.table_value_limit.to_le_bytes());
        key.push(opts.pipeline.sparsest as u8);
        key.push(opts.cfg.levels);
        let fingerprint = crate::util::prop::fnv1a(&key);
        let name = format!(
            "chip-{chip_seed}-{}-{:?}-{fingerprint:016x}.rcs",
            opts.cfg.name(),
            opts.pipeline.method
        );
        dir.join(name.to_ascii_lowercase())
    }

    /// A session for `chip_seed`: warm from the in-memory map, else warm
    /// from the cache dir (if the stored key matches), else cold.
    fn obtain_session(&mut self, chip_seed: u64) -> CompileSession {
        if let Some(s) = self.sessions.remove(&chip_seed) {
            return s;
        }
        let chip = ChipFaults::new(chip_seed, self.sopts.rates);
        if let Some(dir) = &self.sopts.cache_dir {
            let path = Self::cache_path(dir, &self.sopts.opts, &self.sopts.rates, chip_seed);
            if let Ok(mut s) = CompileSession::load(&path) {
                if s.matches(&chip, &self.sopts.opts) {
                    // Execution knobs are not part of the cache key — apply
                    // the service's configuration to the rehydrated session.
                    s.set_time_stages(self.sopts.opts.time_stages);
                    s.set_solve_tier(self.sopts.opts.tier);
                    s.set_table_memory_bytes(self.sopts.opts.table_memory_bytes);
                    return s;
                }
            }
        }
        CompileSession::builder(self.sopts.opts.cfg)
            .options(self.sopts.opts.clone())
            .chip(&chip)
    }

    /// Compile every queued job. Jobs are grouped per chip (one warm
    /// session per chip seed), chips are sharded across the work-stealing
    /// pool, and each chip's jobs drain as one batch. Results come back
    /// in enqueue order; outputs are independent of thread count and
    /// sharding. With a `cache_dir`, every touched session is persisted
    /// after the batch.
    pub fn run(&mut self) -> Result<Vec<JobResult>> {
        let queue = std::mem::take(&mut self.queue);
        if queue.is_empty() {
            return Ok(Vec::new());
        }
        // Group jobs by chip, chips ordered by first appearance.
        let mut order: Vec<u64> = Vec::new();
        let mut by_chip: BTreeMap<u64, Vec<QueuedJob>> = BTreeMap::new();
        for job in queue {
            if !by_chip.contains_key(&job.chip_seed) {
                order.push(job.chip_seed);
            }
            by_chip.entry(job.chip_seed).or_default().push(job);
        }
        let n_chips = order.len();
        let total_threads = self.sopts.opts.threads.max(1);
        let outer = total_threads.min(n_chips);
        let inner = (total_threads / outer).max(1);

        // Under a fleet-wide table budget, split the cap evenly across
        // every session live after this run (retained + newly joined) and
        // apply it to the sessions this batch touches. Sessions idle this
        // round trim to the new budget the next time they run a batch.
        self.per_chip_budget = self.sopts.table_budget.fleet_bytes().map(|total| {
            let mut live: std::collections::BTreeSet<u64> = self.sessions.keys().copied().collect();
            live.extend(order.iter().copied());
            (total / live.len().max(1)).max(1)
        });

        // Move each chip's session + jobs into a cell the pool can claim;
        // every cell is taken by exactly one worker.
        let mut cells: Vec<Mutex<Option<(u64, CompileSession, Vec<QueuedJob>)>>> =
            Vec::with_capacity(n_chips);
        for seed in &order {
            let mut session = self.obtain_session(*seed);
            session.set_threads(inner);
            if let Some(budget) = self.per_chip_budget {
                session.set_table_memory_bytes(budget);
            }
            cells.push(Mutex::new(Some((*seed, session, by_chip.remove(seed).unwrap()))));
        }
        let done: Vec<(u64, CompileSession, Vec<JobResult>)> =
            parallel_work_steal(n_chips, outer, 1, |i| {
                let (seed, mut session, jobs) = cells[i]
                    .lock()
                    .expect("service cell lock poisoned")
                    .take()
                    .expect("each service cell is claimed once");
                let mut metas = Vec::with_capacity(jobs.len());
                for job in jobs {
                    let QueuedJob { job_id, name, weights, .. } = job;
                    session.submit(&name, weights);
                    metas.push((job_id, name));
                }
                let compiled = session.drain();
                let results = metas
                    .into_iter()
                    .zip(compiled)
                    .map(|((job_id, name), (_, tensor))| JobResult {
                        job_id,
                        chip_seed: seed,
                        name,
                        tensor,
                    })
                    .collect();
                (seed, session, results)
            });

        // Reinsert every session and assemble the results first, THEN
        // persist best-effort: a full disk or unwritable cache dir must
        // never throw away a batch of compiled results (the warm sessions
        // stay in memory either way). Failures are reported via
        // [`CompileService::persist_errors`]; legacy (`dedupe = false`)
        // sessions have nothing to persist and are skipped silently.
        let mut results: Vec<JobResult> = Vec::new();
        self.persist_errors.clear();
        for (seed, mut session, rs) in done {
            session.set_threads(total_threads);
            if let Some(dir) = &self.sopts.cache_dir {
                if session.persistable() {
                    let path = Self::cache_path(dir, &self.sopts.opts, &self.sopts.rates, seed);
                    if let Err(e) = session.save(&path) {
                        self.persist_errors.push(format!("chip {seed}: {e:#}"));
                    }
                }
            }
            self.sessions.insert(seed, session);
            results.extend(rs);
        }
        results.sort_by_key(|r| r.job_id);
        Ok(results)
    }

    /// Cache files the latest [`CompileService::run`] failed to write
    /// (empty on a clean run). Warm state is still held in memory, so a
    /// later `run` retries persisting automatically.
    pub fn persist_errors(&self) -> &[String] {
        &self.persist_errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;
    use crate::grouping::GroupConfig;
    use crate::util::prng::Rng;

    fn random_weights(n: usize, max: i64, seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.range_i64(-max, max)).collect()
    }

    #[test]
    fn service_results_in_enqueue_order_and_match_sessions() {
        let cfg = GroupConfig::R2C2;
        let mut opts = CompileOptions::new(cfg, Method::Complete);
        opts.threads = 4;
        let mut service = CompileService::new(ServiceOptions {
            opts: opts.clone(),
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: None,
        });
        let w0 = random_weights(1_500, cfg.max_per_array(), 1);
        let w1 = random_weights(900, cfg.max_per_array(), 2);
        // Interleaved enqueue across two chips.
        let j0 = service.enqueue(7, "a", w0.clone());
        let j1 = service.enqueue(8, "a", w0.clone());
        let j2 = service.enqueue(7, "b", w1.clone());
        assert_eq!(service.pending(), 3);
        let results = service.run().unwrap();
        assert_eq!(service.pending(), 0);
        assert_eq!(
            results.iter().map(|r| r.job_id).collect::<Vec<_>>(),
            vec![j0, j1, j2]
        );
        // Each result equals a standalone per-chip session compile.
        for r in &results {
            let chip = ChipFaults::new(r.chip_seed, FaultRates::paper_default());
            let mut solo = CompileSession::builder(cfg).chip(&chip);
            // Replay this chip's jobs in order up to r.
            for pre in results.iter().filter(|p| p.chip_seed == r.chip_seed) {
                let ws = if pre.name == "a" { &w0 } else { &w1 };
                let out = solo.compile_tensor(&pre.name, ws);
                if pre.job_id == r.job_id {
                    assert_eq!(out.decomps, r.tensor.decomps);
                    assert_eq!(out.errors, r.tensor.errors);
                    break;
                }
            }
        }
        // Warm sessions are retained: re-running the same jobs solves nothing.
        service.enqueue(7, "a", w0.clone());
        service.enqueue(8, "a", w0);
        service.enqueue(7, "b", w1);
        let warm = service.run().unwrap();
        assert!(warm.iter().all(|r| r.tensor.stats.unique_pairs == 0));
        for (a, b) in results.iter().zip(&warm) {
            assert_eq!(a.tensor.decomps, b.tensor.decomps);
        }
        // Historical policy: no fleet budget was derived or applied.
        assert_eq!(service.applied_table_budget(), None);
    }

    #[test]
    fn fleet_budget_splits_across_live_sessions() {
        let cfg = GroupConfig::R2C2;
        let opts = CompileOptions::new(cfg, Method::Complete);
        let total = 64 << 20;
        let mut service = CompileService::new(ServiceOptions {
            opts,
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::Fleet(total),
            cache_dir: None,
        });
        let ws = random_weights(800, cfg.max_per_array(), 5);
        service.enqueue(1, "a", ws.clone());
        service.enqueue(2, "a", ws.clone());
        let _ = service.run().unwrap();
        assert_eq!(service.applied_table_budget(), Some(total / 2));
        for (_, s) in service.sessions() {
            assert_eq!(s.options().table_memory_bytes, total / 2);
        }
        // A third chip joining re-derives the split over all live sessions.
        service.enqueue(3, "a", ws);
        let _ = service.run().unwrap();
        assert_eq!(service.applied_table_budget(), Some(total / 3));
        assert_eq!(
            service.session(3).unwrap().options().table_memory_bytes,
            total / 3
        );
        // Outputs never depend on the budget: results above were computed
        // under an eviction-pressured cap and still match a standalone
        // session (covered by eviction tests in `classes.rs`; here we
        // just confirm the accounting).
        assert_eq!(service.sessions().count(), 3);

        // The auto policy always derives *some* positive fleet cap.
        assert!(TableBudget::Auto.fleet_bytes().unwrap() > 0);
        assert_eq!(TableBudget::PerSession.fleet_bytes(), None);
    }
}
