//! L3 coordinator: the paper's compilation pipeline (§V, Fig 7), the
//! pattern-class registry and per-pattern solution tables that dedupe it
//! (solve once per pattern, not per weight), the chip-scoped
//! [`CompileSession`] API (with persistent warm-start and a
//! [`ShardPlan`]-partitioned distributed solve phase) wrapped around
//! both, and the multi-chip [`CompileService`] batching front-end.
//!
//! The full scan → intern → dedupe → solve → scatter walkthrough, the
//! on-disk byte layouts (RCSS session caches, RCSF shard fragments), and
//! the determinism contract live in `docs/ARCHITECTURE.md` at the
//! repository root.

pub mod classes;
pub mod compiler;
pub(crate) mod persist;
pub mod pipeline;
pub mod service;
pub mod session;
pub mod shard;

pub use classes::{
    PatternCtx, PatternId, PatternRegistry, PatternSolution, SolveCache,
    DEFAULT_TABLE_MEMORY_BYTES,
};
pub use compiler::{
    compile_batch_with_cache, CompileOptions, CompileStats, CompiledTensor, TensorJob,
};
pub use pipeline::{
    decompose_one, decompose_with_ctx, solve_full_range, Method, Outcome, PipelineOptions,
    SolveTier, Stage,
};
pub use service::{CompileService, JobResult, ServiceOptions, TableBudget};
pub use session::{CompileSession, SessionBuilder};
pub use shard::{ShardFragment, ShardPlan, FRAGMENT_MAGIC, FRAGMENT_VERSION};

/// Convenience alias kept for source compatibility; new code should build
/// a [`CompileSession`] instead of carrying bare options around.
pub type Compiler = compiler::CompileOptions;
