//! L3 coordinator: the paper's compilation pipeline (§V, Fig 7), the
//! pattern-class registry that dedupes it, the chip-scoped
//! [`CompileSession`] API (with persistent warm-start) wrapped around
//! both, and the multi-chip [`CompileService`] batching front-end.

pub mod classes;
pub mod compiler;
pub mod pipeline;
pub mod service;
pub mod session;

pub use classes::{PatternCtx, PatternId, PatternRegistry, SolveCache};
pub use compiler::{
    compile_batch_with_cache, compile_model, compile_tensor, compile_tensor_with_cache,
    CompileOptions, CompileStats, CompiledTensor, TensorJob,
};
pub use pipeline::{decompose_one, decompose_with_ctx, Method, Outcome, PipelineOptions, Stage};
pub use service::{CompileService, JobResult, ServiceOptions};
pub use session::{CompileSession, SessionBuilder};

/// Convenience alias kept for source compatibility; new code should build
/// a [`CompileSession`] instead of carrying bare options around.
pub type Compiler = compiler::CompileOptions;
