//! L3 coordinator: the paper's compilation pipeline (§V, Fig 7) and the
//! per-chip/per-model compilation driver around it.

pub mod compiler;
pub mod pipeline;

pub use compiler::{compile_model, compile_tensor, CompileOptions, CompileStats, CompiledTensor};
pub use pipeline::{decompose_one, Method, Outcome, PipelineOptions, Stage};

/// Convenience alias: the full compiler entry point.
pub type Compiler = compiler::CompileOptions;
