//! L3 coordinator: the paper's compilation pipeline (§V, Fig 7), the
//! pattern-class registry that dedupes it, and the per-chip/per-model
//! compilation driver around both.

pub mod classes;
pub mod compiler;
pub mod pipeline;

pub use classes::{PatternCtx, PatternId, PatternRegistry, SolveCache};
pub use compiler::{
    compile_model, compile_tensor, compile_tensor_with_cache, CompileOptions, CompileStats,
    CompiledTensor,
};
pub use pipeline::{decompose_one, decompose_with_ctx, Method, Outcome, PipelineOptions, Stage};

/// Convenience alias: the full compiler entry point.
pub type Compiler = compiler::CompileOptions;
