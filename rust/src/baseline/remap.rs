//! Row-remapping baseline — the weight-remapping family of related work
//! (Vortex DAC'15, DCR ICCD'23): permute weight rows so that
//! fault-sensitive weights land on fault-light cell groups.
//!
//! The paper argues these methods need extra peripherals (mux/demux to
//! undo the permutation) and still leave residual errors; this module
//! implements a representative member — greedy importance×damage
//! assignment — so experiments can compare it against hybrid grouping +
//! the pipeline on equal footing.

use crate::baseline::unprotected::unprotected_decompose;
use crate::fault::GroupFaults;
use crate::grouping::{Decomposition, GroupConfig};

/// Result of a remapped compilation.
#[derive(Clone, Debug)]
pub struct RemapResult {
    pub decomps: Vec<Decomposition>,
    pub errors: Vec<i64>,
    /// The applied permutation: `assignment[i]` = fault-group index used by
    /// weight `i` (hardware must route accordingly — the "dislocation"
    /// overhead the paper mentions).
    pub assignment: Vec<usize>,
    pub total_abs_error: u64,
}

/// Greedy row remapping: sort weights by |w| (importance) descending and
/// fault groups by damage potential ascending, then pair them up. Damage
/// potential of a group = the unprotected error it would inflict on a
/// worst-case weight (range loss per Theorem 1).
pub fn remap_compile(weights: &[i64], faults: &[GroupFaults], cfg: &GroupConfig) -> RemapResult {
    assert_eq!(weights.len(), faults.len());
    let n = weights.len();

    // Damage score per fault group: lost representable range.
    let full = 2 * cfg.max_per_array();
    let mut group_order: Vec<(i64, usize)> = faults
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let fa = crate::grouping::FaultAnalysis::new(cfg, f);
            (full - fa.range_width(), i)
        })
        .collect();
    group_order.sort_unstable(); // least damaged first

    let mut weight_order: Vec<(i64, usize)> =
        weights.iter().enumerate().map(|(i, &w)| (-w.abs(), i)).collect();
    weight_order.sort_unstable(); // most important first

    let mut assignment = vec![0usize; n];
    for ((_, gi), (_, wi)) in group_order.iter().zip(&weight_order) {
        assignment[*wi] = *gi;
    }

    let mut decomps = Vec::with_capacity(n);
    let mut errors = Vec::with_capacity(n);
    let mut total = 0u64;
    for (wi, &w) in weights.iter().enumerate() {
        let f = &faults[assignment[wi]];
        let (d, e) = unprotected_decompose(cfg, f, w);
        total += e.unsigned_abs();
        decomps.push(d);
        errors.push(e);
    }
    RemapResult { decomps, errors, assignment, total_abs_error: total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CompileOptions, CompileSession, CompiledTensor, Method};
    use crate::fault::bank::ChipFaults;
    use crate::fault::FaultRates;
    use crate::util::prng::Rng;

    fn compile_tensor(
        ws: &[i64],
        faults: &[GroupFaults],
        opts: &CompileOptions,
    ) -> CompiledTensor {
        CompileSession::builder(opts.cfg)
            .options(opts.clone())
            .detached()
            .compile_with_faults(ws, faults)
    }

    fn workload(cfg: &GroupConfig, n: usize, seed: u64) -> (Vec<i64>, Vec<GroupFaults>) {
        let mut rng = Rng::new(seed);
        let ws: Vec<i64> =
            (0..n).map(|_| rng.range_i64(-cfg.max_per_array(), cfg.max_per_array())).collect();
        let chip = ChipFaults::new(seed ^ 0x5a, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, n, cfg.cells());
        (ws, faults)
    }

    #[test]
    fn assignment_is_a_permutation() {
        let cfg = GroupConfig::R1C4;
        let (ws, fs) = workload(&cfg, 500, 1);
        let r = remap_compile(&ws, &fs, &cfg);
        let mut seen = r.assignment.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..500).collect::<Vec<_>>());
    }

    #[test]
    fn remap_beats_unprotected_identity() {
        let cfg = GroupConfig::R1C4;
        let (ws, fs) = workload(&cfg, 3_000, 2);
        let remap = remap_compile(&ws, &fs, &cfg);
        let raw = compile_tensor(&ws, &fs, &CompileOptions::new(cfg, Method::Unprotected));
        assert!(
            remap.total_abs_error < raw.stats.total_abs_error,
            "remap {} !< raw {}",
            remap.total_abs_error,
            raw.stats.total_abs_error
        );
    }

    #[test]
    fn pipeline_beats_remap() {
        // The paper's positioning: FF-style decomposition (no HW overhead)
        // outperforms remapping. Verify in aggregate.
        let cfg = GroupConfig::R1C4;
        let (ws, fs) = workload(&cfg, 3_000, 3);
        let remap = remap_compile(&ws, &fs, &cfg);
        let pipe = compile_tensor(&ws, &fs, &CompileOptions::new(cfg, Method::Complete));
        assert!(
            pipe.stats.total_abs_error < remap.total_abs_error,
            "pipeline {} !< remap {}",
            pipe.stats.total_abs_error,
            remap.total_abs_error
        );
    }

    #[test]
    fn errors_match_decompositions() {
        let cfg = GroupConfig::R2C2;
        let (ws, fs) = workload(&cfg, 800, 4);
        let r = remap_compile(&ws, &fs, &cfg);
        for i in 0..ws.len() {
            let f = &fs[r.assignment[i]];
            assert_eq!(
                (ws[i] - r.decomps[i].faulty_value(&cfg, f)).abs(),
                r.errors[i]
            );
        }
    }
}
