//! Comparison baselines.
//!
//! * [`fault_free`] — our reimplementation of the original Fault-Free
//!   algorithm (Shin et al., TC'23): exhaustive decomposition-table search,
//!   the compile-time baseline of Table II / Fig 10.
//! * [`unprotected`] — no mitigation at all: ideal sign decomposition
//!   programmed onto the faulty array as-is (the accuracy floor).

pub mod fault_free;
pub mod remap;
pub mod unprotected;
