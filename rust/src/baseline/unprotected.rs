//! Unprotected mapping: ideal sign decomposition programmed straight onto
//! the faulty arrays. This is what a fault-oblivious toolchain does and is
//! the accuracy floor every mitigation method is measured against.

use crate::fault::GroupFaults;
use crate::grouping::{Decomposition, GroupConfig};

/// Program `w` ignoring faults; return the decomposition and the incurred
/// |error| under the fault map.
pub fn unprotected_decompose(
    cfg: &GroupConfig,
    faults: &GroupFaults,
    w: i64,
) -> (Decomposition, i64) {
    let d = Decomposition::encode_ideal(w, cfg);
    let err = (w - d.faulty_value(cfg, faults)).abs();
    (d, err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{FaultRates, FaultState};
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn zero_error_without_faults() {
        let cfg = GroupConfig::R1C4;
        let faults = GroupFaults::free(cfg.cells());
        for w in [-255, 0, 77] {
            assert_eq!(unprotected_decompose(&cfg, &faults, w).1, 0);
        }
    }

    #[test]
    fn msb_fault_is_catastrophic() {
        // The Fig 1b scenario: large distortion from a single MSB fault.
        let cfg = GroupConfig::R1C4;
        let mut faults = GroupFaults::free(cfg.cells());
        faults.pos[0] = FaultState::Sa0; // MSB stuck high
        faults.pos[2] = FaultState::Sa1; // 2nd LSB stuck low
        let (_, err) = unprotected_decompose(&cfg, &faults, 52);
        assert_eq!(err, 188); // 52 → 240, exactly Fig 1b
    }

    #[test]
    fn error_bounded_by_span() {
        prop_check("unprotected-bound", 200, |rng| {
            let cfg = GroupConfig::R2C2;
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.3, p_sa1: 0.3 }, rng);
            let w = rng.range_i64(-30, 30);
            let (_, err) = unprotected_decompose(&cfg, &faults, w);
            prop_assert!(err <= 2 * cfg.max_per_array(), "error beyond physical span");
            Ok(())
        });
    }
}
