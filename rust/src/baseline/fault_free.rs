//! Original Fault-Free (FF) algorithm — Shin et al., *IEEE TC* 2023 —
//! reimplemented as the compile-time baseline.
//!
//! FF operates on the *decomposition table*: the set of `(w⁺, w⁻)` pairs
//! with `w⁺ − w⁻ = w` (the diagonal) and, failing that, all other pairs
//! (off-diagonals). For conventional column grouping (r = 1) the encoding
//! of a partial weight into cells is the unique base-L digit expansion, so
//! each pair maps to one bitmap pair and FF checks it directly against the
//! fault map:
//!
//! 1. **FAWD stage** — walk the diagonal looking for a *fault-masked* pair
//!    (every stuck cell already holds the digit the encoding wants).
//! 2. **CVM stage** — if none exists, scan all `(w⁺, w⁻)` pairs for the
//!    minimum distortion `|w − (d(X̃⁺) − d(X̃⁻))|`. This is the `O(range²)`
//!    scan that dominates FF's reported multi-hour compile times.
//!
//! For r > 1 the per-weight table is no longer a simple product of two
//! value ranges (each partial weight has combinatorially many encodings);
//! the paper notes FF "fails to compile R2C4, as the corresponding
//! decomposition table becomes prohibitively large". We reproduce that
//! behaviour faithfully: [`ff_decompose`] returns `Unsupported` for r > 1.

use crate::fault::GroupFaults;
use crate::grouping::{Decomposition, GroupConfig};

/// Outcome of the original FF algorithm for one weight.
#[derive(Clone, Debug)]
pub enum FfOutcome {
    /// Fault-masked (exact) pair found on the diagonal during FAWD.
    Exact(Decomposition),
    /// CVM fallback pair with the achieved |error|.
    Approx(Decomposition, i64),
    /// Configuration outside FF's reach (row grouping r > 1).
    Unsupported,
}

impl FfOutcome {
    pub fn decomposition(&self) -> Option<&Decomposition> {
        match self {
            FfOutcome::Exact(d) | FfOutcome::Approx(d, _) => Some(d),
            FfOutcome::Unsupported => None,
        }
    }
    pub fn error(&self) -> i64 {
        match self {
            FfOutcome::Exact(_) => 0,
            FfOutcome::Approx(_, e) => *e,
            FfOutcome::Unsupported => i64::MAX,
        }
    }
}

/// Run original FF for one weight. `w` must satisfy |w| ≤ L^c − 1.
pub fn ff_decompose(cfg: &GroupConfig, faults: &GroupFaults, w: i64) -> FfOutcome {
    if cfg.rows != 1 {
        return FfOutcome::Unsupported;
    }
    let max = cfg.max_per_array();
    debug_assert!(w.abs() <= max);

    // --- Stage 1: FAWD — diagonal walk for a fault-masked pair. ---------
    // Walk outward from the sparsest pair (wp = max(w,0)) to mimic FF's
    // preference for low-magnitude representations.
    let start = w.max(0);
    for wp in start..=max {
        let wn = wp - w;
        if wn > max {
            break;
        }
        let pos = encode_digits(wp, cfg);
        let neg = encode_digits(wn, cfg);
        if masked(&pos, &faults.pos, cfg) && masked(&neg, &faults.neg, cfg) {
            return FfOutcome::Exact(Decomposition {
                pos: crate::grouping::Bitmap { cells: pos },
                neg: crate::grouping::Bitmap { cells: neg },
            });
        }
    }

    // --- Stage 2: CVM — full table scan. ---------------------------------
    let mut best: Option<(i64, u64, Decomposition)> = None;
    for wp in 0..=max {
        let pos = encode_digits(wp, cfg);
        let pos_bm = crate::grouping::Bitmap { cells: pos };
        let pos_val = pos_bm.decode_faulty(cfg, &faults.pos);
        for wn in 0..=max {
            let neg = encode_digits(wn, cfg);
            let neg_bm = crate::grouping::Bitmap { cells: neg };
            let err = (w - (pos_val - neg_bm.decode_faulty(cfg, &faults.neg))).abs();
            let l1 = (wp + wn) as u64;
            let better = match &best {
                None => true,
                Some((be, bl1, _)) => err < *be || (err == *be && l1 < *bl1),
            };
            if better {
                best = Some((err, l1, Decomposition { pos: pos_bm.clone(), neg: neg_bm }));
            }
            if let Some((0, 0, _)) = best {
                break;
            }
        }
    }
    let (err, _, d) = best.expect("CVM scan always finds a pair");
    FfOutcome::Approx(d, err)
}

/// Unique base-L digit encoding for r = 1 (MSB first).
fn encode_digits(mut v: i64, cfg: &GroupConfig) -> Vec<u8> {
    let l = cfg.levels as i64;
    let mut out = vec![0u8; cfg.cols];
    for col in (0..cfg.cols).rev() {
        out[col] = (v % l) as u8;
        v /= l;
    }
    debug_assert_eq!(v, 0);
    out
}

/// Are all stuck cells consistent with the wanted digits? (fault-masked)
fn masked(digits: &[u8], faults: &[crate::fault::FaultState], cfg: &GroupConfig) -> bool {
    use crate::fault::FaultState;
    digits.iter().zip(faults).all(|(&d, f)| match f {
        FaultState::Free => true,
        FaultState::Sa0 => d == cfg.levels - 1,
        FaultState::Sa1 => d == 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::GroupTables;
    use crate::fault::{FaultRates, FaultState};
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn fault_free_map_is_exact_everywhere() {
        let cfg = GroupConfig::R1C4;
        let faults = GroupFaults::free(cfg.cells());
        for w in [-255, -52, 0, 19, 255] {
            match ff_decompose(&cfg, &faults, w) {
                FfOutcome::Exact(d) => assert_eq!(d.faulty_value(&cfg, &faults), w),
                other => panic!("expected exact, got {other:?}"),
            }
        }
    }

    #[test]
    fn paper_fig3_example() {
        // Fig 3: w = 19, faults distort the naive mapping; FF finds an
        // alternative (w⁺, w⁻) that restores 19 exactly.
        let cfg = GroupConfig::R1C4;
        let mut faults = GroupFaults::free(cfg.cells());
        // The exact fault pattern of Fig 3c isn't fully specified; use a
        // pattern that breaks the naive (19, 0) pair but is maskable.
        faults.neg[1] = FaultState::Sa0; // neg array bit stuck high
        let naive = Decomposition::encode_ideal(19, &cfg);
        assert_ne!(naive.faulty_value(&cfg, &faults), 19);
        match ff_decompose(&cfg, &faults, 19) {
            FfOutcome::Exact(d) => assert_eq!(d.faulty_value(&cfg, &faults), 19),
            other => panic!("FF should mask this pattern, got {other:?}"),
        }
    }

    #[test]
    fn unsupported_for_row_grouping() {
        let cfg = GroupConfig::R2C2;
        let faults = GroupFaults::free(cfg.cells());
        assert!(matches!(ff_decompose(&cfg, &faults, 3), FfOutcome::Unsupported));
    }

    #[test]
    fn ff_error_matches_table_cvm_optimum() {
        // FF explores exactly the unique-encoding pairs; for r=1 those span
        // all achievable (value, value) combinations, so its CVM optimum
        // must equal the table-based optimum.
        prop_check("ff-vs-table", 60, |rng| {
            let cfg = GroupConfig::new(1, 3, 4);
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.2, p_sa1: 0.2 }, rng);
            let w = rng.range_i64(-cfg.max_per_array(), cfg.max_per_array());
            let ff = ff_decompose(&cfg, &faults, w);
            let tables = GroupTables::build(&cfg, &faults);
            let (_, tbl_err) = tables.cvm(&cfg, &faults, w);
            prop_assert!(
                ff.error() == tbl_err,
                "FF err {} vs table err {tbl_err} (w={w}, faults={faults:?})",
                ff.error()
            );
            Ok(())
        });
    }
}
