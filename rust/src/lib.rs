//! # RCHG — Row-Column Hybrid Grouping for fault-resilient IMC arrays
//!
//! Production-oriented reproduction of *"Row-Column Hybrid Grouping for
//! Fault-Resilient Multi-Bit Weight Representation on IMC Arrays"*
//! (Jeon et al., 2025): a fault model for stuck-at faults (SAFs) on ReRAM
//! crossbars, the row-column hybrid grouping weight representation, and an
//! ILP-based compilation pipeline that decomposes every DNN weight into
//! positive/negative cell bitmaps that mask the chip's fault pattern.
//!
//! Architecture (three layers):
//! * **L3 (this crate)** — the compilation pipeline and every substrate it
//!   needs (exact ILP solver, fault models, crossbar mapper, energy model,
//!   quantizers, dataset/eval drivers) plus a PJRT runtime that executes
//!   the AOT-compiled model graphs. Python never runs at this layer.
//! * **L2 (python/compile/model.py)** — JAX forward graphs for the eval
//!   models, lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas crossbar-MVM kernel
//!   (bit-sliced MACs + shift-and-add + pos/neg subtraction).
//!
//! Start with [`coordinator::Compiler`] (the paper's contribution) or the
//! `examples/` directory.

pub mod arrays;
pub mod baseline;
pub mod energy;
pub mod experiments;
pub mod coordinator;
pub mod decompose;
pub mod fault;
pub mod grouping;
pub mod ilp;
pub mod metrics;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
