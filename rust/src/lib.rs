//! # RCHG — Row-Column Hybrid Grouping for fault-resilient IMC arrays
//!
//! Production-oriented reproduction of *"Row-Column Hybrid Grouping for
//! Fault-Resilient Multi-Bit Weight Representation on IMC Arrays"*
//! (Jeon et al., 2025): a fault model for stuck-at faults (SAFs) on ReRAM
//! crossbars, the row-column hybrid grouping weight representation, and an
//! ILP-based compilation pipeline that decomposes every DNN weight into
//! positive/negative cell bitmaps that mask the chip's fault pattern.
//!
//! Architecture (three layers):
//! * **L3 (this crate)** — the compilation pipeline and every substrate it
//!   needs (exact ILP solver, fault models, crossbar mapper, energy model,
//!   quantizers, dataset/eval drivers) plus a PJRT runtime that executes
//!   the AOT-compiled model graphs. Python never runs at this layer.
//! * **L2 (python/compile/model.py)** — JAX forward graphs for the eval
//!   models, lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas crossbar-MVM kernel
//!   (bit-sliced MACs + shift-and-add + pos/neg subtraction).
//!
//! ## Compile sessions (the public API)
//!
//! Compilation is chip-scoped and recurring: a physical chip's SAF
//! pattern is fixed, and every model revision deployed to it is
//! recompiled against the same fault maps. The entry point is therefore a
//! [`coordinator::CompileSession`] — built per chip via
//! `CompileSession::builder(cfg).method(…).threads(…).chip(&chip)` — that
//! owns the pattern-class state and accumulates per-session statistics:
//!
//! * `compile_tensor(name, weights)` / `compile_model(tensors)` /
//!   `compile_with_faults(weights, faults)` — everything compiled through
//!   one session shares solved work;
//! * `submit(name, weights)` + `drain()` — batch mode: one work-stealing
//!   solve fan-out over the union of all queued tensors' fresh pairs;
//! * `save(path)` / `CompileSession::load(path)` — persistent warm-start:
//!   the interned patterns and their solution tables are serialized
//!   ("RCSS" v2, keyed by chip seed, [`grouping::GroupConfig`], and
//!   pipeline fingerprint, with a checksum), so recompiling a revised
//!   model on the same chip starts warm — an unchanged tensor performs
//!   **zero** fresh solves, and so does a *changed* tensor whose new
//!   weight values hit already-tabled patterns.
//!
//! Above sessions sits [`coordinator::CompileService`]: a batched compile
//! front-end over many chips (one warm session per chip seed, chips
//! sharded across the work-stealing pool, optional cache directory, and a
//! fleet-wide pattern-table memory budget via
//! [`coordinator::TableBudget`] — fixed or auto-sized from system RAM,
//! split across live sessions), surfaced as `rchg serve-batch`.
//!
//! *One* chip's solve phase also distributes: a [`coordinator::ShardPlan`]
//! partitions the chip's pattern-id space into K contiguous ranges,
//! [`coordinator::CompileSession::solve_shard`] solves one range into a
//! serializable [`coordinator::ShardFragment`], and
//! [`coordinator::CompileSession::merge_fragments`] reassembles a warm
//! cache **byte-identical** to an unsharded compile — surfaced as
//! `rchg shard-solve --shard k/K` and `rchg merge-shards`.
//!
//! The [`net`] **compile fabric** puts all of this on the wire (std TCP,
//! "RCWP" v1 framed protocol): `rchg serve` is a daemon wrapping the
//! service whose coordinator schedules shard ranges onto connected
//! `rchg worker` hosts — with timeout/loss reassignment — and `rchg
//! submit` ships jobs and streams results back. Distributed or local,
//! cold or warm, the compiled bitmaps and session bytes are identical.
//!
//! The old free functions are **removed**: `compile_tensor(ws, f, opts)`
//! → `session.compile_with_faults(ws, f)` (use `.detached()` when there
//! is no chip); `compile_tensor_with_cache` → the same (the session owns
//! the cache); `compile_model(tensors, chip, opts)` →
//! `session.compile_model(tensors)`; [`nn::ChipCompiler`] keeps its
//! surface and is a thin adapter over a session.
//!
//! ## Solve-once-per-pattern compilation (the core underneath)
//!
//! The compiler's unit of work is a **pattern class**, not a weight. A
//! compilation runs four phases ([`coordinator::compiler`]):
//!
//! 1. **Scan** — intern every group's fault pattern
//!   ([`fault::GroupFaults::pattern_key`]) into a
//!   [`coordinator::PatternRegistry`]; each class carries one shared
//!   [`coordinator::PatternCtx`] whose `FaultAnalysis`/`GroupTables` are
//!   built lazily, at most once, and shared across threads.
//! 2. **Dedupe** — resolve every (pattern, weight) request against the
//!   session's chip-wide [`coordinator::SolveCache`]; anything resident
//!   (from any earlier tensor, batch, or session generation) is a hit.
//! 3. **Solve** — on the default [`coordinator::SolveTier::BatchTable`]
//!   tier each missing *pattern* is solved **once for its whole weight
//!   range** ([`coordinator::solve_full_range`]: one shared
//!   [`decompose::DiffTable`] pass instead of one value-table sweep per
//!   weight) and installed as a dense [`coordinator::PatternSolution`]
//!   table; the paper-protocol baselines (FF, ILP-only) and intractable
//!   configs keep the per-weight cost model
//!   ([`coordinator::SolveTier::PerWeight`], bounded per-pattern maps).
//!   Fan-out runs on an atomic-counter work-stealing scheduler
//!   ([`util::pool::parallel_work_steal`]); work order is fixed by the
//!   scan, so results are byte-deterministic at any thread count and
//!   across tiers.
//! 4. **Scatter** — O(1) table lookups map every weight back to its
//!   outcome.
//!
//! At the paper's published SAF rates most groups are fault-free or share
//! a low-cardinality pattern, and weight ranges are small and dense (61
//! values on R2C2, 511 on R1C4), so one table build amortizes across
//! every weight of the class — the solver sweeps ≥2× less than even the
//! pair-cache design, and a warm session does no solve work at all for
//! any weight of a known pattern. Resident table memory is bounded
//! (`CompileOptions::table_memory_bytes`, default
//! [`coordinator::DEFAULT_TABLE_MEMORY_BYTES`]): least-recently-used
//! patterns are evicted deterministically at batch boundaries and simply
//! re-solved if they recur.
//!
//! Start with [`coordinator::CompileSession`] or the `examples/`
//! directory (`quickstart` walks a save/load warm-start). The end-to-end
//! architecture walkthrough — pipeline phases, the RCSS/RCSF on-disk byte
//! layouts, and the determinism contract (byte-identity across thread
//! counts, solve tiers, and shard counts) — lives in
//! `docs/ARCHITECTURE.md` at the repository root; the top-level
//! `README.md` has the CLI quickstart.

pub mod arrays;
pub mod baseline;
pub mod energy;
pub mod experiments;
pub mod coordinator;
pub mod decompose;
pub mod fault;
pub mod grouping;
pub mod ilp;
pub mod metrics;
pub mod net;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod store;
pub mod tensor;
pub mod util;
