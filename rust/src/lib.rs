//! # RCHG — Row-Column Hybrid Grouping for fault-resilient IMC arrays
//!
//! Production-oriented reproduction of *"Row-Column Hybrid Grouping for
//! Fault-Resilient Multi-Bit Weight Representation on IMC Arrays"*
//! (Jeon et al., 2025): a fault model for stuck-at faults (SAFs) on ReRAM
//! crossbars, the row-column hybrid grouping weight representation, and an
//! ILP-based compilation pipeline that decomposes every DNN weight into
//! positive/negative cell bitmaps that mask the chip's fault pattern.
//!
//! Architecture (three layers):
//! * **L3 (this crate)** — the compilation pipeline and every substrate it
//!   needs (exact ILP solver, fault models, crossbar mapper, energy model,
//!   quantizers, dataset/eval drivers) plus a PJRT runtime that executes
//!   the AOT-compiled model graphs. Python never runs at this layer.
//! * **L2 (python/compile/model.py)** — JAX forward graphs for the eval
//!   models, lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas crossbar-MVM kernel
//!   (bit-sliced MACs + shift-and-add + pos/neg subtraction).
//!
//! ## Compile sessions (the public API)
//!
//! Compilation is chip-scoped and recurring: a physical chip's SAF
//! pattern is fixed, and every model revision deployed to it is
//! recompiled against the same fault maps. The entry point is therefore a
//! [`coordinator::CompileSession`] — built per chip via
//! `CompileSession::builder(cfg).method(…).threads(…).chip(&chip)` — that
//! owns the pattern-class state and accumulates per-session statistics:
//!
//! * `compile_tensor(name, weights)` / `compile_model(tensors)` /
//!   `compile_with_faults(weights, faults)` — everything compiled through
//!   one session shares solved work;
//! * `submit(name, weights)` + `drain()` — batch mode: one work-stealing
//!   solve fan-out over the union of all queued tensors' fresh pairs;
//! * `save(path)` / `CompileSession::load(path)` — persistent warm-start:
//!   the interned patterns and solved pairs are serialized (keyed by chip
//!   seed, [`grouping::GroupConfig`], and pipeline fingerprint, with a
//!   checksum), so recompiling a revised model on the same chip starts
//!   warm — an unchanged tensor performs **zero** fresh solves.
//!
//! Above sessions sits [`coordinator::CompileService`]: a batched compile
//! front-end over many chips (one warm session per chip seed, chips
//! sharded across the work-stealing pool, optional cache directory),
//! surfaced as `rchg serve-batch`.
//!
//! Migrating from the deprecated free functions (kept as one-shot shims
//! for one release): `compile_tensor(ws, f, opts)` →
//! `session.compile_with_faults(ws, f)`; `compile_tensor_with_cache` →
//! the same (the session owns the cache); `compile_model(tensors, chip,
//! opts)` → `session.compile_model(tensors)`; [`nn::ChipCompiler`] keeps
//! its surface and is now a thin adapter over a session.
//!
//! ## Dedupe-first compilation (the core underneath)
//!
//! The compiler's unit of work is a **pattern class**, not a weight. A
//! compilation runs four phases ([`coordinator::compiler`]):
//!
//! 1. **Scan** — intern every group's fault pattern
//!   ([`fault::GroupFaults::pattern_key`]) into a
//!   [`coordinator::PatternRegistry`]; each class carries one shared
//!   [`coordinator::PatternCtx`] whose `FaultAnalysis`/`GroupTables` are
//!   built lazily, at most once, and shared across threads.
//! 2. **Dedupe** — collapse the tensor to unique (pattern, weight) pairs
//!   against the session's chip-wide [`coordinator::SolveCache`]; tensors
//!   of one chip reuse each other's solved pairs.
//! 3. **Solve** — run the staged pipeline (Fig 7) once per unique pair,
//!   fanned out over an atomic-counter work-stealing scheduler
//!   ([`util::pool::parallel_work_steal`]); slot order is fixed by the
//!   scan, so results are byte-deterministic at any thread count.
//! 4. **Scatter** — map solved pairs back to weight indices.
//!
//! At the paper's published SAF rates most groups are fault-free or share
//! a low-cardinality pattern, so unique pairs ≪ weights and the solver
//! does 5–20× less work than per-weight iteration
//! (`CompileStats::dedup_ratio`) — and a warm session does no solver work
//! at all on unchanged tensors.
//!
//! Start with [`coordinator::CompileSession`] or the `examples/`
//! directory (`quickstart` walks a save/load warm-start).

pub mod arrays;
pub mod baseline;
pub mod energy;
pub mod experiments;
pub mod coordinator;
pub mod decompose;
pub mod fault;
pub mod grouping;
pub mod ilp;
pub mod metrics;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
