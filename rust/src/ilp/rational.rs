//! Exact rational arithmetic for the simplex tableau.
//!
//! `i128` numerator/denominator, normalized (gcd-reduced, positive
//! denominator) after every operation. The decomposition ILPs are tiny
//! (≤ ~40 variables, coefficients ≤ L^c), so i128 gives enormous headroom;
//! arithmetic overflow panics loudly in debug and is checked in release
//! via `checked_*` where growth is possible.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, Div, Mul, Neg, Sub};

#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Rat {
    num: i128,
    den: i128, // always > 0
}

pub const ZERO: Rat = Rat { num: 0, den: 1 };
pub const ONE: Rat = Rat { num: 1, den: 1 };

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

impl Rat {
    pub fn new(num: i128, den: i128) -> Rat {
        assert!(den != 0, "rational with zero denominator");
        let sign = if den < 0 { -1 } else { 1 };
        let g = gcd(num, den);
        Rat { num: sign * num / g, den: sign * den / g }
    }

    pub fn int(v: i64) -> Rat {
        Rat { num: v as i128, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }
    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }
    pub fn is_neg(&self) -> bool {
        self.num < 0
    }
    pub fn is_pos(&self) -> bool {
        self.num > 0
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// Largest integer ≤ self.
    pub fn floor(&self) -> i64 {
        let q = self.num.div_euclid(self.den);
        q as i64
    }

    /// Smallest integer ≥ self.
    pub fn ceil(&self) -> i64 {
        let q = (-(-self.num).div_euclid(self.den)) as i64;
        q
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    pub fn abs(&self) -> Rat {
        Rat { num: self.num.abs(), den: self.den }
    }

    pub fn recip(&self) -> Rat {
        assert!(self.num != 0, "reciprocal of zero");
        Rat::new(self.den, self.num)
    }
}

impl Add for Rat {
    type Output = Rat;
    fn add(self, o: Rat) -> Rat {
        // Reduce cross terms first to limit growth.
        let g = gcd(self.den, o.den);
        let (da, db) = (self.den / g, o.den / g);
        let num = self
            .num
            .checked_mul(db)
            .and_then(|x| o.num.checked_mul(da).and_then(|y| x.checked_add(y)))
            .expect("rational overflow (add)");
        let den = self.den.checked_mul(db).expect("rational overflow (add den)");
        Rat::new(num, den)
    }
}

impl Sub for Rat {
    type Output = Rat;
    fn sub(self, o: Rat) -> Rat {
        self + (-o)
    }
}

impl Neg for Rat {
    type Output = Rat;
    fn neg(self) -> Rat {
        Rat { num: -self.num, den: self.den }
    }
}

impl Mul for Rat {
    type Output = Rat;
    fn mul(self, o: Rat) -> Rat {
        // Cross-reduce before multiplying.
        let g1 = gcd(self.num, o.den);
        let g2 = gcd(o.num, self.den);
        let num = (self.num / g1)
            .checked_mul(o.num / g2)
            .expect("rational overflow (mul)");
        let den = (self.den / g2)
            .checked_mul(o.den / g1)
            .expect("rational overflow (mul den)");
        Rat::new(num, den)
    }
}

impl Div for Rat {
    type Output = Rat;
    fn div(self, o: Rat) -> Rat {
        self * o.recip()
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, o: &Rat) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Rat {
    fn cmp(&self, o: &Rat) -> Ordering {
        // den > 0 on both sides.
        let lhs = self.num.checked_mul(o.den).expect("rational overflow (cmp)");
        let rhs = o.num.checked_mul(self.den).expect("rational overflow (cmp)");
        lhs.cmp(&rhs)
    }
}

impl fmt::Debug for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization() {
        assert_eq!(Rat::new(2, 4), Rat::new(1, 2));
        assert_eq!(Rat::new(-2, -4), Rat::new(1, 2));
        assert_eq!(Rat::new(2, -4), Rat::new(-1, 2));
        assert_eq!(Rat::new(0, -7), ZERO);
    }

    #[test]
    fn arithmetic() {
        let a = Rat::new(1, 2);
        let b = Rat::new(1, 3);
        assert_eq!(a + b, Rat::new(5, 6));
        assert_eq!(a - b, Rat::new(1, 6));
        assert_eq!(a * b, Rat::new(1, 6));
        assert_eq!(a / b, Rat::new(3, 2));
        assert_eq!(-a, Rat::new(-1, 2));
    }

    #[test]
    fn ordering() {
        assert!(Rat::new(1, 3) < Rat::new(1, 2));
        assert!(Rat::new(-1, 2) < ZERO);
        assert!(Rat::int(3) > Rat::new(5, 2));
    }

    #[test]
    fn floor_ceil() {
        assert_eq!(Rat::new(7, 2).floor(), 3);
        assert_eq!(Rat::new(7, 2).ceil(), 4);
        assert_eq!(Rat::new(-7, 2).floor(), -4);
        assert_eq!(Rat::new(-7, 2).ceil(), -3);
        assert_eq!(Rat::int(5).floor(), 5);
        assert_eq!(Rat::int(5).ceil(), 5);
    }

    #[test]
    fn integer_detection() {
        assert!(Rat::new(4, 2).is_integer());
        assert!(!Rat::new(5, 2).is_integer());
    }

    #[test]
    fn prop_field_axioms() {
        use crate::util::prop::prop_check;
        prop_check("rat-axioms", 300, |rng| {
            let r = |rng: &mut crate::util::prng::Rng| {
                Rat::new(rng.range_i64(-50, 50) as i128, rng.range_i64(1, 20) as i128)
            };
            let (a, b, c) = (r(rng), r(rng), r(rng));
            if (a + b) + c != a + (b + c) {
                return Err("add assoc".into());
            }
            if a * (b + c) != a * b + a * c {
                return Err("distributivity".into());
            }
            if !b.is_zero() && (a / b) * b != a {
                return Err("div/mul inverse".into());
            }
            Ok(())
        });
    }
}
