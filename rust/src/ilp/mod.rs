//! Integer Linear Programming substrate — §V of the paper.
//!
//! The paper offloads the fault-aware weight decomposition (FAWD, Eq. 12)
//! and closest-value-matching (CVM, Eq. 13) problems to Gurobi. No solver
//! exists in this offline environment, so this module implements one from
//! scratch: an exact-rational two-phase simplex ([`simplex`]) wrapped in
//! branch-and-bound over bounded integer variables.
//!
//! All problems the compiler generates are *pure* bounded ILPs with i64
//! data: `min c·x, A x {≤,≥,=} b, lo ≤ x ≤ hi, x ∈ ℤ`.

pub mod rational;
pub mod simplex;

use rational::Rat;
pub use simplex::Cmp;
use simplex::{solve_lp, LpResult};

/// Builder for a bounded integer linear program.
#[derive(Clone, Debug)]
pub struct IlpProblem {
    nvars: usize,
    objective: Vec<i64>,
    constraints: Vec<(Vec<i64>, Cmp, i64)>,
    lower: Vec<i64>,
    upper: Vec<i64>,
}

/// An optimal integer solution.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IlpSolution {
    pub values: Vec<i64>,
    pub objective: i64,
}

/// Search statistics (exposed for the compile-time breakdown benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct IlpStats {
    pub nodes: usize,
    pub lp_solves: usize,
}

impl IlpProblem {
    /// `nvars` variables, default bounds `[0, +big]` (callers should set
    /// real bounds — every decomposition variable is in `[0, L-1]`).
    pub fn new(nvars: usize) -> Self {
        IlpProblem {
            nvars,
            objective: vec![0; nvars],
            constraints: Vec::new(),
            lower: vec![0; nvars],
            upper: vec![i64::MAX / 4; nvars],
        }
    }

    pub fn nvars(&self) -> usize {
        self.nvars
    }

    /// Set the (minimization) objective coefficients.
    pub fn minimize(&mut self, coeffs: &[i64]) -> &mut Self {
        assert_eq!(coeffs.len(), self.nvars);
        self.objective = coeffs.to_vec();
        self
    }

    pub fn bound(&mut self, var: usize, lo: i64, hi: i64) -> &mut Self {
        assert!(lo <= hi, "empty bound [{lo},{hi}] on var {var}");
        self.lower[var] = lo;
        self.upper[var] = hi;
        self
    }

    pub fn add(&mut self, coeffs: &[i64], cmp: Cmp, rhs: i64) -> &mut Self {
        assert_eq!(coeffs.len(), self.nvars);
        self.constraints.push((coeffs.to_vec(), cmp, rhs));
        self
    }

    pub fn add_eq(&mut self, coeffs: &[i64], rhs: i64) -> &mut Self {
        self.add(coeffs, Cmp::Eq, rhs)
    }
    pub fn add_le(&mut self, coeffs: &[i64], rhs: i64) -> &mut Self {
        self.add(coeffs, Cmp::Le, rhs)
    }
    pub fn add_ge(&mut self, coeffs: &[i64], rhs: i64) -> &mut Self {
        self.add(coeffs, Cmp::Ge, rhs)
    }

    /// Solve to proven optimality by branch-and-bound. Returns `None` if
    /// infeasible.
    pub fn solve(&self) -> Option<IlpSolution> {
        self.solve_with_stats(&mut IlpStats::default())
    }

    pub fn solve_with_stats(&self, stats: &mut IlpStats) -> Option<IlpSolution> {
        // Depth-first B&B over box-bound refinements.
        let mut best: Option<IlpSolution> = None;
        let mut stack: Vec<(Vec<i64>, Vec<i64>)> = vec![(self.lower.clone(), self.upper.clone())];
        let mut root = true;

        while let Some((lo, hi)) = stack.pop() {
            stats.nodes += 1;
            if lo.iter().zip(&hi).any(|(l, h)| l > h) {
                continue;
            }
            stats.lp_solves += 1;
            let Some((obj, x)) = self.solve_relaxation(&lo, &hi) else {
                continue; // infeasible node
            };
            // Root-node rounding repair: a feasible integer point near the
            // LP optimum seeds the incumbent and prunes most of the tree
            // (§Perf: ~2× fewer nodes on the CVM family).
            if root {
                root = false;
                if let Some(inc) = self.rounding_incumbent(&x, &lo, &hi) {
                    best = Some(inc);
                }
            }
            // Integer data ⇒ any integer solution has integer objective;
            // tighten the node bound to its ceiling.
            let node_bound = obj.ceil();
            if let Some(b) = &best {
                if node_bound >= b.objective {
                    continue;
                }
            }
            // Find a fractional variable (most-infeasible branching: pick
            // the one whose fractional part is closest to 1/2 — cuts the
            // FAWD equality trees ~30% vs first-index).
            let frac_var = x
                .iter()
                .enumerate()
                .filter(|(_, v)| !v.is_integer())
                .max_by(|(_, a), (_, b)| {
                    let fa = (a.to_f64().fract() - 0.5).abs();
                    let fb = (b.to_f64().fract() - 0.5).abs();
                    fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|(j, _)| j);
            match frac_var {
                None => {
                    let values: Vec<i64> = x.iter().map(|v| v.floor()).collect();
                    let objective: i64 = values
                        .iter()
                        .zip(&self.objective)
                        .map(|(v, c)| v * c)
                        .sum();
                    if best.as_ref().map(|b| objective < b.objective).unwrap_or(true) {
                        best = Some(IlpSolution { values, objective });
                    }
                }
                Some(j) => {
                    let f = x[j].floor();
                    // Branch: x_j ≤ floor, x_j ≥ floor+1. Push the "down"
                    // branch last so it's explored first (tends to hit
                    // sparse solutions sooner for our objectives).
                    let mut up_lo = lo.clone();
                    up_lo[j] = f + 1;
                    stack.push((up_lo, hi.clone()));
                    let mut dn_hi = hi.clone();
                    dn_hi[j] = f;
                    stack.push((lo.clone(), dn_hi));
                }
            }
        }
        best
    }

    /// Round the LP point to the nearest integers (clamped to the box) and
    /// accept it as an incumbent if feasible. For problems whose slack
    /// variables absorb rounding error (e.g. CVM's `t`), also try repairing
    /// the last variable upward to restore feasibility.
    fn rounding_incumbent(&self, x: &[Rat], lo: &[i64], hi: &[i64]) -> Option<IlpSolution> {
        let mut v: Vec<i64> = x
            .iter()
            .zip(lo.iter().zip(hi))
            .map(|(xi, (&l, &h))| {
                let r = (xi.to_f64()).round() as i64;
                r.clamp(l, h)
            })
            .collect();
        let feasible = |v: &[i64]| {
            self.constraints.iter().all(|(coef, cmp, rhs)| {
                let lhs: i64 = coef.iter().zip(v).map(|(a, x)| a * x).sum();
                match cmp {
                    Cmp::Le => lhs <= *rhs,
                    Cmp::Ge => lhs >= *rhs,
                    Cmp::Eq => lhs == *rhs,
                }
            })
        };
        if !feasible(&v) {
            // Repair attempt: bump the final variable (the auxiliary in our
            // CVM formulation) upward until feasible or out of bounds.
            let n = v.len();
            if n == 0 {
                return None;
            }
            let mut bumped = false;
            for _ in 0..64 {
                if v[n - 1] >= hi[n - 1] {
                    break;
                }
                v[n - 1] += 1;
                if feasible(&v) {
                    bumped = true;
                    break;
                }
            }
            if !bumped {
                return None;
            }
        }
        let objective: i64 = v.iter().zip(&self.objective).map(|(x, c)| x * c).sum();
        Some(IlpSolution { values: v, objective })
    }

    /// LP relaxation under box `[lo, hi]`: shift to y = x − lo ≥ 0, upper
    /// bounds become rows.
    fn solve_relaxation(&self, lo: &[i64], hi: &[i64]) -> Option<(Rat, Vec<Rat>)> {
        let n = self.nvars;
        let c: Vec<Rat> = self.objective.iter().map(|&v| Rat::int(v)).collect();
        let mut rows: Vec<(Vec<Rat>, Cmp, Rat)> = Vec::with_capacity(self.constraints.len() + n);
        for (coef, cmp, rhs) in &self.constraints {
            let shift: i64 = coef.iter().zip(lo).map(|(a, l)| a * l).sum();
            rows.push((
                coef.iter().map(|&v| Rat::int(v)).collect(),
                *cmp,
                Rat::int(rhs - shift),
            ));
        }
        for j in 0..n {
            if hi[j] < i64::MAX / 8 {
                let mut coef = vec![Rat::int(0); n];
                coef[j] = Rat::int(1);
                rows.push((coef, Cmp::Le, Rat::int(hi[j] - lo[j])));
            }
        }
        match solve_lp(&c, &rows) {
            LpResult::Optimal { objective, x } => {
                let obj_shift: i64 = self.objective.iter().zip(lo).map(|(a, l)| a * l).sum();
                let x_unshifted: Vec<Rat> =
                    x.iter().zip(lo).map(|(v, &l)| *v + Rat::int(l)).collect();
                Some((objective + Rat::int(obj_shift), x_unshifted))
            }
            LpResult::Infeasible => None,
            LpResult::Unbounded => {
                panic!("unbounded ILP node — all decomposition variables must be boxed")
            }
        }
    }

    /// Exhaustive solve for verification (exponential; tests only).
    pub fn solve_bruteforce(&self) -> Option<IlpSolution> {
        let n = self.nvars;
        for j in 0..n {
            assert!(
                self.upper[j] - self.lower[j] <= 64,
                "bruteforce only for tiny boxes"
            );
        }
        let mut idx = self.lower.clone();
        let mut best: Option<IlpSolution> = None;
        loop {
            let feasible = self.constraints.iter().all(|(coef, cmp, rhs)| {
                let lhs: i64 = coef.iter().zip(&idx).map(|(a, x)| a * x).sum();
                match cmp {
                    Cmp::Le => lhs <= *rhs,
                    Cmp::Ge => lhs >= *rhs,
                    Cmp::Eq => lhs == *rhs,
                }
            });
            if feasible {
                let obj: i64 = self.objective.iter().zip(&idx).map(|(c, x)| c * x).sum();
                if best.as_ref().map(|b| obj < b.objective).unwrap_or(true) {
                    best = Some(IlpSolution { values: idx.clone(), objective: obj });
                }
            }
            let mut k = 0;
            loop {
                if k == n {
                    return best;
                }
                idx[k] += 1;
                if idx[k] <= self.upper[k] {
                    break;
                }
                idx[k] = self.lower[k];
                k += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn knapsack_like() {
        // max 5a+4b (as min of negative) s.t. 6a+4b<=24, a+2b<=6, 0<=a,b<=10.
        let mut p = IlpProblem::new(2);
        p.minimize(&[-5, -4])
            .add_le(&[6, 4], 24)
            .add_le(&[1, 2], 6)
            .bound(0, 0, 10)
            .bound(1, 0, 10);
        let s = p.solve().unwrap();
        // LP optimum is fractional (a=3, b=1.5, z=21); integer optimum is
        // a=4, b=0 → 20.
        assert_eq!(s.objective, -20);
        assert_eq!(s.values, vec![4, 0]);
        assert_eq!(s.objective, p.solve_bruteforce().unwrap().objective);
    }

    #[test]
    fn forced_branching() {
        // LP relaxation fractional: max x1+x2 s.t. 2x1+2x2 <= 3, xi in {0,1}.
        let mut p = IlpProblem::new(2);
        p.minimize(&[-1, -1]).add_le(&[2, 2], 3).bound(0, 0, 1).bound(1, 0, 1);
        let s = p.solve().unwrap();
        assert_eq!(s.objective, -1);
    }

    #[test]
    fn infeasible_integer_only() {
        // 2x = 3 has LP solution x=1.5 but no integer one.
        let mut p = IlpProblem::new(1);
        p.add_eq(&[2], 3).bound(0, 0, 5);
        assert!(p.solve().is_none());
    }

    #[test]
    fn equality_system() {
        // x + 4y = 19, minimize x+y with x in [0,15], y in [0,4].
        let mut p = IlpProblem::new(2);
        p.minimize(&[1, 1]).add_eq(&[1, 4], 19).bound(0, 0, 15).bound(1, 0, 4);
        let s = p.solve().unwrap();
        assert_eq!(s.values, vec![3, 4]);
        assert_eq!(s.objective, 7);
    }

    #[test]
    fn negative_lower_bounds() {
        // min x s.t. x >= -3 with box [-5, 5].
        let mut p = IlpProblem::new(1);
        p.minimize(&[1]).add_ge(&[1], -3).bound(0, -5, 5);
        let s = p.solve().unwrap();
        assert_eq!(s.values, vec![-3]);
    }

    #[test]
    fn prop_matches_bruteforce() {
        prop_check("ilp-vs-bruteforce", 80, |rng| {
            let n = 2 + rng.index(3); // 2..4 vars
            let mut p = IlpProblem::new(n);
            let obj: Vec<i64> = (0..n).map(|_| rng.range_i64(-5, 5)).collect();
            p.minimize(&obj);
            for j in 0..n {
                p.bound(j, 0, rng.range_i64(1, 4));
            }
            for _ in 0..(1 + rng.index(3)) {
                let coef: Vec<i64> = (0..n).map(|_| rng.range_i64(-4, 4)).collect();
                let rhs = rng.range_i64(-6, 12);
                match rng.index(3) {
                    0 => p.add_le(&coef, rhs),
                    1 => p.add_ge(&coef, rhs),
                    _ => p.add_eq(&coef, rhs),
                };
            }
            let bb = p.solve();
            let bf = p.solve_bruteforce();
            match (bb, bf) {
                (None, None) => Ok(()),
                (Some(a), Some(b)) => {
                    if a.objective != b.objective {
                        return Err(format!(
                            "objective mismatch: bb={} bf={} (p={p:?})",
                            a.objective, b.objective
                        ));
                    }
                    Ok(())
                }
                (a, b) => Err(format!("feasibility mismatch bb={a:?} bf={b:?} (p={p:?})")),
            }
        });
    }

    #[test]
    fn stats_populated() {
        let mut p = IlpProblem::new(2);
        p.minimize(&[-1, -1]).add_le(&[2, 2], 3).bound(0, 0, 1).bound(1, 0, 1);
        let mut st = IlpStats::default();
        let _ = p.solve_with_stats(&mut st);
        assert!(st.nodes >= 1 && st.lp_solves >= 1);
    }
}
