//! Two-phase primal simplex over exact rationals.
//!
//! Dense tableau, Bland's anti-cycling rule. Sized for the decomposition
//! ILPs (≤ ~40 structural variables, ≤ ~90 rows once bounds are folded
//! in); exactness matters more than asymptotics here — a wrong pivot
//! tolerance would silently corrupt weight decompositions.
//!
//! Standard form solved: minimize `c·x` subject to `A x {≤,≥,=} b`,
//! `x ≥ 0`. Upper bounds are expected to be encoded as explicit `≤`
//! constraints by the caller ([`crate::ilp::IlpProblem`] does this).

use super::rational::{Rat, ONE, ZERO};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

#[derive(Clone, Debug)]
pub enum LpResult {
    Optimal { objective: Rat, x: Vec<Rat> },
    Infeasible,
    Unbounded,
}

/// Solve min c·x s.t. rows, x ≥ 0.
pub fn solve_lp(c: &[Rat], rows: &[(Vec<Rat>, Cmp, Rat)]) -> LpResult {
    let n = c.len();
    let m = rows.len();

    // Normalize rows to b ≥ 0 by flipping sign/comparison.
    let mut a: Vec<Vec<Rat>> = Vec::with_capacity(m);
    let mut b: Vec<Rat> = Vec::with_capacity(m);
    let mut cmp: Vec<Cmp> = Vec::with_capacity(m);
    for (coef, cm, rhs) in rows {
        assert_eq!(coef.len(), n, "constraint arity mismatch");
        if rhs.is_neg() {
            a.push(coef.iter().map(|&v| -v).collect());
            b.push(-*rhs);
            cmp.push(match cm {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            });
        } else {
            a.push(coef.clone());
            b.push(*rhs);
            cmp.push(*cm);
        }
    }

    // Column layout: [x (n)] [slack/surplus (m_slack)] [artificial (m_art)] [rhs].
    let mut n_slack = 0usize;
    let mut n_art = 0usize;
    for cm in &cmp {
        match cm {
            Cmp::Le => n_slack += 1,
            Cmp::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Cmp::Eq => n_art += 1,
        }
    }
    let total = n + n_slack + n_art;
    let rhs_col = total;

    let mut t: Vec<Vec<Rat>> = vec![vec![ZERO; total + 1]; m];
    let mut basis: Vec<usize> = vec![usize::MAX; m];
    let mut art_cols: Vec<usize> = Vec::with_capacity(n_art);

    let mut slack_at = n;
    let mut art_at = n + n_slack;
    for i in 0..m {
        for j in 0..n {
            t[i][j] = a[i][j];
        }
        t[i][rhs_col] = b[i];
        match cmp[i] {
            Cmp::Le => {
                t[i][slack_at] = ONE;
                basis[i] = slack_at;
                slack_at += 1;
            }
            Cmp::Ge => {
                t[i][slack_at] = -ONE; // surplus
                slack_at += 1;
                t[i][art_at] = ONE;
                basis[i] = art_at;
                art_cols.push(art_at);
                art_at += 1;
            }
            Cmp::Eq => {
                t[i][art_at] = ONE;
                basis[i] = art_at;
                art_cols.push(art_at);
                art_at += 1;
            }
        }
    }

    // ---- Phase 1: minimize sum of artificials --------------------------
    if n_art > 0 {
        let mut obj1 = vec![ZERO; total + 1];
        for &ac in &art_cols {
            obj1[ac] = ONE;
        }
        // Price out basic artificials.
        for i in 0..m {
            if art_cols.contains(&basis[i]) {
                for j in 0..=total {
                    obj1[j] = obj1[j] - t[i][j];
                }
            }
        }
        if !pivot_to_optimality(&mut t, &mut obj1, &mut basis, total) {
            // Phase 1 objective is bounded below by 0; unbounded impossible.
            unreachable!("phase-1 cannot be unbounded");
        }
        // Feasible iff artificial sum is 0 (objective row rhs holds -obj).
        if !obj1[rhs_col].is_zero() {
            return LpResult::Infeasible;
        }
        // Drive any basic artificial out of the basis (degenerate rows).
        for i in 0..m {
            if art_cols.contains(&basis[i]) {
                // Find a non-artificial column with nonzero entry to pivot in.
                let piv = (0..n + n_slack).find(|&j| !t[i][j].is_zero());
                match piv {
                    Some(j) => {
                        pivot(&mut t, &mut basis, i, j, total);
                    }
                    None => {
                        // Redundant row: force basis entry to a harmless
                        // marker (row is all-zero among structurals).
                        basis[i] = usize::MAX - 1;
                    }
                }
            }
        }
    }

    // ---- Phase 2: minimize c over structural + slack columns -----------
    let mut obj = vec![ZERO; total + 1];
    for j in 0..n {
        obj[j] = c[j];
    }
    // Artificial columns must never re-enter: mark with +inf-ish cost by
    // zeroing them from the tableau instead.
    for i in 0..m {
        for &ac in &art_cols {
            t[i][ac] = ZERO;
        }
    }
    // Price out basic variables.
    for i in 0..m {
        let bi = basis[i];
        if bi < total && !obj[bi].is_zero() {
            let coef = obj[bi];
            for j in 0..=total {
                obj[j] = obj[j] - coef * t[i][j];
            }
        }
    }
    if !pivot_to_optimality(&mut t, &mut obj, &mut basis, total) {
        return LpResult::Unbounded;
    }

    let mut x = vec![ZERO; n];
    for i in 0..m {
        if basis[i] < n {
            x[basis[i]] = t[i][rhs_col];
        }
    }
    // Objective row rhs holds -z.
    LpResult::Optimal { objective: -obj[rhs_col], x }
}

/// Bland-rule simplex iterations until optimal (true) or unbounded (false).
fn pivot_to_optimality(
    t: &mut [Vec<Rat>],
    obj: &mut [Rat],
    basis: &mut [usize],
    total: usize,
) -> bool {
    let m = t.len();
    let rhs_col = total;
    loop {
        // Entering: smallest-index column with negative reduced cost.
        let Some(enter) = (0..total).find(|&j| obj[j].is_neg()) else {
            return true;
        };
        // Leaving: min ratio b_i / a_ie over a_ie > 0, tie → smallest basis index.
        let mut leave: Option<usize> = None;
        let mut best: Option<Rat> = None;
        for i in 0..m {
            if t[i][enter].is_pos() {
                let ratio = t[i][rhs_col] / t[i][enter];
                let better = match &best {
                    None => true,
                    Some(b) => {
                        ratio < *b || (ratio == *b && basis[i] < basis[leave.unwrap()])
                    }
                };
                if better {
                    best = Some(ratio);
                    leave = Some(i);
                }
            }
        }
        let Some(li) = leave else {
            return false; // unbounded
        };
        pivot_with_obj(t, obj, basis, li, enter, total);
    }
}

fn pivot(t: &mut [Vec<Rat>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let piv = t[row][col];
    debug_assert!(!piv.is_zero());
    let inv = piv.recip();
    for j in 0..=total {
        t[row][j] = t[row][j] * inv;
    }
    for i in 0..t.len() {
        if i != row && !t[i][col].is_zero() {
            let f = t[i][col];
            for j in 0..=total {
                let delta = f * t[row][j];
                t[i][j] = t[i][j] - delta;
            }
        }
    }
    basis[row] = col;
}

fn pivot_with_obj(
    t: &mut [Vec<Rat>],
    obj: &mut [Rat],
    basis: &mut [usize],
    row: usize,
    col: usize,
    total: usize,
) {
    pivot(t, basis, row, col, total);
    if !obj[col].is_zero() {
        let f = obj[col];
        for j in 0..=total {
            let delta = f * t[row][j];
            obj[j] = obj[j] - delta;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(v: i64) -> Rat {
        Rat::int(v)
    }

    #[test]
    fn simple_le_maximization_as_min() {
        // max x+y s.t. x+2y<=4, 3x+y<=6  → min -(x+y); optimum at (8/5, 6/5), z=14/5.
        let c = vec![r(-1), r(-1)];
        let rows = vec![
            (vec![r(1), r(2)], Cmp::Le, r(4)),
            (vec![r(3), r(1)], Cmp::Le, r(6)),
        ];
        match solve_lp(&c, &rows) {
            LpResult::Optimal { objective, x } => {
                assert_eq!(objective, Rat::new(-14, 5));
                assert_eq!(x, vec![Rat::new(8, 5), Rat::new(6, 5)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn equality_and_ge() {
        // min x+y s.t. x+y=3, x>=1 → z=3.
        let c = vec![r(1), r(1)];
        let rows = vec![
            (vec![r(1), r(1)], Cmp::Eq, r(3)),
            (vec![r(1), r(0)], Cmp::Ge, r(1)),
        ];
        match solve_lp(&c, &rows) {
            LpResult::Optimal { objective, .. } => assert_eq!(objective, r(3)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1 and x >= 2.
        let c = vec![r(0)];
        let rows = vec![
            (vec![r(1)], Cmp::Le, r(1)),
            (vec![r(1)], Cmp::Ge, r(2)),
        ];
        assert!(matches!(solve_lp(&c, &rows), LpResult::Infeasible));
    }

    #[test]
    fn unbounded_detected() {
        // min -x s.t. x >= 0 (no upper bound).
        let c = vec![r(-1)];
        let rows = vec![(vec![r(1)], Cmp::Ge, r(0))];
        assert!(matches!(solve_lp(&c, &rows), LpResult::Unbounded));
    }

    #[test]
    fn negative_rhs_normalization() {
        // min x s.t. -x <= -2  (i.e. x >= 2).
        let c = vec![r(1)];
        let rows = vec![(vec![r(-1)], Cmp::Le, r(-2))];
        match solve_lp(&c, &rows) {
            LpResult::Optimal { objective, x } => {
                assert_eq!(objective, r(2));
                assert_eq!(x[0], r(2));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn degenerate_redundant_rows() {
        // Duplicate equality constraints → redundant artificial row.
        let c = vec![r(1), r(2)];
        let rows = vec![
            (vec![r(1), r(1)], Cmp::Eq, r(2)),
            (vec![r(2), r(2)], Cmp::Eq, r(4)),
            (vec![r(1), r(0)], Cmp::Le, r(2)),
        ];
        match solve_lp(&c, &rows) {
            LpResult::Optimal { objective, .. } => assert_eq!(objective, r(2)), // x=2,y=0
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn matches_bruteforce_on_random_bounded_lps() {
        use crate::util::prop::prop_check;
        // Random ILP-like LPs with box bounds encoded as rows; compare the
        // LP optimum against a fine brute-force grid lower bound sanity:
        // LP optimum must be ≤ best integer point (for minimization) and
        // all constraints must hold at the returned x.
        prop_check("lp-vs-grid", 120, |rng| {
            let n = 2 + rng.index(2); // 2..3 vars
            let mut rows: Vec<(Vec<Rat>, Cmp, Rat)> = Vec::new();
            // Box: x_i <= ub_i.
            let ubs: Vec<i64> = (0..n).map(|_| rng.range_i64(1, 5)).collect();
            for (i, &u) in ubs.iter().enumerate() {
                let mut coef = vec![ZERO; n];
                coef[i] = ONE;
                rows.push((coef, Cmp::Le, Rat::int(u)));
            }
            for _ in 0..2 {
                let coef: Vec<Rat> = (0..n).map(|_| Rat::int(rng.range_i64(-3, 3))).collect();
                let rhs = Rat::int(rng.range_i64(0, 10));
                rows.push((coef, Cmp::Le, rhs));
            }
            let c: Vec<Rat> = (0..n).map(|_| Rat::int(rng.range_i64(-4, 4))).collect();
            let res = solve_lp(&c, &rows);
            let LpResult::Optimal { objective, x } = res else {
                return Err("bounded feasible LP not optimal".into());
            };
            // Feasibility of returned x.
            for (coef, cm, rhs) in &rows {
                let lhs = coef
                    .iter()
                    .zip(&x)
                    .fold(ZERO, |acc, (a, xi)| acc + *a * *xi);
                let ok = match cm {
                    Cmp::Le => lhs <= *rhs,
                    Cmp::Ge => lhs >= *rhs,
                    Cmp::Eq => lhs == *rhs,
                };
                if !ok {
                    return Err(format!("infeasible solution returned: {lhs:?} vs {rhs:?}"));
                }
            }
            // LP optimum lower-bounds every feasible integer point.
            let mut idx = vec![0i64; n];
            loop {
                let feasible = rows.iter().all(|(coef, cm, rhs)| {
                    let lhs = coef
                        .iter()
                        .zip(&idx)
                        .fold(ZERO, |acc, (a, &xi)| acc + *a * Rat::int(xi));
                    match cm {
                        Cmp::Le => lhs <= *rhs,
                        Cmp::Ge => lhs >= *rhs,
                        Cmp::Eq => lhs == *rhs,
                    }
                });
                if feasible {
                    let z = c
                        .iter()
                        .zip(&idx)
                        .fold(ZERO, |acc, (a, &xi)| acc + *a * Rat::int(xi));
                    if z < objective {
                        return Err(format!(
                            "integer point {idx:?} beats LP optimum {objective:?}"
                        ));
                    }
                }
                // Advance odometer.
                let mut k = 0;
                loop {
                    if k == n {
                        return Ok(());
                    }
                    idx[k] += 1;
                    if idx[k] <= ubs[k] {
                        break;
                    }
                    idx[k] = 0;
                    k += 1;
                }
            }
        });
    }
}
