//! The fabric client: submit compile jobs to an `rchg serve` daemon.
//!
//! One [`CompileClient`] wraps one connection; requests are sequential
//! (submit → stream results → done). The server streams one
//! [`TensorResult`] frame per tensor, so a client can hand decompositions
//! downstream while later tensors are still in flight, then closes the
//! job with a [`FabricSummary`]. A warm chip session can also be pulled
//! down as verbatim RCSS bytes ([`CompileClient::fetch_session`]) — the
//! same bytes `CompileSession::save` would write on the server, loadable
//! anywhere with `CompileSession::from_bytes`.

use super::protocol::{
    decode_error, decode_info, decode_stats, decode_summary, decode_tensor_result,
    encode_chip_seed, encode_compile_request, read_frame, write_frame, FabricInfo, FabricSummary,
    FrameType, TensorResult,
};
use crate::coordinator::Method;
use crate::obs::MetricsSnapshot;
use crate::grouping::GroupConfig;
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpStream;

/// A connection to an `rchg serve` coordinator.
pub struct CompileClient {
    stream: TcpStream,
}

impl CompileClient {
    pub fn connect(addr: &str) -> Result<CompileClient> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect to fabric {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(CompileClient { stream })
    }

    /// Compile one chip's named tensor set on the fabric. Results come
    /// back in submit order; whether the job ran locally or fanned out
    /// across workers is reported in the summary and never changes a
    /// result byte.
    pub fn compile_model(
        &mut self,
        chip_seed: u64,
        cfg: GroupConfig,
        method: Method,
        tensors: &[(String, Vec<i64>)],
    ) -> Result<(Vec<TensorResult>, FabricSummary)> {
        let payload = encode_compile_request(chip_seed, cfg, method, tensors);
        write_frame(&mut self.stream, FrameType::CompileRequest, &payload)?;
        let mut results = Vec::with_capacity(tensors.len());
        loop {
            let frame = self.expect_frame("compile results")?;
            match frame.frame_type {
                FrameType::CompileResult => results.push(decode_tensor_result(&frame.payload)?),
                FrameType::CompileDone => {
                    return Ok((results, decode_summary(&frame.payload)?))
                }
                FrameType::Error => bail!("fabric: {}", decode_error(&frame.payload)),
                t => bail!("unexpected {t:?} frame in a compile stream"),
            }
        }
    }

    /// Fetch a chip's warm session cache as verbatim RCSS bytes.
    pub fn fetch_session(&mut self, chip_seed: u64) -> Result<Vec<u8>> {
        write_frame(&mut self.stream, FrameType::FetchSession, &encode_chip_seed(chip_seed))?;
        let frame = self.expect_frame("session bytes")?;
        match frame.frame_type {
            FrameType::SessionBytes => Ok(frame.payload),
            FrameType::Error => bail!("fabric: {}", decode_error(&frame.payload)),
            t => bail!("unexpected {t:?} frame for a session fetch"),
        }
    }

    /// Current fabric status (idle workers, warm sessions, job counters).
    pub fn info(&mut self) -> Result<FabricInfo> {
        write_frame(&mut self.stream, FrameType::Info, &[])?;
        let frame = self.expect_frame("fabric info")?;
        match frame.frame_type {
            FrameType::InfoReply => decode_info(&frame.payload),
            FrameType::Error => bail!("fabric: {}", decode_error(&frame.payload)),
            t => bail!("unexpected {t:?} frame for an info request"),
        }
    }

    /// Scrape the coordinator's live metrics registry (queue depth,
    /// per-shard latency histogram, store hit counters, job totals) as a
    /// name-sorted snapshot — the wire behind `rchg submit --stats` and
    /// `rchg top`.
    pub fn stats(&mut self) -> Result<MetricsSnapshot> {
        write_frame(&mut self.stream, FrameType::StatsPull, &[])?;
        let frame = self.expect_frame("fabric stats")?;
        match frame.frame_type {
            FrameType::StatsPush => decode_stats(&frame.payload),
            FrameType::Error => bail!("fabric: {}", decode_error(&frame.payload)),
            t => bail!("unexpected {t:?} frame for a stats request"),
        }
    }

    /// Ask the coordinator to stop (it finishes in-flight jobs on their
    /// own connections, closes pooled workers, and exits its accept
    /// loop). Consumes the client.
    pub fn shutdown_server(mut self) -> Result<()> {
        write_frame(&mut self.stream, FrameType::Shutdown, &[])
    }

    fn expect_frame(&mut self, what: &str) -> Result<super::protocol::Frame> {
        read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("fabric closed the connection awaiting {what}"))
    }
}
