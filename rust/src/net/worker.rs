//! The fabric worker: `rchg worker`.
//!
//! A worker is a host that lends its cores to the coordinator: it
//! connects, registers ([`FrameType::Hello`] → ack), then loops solving
//! shard jobs — each job is one [`ShardPlan`] range of one chip's
//! pattern space, returned as verbatim RCSF fragment bytes. Jobs arrive
//! in two flavors:
//!
//! - [`FrameType::ShardJob`] carries the full tensor set; the worker
//!   re-scans it into a registry and solves its range with
//!   [`CompileSession::solve_shard`].
//! - [`FrameType::ShardSnapshotJob`] carries a sealed "RCRG" registry
//!   snapshot instead — the coordinator already scanned, so the worker
//!   reconstructs the registry directly and solves its range with
//!   [`CompileSession::solve_shard_from_snapshot`], never touching the
//!   tensors. Both flavors produce byte-identical fragments.
//!
//! The worker holds no *chip-scoped* state between jobs: every job
//! carries its full identity (chip + config + pipeline, in the RCSS
//! cache-key layout), so any worker can solve any range of any chip,
//! and losing a worker loses nothing but time.
//!
//! What a worker *does* keep across jobs is a process-lifetime
//! fleet-store replica (see [`crate::store`]): before solving it asks
//! the coordinator which of the job's fault patterns the fleet already
//! solved ([`FrameType::StoreGet`]), installs the answer, and after
//! solving it publishes its fresh full-range tables back
//! ([`FrameType::StorePut`]) — so a pattern any chip in the fleet has
//! hit is solved exactly once, no matter which worker drew it. Store
//! traffic only moves where solve time is spent: the fragment bytes a
//! store-assisted worker returns are byte-identical to a store-less
//! solve (the store's determinism contract).
//!
//! A job that fails to solve (malformed spec, unsupported config)
//! answers with an [`FrameType::Error`] frame; the coordinator requeues
//! the range elsewhere and drops this worker. A clean EOF from the
//! coordinator — shutdown, or the coordinator dropping a lost worker —
//! ends the loop normally.

use super::protocol::{
    decode_error, decode_shard_job, decode_shard_snapshot_job, decode_store_put, encode_hello,
    encode_store_get, encode_store_put, read_frame, write_frame, FrameType,
};
use crate::coordinator::persist::{decode_registry_snapshot, CacheKey};
use crate::coordinator::{CompileSession, Outcome, PatternSolution, ShardFragment, ShardPlan};
use crate::fault::GroupFaults;
use crate::obs::{self, MetricsSnapshot};
use crate::store::{StoreCtx, StoreHandle};
use crate::util::failpoint;
use crate::util::fnv::FnvMap;
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpStream;

/// What a worker accomplished before its coordinator hung up.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// Shard jobs solved and returned.
    pub jobs: u64,
    /// Pattern classes solved across all jobs.
    pub patterns_solved: u64,
    /// Pattern tables answered by the fleet store instead of a local
    /// solve (via the coordinator or this worker's own replica).
    pub store_hits: u64,
    /// Fresh pattern tables published back to the coordinator.
    pub store_published: u64,
    /// The worker process's full [`obs`] registry, snapshotted when the
    /// loop ends — `worker.*` counters plus whatever the solve sessions
    /// recorded — so `rchg worker` prints one unified exposition instead
    /// of growing ad-hoc summary fields.
    pub metrics: MetricsSnapshot,
}

/// Connect to a coordinator at `addr` and solve shard jobs until it
/// hangs up (or sends [`FrameType::Shutdown`]). `threads` is this
/// worker's solve fan-out (thread count never changes solved bytes).
pub fn run_worker(addr: &str, threads: usize) -> Result<WorkerReport> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect to coordinator {addr}"))?;
    stream.set_nodelay(true).ok();
    write_frame(&mut stream, FrameType::Hello, &encode_hello(threads))?;
    let ack = read_frame(&mut stream)?
        .ok_or_else(|| anyhow!("coordinator closed during the handshake"))?;
    match ack.frame_type {
        FrameType::HelloAck => {}
        FrameType::Error => bail!("coordinator rejected worker: {}", decode_error(&ack.payload)),
        t => bail!("unexpected {t:?} frame during the handshake"),
    }
    // The worker's process-lifetime fleet-store replica: memory-only
    // (the coordinator owns the durable file tier), shared across every
    // job this connection serves.
    let store = StoreHandle::in_memory();
    let mut report = WorkerReport::default();
    loop {
        let frame = match read_frame(&mut stream)? {
            Some(f) => f,
            None => break, // coordinator hung up between jobs: done
        };
        match frame.frame_type {
            FrameType::ShardJob | FrameType::ShardSnapshotJob => {
                // Chaos hook: a worker that dies the moment a job lands.
                // The error propagates out of `run_worker`, the stream
                // drops, and the coordinator requeues the range.
                failpoint::check("worker.crash_before_solve")?;
                let mut sp = obs::span("worker.job");
                sp.field_str(
                    "kind",
                    if frame.frame_type == FrameType::ShardJob { "tensors" } else { "snapshot" },
                );
                let outcome = if frame.frame_type == FrameType::ShardJob {
                    solve_job(&mut stream, &store, &frame.payload, threads)
                } else {
                    solve_snapshot_job(&mut stream, &store, &frame.payload, threads)
                };
                // Chaos hook: a worker that solves the range but dies
                // before reporting — the costliest requeue case (the work
                // is redone elsewhere; dedupe keeps the bytes identical).
                failpoint::check("worker.crash_after_solve")?;
                match outcome {
                    Ok(done) => {
                        write_frame(&mut stream, FrameType::ShardResult, &done.fragment_bytes)?;
                        report.jobs += 1;
                        report.patterns_solved += done.solved as u64;
                        report.store_hits += done.store_hits as u64;
                        report.store_published += done.published as u64;
                        sp.field_u64("solved_patterns", done.solved as u64);
                        sp.field_u64("store_hits", done.store_hits as u64);
                        let m = obs::metrics();
                        m.inc("worker.jobs", 1);
                        m.inc("worker.patterns_solved", done.solved as u64);
                    }
                    Err(e) => {
                        eprintln!("worker: shard job failed: {e:#}");
                        sp.field_str("error", &format!("{e:#}"));
                        obs::metrics().inc("worker.job_errors", 1);
                        write_frame(&mut stream, FrameType::Error, format!("{e:#}").as_bytes())?;
                    }
                }
            }
            FrameType::Shutdown => break,
            t => bail!("unexpected {t:?} frame from coordinator"),
        }
    }
    report.metrics = obs::metrics().snapshot();
    Ok(report)
}

/// One solved shard job, ready to return to the coordinator.
struct SolvedJob {
    fragment_bytes: Vec<u8>,
    solved: usize,
    store_hits: usize,
    published: usize,
}

/// Solve one wire-delivered shard job: rebuild the session the job's
/// cache key describes, submit the full tensor set (every shard scans
/// everything so all shards derive the identical registry), sync the
/// job's patterns with the coordinator's fleet store, solve only the
/// assigned range, publish what came out fresh, and serialize the
/// fragment.
fn solve_job(
    stream: &mut TcpStream,
    store: &StoreHandle,
    payload: &[u8],
    threads: usize,
) -> Result<SolvedJob> {
    let spec = decode_shard_job(payload)?;
    let key = CacheKey::new(&spec.chip, spec.cfg, spec.pipeline);
    let mut session = CompileSession::for_key(&key);
    session.set_threads(threads);
    session.set_store(store.clone());
    for (name, ws) in &spec.tensors {
        session.submit(name, ws.clone());
    }
    let sctx = StoreCtx::new(spec.cfg, spec.pipeline);
    let patterns = session.queued_patterns();
    sync_with_fleet(stream, store, &sctx, &patterns)?;
    // Everything the replica holds *before* the solve came from the
    // fleet; anything beyond it afterwards is this job's fresh work.
    let known = fleet_known(store, &sctx, &patterns);
    let hits_before = store.counters().hits;

    let plan = ShardPlan::new(spec.shards as usize);
    let fragment = session.solve_shard(&plan, spec.shard as usize)?;
    let store_hits = (store.counters().hits - hits_before) as usize;
    publish_fresh(stream, &sctx, &known, store_hits, fragment)
}

/// Solve one snapshot-delivered shard job: the coordinator already
/// scanned, so the payload carries a sealed "RCRG" registry snapshot
/// instead of tensors. The worker rebuilds the registry from the
/// snapshot and solves only the assigned range — per-job cost is
/// O(in-range patterns), not O(total weights). The store sync likewise
/// covers only the in-range patterns: nothing outside the range is
/// solved here, so syncing the rest would move bytes for nothing.
fn solve_snapshot_job(
    stream: &mut TcpStream,
    store: &StoreHandle,
    payload: &[u8],
    threads: usize,
) -> Result<SolvedJob> {
    let spec = decode_shard_snapshot_job(payload)?;
    let (key, patterns) = decode_registry_snapshot(&spec.snapshot)?;
    let mut session = CompileSession::for_key(&key);
    session.set_threads(threads);
    session.set_store(store.clone());

    let plan = ShardPlan::new(spec.shards as usize);
    if spec.shard as usize >= plan.shards() {
        bail!("shard {} out of range for a {}-way plan", spec.shard, plan.shards());
    }
    let range = plan.range(spec.shard as usize, patterns.len());
    let sctx = StoreCtx::new(key.cfg, key.pipeline);
    let in_range = &patterns[range];
    sync_with_fleet(stream, store, &sctx, in_range)?;
    let known = fleet_known(store, &sctx, in_range);
    let hits_before = store.counters().hits;

    let fragment = session.solve_shard_from_snapshot(&spec.snapshot, &plan, spec.shard as usize)?;
    let store_hits = (store.counters().hits - hits_before) as usize;
    publish_fresh(stream, &sctx, &known, store_hits, fragment)
}

/// Pre-solve store sync: ask the coordinator for whichever of
/// `patterns` this replica does not hold yet and install the reply. The
/// reply is consumed before any bail below it, so every error leaves
/// the stream at a frame boundary.
fn sync_with_fleet(
    stream: &mut TcpStream,
    store: &StoreHandle,
    sctx: &StoreCtx,
    patterns: &[GroupFaults],
) -> Result<()> {
    // Chaos hook: a worker whose fleet-store sync silently fails. Every
    // pattern then solves locally — slower, byte-identical (the store's
    // determinism contract is exactly what this exercises).
    if failpoint::fires("worker.drop_store_sync") {
        return Ok(());
    }
    let unknown: Vec<GroupFaults> =
        patterns.iter().filter(|p| !store.contains(sctx, p)).cloned().collect();
    if unknown.is_empty() {
        return Ok(());
    }
    write_frame(stream, FrameType::StoreGet, &encode_store_get(sctx, &unknown))?;
    let reply = read_frame(stream)?
        .ok_or_else(|| anyhow!("coordinator closed during the store sync"))?;
    match reply.frame_type {
        FrameType::StorePut => {
            let b = decode_store_put(&reply.payload).context("parse store sync reply")?;
            for (p, t) in &b.entries {
                store.publish_table(&b.ctx, p, t);
            }
            Ok(())
        }
        FrameType::Error => {
            bail!("coordinator store sync failed: {}", decode_error(&reply.payload))
        }
        t => bail!("unexpected {t:?} frame in the store sync"),
    }
}

/// Content hashes of the job patterns the replica holds after the sync
/// but before the solve — the boundary between fleet work and this
/// job's fresh work.
fn fleet_known(store: &StoreHandle, sctx: &StoreCtx, patterns: &[GroupFaults]) -> FnvMap<u64, ()> {
    patterns
        .iter()
        .filter(|p| store.contains(sctx, p))
        .map(|p| (sctx.content_hash(p), ()))
        .collect()
}

/// Publish the range's freshly solved full-range tables back to the
/// coordinator before returning the fragment (Pairs-tier partial
/// solutions stay out of the store by design), then pack the result.
fn publish_fresh(
    stream: &mut TcpStream,
    sctx: &StoreCtx,
    known: &FnvMap<u64, ()>,
    store_hits: usize,
    fragment: ShardFragment,
) -> Result<SolvedJob> {
    let fresh: Vec<(GroupFaults, Vec<Outcome>)> = fragment
        .parts()
        .filter_map(|(p, s)| match s {
            Some(PatternSolution::Table(t)) if !known.contains_key(&sctx.content_hash(p)) => {
                Some((p.clone(), t.clone()))
            }
            _ => None,
        })
        .collect();
    if !fresh.is_empty() {
        write_frame(stream, FrameType::StorePut, &encode_store_put(sctx, &fresh))?;
    }
    Ok(SolvedJob {
        fragment_bytes: fragment.to_bytes(),
        solved: fragment.solved_patterns(),
        store_hits,
        published: fresh.len(),
    })
}
