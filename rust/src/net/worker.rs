//! The fabric worker: `rchg worker`.
//!
//! A worker is a host that lends its cores to the coordinator: it
//! connects, registers ([`FrameType::Hello`] → ack), then loops solving
//! [`FrameType::ShardJob`]s — each job is one [`ShardPlan`] range of one
//! chip's pattern space, solved with [`CompileSession::solve_shard`] and
//! returned as verbatim RCSF fragment bytes. The worker holds no state
//! between jobs: every job carries its full identity (chip + config +
//! pipeline, in the RCSS cache-key layout) and tensor set, so any worker
//! can solve any range of any chip, and losing a worker loses nothing
//! but time.
//!
//! A job that fails to solve (malformed spec, unsupported config)
//! answers with an [`FrameType::Error`] frame; the coordinator requeues
//! the range elsewhere and drops this worker. A clean EOF from the
//! coordinator — shutdown, or the coordinator dropping a lost worker —
//! ends the loop normally.

use super::protocol::{
    decode_error, decode_shard_job, encode_hello, read_frame, write_frame, FrameType,
};
use crate::coordinator::persist::CacheKey;
use crate::coordinator::{CompileSession, ShardPlan};
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpStream;

/// What a worker accomplished before its coordinator hung up.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerReport {
    /// Shard jobs solved and returned.
    pub jobs: u64,
    /// Pattern classes solved across all jobs.
    pub patterns_solved: u64,
}

/// Connect to a coordinator at `addr` and solve shard jobs until it
/// hangs up (or sends [`FrameType::Shutdown`]). `threads` is this
/// worker's solve fan-out (thread count never changes solved bytes).
pub fn run_worker(addr: &str, threads: usize) -> Result<WorkerReport> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect to coordinator {addr}"))?;
    stream.set_nodelay(true).ok();
    write_frame(&mut stream, FrameType::Hello, &encode_hello(threads))?;
    let ack = read_frame(&mut stream)?
        .ok_or_else(|| anyhow!("coordinator closed during the handshake"))?;
    match ack.frame_type {
        FrameType::HelloAck => {}
        FrameType::Error => bail!("coordinator rejected worker: {}", decode_error(&ack.payload)),
        t => bail!("unexpected {t:?} frame during the handshake"),
    }
    let mut report = WorkerReport::default();
    loop {
        let frame = match read_frame(&mut stream)? {
            Some(f) => f,
            None => break, // coordinator hung up between jobs: done
        };
        match frame.frame_type {
            FrameType::ShardJob => match solve_job(&frame.payload, threads) {
                Ok((bytes, solved)) => {
                    write_frame(&mut stream, FrameType::ShardResult, &bytes)?;
                    report.jobs += 1;
                    report.patterns_solved += solved as u64;
                }
                Err(e) => {
                    eprintln!("worker: shard job failed: {e:#}");
                    write_frame(&mut stream, FrameType::Error, format!("{e:#}").as_bytes())?;
                }
            },
            FrameType::Shutdown => break,
            t => bail!("unexpected {t:?} frame from coordinator"),
        }
    }
    Ok(report)
}

/// Solve one wire-delivered shard job: rebuild the session the job's
/// cache key describes, submit the full tensor set (every shard scans
/// everything so all shards derive the identical registry), solve only
/// the assigned range, and serialize the fragment.
fn solve_job(payload: &[u8], threads: usize) -> Result<(Vec<u8>, usize)> {
    let spec = decode_shard_job(payload)?;
    let key = CacheKey::new(&spec.chip, spec.cfg, spec.pipeline);
    let mut session = CompileSession::for_key(&key);
    session.set_threads(threads);
    for (name, ws) in &spec.tensors {
        session.submit(name, ws.clone());
    }
    let plan = ShardPlan::new(spec.shards as usize);
    let fragment = session.solve_shard(&plan, spec.shard as usize)?;
    let solved = fragment.solved_patterns();
    Ok((fragment.to_bytes(), solved))
}
