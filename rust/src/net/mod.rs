//! Compile fabric: the networked coordinator/worker subsystem.
//!
//! Everything below `coordinator` stops at the process boundary — the
//! batch service is in-process, and shard fragments move as files. This
//! module puts the same machinery on the wire (std TCP, no new
//! dependencies) with three roles:
//!
//! * **coordinator** ([`FabricServer`], `rchg serve --listen <addr>`) —
//!   a daemon wrapping [`crate::coordinator::CompileService`]. Clients
//!   submit compile jobs and get per-tensor results streamed back; for a
//!   large cold job the built-in coordinator derives a
//!   [`crate::coordinator::ShardPlan`], schedules the pattern-id ranges
//!   onto connected workers, collects their fragments over the wire, and
//!   merges them into a warm session — byte-identical to a local
//!   unsharded compile.
//! * **worker** ([`run_worker`], `rchg worker --connect <addr>`) — a
//!   host that executes [`crate::coordinator::CompileSession::solve_shard`]
//!   jobs it is handed. Stateless between jobs; a lost worker only costs
//!   time (its range is reassigned to a live worker, or solved locally).
//! * **client** ([`CompileClient`], `rchg submit --connect <addr>`) —
//!   submits jobs, streams results, fetches warm RCSS session bytes,
//!   inspects fabric status, scrapes the coordinator's live metrics
//!   registry (`StatsPull` → `StatsPush`, see [`crate::obs`] and
//!   `rchg top`), and can stop the daemon.
//!
//! The wire protocol ("RCWP" v1, [`protocol`]) is length-prefixed framed
//! binary — magic, version, frame type, payload length, FNV-1a checksum
//! — with clean rejection of truncated, corrupted, and
//! version-mismatched frames. Payloads reuse the persistence codecs:
//! shard jobs open with the RCSS cache-key layout, shard results are
//! verbatim RCSF fragment bytes, and session fetches are verbatim RCSS
//! files. Byte layouts and deployment topologies are documented in
//! `docs/ARCHITECTURE.md`.

#[cfg(feature = "failpoints")]
pub mod chaos;
pub mod client;
pub mod protocol;
pub mod server;
pub mod worker;

pub use client::CompileClient;
pub use protocol::{FabricInfo, FabricSummary, Frame, FrameType, TensorResult};
pub use server::{FabricServer, FabricStats, ServeOptions};
pub use worker::{run_worker, WorkerReport};
