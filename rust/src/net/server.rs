//! The fabric coordinator: `rchg serve`.
//!
//! A [`FabricServer`] is a TCP daemon with two kinds of peers:
//!
//! * **clients** submit compile jobs ([`FrameType::CompileRequest`]) and
//!   get per-tensor results streamed back — the networked face of
//!   [`CompileService`];
//! * **workers** (`rchg worker`) register into a pool and are handed
//!   [`FrameType::ShardJob`]s when a job is big enough to fan out.
//!
//! For a large job the coordinator derives a deterministic
//! [`ShardPlan`] (K = min(idle workers, `max_shards`)), dispatches each
//! pattern-id range to a worker, and collects [`ShardFragment`]s back
//! over the wire. A worker that disconnects, times out, or returns a
//! malformed fragment costs nothing but time: its range is **requeued**
//! and picked up by the next live worker (or solved locally when none
//! remain), so a job always completes. The merged warm session — and
//! therefore every compiled bitmap and the RCSS bytes saved from it —
//! is **byte-identical** to a local unsharded compile; the fabric only
//! moves *where* solve time is spent, never a single output byte (the
//! shard-count invariance proven in `tests/sharding.rs` carries over
//! verbatim because the wire ships the same RCSF fragment bytes the
//! file-based flow uses).
//!
//! Small jobs, repeat jobs against a warm session, and jobs arriving
//! while no workers are connected run through the in-process
//! [`CompileService`] directly — the fabric degrades to `serve-batch`
//! behavior, never to failure.

use super::protocol::{
    decode_chip_seed, decode_compile_request, decode_error, decode_hello, decode_store_get,
    decode_store_put, encode_info, encode_shard_job, encode_shard_snapshot_job, encode_stats,
    encode_store_put, encode_summary, encode_tensor_result, read_frame, write_frame,
    CompileRequest, FabricInfo, FabricSummary, Frame, FrameType, TensorResult,
};
use crate::coordinator::persist::CacheKey;
use crate::coordinator::{
    CompileOptions, CompileService, CompileSession, ServiceOptions, ShardFragment, ShardPlan,
    SolveTier,
};
use crate::fault::bank::ChipFaults;
use crate::obs;
use crate::store::StoreHandle;
use crate::util::failpoint;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long a freshly accepted connection gets to send its opening frame.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// Fabric configuration: the in-process service the daemon wraps, plus
/// the coordinator's scheduling knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Compile options, fault rates, table budget, and cache dir shared
    /// with the in-process [`CompileService`].
    pub service: ServiceOptions,
    /// Fan a job out to workers only when its total weight count reaches
    /// this (smaller jobs compile locally faster than they schedule).
    pub shard_min_weights: usize,
    /// Cap on shard ranges per distributed job.
    pub max_shards: usize,
    /// How long a dispatched worker may stay silent before its range is
    /// reassigned to a live worker.
    pub worker_timeout: Duration,
    /// Ship table-tier shard jobs as sealed "RCRG" registry snapshots
    /// (the coordinator scans once; workers solve their range without the
    /// tensor set or a re-scan). `false` forces the tensor-shipping
    /// `ShardJob` path everywhere — the two produce byte-identical
    /// results (pinned by the fabric e2e suite); this is an escape hatch
    /// and an A/B lever, not a semantic switch.
    pub snapshot_dispatch: bool,
}

/// Cumulative fabric counters (returned by [`FabricServer::run`] and
/// served over [`FrameType::Info`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct FabricStats {
    pub workers_joined: u64,
    pub jobs: u64,
    pub distributed_jobs: u64,
    pub shards_dispatched: u64,
    pub reassignments: u64,
    /// Distributed rounds whose shards were dispatched as registry
    /// snapshots instead of tensor sets (see
    /// [`ServeOptions::snapshot_dispatch`]).
    pub snapshot_rounds: u64,
}

impl FabricStats {
    /// Mirror these lifetime counters into the global [`obs`] registry as
    /// `fabric.*` gauges. Called at scrape time (a `StatsPull`), never on
    /// the dispatch path — the stats struct stays the single writer and
    /// the registry is a read-time mirror.
    pub fn record_metrics(&self) {
        let m = obs::metrics();
        m.gauge("fabric.workers_joined", self.workers_joined as i64);
        m.gauge("fabric.jobs", self.jobs as i64);
        m.gauge("fabric.distributed_jobs", self.distributed_jobs as i64);
        m.gauge("fabric.shards_dispatched", self.shards_dispatched as i64);
        m.gauge("fabric.reassignments", self.reassignments as i64);
        m.gauge("fabric.snapshot_rounds", self.snapshot_rounds as i64);
    }
}

struct WorkerConn {
    id: u64,
    stream: TcpStream,
}

struct FabricState {
    sopts: ServeOptions,
    listen_addr: SocketAddr,
    service: Mutex<CompileService>,
    /// The service's fleet-global solution store (see [`crate::store`]),
    /// cloned out so worker `StoreGet`/`StorePut` frames are answered
    /// without taking the service lock.
    store: StoreHandle,
    /// Idle registered workers. A distributed job *claims* workers out of
    /// the pool and returns the survivors when done.
    workers: Mutex<Vec<WorkerConn>>,
    stats: Mutex<FabricStats>,
    next_worker: AtomicU64,
    /// Compile jobs currently being served; shutdown waits for this to
    /// drain so in-flight jobs finish on their own connections.
    active_jobs: AtomicU64,
    shutdown: AtomicBool,
}

/// RAII marker of one in-flight compile job (see
/// [`FabricState::active_jobs`]); decrements on every exit path.
struct JobGuard<'a>(&'a AtomicU64);

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Everything one distributed round's driver threads share.
struct ShardRound<'a> {
    plan: ShardPlan,
    shards: usize,
    key: CacheKey,
    req: &'a CompileRequest,
    sopts: &'a ServeOptions,
    /// Fleet store serving worker `StoreGet`/`StorePut` frames.
    store: &'a StoreHandle,
    /// Shard indices not yet solved (a lost worker's range is pushed
    /// back here — that is the reassignment mechanism).
    pending: Mutex<Vec<usize>>,
    frags: Vec<Mutex<Option<ShardFragment>>>,
    reassigned: AtomicU32,
    /// Sealed "RCRG" registry snapshot for this round, when the
    /// snapshot path is on: the coordinator scanned the tensor set once,
    /// and every dispatch ships these bytes instead of the tensors.
    snapshot: Option<Vec<u8>>,
}

/// The compile-fabric daemon. See the module docs; construct with
/// [`FabricServer::bind`], then block in [`FabricServer::run`].
pub struct FabricServer {
    listener: TcpListener,
    state: Arc<FabricState>,
}

impl FabricServer {
    /// Bind the coordinator to `addr` (e.g. `"127.0.0.1:7077"`; port 0
    /// picks an ephemeral port — see [`FabricServer::local_addr`]).
    pub fn bind(addr: &str, sopts: ServeOptions) -> Result<FabricServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind fabric listener {addr}"))?;
        let listen_addr = listener.local_addr().context("fabric listener address")?;
        let service = CompileService::new(sopts.service.clone());
        let store = service.store().clone();
        let state = Arc::new(FabricState {
            sopts,
            listen_addr,
            service: Mutex::new(service),
            store,
            workers: Mutex::new(Vec::new()),
            stats: Mutex::new(FabricStats::default()),
            next_worker: AtomicU64::new(0),
            active_jobs: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });
        Ok(FabricServer { listener, state })
    }

    /// The address the fabric actually listens on.
    pub fn local_addr(&self) -> SocketAddr {
        self.state.listen_addr
    }

    /// The fabric's fleet solution store. [`FabricServer::run`] consumes
    /// the server, so callers that want to report store counters after
    /// shutdown clone this handle first.
    pub fn store(&self) -> StoreHandle {
        self.state.store.clone()
    }

    /// Accept and serve connections until a [`FrameType::Shutdown`] frame
    /// arrives, then wait for in-flight compile jobs to finish on their
    /// own connections before returning. Each connection is handled on
    /// its own thread; worker connections are parked in the pool between
    /// dispatches. Returns the cumulative fabric counters.
    pub fn run(self) -> Result<FabricStats> {
        loop {
            let (stream, _peer) = match self.listener.accept() {
                Ok(pair) => pair,
                Err(e) => {
                    if self.state.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    return Err(e).context("accept fabric connection");
                }
            };
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let state = Arc::clone(&self.state);
            std::thread::spawn(move || {
                if let Err(e) = handle_connection(state, stream) {
                    eprintln!("fabric: connection error: {e:#}");
                }
            });
        }
        // Let in-flight jobs complete and stream their results (job
        // runtime is bounded: local solves terminate, and every worker
        // dispatch is bounded by the worker timeout).
        while self.state.active_jobs.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(25));
        }
        // Closing pooled worker connections lets `rchg worker` processes
        // observe a clean EOF and exit.
        self.state.workers.lock().expect("worker pool lock").clear();
        let stats = *self.state.stats.lock().expect("stats lock");
        Ok(stats)
    }
}

fn send_error(stream: &mut TcpStream, msg: &str) {
    let _ = write_frame(stream, FrameType::Error, msg.as_bytes());
}

fn handle_connection(state: Arc<FabricState>, mut stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("set handshake timeout")?;
    let first = match read_frame(&mut stream) {
        Ok(Some(f)) => f,
        Ok(None) => return Ok(()),
        Err(e) => {
            send_error(&mut stream, &format!("{e:#}"));
            return Err(e);
        }
    };
    if first.frame_type == FrameType::Hello {
        return register_worker(&state, stream, &first.payload);
    }
    // A client connection: serve request frames until it closes.
    stream.set_read_timeout(None).context("clear client timeout")?;
    let mut next: Option<Frame> = Some(first);
    loop {
        let frame = match next.take() {
            Some(f) => f,
            None => match read_frame(&mut stream) {
                Ok(Some(f)) => f,
                Ok(None) => return Ok(()),
                Err(e) => {
                    send_error(&mut stream, &format!("{e:#}"));
                    return Err(e);
                }
            },
        };
        match frame.frame_type {
            FrameType::CompileRequest => {
                if let Err(e) = handle_compile(&state, &mut stream, &frame.payload) {
                    send_error(&mut stream, &format!("{e:#}"));
                    return Err(e);
                }
            }
            FrameType::FetchSession => handle_fetch(&state, &mut stream, &frame.payload)?,
            FrameType::Info => handle_info(&state, &mut stream)?,
            FrameType::StatsPull => handle_stats(&state, &mut stream)?,
            FrameType::Shutdown => {
                state.shutdown.store(true, Ordering::SeqCst);
                // Wake the accept loop so it observes the flag.
                let _ = TcpStream::connect(state.listen_addr);
                return Ok(());
            }
            t => {
                send_error(&mut stream, &format!("unexpected {t:?} frame"));
                bail!("unexpected {t:?} frame from client");
            }
        }
    }
}

fn register_worker(state: &Arc<FabricState>, mut stream: TcpStream, payload: &[u8]) -> Result<()> {
    let threads = decode_hello(payload);
    write_frame(&mut stream, FrameType::HelloAck, &[])?;
    // Dispatch sets per-job timeouts; an idle pooled worker just waits.
    stream.set_read_timeout(None).context("clear worker timeout")?;
    let id = state.next_worker.fetch_add(1, Ordering::Relaxed) + 1;
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".into());
    eprintln!("fabric: worker {id} joined from {peer} ({threads} threads)");
    obs::event(
        "fabric.worker.joined",
        obs::SpanHandle::NONE,
        vec![("worker", Json::Num(id as f64)), ("threads", Json::Num(threads as f64))],
    );
    state.workers.lock().expect("worker pool lock").push(WorkerConn { id, stream });
    state.stats.lock().expect("stats lock").workers_joined += 1;
    Ok(())
}

/// Validate a compile request, pick the execution path (distributed vs
/// local), and stream the per-tensor results back. Request-level
/// validation failures answer with an [`FrameType::Error`] frame and
/// keep the connection alive; transport failures propagate.
fn handle_compile(state: &Arc<FabricState>, stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    state.active_jobs.fetch_add(1, Ordering::SeqCst);
    let _in_flight = JobGuard(&state.active_jobs);
    let req = match decode_compile_request(payload) {
        Ok(r) => r,
        Err(e) => {
            send_error(stream, &format!("bad compile request: {e:#}"));
            return Ok(());
        }
    };
    let opts = &state.sopts.service.opts;
    if req.cfg != opts.cfg || req.method != opts.pipeline.method {
        send_error(
            stream,
            &format!(
                "this fabric compiles {} {:?}; the job asked for {} {:?}",
                opts.cfg, opts.pipeline.method, req.cfg, req.method
            ),
        );
        return Ok(());
    }
    let maxv = req.cfg.max_per_array();
    for (name, ws) in &req.tensors {
        // Explicit two-sided compare: `abs()` would overflow on i64::MIN.
        if let Some(w) = ws.iter().find(|&&w| w > maxv || w < -maxv) {
            send_error(
                stream,
                &format!("tensor {name:?} weight {w} is outside ±{maxv} for {}", req.cfg),
            );
            return Ok(());
        }
    }
    let total_weights: usize = req.tensors.iter().map(|(_, ws)| ws.len()).sum();
    // Warm chips — retained in memory or persisted under the cache dir —
    // take the local path: a warm recompile is pure cache hits, cheaper
    // than re-solving the chip distributed.
    let has_warm_session = state
        .service
        .lock()
        .expect("service lock")
        .has_cached_session(req.chip_seed);
    let idle_workers = state.workers.lock().expect("worker pool lock").len();
    let distribute =
        total_weights >= state.sopts.shard_min_weights && idle_workers > 0 && !has_warm_session;
    let (results, summary) = if distribute {
        distributed_compile(state, &req)?
    } else {
        local_compile(state, &req)?
    };
    // Count the work before streaming: a client that disconnects
    // mid-stream must not erase the counters of solves that happened.
    {
        let mut stats = state.stats.lock().expect("stats lock");
        stats.jobs += 1;
        if summary.shards > 0 {
            stats.distributed_jobs += 1;
            stats.shards_dispatched += summary.shards as u64;
            stats.reassignments += summary.reassigned as u64;
        }
    }
    let cells = req.cfg.cells();
    for r in &results {
        write_frame(stream, FrameType::CompileResult, &encode_tensor_result(r, cells))?;
    }
    write_frame(stream, FrameType::CompileDone, &encode_summary(&summary))?;
    Ok(())
}

/// Compile through the in-process service (small jobs, warm sessions, or
/// a workerless fabric). The service lock is held across enqueue + run
/// so concurrent clients cannot interleave their batches.
fn local_compile(
    state: &Arc<FabricState>,
    req: &CompileRequest,
) -> Result<(Vec<TensorResult>, FabricSummary)> {
    let mut svc = state.service.lock().expect("service lock");
    for (name, ws) in &req.tensors {
        svc.enqueue(req.chip_seed, name, ws.clone());
    }
    let compiled = svc.run()?;
    for e in svc.persist_errors() {
        eprintln!("fabric: warning: session cache not persisted — {e}");
    }
    drop(svc);
    let mut weights = 0u64;
    let mut fresh = 0u64;
    let results: Vec<TensorResult> = compiled
        .into_iter()
        .map(|r| {
            weights += r.tensor.decomps.len() as u64;
            fresh += r.tensor.stats.unique_pairs as u64;
            TensorResult {
                name: r.name,
                errors: r.tensor.errors,
                decomps: r.tensor.decomps,
                fresh_solves: r.tensor.stats.unique_pairs as u64,
            }
        })
        .collect();
    let summary = FabricSummary {
        tensors: results.len() as u32,
        weights,
        fresh_solves: fresh,
        shards: 0,
        workers: 0,
        reassigned: 0,
    };
    Ok((results, summary))
}

fn session_for(chip: &ChipFaults, opts: &CompileOptions, store: &StoreHandle) -> CompileSession {
    CompileSession::builder(opts.cfg)
        .options(opts.clone())
        .store(store.clone())
        .chip(chip)
}

/// Fan one job's solve phase across the worker pool: claim every idle
/// worker, derive the plan, dispatch ranges with reassignment-on-loss,
/// solve any leftovers locally, merge, compile, and retain the warm
/// session in the service.
fn distributed_compile(
    state: &Arc<FabricState>,
    req: &CompileRequest,
) -> Result<(Vec<TensorResult>, FabricSummary)> {
    let mut dspan = obs::span("fabric.distribute");
    dspan.field_u64("chip_seed", req.chip_seed);
    dspan.field_u64("weights", req.tensors.iter().map(|(_, ws)| ws.len() as u64).sum());
    let sopts = &state.sopts;
    let chip = ChipFaults::new(req.chip_seed, sopts.service.rates);
    let mut claimed: Vec<WorkerConn> =
        std::mem::take(&mut *state.workers.lock().expect("worker pool lock"));
    if claimed.is_empty() {
        // Lost the worker-claim race to a concurrent job: this compile is
        // local after all (and reported as such).
        return local_compile(state, req);
    }
    let shards = claimed.len().clamp(1, sopts.max_shards.max(1));
    // Workers beyond the shard count have nothing to do this round.
    let extra = claimed.split_off(shards.min(claimed.len()));
    if !extra.is_empty() {
        state.workers.lock().expect("worker pool lock").extend(extra);
    }
    let dispatched_workers = claimed.len() as u32;
    let pipeline = sopts.service.opts.pipeline;
    // Snapshot dispatch: scan the tensor set once right here, then ship
    // every worker the sealed registry instead of the tensors. Gated to
    // the full-range table tier — per-weight fresh work needs the actual
    // weights on the worker, so those rounds keep the tensor path.
    let snapshot = if sopts.snapshot_dispatch
        && sopts.service.opts.effective_tier() == SolveTier::BatchTable
    {
        let mut scan = session_for(&chip, &sopts.service.opts, &state.store);
        for (name, ws) in &req.tensors {
            scan.submit(name, ws.clone());
        }
        match scan.scan_to_snapshot() {
            Ok(bytes) => Some(bytes),
            Err(e) => {
                eprintln!("fabric: snapshot scan failed ({e:#}); shipping tensors instead");
                None
            }
        }
    } else {
        None
    };
    if snapshot.is_some() {
        state.stats.lock().expect("stats lock").snapshot_rounds += 1;
    }
    let round = ShardRound {
        plan: ShardPlan::new(shards),
        shards,
        key: CacheKey::new(&chip, req.cfg, pipeline),
        req,
        sopts,
        store: &state.store,
        pending: Mutex::new((0..shards).rev().collect()),
        frags: (0..shards).map(|_| Mutex::new(None)).collect(),
        reassigned: AtomicU32::new(0),
        snapshot,
    };
    let survivors: Vec<WorkerConn> = std::thread::scope(|s| {
        let handles: Vec<_> = claimed
            .into_iter()
            .map(|w| {
                let round = &round;
                s.spawn(move || drive_worker(w, round))
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("shard driver panicked"))
            .collect()
    });
    state.workers.lock().expect("worker pool lock").extend(survivors);

    // Any range every worker failed on (or that was never assigned
    // because the pool drained) is solved locally — a fabric losing its
    // whole fleet mid-job still completes the job.
    for (k, slot) in round.frags.iter().enumerate() {
        if slot.lock().expect("fragment lock").is_some() {
            continue;
        }
        eprintln!("fabric: solving shard {}/{shards} locally (no live worker)", k + 1);
        let mut session = session_for(&chip, &sopts.service.opts, &state.store);
        for (name, ws) in &req.tensors {
            session.submit(name, ws.clone());
        }
        let frag = session.solve_shard(&round.plan, k)?;
        *slot.lock().expect("fragment lock") = Some(frag);
    }
    let fragments: Vec<ShardFragment> = round
        .frags
        .iter()
        .map(|m| {
            m.lock()
                .expect("fragment lock")
                .take()
                .expect("every shard range resolved above")
        })
        .collect();
    let shard_solves: u64 = fragments.iter().map(|f| f.solved_patterns() as u64).sum();

    // Merge into a session configured exactly like the service's own
    // (execution knobs included), compile the job from the warm cache,
    // and hand the session to the service for future (local) jobs.
    let mut session = session_for(&chip, &sopts.service.opts, &state.store);
    // Under a fleet-wide table budget the merged session joins the cap
    // right away with a conservative even share over the live set
    // (eviction only ever costs re-solves, never output bytes);
    // `install_session` re-derives the proportional split afterwards.
    if let Some(total) = sopts.service.table_budget.fleet_bytes() {
        let live = state.service.lock().expect("service lock").sessions().count() + 1;
        session.set_table_memory_bytes((total / live).max(1));
    }
    dspan.field_u64("shards", shards as u64);
    dspan.field_u64("shard_solves", shard_solves);
    {
        let mut msp = obs::child_span("fabric.merge", dspan.handle());
        msp.field_u64("fragments", fragments.len() as u64);
        session
            .merge_fragments(&fragments)
            .context("merge worker shard fragments")?;
    }
    for (name, ws) in &req.tensors {
        session.submit(name, ws.clone());
    }
    let compiled = session.drain();
    let mut weights = 0u64;
    let mut catch_up = 0u64;
    let results: Vec<TensorResult> = compiled
        .into_iter()
        .map(|(name, t)| {
            weights += t.decomps.len() as u64;
            catch_up += t.stats.unique_pairs as u64;
            TensorResult {
                name,
                errors: t.errors,
                decomps: t.decomps,
                fresh_solves: t.stats.unique_pairs as u64,
            }
        })
        .collect();
    {
        let mut svc = state.service.lock().expect("service lock");
        let before = svc.persist_errors().len();
        svc.install_session(req.chip_seed, session);
        for e in &svc.persist_errors()[before..] {
            eprintln!("fabric: warning: session cache not persisted — {e}");
        }
    }
    let summary = FabricSummary {
        tensors: results.len() as u32,
        weights,
        fresh_solves: shard_solves + catch_up,
        shards: shards as u32,
        workers: dispatched_workers,
        reassigned: round.reassigned.load(Ordering::Relaxed),
    };
    Ok((results, summary))
}

/// Feed one worker shard ranges until none are pending. Returns the
/// worker for re-pooling, or `None` when it was lost (its last range is
/// already requeued for a live worker — or the local fallback — to
/// pick up).
fn drive_worker(mut w: WorkerConn, round: &ShardRound<'_>) -> Option<WorkerConn> {
    loop {
        let shard = match round.pending.lock().expect("pending lock").pop() {
            Some(s) => s,
            None => return Some(w),
        };
        match dispatch_one(&mut w, round, shard) {
            Ok(frag) => {
                *round.frags[shard].lock().expect("fragment lock") = Some(frag);
                // Chaos hook: the requeue race — a solved range is pushed
                // back as if its result had been lost, so some worker (or
                // the local fallback) solves it a second time. The
                // duplicate fragment must be byte-identical and merging it
                // must be idempotent. Arm with `count=1` or the round
                // never drains.
                if failpoint::fires("server.requeue_race") {
                    eprintln!(
                        "fabric: failpoint server.requeue_race: requeueing solved shard {}/{}",
                        shard + 1,
                        round.shards
                    );
                    round.pending.lock().expect("pending lock").push(shard);
                    round.reassigned.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(e) => {
                eprintln!(
                    "fabric: worker {} lost on shard {}/{}: {e:#} — range requeued",
                    w.id,
                    shard + 1,
                    round.shards
                );
                round.pending.lock().expect("pending lock").push(shard);
                round.reassigned.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
    }
}

/// Send one shard job and await its fragment, bounded by the worker
/// timeout. Between the job and its result the worker may interleave
/// fleet-store traffic: one `StoreGet` (answered with a `StorePut` of
/// every pattern the store holds) and any number of `StorePut`
/// publishes of its freshly solved patterns — the trust model is the
/// same as for the fragment itself (workers only publish what they
/// locally solved). Any failure — transport error, timeout,
/// worker-reported error, or a fragment that does not match the
/// assignment — makes the caller requeue the range and drop the worker.
fn dispatch_one(w: &mut WorkerConn, round: &ShardRound<'_>, shard: usize) -> Result<ShardFragment> {
    let dispatched_at = Instant::now();
    let mut sp = obs::span("fabric.shard");
    sp.field_u64("worker", w.id);
    sp.field_u64("shard", shard as u64);
    sp.field_u64("shards", round.shards as u64);
    let timeout = Some(round.sopts.worker_timeout);
    w.stream.set_read_timeout(timeout).context("set worker read timeout")?;
    w.stream.set_write_timeout(timeout).context("set worker write timeout")?;
    if let Some(snap) = &round.snapshot {
        let payload = encode_shard_snapshot_job(shard as u32, round.shards as u32, snap);
        write_frame(&mut w.stream, FrameType::ShardSnapshotJob, &payload)?;
    } else {
        let payload = encode_shard_job(
            &round.key.chip,
            round.key.cfg,
            round.key.pipeline,
            shard as u32,
            round.shards as u32,
            &round.req.tensors,
        );
        write_frame(&mut w.stream, FrameType::ShardJob, &payload)?;
    }
    loop {
        let frame = read_frame(&mut w.stream)?
            .ok_or_else(|| anyhow!("worker disconnected before returning the shard"))?;
        match frame.frame_type {
            FrameType::StoreGet => {
                let q = decode_store_get(&frame.payload).context("parse worker store query")?;
                let mut entries = Vec::new();
                for p in q.patterns {
                    if let Some(t) = round.store.lookup_table(&q.ctx, &p) {
                        entries.push((p, t));
                    }
                }
                write_frame(
                    &mut w.stream,
                    FrameType::StorePut,
                    &encode_store_put(&q.ctx, &entries),
                )?;
            }
            FrameType::StorePut => {
                let b = decode_store_put(&frame.payload).context("parse worker store publish")?;
                for (p, t) in &b.entries {
                    round.store.publish_table(&b.ctx, p, t);
                }
            }
            FrameType::ShardResult => {
                let frag = ShardFragment::from_bytes(&frame.payload)
                    .context("parse worker shard fragment")?;
                if frag.shard() != shard || frag.shards() != round.shards {
                    bail!(
                        "worker returned shard {}/{} for assignment {}/{}",
                        frag.shard() + 1,
                        frag.shards(),
                        shard + 1,
                        round.shards
                    );
                }
                if let Some(why) = round.key.mismatch(frag.cache_key()) {
                    bail!("worker fragment does not belong to this job: {why}");
                }
                // Chaos hook: a coordinator that loses a fully valid
                // fragment after receiving it (result arrived past the
                // deadline, say). The caller requeues the range and drops
                // this worker — the late-fragment merge case.
                if failpoint::fires("server.drop_fragment") {
                    bail!("failpoint server.drop_fragment: discarding the valid fragment");
                }
                // Dispatch-to-fragment wall time, including the worker's
                // interleaved store traffic — the fleet's per-shard
                // latency distribution scraped by `rchg top`.
                let lat_us = dispatched_at.elapsed().as_micros() as u64;
                obs::metrics().observe("fabric.shard.latency_us", lat_us);
                sp.field_u64("solved_patterns", frag.solved_patterns() as u64);
                return Ok(frag);
            }
            FrameType::Error => bail!("worker reported: {}", decode_error(&frame.payload)),
            t => bail!("unexpected {t:?} frame from worker"),
        }
    }
}

fn handle_fetch(state: &Arc<FabricState>, stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let chip_seed = match decode_chip_seed(payload) {
        Ok(s) => s,
        Err(e) => {
            send_error(stream, &format!("bad session fetch: {e:#}"));
            return Ok(());
        }
    };
    let bytes = {
        let svc = state.service.lock().expect("service lock");
        match svc.session(chip_seed) {
            // Retained session: serialize its live warm state.
            Some(session) => session.to_bytes(),
            // In-memory miss: serve the cache-dir file verbatim, so a
            // restarted coordinator covers the same warm set the compile
            // router's `has_cached_session` check sees.
            None => svc
                .cached_session_bytes(chip_seed)
                .ok_or_else(|| anyhow!("no warm session for chip {chip_seed}")),
        }
    };
    match bytes {
        Ok(b) => write_frame(stream, FrameType::SessionBytes, &b),
        Err(e) => {
            send_error(stream, &format!("{e:#}"));
            Ok(())
        }
    }
}

/// Answer a [`FrameType::StatsPull`]: refresh the scrape-time gauges
/// (fabric counters, live pool/queue state, store counters), snapshot the
/// global registry, and ship it as one [`FrameType::StatsPush`]. The
/// compile-path counters and the shard latency histogram are already in
/// the registry — this only mirrors the lifetime structs that keep their
/// own single-writer state.
fn handle_stats(state: &Arc<FabricState>, stream: &mut TcpStream) -> Result<()> {
    state.stats.lock().expect("stats lock").record_metrics();
    let m = obs::metrics();
    m.gauge(
        "fabric.workers_idle",
        state.workers.lock().expect("worker pool lock").len() as i64,
    );
    m.gauge("fabric.queue_depth", state.active_jobs.load(Ordering::SeqCst) as i64);
    m.gauge(
        "fabric.sessions_warm",
        state.service.lock().expect("service lock").sessions().count() as i64,
    );
    state.store.counters().record_metrics();
    write_frame(stream, FrameType::StatsPush, &encode_stats(&m.snapshot()))
}

fn handle_info(state: &Arc<FabricState>, stream: &mut TcpStream) -> Result<()> {
    let info = {
        let stats = state.stats.lock().expect("stats lock");
        FabricInfo {
            workers: state.workers.lock().expect("worker pool lock").len() as u32,
            sessions: state.service.lock().expect("service lock").sessions().count() as u32,
            jobs: stats.jobs,
            distributed_jobs: stats.distributed_jobs,
            reassignments: stats.reassignments,
        }
    };
    write_frame(stream, FrameType::InfoReply, &encode_info(&info))
}
