//! Chaos harness for the compile fabric (requires the `failpoints`
//! feature).
//!
//! One **scenario** = one localhost fleet (coordinator + workers in this
//! process), one set of armed failpoints, one compile job. After the
//! faulted job the harness checks the repo's spine invariant:
//!
//! * the job **completed** — then its per-tensor outputs and its fetched
//!   RCSS session bytes must be byte-identical to a fault-free local
//!   compile of the same chip; or
//! * the job **failed with a typed error** — then the fabric must still
//!   be alive: a follow-up fault-free job on the same fleet must
//!   complete byte-identically.
//!
//! Anything else — a hang (caught by a watchdog), a panic, or
//! silently-wrong bytes — is an invariant violation and fails the run.
//!
//! Scenarios come in two kinds: **scripted** (one per failpoint, see
//! `tests/chaos.rs`) and **seeded random schedules** ([`random_scenario`]
//! arms 1–2 points drawn from [`MENU`] with [`Rng`]-derived parameters).
//! Both replay exactly from their seed/spec — report a failing seed and
//! anyone can reproduce the run with `rchg chaos --seed N`.

use super::{run_worker, CompileClient, FabricServer, ServeOptions, TensorResult};
use crate::coordinator::{
    CompileOptions, CompileSession, CompiledTensor, Method, ServiceOptions, TableBudget,
};
use crate::experiments::compile_time::synthetic_model_tensors;
use crate::fault::bank::ChipFaults;
use crate::fault::FaultRates;
use crate::grouping::GroupConfig;
use crate::util::failpoint;
use crate::util::prng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Grouping config every chaos fleet compiles (matches `tests/net_fabric.rs`).
pub const CFG: GroupConfig = GroupConfig::R2C2;

/// Per-scenario wall-clock bound. A scenario that has not produced an
/// outcome by then counts as a hang — itself an invariant violation.
pub const WATCHDOG: Duration = Duration::from_secs(180);

/// One chaos scenario: which failpoints are armed while one compile job
/// runs against a fresh localhost fleet.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Label for reports ("frame-corrupt-shard-result", "rand-7-2", …).
    pub name: String,
    /// `(failpoint, spec)` pairs armed for the faulted job.
    pub failpoints: Vec<(String, String)>,
    /// Worker processes (threads here) joining the fleet.
    pub workers: usize,
    /// Give the coordinator a file-tier solution store (required by the
    /// `store.*` points; they never fire on a memory-only store).
    pub store_dir: bool,
    /// Ship registry snapshots (`ShardSnapshotJob`) vs tensor sets
    /// (`ShardJob`) — chooses which frame tag the job path writes.
    pub snapshot_dispatch: bool,
    /// Coordinator's silent-worker deadline. Scripted stall scenarios
    /// lower this so a stalled frame converts into a timeout quickly.
    pub worker_timeout_ms: u64,
}

impl Scenario {
    pub fn new(name: &str, failpoints: &[(&str, &str)]) -> Scenario {
        Scenario {
            name: name.to_string(),
            failpoints: failpoints
                .iter()
                .map(|(n, s)| (n.to_string(), s.to_string()))
                .collect(),
            workers: 2,
            store_dir: false,
            snapshot_dispatch: true,
            worker_timeout_ms: 30_000,
        }
    }
}

/// How one scenario ended (both ends satisfy the invariant).
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    /// The faulted job completed byte-identically.
    pub completed: bool,
    /// The typed error the faulted job surfaced (when not completed).
    pub error: Option<String>,
}

/// Aggregate of one seeded schedule (see [`run_seed`]).
#[derive(Clone, Debug, Default)]
pub struct ChaosReport {
    pub seed: u64,
    pub scenarios: usize,
    /// Faulted jobs that completed byte-identically despite the faults.
    pub completed: usize,
    /// Faulted jobs that surfaced a typed error (fabric stayed alive).
    pub typed_errors: usize,
}

/// The deterministic tensor set every scenario compiles.
pub fn model(limit: usize) -> Vec<(String, Vec<i64>)> {
    synthetic_model_tensors("resnet20", &CFG, limit).expect("synthetic model")
}

/// Fault-free single-process reference: per-tensor outputs + the RCSS
/// bytes a local session saves after compiling the same tensor set.
pub fn local_reference(
    chip_seed: u64,
    tensors: &[(String, Vec<i64>)],
) -> (Vec<(String, CompiledTensor)>, Vec<u8>) {
    let chip = ChipFaults::new(chip_seed, FaultRates::paper_default());
    let mut session = CompileSession::builder(CFG).method(Method::Complete).chip(&chip);
    for (name, ws) in tensors {
        session.submit(name, ws.clone());
    }
    let out = session.drain();
    let bytes = session.to_bytes().expect("reference session serializes");
    (out, bytes)
}

/// Compare a fabric job's results against the local reference —
/// `Err` (not a panic) on any divergence, so the chaos driver can report
/// the scenario that broke byte-identity.
pub fn check_results(
    got: &[TensorResult],
    want: &[(String, CompiledTensor)],
) -> Result<()> {
    if got.len() != want.len() {
        bail!("tensor count diverged: fabric {} vs local {}", got.len(), want.len());
    }
    for (g, (name, w)) in got.iter().zip(want) {
        if &g.name != name {
            bail!("tensor order diverged: fabric {:?} vs local {:?}", g.name, name);
        }
        if g.errors != w.errors {
            bail!("residual errors of {name} diverged from the fault-free compile");
        }
        if g.decomps != w.decomps {
            bail!("bitmaps of {name} diverged from the fault-free compile");
        }
    }
    Ok(())
}

/// Fabric options every scenario serves under: always fan out
/// (`shard_min_weights = 1`), paper-default fault rates.
pub fn chaos_serve_opts(scenario: &Scenario, store_dir: Option<PathBuf>) -> ServeOptions {
    let mut opts = CompileOptions::new(CFG, Method::Complete);
    opts.threads = 2;
    ServeOptions {
        service: ServiceOptions {
            opts,
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: None,
            store_dir,
        },
        shard_min_weights: 1,
        max_shards: 8,
        worker_timeout: Duration::from_millis(scenario.worker_timeout_ms.max(1)),
        snapshot_dispatch: scenario.snapshot_dispatch,
    }
}

/// A unique scratch directory under the system temp dir (no timestamps —
/// a process-wide counter keeps replays deterministic).
pub fn scratch_dir(label: &str) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("rchg-chaos-{}-{label}-{n}", std::process::id()))
}

/// Poll the fabric until `n` workers sit idle in the pool (bounded).
pub fn wait_for_workers(addr: SocketAddr, n: usize) -> Result<()> {
    let mut client = CompileClient::connect(&addr.to_string())?;
    for _ in 0..600 {
        if client.info()?.workers as usize >= n {
            return Ok(());
        }
        thread::sleep(Duration::from_millis(10));
    }
    bail!("{n} workers never registered with the fabric at {addr}")
}

/// Run one scenario under the watchdog. `Ok` means the invariant held
/// (either way the job ended); `Err` carries the violation — including
/// "scenario hung" and "scenario panicked", which the in-scenario code
/// can never report about itself.
pub fn run_scenario(
    scenario: &Scenario,
    chip_seed: u64,
    weight_limit: usize,
) -> Result<ScenarioOutcome> {
    let (tx, rx) = mpsc::channel();
    let s = scenario.clone();
    let body = thread::spawn(move || {
        let out = run_scenario_inner(&s, chip_seed, weight_limit);
        let _ = tx.send(out);
    });
    match rx.recv_timeout(WATCHDOG) {
        Ok(outcome) => {
            body.join().map_err(|_| anyhow!("scenario {} panicked", scenario.name))?;
            outcome.with_context(|| format!("scenario {}", scenario.name))
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            // The body died without sending — a panic mid-scenario.
            failpoint::clear();
            let _ = body.join();
            bail!("scenario {} panicked before reporting an outcome", scenario.name)
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            // Leak the wedged fleet (it holds an ephemeral port and some
            // threads); disarm everything so the next scenario is clean.
            failpoint::clear();
            bail!("scenario {} hung past {:?} — the no-hang invariant is broken", scenario.name, WATCHDOG)
        }
    }
}

fn run_scenario_inner(
    scenario: &Scenario,
    chip_seed: u64,
    weight_limit: usize,
) -> Result<ScenarioOutcome> {
    failpoint::clear();
    let tensors = model(weight_limit);
    // The fault-free truth, computed before anything is armed.
    let (want, want_bytes) = local_reference(chip_seed, &tensors);

    let store_dir = scenario.store_dir.then(|| scratch_dir("store"));
    if let Some(d) = &store_dir {
        let _ = std::fs::remove_dir_all(d);
    }
    let server = FabricServer::bind("127.0.0.1:0", chaos_serve_opts(scenario, store_dir.clone()))
        .context("bind chaos fabric")?;
    let addr = server.local_addr();
    let server = thread::spawn(move || server.run());
    let addr_s = addr.to_string();
    let workers: Vec<_> = (0..scenario.workers)
        .map(|_| {
            let a = addr_s.clone();
            // A worker killed by a failpoint returns Err — that is the
            // scenario, not a harness failure.
            thread::spawn(move || run_worker(&a, 1))
        })
        .collect();
    wait_for_workers(addr, scenario.workers)?;

    // Arm, run the faulted job, disarm. The registry is process-global,
    // so failpoints see client, coordinator, and worker traffic alike —
    // specs use `tag=` to pick a conversation leg.
    for (name, spec) in &scenario.failpoints {
        failpoint::configure(name, spec)
            .with_context(|| format!("arm failpoint {name} = {spec:?}"))?;
    }
    let faulted = CompileClient::connect(&addr_s)
        .context("connect faulted client")
        .and_then(|mut client| {
            let (results, _summary) =
                client.compile_model(chip_seed, CFG, Method::Complete, &tensors)?;
            let session = client.fetch_session(chip_seed)?;
            Ok((results, session))
        });
    failpoint::clear();

    let outcome = match faulted {
        Ok((results, session_bytes)) => {
            check_results(&results, &want).context("faulted job completed with wrong bytes")?;
            if session_bytes != want_bytes {
                bail!("faulted job's fetched RCSS bytes diverged from a fault-free save");
            }
            ScenarioOutcome { completed: true, error: None }
        }
        Err(e) => {
            // A typed error is a legal ending — but only if the fabric
            // survived it: the same fleet must now complete the same job
            // fault-free, byte-identically.
            let mut client =
                CompileClient::connect(&addr_s).context("fabric died after a typed error")?;
            let (results, _summary) = client
                .compile_model(chip_seed, CFG, Method::Complete, &tensors)
                .context("fault-free recovery job failed after a typed error")?;
            check_results(&results, &want).context("recovery job diverged")?;
            let session = client.fetch_session(chip_seed).context("recovery session fetch")?;
            if session != want_bytes {
                bail!("recovery job's fetched RCSS bytes diverged from a fault-free save");
            }
            ScenarioOutcome { completed: false, error: Some(format!("{e:#}")) }
        }
    };

    // Tear the fleet down; worker threads end on the coordinator's EOF.
    CompileClient::connect(&addr_s)?.shutdown_server()?;
    server.join().map_err(|_| anyhow!("fabric server panicked"))??;
    for w in workers {
        // Err = the scenario killed this worker; panic = harness bug.
        let _ = w.join().map_err(|_| anyhow!("worker thread panicked"))?;
    }
    if let Some(d) = &store_dir {
        let _ = std::fs::remove_dir_all(d);
    }
    Ok(outcome)
}

/// The failpoints seeded schedules draw from; [`random_scenario`] fills
/// in each pick's spec with `Rng`-derived parameters.
pub const MENU: &[&str] = &[
    "net.frame.corrupt",
    "net.frame.truncate",
    "net.frame.wrong_version",
    "worker.crash_before_solve",
    "worker.crash_after_solve",
    "worker.drop_store_sync",
    "server.drop_fragment",
    "server.requeue_race",
    "store.torn_blob_write",
    "store.blob_read_error",
];

/// Frame tags the random frame-level faults aim at: the job-path legs.
/// (Handshake legs are exercised by scripted scenarios; randomly breaking
/// `Hello` would mostly test the harness's ability to start a fleet.)
const FRAME_TAGS: &[&str] =
    &["ShardSnapshotJob", "ShardResult", "StorePut", "StoreGet", "CompileResult"];

/// Derive scenario `idx` of the schedule `seed`: 1–2 distinct menu
/// entries with seeded parameters. Same (seed, idx) → same scenario,
/// always.
pub fn random_scenario(seed: u64, idx: usize) -> Scenario {
    let mut rng = Rng::new(seed ^ 0x6368_616f_73).fork(idx as u64);
    let k = 1 + rng.index(2);
    let picks = rng.sample_indices(MENU.len(), k);
    let mut s = Scenario::new(&format!("rand-{seed}-{idx}"), &[]);
    s.workers = 1 + rng.index(2);
    for p in picks {
        let name = MENU[p];
        let spec = match name {
            // Byte 16+ is payload/checksum territory on every frame: the
            // corruption is always caught by the checksum, never by a
            // resized length field (which would stall the reader until
            // its socket timeout — a scripted concern, not a random one).
            "net.frame.corrupt" => format!(
                "corrupt={}; tag={}; count=1",
                16 + rng.index(8),
                FRAME_TAGS[rng.index(FRAME_TAGS.len())]
            ),
            "net.frame.truncate" => format!(
                "truncate={}; tag={}; count=1",
                rng.index(24),
                FRAME_TAGS[rng.index(FRAME_TAGS.len())]
            ),
            "net.frame.wrong_version" => format!(
                "wrong_version; tag={}; count=1",
                FRAME_TAGS[rng.index(FRAME_TAGS.len())]
            ),
            "worker.drop_store_sync" => "return".to_string(),
            "store.torn_blob_write" => {
                s.store_dir = true;
                format!("truncate={}; count=2", 1 + rng.index(64))
            }
            "store.blob_read_error" => {
                s.store_dir = true;
                "return; count=3".to_string()
            }
            // The lifecycle/scheduling points: one deterministic firing
            // (an unlimited requeue_race would never drain the round).
            _ => "return; count=1".to_string(),
        };
        s.name.push_str(&format!("+{name}"));
        s.failpoints.push((name.to_string(), spec));
    }
    s
}

/// Run `scenarios` seeded random scenarios and fold the outcomes.
/// `Err` = some scenario violated the invariant; the message names the
/// scenario, which encodes `(seed, idx)` for replay.
pub fn run_seed(seed: u64, scenarios: usize, weight_limit: usize) -> Result<ChaosReport> {
    let mut report = ChaosReport { seed, ..ChaosReport::default() };
    for idx in 0..scenarios {
        let scenario = random_scenario(seed, idx);
        let chip_seed = 100 + idx as u64;
        let outcome = run_scenario(&scenario, chip_seed, weight_limit)
            .with_context(|| format!("chaos seed {seed}, scenario {idx}"))?;
        report.scenarios += 1;
        if outcome.completed {
            report.completed += 1;
        } else {
            report.typed_errors += 1;
        }
    }
    Ok(report)
}
