//! RCWP v1 — the compile fabric's wire protocol.
//!
//! Every message on a fabric connection is one **frame**: a fixed
//! little-endian header (`magic "RCWP" · version u32 · frame type u32 ·
//! payload length u32`), the payload bytes, and a trailing FNV-1a
//! checksum over header + payload. [`read_frame`] verifies magic,
//! version, type, length bound, and checksum before returning a byte of
//! payload to any decoder — a truncated, corrupted, or
//! version-mismatched frame is rejected with an error, and a connection
//! that closes *between* frames reads as a clean `None` (closing
//! *inside* a frame is an error).
//!
//! Payload codecs reuse the coordinator's persistence machinery
//! (`coordinator/persist.rs`): the shard-job payload opens with the same
//! cache-key byte layout as RCSS/RCSF files ([`decode_shard_job`] →
//! chip seed + fault rates, [`GroupConfig`], pipeline fingerprint), and
//! shard results travel as verbatim RCSF fragment bytes
//! ([`crate::coordinator::ShardFragment::to_bytes`]) — one codec, three
//! surfaces (session file, fragment file, wire).
//!
//! Conversation shapes (see [`super::server`] for the roles):
//!
//! ```text
//! worker:  Hello → HelloAck, then per assignment:
//!          ShardJob → (StoreGet → StorePut)? → StorePut* → ShardResult | Error
//! client:  CompileRequest → CompileResult* → CompileDone
//!          FetchSession   → SessionBytes | Error
//!          Info           → InfoReply
//!          Shutdown       → (server stops)
//! ```
//!
//! The `StoreGet`/`StorePut` pair is the fleet solution store's fabric
//! tier (see [`crate::store`]): before solving a shard range a worker
//! asks the coordinator for any already-solved patterns (`StoreGet`,
//! answered by one `StorePut`), and after solving it publishes what it
//! solved fresh (`StorePut`, no reply) so the next worker — or the next
//! chip — starts from the fleet's accumulated work.

use crate::coordinator::persist::{
    push_i64, push_u32, push_u64, read_key, read_pattern_solution, write_key,
    write_pattern_solution, CacheKey, Reader,
};
use crate::coordinator::{Method, Outcome, PatternSolution, PipelineOptions};
use crate::fault::bank::ChipFaults;
use crate::fault::GroupFaults;
use crate::grouping::{Bitmap, Decomposition, GroupConfig};
use crate::obs::{Histogram, MetricValue, MetricsSnapshot};
use crate::store::{read_store_ctx, StoreCtx};
use crate::util::failpoint;
use crate::util::prop::{fnv1a, fnv1a_with};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

/// Frame magic ("RCWP").
pub const WIRE_MAGIC: u32 = 0x5243_5750;
/// Wire protocol version. Version mismatches are rejected per frame, so
/// a mixed-version fleet fails loudly at the first exchange.
pub const WIRE_VERSION: u32 = 1;
/// Fixed frame header length: magic, version, frame type, payload length.
pub const FRAME_HEADER_LEN: usize = 16;
/// Hard cap on one frame's payload. A corrupt or hostile length field
/// must produce a clean error, not a multi-gigabyte allocation.
pub const MAX_FRAME_PAYLOAD: usize = 256 << 20;

/// Everything that travels on a fabric connection. Codes are part of the
/// wire format — never renumber, only append.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameType {
    /// Worker → server: join the worker pool (payload: u32 thread count).
    Hello,
    /// Server → worker: registration accepted.
    HelloAck,
    /// Client → server: compile one chip's tensor set.
    CompileRequest,
    /// Server → client: one compiled tensor (streamed per tensor).
    CompileResult,
    /// Server → client: end of a compile stream, with a job summary.
    CompileDone,
    /// Server → worker: solve one shard range of a chip's pattern space.
    ShardJob,
    /// Worker → server: the solved range as verbatim RCSF fragment bytes.
    ShardResult,
    /// Client → server: fetch a chip's warm session cache.
    FetchSession,
    /// Server → client: verbatim RCSS session cache bytes.
    SessionBytes,
    /// Client → server: request fabric status.
    Info,
    /// Server → client: fabric status.
    InfoReply,
    /// Client → server: stop the fabric.
    Shutdown,
    /// Either direction: human-readable failure for the previous request.
    Error,
    /// Worker → coordinator: which of these fault patterns does the
    /// fleet store already hold? (payload: store context + patterns).
    StoreGet,
    /// Either direction: a batch of (pattern, full-range table) store
    /// entries — the coordinator's reply to a `StoreGet`, and a
    /// worker's unsolicited publish of freshly solved patterns.
    StorePut,
    /// Server → worker: solve one shard range from a sealed "RCRG"
    /// registry snapshot instead of the tensor set (payload: shard ·
    /// shards · snapshot bytes). The snapshot-path replacement for
    /// `ShardJob` on table-tier rounds.
    ShardSnapshotJob,
    /// Client → server: scrape the coordinator's live metrics registry
    /// (`rchg submit --stats`, `rchg top`). Empty payload.
    StatsPull,
    /// Server → client: a name-sorted metrics snapshot (counters, gauges,
    /// fixed-layout log2 histograms) — the reply to a `StatsPull`.
    StatsPush,
}

impl FrameType {
    /// Stable wire code — never renumber.
    pub fn code(self) -> u32 {
        match self {
            FrameType::Hello => 1,
            FrameType::HelloAck => 2,
            FrameType::CompileRequest => 3,
            FrameType::CompileResult => 4,
            FrameType::CompileDone => 5,
            FrameType::ShardJob => 6,
            FrameType::ShardResult => 7,
            FrameType::FetchSession => 8,
            FrameType::SessionBytes => 9,
            FrameType::Info => 10,
            FrameType::InfoReply => 11,
            FrameType::Shutdown => 12,
            FrameType::Error => 13,
            FrameType::StoreGet => 14,
            FrameType::StorePut => 15,
            FrameType::ShardSnapshotJob => 16,
            FrameType::StatsPull => 17,
            FrameType::StatsPush => 18,
        }
    }

    pub fn from_code(c: u32) -> Option<FrameType> {
        Some(match c {
            1 => FrameType::Hello,
            2 => FrameType::HelloAck,
            3 => FrameType::CompileRequest,
            4 => FrameType::CompileResult,
            5 => FrameType::CompileDone,
            6 => FrameType::ShardJob,
            7 => FrameType::ShardResult,
            8 => FrameType::FetchSession,
            9 => FrameType::SessionBytes,
            10 => FrameType::Info,
            11 => FrameType::InfoReply,
            12 => FrameType::Shutdown,
            13 => FrameType::Error,
            14 => FrameType::StoreGet,
            15 => FrameType::StorePut,
            16 => FrameType::ShardSnapshotJob,
            17 => FrameType::StatsPull,
            18 => FrameType::StatsPush,
            _ => return None,
        })
    }
}

/// One decoded frame: its type and raw payload bytes (already
/// checksum-verified by [`read_frame`]).
#[derive(Clone, Debug)]
pub struct Frame {
    pub frame_type: FrameType,
    pub payload: Vec<u8>,
}

/// The full wire bytes of one frame (header · payload · checksum).
/// Exposed so tests can corrupt frames byte-by-byte.
pub fn frame_bytes(frame_type: FrameType, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(FRAME_HEADER_LEN + payload.len() + 8);
    push_u32(&mut buf, WIRE_MAGIC);
    push_u32(&mut buf, WIRE_VERSION);
    push_u32(&mut buf, frame_type.code());
    push_u32(&mut buf, payload.len() as u32);
    buf.extend_from_slice(payload);
    let sum = fnv1a(&buf);
    push_u64(&mut buf, sum);
    buf
}

/// Write one frame (single `write_all` + flush, so frames never
/// interleave on a connection written from one thread at a time).
pub fn write_frame(w: &mut impl Write, frame_type: FrameType, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_PAYLOAD {
        bail!(
            "refusing to send a {}-byte RCWP payload (cap {MAX_FRAME_PAYLOAD})",
            payload.len()
        );
    }
    let mut buf = frame_bytes(frame_type, payload);
    if failpoint::ENABLED {
        inject_frame_failpoints(w, frame_type, &mut buf)?;
    }
    w.write_all(&buf).context("write RCWP frame")?;
    w.flush().context("flush RCWP frame")?;
    Ok(())
}

/// Chaos-suite hooks on the frame write path (`net.frame.*`, see
/// [`crate::util::failpoint`]). The sites tag every evaluation with the
/// frame-type debug name so a spec like `tag=ShardResult` targets one
/// conversation leg even when client, coordinator, and workers share the
/// process. Only reached when [`failpoint::ENABLED`]; a release build
/// never pays the tag allocation.
fn inject_frame_failpoints(
    w: &mut impl Write,
    frame_type: FrameType,
    buf: &mut [u8],
) -> Result<()> {
    use crate::util::failpoint::Action;
    let tag = format!("{frame_type:?}");
    if let Action::Delay(d) = failpoint::eval("net.frame.stall", Some(&tag)) {
        std::thread::sleep(d);
    }
    if let Action::Truncate(n) = failpoint::eval("net.frame.truncate", Some(&tag)) {
        // A crash mid-write: the peer sees a torn frame then EOF.
        let n = n.min(buf.len());
        w.write_all(&buf[..n]).context("write RCWP frame")?;
        w.flush().ok();
        bail!("failpoint net.frame.truncate: sent {n} of {} frame bytes", buf.len());
    }
    if let Action::Corrupt(i) = failpoint::eval("net.frame.corrupt", Some(&tag)) {
        // One flipped bit pattern on the wire; the peer's checksum (or
        // header validation) must reject the frame.
        let i = i.min(buf.len() - 1);
        buf[i] ^= 0xff;
    }
    if failpoint::eval("net.frame.wrong_version", Some(&tag)) == Action::WrongVersion {
        // Patch the version field and re-seal the checksum, so the peer
        // exercises its version check rather than the checksum path.
        buf[4..8].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
        let body = buf.len() - 8;
        let sum = fnv1a(&buf[..body]);
        buf[body..].copy_from_slice(&sum.to_le_bytes());
    }
    Ok(())
}

/// Read one frame. `Ok(None)` means the peer closed the connection
/// cleanly *at a frame boundary*; closing mid-frame, a bad magic, an
/// unsupported version, an unknown frame type, an oversized length, or a
/// checksum mismatch are all errors — a malformed frame never reaches a
/// payload decoder.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    let mut filled = 0usize;
    while filled < FRAME_HEADER_LEN {
        let n = r.read(&mut header[filled..]).context("read RCWP frame header")?;
        if n == 0 {
            if filled == 0 {
                return Ok(None);
            }
            bail!("connection closed mid-frame ({filled} of {FRAME_HEADER_LEN} header bytes)");
        }
        filled += n;
    }
    let word = |i: usize| u32::from_le_bytes(header[4 * i..4 * i + 4].try_into().unwrap());
    let magic = word(0);
    if magic != WIRE_MAGIC {
        bail!("bad RCWP frame magic {magic:#010x}");
    }
    let version = word(1);
    if version != WIRE_VERSION {
        bail!("unsupported RCWP version {version} (this build speaks {WIRE_VERSION})");
    }
    let frame_type = FrameType::from_code(word(2))
        .ok_or_else(|| anyhow!("unknown RCWP frame type {}", word(2)))?;
    let len = word(3) as usize;
    if len > MAX_FRAME_PAYLOAD {
        bail!("RCWP payload length {len} exceeds the {MAX_FRAME_PAYLOAD}-byte cap");
    }
    let mut body = vec![0u8; len + 8];
    r.read_exact(&mut body)
        .context("read RCWP frame payload (truncated frame)")?;
    let stored = u64::from_le_bytes(body[len..].try_into().unwrap());
    body.truncate(len);
    // Stream the checksum over header then payload — no joining copy
    // (payloads run up to MAX_FRAME_PAYLOAD).
    if fnv1a_with(fnv1a(&header), &body) != stored {
        bail!("RCWP frame checksum mismatch (corrupted frame)");
    }
    Ok(Some(Frame { frame_type, payload: body }))
}

// ---- payload codecs -----------------------------------------------------

/// Hello payload: the worker's solve thread count (informational).
pub fn encode_hello(threads: usize) -> Vec<u8> {
    (threads as u32).to_le_bytes().to_vec()
}

/// Tolerant hello decode: an empty payload reads as 0 threads.
pub fn decode_hello(payload: &[u8]) -> usize {
    match payload.get(..4) {
        Some(b) => u32::from_le_bytes(b.try_into().unwrap()) as usize,
        None => 0,
    }
}

/// Error payload: a UTF-8 message (lossily decoded, it is diagnostics).
pub fn decode_error(payload: &[u8]) -> String {
    String::from_utf8_lossy(payload).into_owned()
}

/// FetchSession payload: the chip seed whose warm cache is requested.
pub fn encode_chip_seed(chip_seed: u64) -> Vec<u8> {
    chip_seed.to_le_bytes().to_vec()
}

pub fn decode_chip_seed(payload: &[u8]) -> Result<u64> {
    let mut r = Reader::new(payload);
    let seed = r.u64().context("chip-seed payload")?;
    if r.remaining() != 0 {
        bail!("chip-seed payload has {} trailing bytes", r.remaining());
    }
    Ok(seed)
}

fn push_tensors(buf: &mut Vec<u8>, tensors: &[(String, Vec<i64>)]) {
    push_u32(buf, tensors.len() as u32);
    for (name, ws) in tensors {
        push_u32(buf, name.len() as u32);
        buf.extend_from_slice(name.as_bytes());
        push_u32(buf, ws.len() as u32);
        for &w in ws {
            push_i64(buf, w);
        }
    }
}

fn read_tensors(r: &mut Reader<'_>) -> Result<Vec<(String, Vec<i64>)>> {
    let n = r.u32()? as usize;
    if n > 65_536 {
        bail!("unreasonable tensor count {n} in RCWP payload");
    }
    let mut out = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        let name_len = r.u32()? as usize;
        if name_len > 4_096 {
            bail!("unreasonable tensor name length {name_len} in RCWP payload");
        }
        let name = std::str::from_utf8(r.bytes(name_len)?)
            .context("tensor name is not UTF-8")?
            .to_string();
        let n_w = r.u32()? as usize;
        if r.remaining() < n_w.saturating_mul(8) {
            bail!("RCWP payload truncated inside tensor {name:?} ({n_w} weights declared)");
        }
        let mut ws = Vec::with_capacity(n_w);
        for _ in 0..n_w {
            ws.push(r.i64()?);
        }
        out.push((name, ws));
    }
    Ok(out)
}

/// A client's compile job: one chip's named tensor set, plus the
/// grouping config + method the client expects (the server rejects a
/// request that disagrees with its own configuration instead of
/// silently compiling under different options).
#[derive(Clone, Debug)]
pub struct CompileRequest {
    pub chip_seed: u64,
    pub cfg: GroupConfig,
    pub method: Method,
    pub tensors: Vec<(String, Vec<i64>)>,
}

pub fn encode_compile_request(
    chip_seed: u64,
    cfg: GroupConfig,
    method: Method,
    tensors: &[(String, Vec<i64>)],
) -> Vec<u8> {
    let weights: usize = tensors.iter().map(|(_, w)| w.len()).sum();
    let mut buf = Vec::with_capacity(32 + 8 * weights);
    push_u64(&mut buf, chip_seed);
    push_u32(&mut buf, cfg.rows as u32);
    push_u32(&mut buf, cfg.cols as u32);
    push_u32(&mut buf, cfg.levels as u32);
    buf.push(method.code());
    push_tensors(&mut buf, tensors);
    buf
}

pub fn decode_compile_request(payload: &[u8]) -> Result<CompileRequest> {
    let mut r = Reader::new(payload);
    let chip_seed = r.u64()?;
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let levels = r.u32()?;
    if rows == 0 || cols == 0 || !(2..=255).contains(&levels) {
        bail!("bad grouping config R{rows}C{cols}@{levels} in compile request");
    }
    let cfg = GroupConfig::new(rows, cols, levels as u8);
    let method = Method::from_code(r.u8()?)
        .ok_or_else(|| anyhow!("bad method code in compile request"))?;
    let tensors = read_tensors(&mut r)?;
    if r.remaining() != 0 {
        bail!("compile request has {} trailing bytes", r.remaining());
    }
    Ok(CompileRequest { chip_seed, cfg, method, tensors })
}

/// A shard-solve assignment, decoded from the wire. The identity fields
/// (chip + config + pipeline) travel in the exact cache-key byte layout
/// RCSS/RCSF files open with, so worker and coordinator agree on the
/// fragment key by construction.
#[derive(Clone, Debug)]
pub struct ShardJobSpec {
    pub chip: ChipFaults,
    pub cfg: GroupConfig,
    pub pipeline: PipelineOptions,
    /// 0-based shard index within the plan.
    pub shard: u32,
    /// Total shards in the plan.
    pub shards: u32,
    pub tensors: Vec<(String, Vec<i64>)>,
}

pub fn encode_shard_job(
    chip: &ChipFaults,
    cfg: GroupConfig,
    pipeline: PipelineOptions,
    shard: u32,
    shards: u32,
    tensors: &[(String, Vec<i64>)],
) -> Vec<u8> {
    let weights: usize = tensors.iter().map(|(_, w)| w.len()).sum();
    let mut buf = Vec::with_capacity(80 + 8 * weights);
    write_key(&mut buf, &CacheKey::new(chip, cfg, pipeline));
    push_u32(&mut buf, shard);
    push_u32(&mut buf, shards);
    push_tensors(&mut buf, tensors);
    buf
}

pub fn decode_shard_job(payload: &[u8]) -> Result<ShardJobSpec> {
    let mut r = Reader::new(payload);
    let key = read_key(&mut r).context("shard job cache key")?;
    let shard = r.u32()?;
    let shards = r.u32()?;
    if shards == 0 || shard >= shards {
        bail!("bad shard assignment {shard} of {shards} in shard job");
    }
    let tensors = read_tensors(&mut r)?;
    if r.remaining() != 0 {
        bail!("shard job has {} trailing bytes", r.remaining());
    }
    Ok(ShardJobSpec {
        chip: key.chip,
        cfg: key.cfg,
        pipeline: key.pipeline,
        shard,
        shards,
        tensors,
    })
}

/// A snapshot-path shard-solve assignment, decoded from the wire: the
/// shard coordinates plus the coordinator's sealed "RCRG" registry
/// snapshot, verbatim. The snapshot carries its own cache-key header and
/// checksum, so identity validation happens in the RCRG decoder — this
/// codec only frames it.
#[derive(Clone, Debug)]
pub struct ShardSnapshotJobSpec {
    /// 0-based shard index within the plan.
    pub shard: u32,
    /// Total shards in the plan.
    pub shards: u32,
    /// Sealed "RCRG" v1 registry snapshot bytes.
    pub snapshot: Vec<u8>,
}

pub fn encode_shard_snapshot_job(shard: u32, shards: u32, snapshot: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + snapshot.len());
    push_u32(&mut buf, shard);
    push_u32(&mut buf, shards);
    buf.extend_from_slice(snapshot);
    buf
}

pub fn decode_shard_snapshot_job(payload: &[u8]) -> Result<ShardSnapshotJobSpec> {
    let mut r = Reader::new(payload);
    let shard = r.u32()?;
    let shards = r.u32()?;
    if shards == 0 || shard >= shards {
        bail!("bad shard assignment {shard} of {shards} in snapshot shard job");
    }
    let snapshot = r.bytes(r.remaining())?.to_vec();
    if snapshot.is_empty() {
        bail!("snapshot shard job carries no registry snapshot");
    }
    Ok(ShardSnapshotJobSpec { shard, shards, snapshot })
}

/// One compiled tensor streamed back to the client: the decomposition
/// bitmaps and residual error per weight, plus the fresh solve work this
/// tensor triggered server-side (0 on a warm cache).
#[derive(Clone, Debug)]
pub struct TensorResult {
    pub name: String,
    pub errors: Vec<i64>,
    pub decomps: Vec<Decomposition>,
    pub fresh_solves: u64,
}

pub fn encode_tensor_result(res: &TensorResult, cells: usize) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32 + res.errors.len() * (8 + 2 * cells));
    push_u32(&mut buf, res.name.len() as u32);
    buf.extend_from_slice(res.name.as_bytes());
    push_u64(&mut buf, res.fresh_solves);
    push_u32(&mut buf, cells as u32);
    push_u32(&mut buf, res.errors.len() as u32);
    for (err, d) in res.errors.iter().zip(&res.decomps) {
        push_i64(&mut buf, *err);
        buf.extend_from_slice(&d.pos.cells);
        buf.extend_from_slice(&d.neg.cells);
    }
    buf
}

pub fn decode_tensor_result(payload: &[u8]) -> Result<TensorResult> {
    let mut r = Reader::new(payload);
    let name_len = r.u32()? as usize;
    if name_len > 4_096 {
        bail!("unreasonable tensor name length {name_len} in tensor result");
    }
    let name = std::str::from_utf8(r.bytes(name_len)?)
        .context("tensor name is not UTF-8")?
        .to_string();
    let fresh_solves = r.u64()?;
    let cells = r.u32()? as usize;
    if cells == 0 || cells > 64 {
        bail!("unreasonable cell count {cells} in tensor result");
    }
    let n = r.u32()? as usize;
    if r.remaining() < n.saturating_mul(8 + 2 * cells) {
        bail!("tensor result truncated ({n} weights declared)");
    }
    let mut errors = Vec::with_capacity(n);
    let mut decomps = Vec::with_capacity(n);
    for _ in 0..n {
        errors.push(r.i64()?);
        let pos = Bitmap { cells: r.bytes(cells)?.to_vec() };
        let neg = Bitmap { cells: r.bytes(cells)?.to_vec() };
        decomps.push(Decomposition { pos, neg });
    }
    if r.remaining() != 0 {
        bail!("tensor result has {} trailing bytes", r.remaining());
    }
    Ok(TensorResult { name, errors, decomps, fresh_solves })
}

/// End-of-stream summary of one compile job.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FabricSummary {
    pub tensors: u32,
    pub weights: u64,
    /// Fresh solve work this job performed server-side: pattern classes
    /// solved on shard workers plus any per-pair catch-up at merge time
    /// (distributed), or unique (pattern, weight) solves (local). 0 means
    /// the job ran entirely warm.
    pub fresh_solves: u64,
    /// Shard ranges of the distributed solve (0 = compiled locally).
    pub shards: u32,
    /// Workers the coordinator dispatched shard ranges to.
    pub workers: u32,
    /// Shard ranges reassigned after a worker was lost.
    pub reassigned: u32,
}

pub fn encode_summary(s: &FabricSummary) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32);
    push_u32(&mut buf, s.tensors);
    push_u64(&mut buf, s.weights);
    push_u64(&mut buf, s.fresh_solves);
    push_u32(&mut buf, s.shards);
    push_u32(&mut buf, s.workers);
    push_u32(&mut buf, s.reassigned);
    buf
}

pub fn decode_summary(payload: &[u8]) -> Result<FabricSummary> {
    let mut r = Reader::new(payload);
    let s = FabricSummary {
        tensors: r.u32()?,
        weights: r.u64()?,
        fresh_solves: r.u64()?,
        shards: r.u32()?,
        workers: r.u32()?,
        reassigned: r.u32()?,
    };
    if r.remaining() != 0 {
        bail!("fabric summary has {} trailing bytes", r.remaining());
    }
    Ok(s)
}

/// Fabric status returned by an [`FrameType::Info`] request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FabricInfo {
    /// Workers currently idle in the pool (dispatched workers are
    /// temporarily claimed by their job).
    pub workers: u32,
    /// Warm chip sessions held by the server.
    pub sessions: u32,
    pub jobs: u64,
    pub distributed_jobs: u64,
    pub reassignments: u64,
}

pub fn encode_info(i: &FabricInfo) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32);
    push_u32(&mut buf, i.workers);
    push_u32(&mut buf, i.sessions);
    push_u64(&mut buf, i.jobs);
    push_u64(&mut buf, i.distributed_jobs);
    push_u64(&mut buf, i.reassignments);
    buf
}

pub fn decode_info(payload: &[u8]) -> Result<FabricInfo> {
    let mut r = Reader::new(payload);
    let i = FabricInfo {
        workers: r.u32()?,
        sessions: r.u32()?,
        jobs: r.u64()?,
        distributed_jobs: r.u64()?,
        reassignments: r.u64()?,
    };
    if r.remaining() != 0 {
        bail!("fabric info has {} trailing bytes", r.remaining());
    }
    Ok(i)
}

/// StatsPush payload: a name-sorted [`MetricsSnapshot`]. Layout per
/// entry: `u32 name_len · name bytes · u8 kind` then the kind's body —
/// counter (`0`): `u64`; gauge (`1`): `i64`; histogram (`2`):
/// `u64 count · u64 sum · HIST_BUCKETS × u64`. The bucket count is fixed
/// by [`crate::obs::HIST_BUCKETS`]; changing the histogram layout is a
/// wire-protocol bump.
pub fn encode_stats(snap: &MetricsSnapshot) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + snap.entries.len() * 32);
    push_u32(&mut buf, snap.entries.len() as u32);
    for (name, value) in &snap.entries {
        push_u32(&mut buf, name.len() as u32);
        buf.extend_from_slice(name.as_bytes());
        match value {
            MetricValue::Counter(c) => {
                buf.push(0);
                push_u64(&mut buf, *c);
            }
            MetricValue::Gauge(g) => {
                buf.push(1);
                push_i64(&mut buf, *g);
            }
            MetricValue::Histogram(h) => {
                buf.push(2);
                push_u64(&mut buf, h.count);
                push_u64(&mut buf, h.sum);
                for b in &h.buckets {
                    push_u64(&mut buf, *b);
                }
            }
        }
    }
    buf
}

pub fn decode_stats(payload: &[u8]) -> Result<MetricsSnapshot> {
    let mut r = Reader::new(payload);
    let n = r.u32()? as usize;
    if n > 65_536 {
        bail!("unreasonable metric count {n} in RCWP stats payload");
    }
    let mut entries = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        let name_len = r.u32()? as usize;
        if name_len > 4_096 {
            bail!("unreasonable metric name length {name_len} in RCWP stats payload");
        }
        let name = std::str::from_utf8(r.bytes(name_len)?)
            .context("metric name is not UTF-8")?
            .to_string();
        let value = match r.u8()? {
            0 => MetricValue::Counter(r.u64()?),
            1 => MetricValue::Gauge(r.i64()?),
            2 => {
                let count = r.u64()?;
                let sum = r.u64()?;
                let mut h = Histogram { count, sum, ..Histogram::default() };
                for b in h.buckets.iter_mut() {
                    *b = r.u64()?;
                }
                MetricValue::Histogram(h)
            }
            k => bail!("unknown metric kind {k} for {name:?} in RCWP stats payload"),
        };
        entries.push((name, value));
    }
    if r.remaining() != 0 {
        bail!("stats payload has {} trailing bytes", r.remaining());
    }
    Ok(MetricsSnapshot { entries })
}

/// A decoded [`FrameType::StoreGet`]: which of these fault patterns does
/// the fleet store hold, under one store context?
#[derive(Clone, Debug)]
pub struct StoreQuery {
    pub ctx: StoreCtx,
    pub patterns: Vec<GroupFaults>,
}

/// A decoded [`FrameType::StorePut`]: (pattern, full-range table) store
/// entries under one store context. Only dense tables travel — the
/// store's scope ends where request-dependent partial state begins.
#[derive(Clone, Debug)]
pub struct StoreBatch {
    pub ctx: StoreCtx,
    pub entries: Vec<(GroupFaults, Vec<Outcome>)>,
}

/// StoreGet payload: the canonical store-context bytes (the content
/// hash's own preimage layout), then the queried patterns as raw
/// pos/neg fault-state bytes.
pub fn encode_store_get(ctx: &StoreCtx, patterns: &[GroupFaults]) -> Vec<u8> {
    let cells = ctx.cells();
    let mut buf = Vec::with_capacity(32 + patterns.len() * 2 * cells);
    ctx.push_bytes(&mut buf);
    push_u32(&mut buf, patterns.len() as u32);
    for p in patterns {
        debug_assert_eq!((p.pos.len(), p.neg.len()), (cells, cells));
        buf.extend(p.pos.iter().map(|&f| f as u8));
        buf.extend(p.neg.iter().map(|&f| f as u8));
    }
    buf
}

pub fn decode_store_get(payload: &[u8]) -> Result<StoreQuery> {
    let mut r = Reader::new(payload);
    let ctx = read_store_ctx(&mut r).context("store query context")?;
    let cells = ctx.cells();
    let n = r.u32()? as usize;
    if n > 65_536 {
        bail!("unreasonable store query count {n} in RCWP payload");
    }
    if r.remaining() != n * 2 * cells {
        bail!("store query payload length mismatch ({n} patterns declared)");
    }
    let mut patterns = Vec::with_capacity(n);
    for _ in 0..n {
        let pos = r.fault_states(cells)?;
        let neg = r.fault_states(cells)?;
        patterns.push(GroupFaults { pos, neg });
    }
    Ok(StoreQuery { ctx, patterns })
}

/// StorePut payload: the canonical store-context bytes, then each entry
/// in the RCSS per-pattern solution framing (fault bytes · table tag ·
/// dense outcome table) — the same codec RCPS blobs and session files
/// use, so worker and coordinator agree on solution bytes by
/// construction.
pub fn encode_store_put(ctx: &StoreCtx, entries: &[(GroupFaults, Vec<Outcome>)]) -> Vec<u8> {
    let cells = ctx.cells();
    let mut buf =
        Vec::with_capacity(32 + entries.len() * (2 * cells + 5 + ctx.table_len() * (9 + 2 * cells)));
    ctx.push_bytes(&mut buf);
    push_u32(&mut buf, entries.len() as u32);
    for (pattern, table) in entries {
        debug_assert_eq!(table.len(), ctx.table_len());
        write_pattern_solution(&mut buf, pattern, Some(&PatternSolution::Table(table.clone())));
    }
    buf
}

pub fn decode_store_put(payload: &[u8]) -> Result<StoreBatch> {
    let mut r = Reader::new(payload);
    let ctx = read_store_ctx(&mut r).context("store batch context")?;
    let key = ctx.cache_key();
    let cells = ctx.cells();
    let table_len = ctx.table_len();
    let n = r.u32()? as usize;
    if n > 65_536 {
        bail!("unreasonable store entry count {n} in RCWP payload");
    }
    // Sanity cap before allocating: every entry costs at least its fault
    // bytes plus a tag.
    if r.remaining() < n * (2 * cells + 1) {
        bail!("store batch truncated ({n} entries declared)");
    }
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let (pattern, solution) = read_pattern_solution(&mut r, &key, false)?;
        match solution.expect("store entries are never empty") {
            PatternSolution::Table(t) if t.len() == table_len => entries.push((pattern, t)),
            PatternSolution::Table(t) => {
                bail!("store entry table has {} outcomes (config wants {table_len})", t.len())
            }
            PatternSolution::Pairs(_) => bail!("store frames carry full-range tables only"),
        }
    }
    if r.remaining() != 0 {
        bail!("store batch has {} trailing bytes", r.remaining());
    }
    Ok(StoreBatch { ctx, entries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultRates;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_every_type() {
        for t in (1..=18).filter_map(FrameType::from_code) {
            let payload = vec![0xAB; 37];
            let bytes = frame_bytes(t, &payload);
            let frame = read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap();
            assert_eq!(frame.frame_type, t);
            assert_eq!(frame.payload, payload);
            assert_eq!(t, FrameType::from_code(t.code()).unwrap());
        }
    }

    #[test]
    fn clean_eof_vs_truncation() {
        // Empty stream: clean end at a frame boundary.
        assert!(read_frame(&mut Cursor::new(&[] as &[u8])).unwrap().is_none());
        // Any proper prefix of a frame is a truncation error.
        let bytes = frame_bytes(FrameType::Hello, &encode_hello(4));
        for cut in 1..bytes.len() {
            assert!(
                read_frame(&mut Cursor::new(&bytes[..cut])).is_err(),
                "prefix of {cut} bytes must be rejected"
            );
        }
        // Two frames back to back parse in order, then clean EOF.
        let mut two = bytes.clone();
        two.extend_from_slice(&frame_bytes(FrameType::Shutdown, &[]));
        let mut cur = Cursor::new(&two);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap().frame_type, FrameType::Hello);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap().frame_type, FrameType::Shutdown);
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn corruption_is_rejected_everywhere() {
        let bytes = frame_bytes(FrameType::CompileDone, &encode_summary(&FabricSummary::default()));
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                read_frame(&mut Cursor::new(&bad)).is_err(),
                "flip at byte {i} must be rejected"
            );
        }
    }

    #[test]
    fn version_and_magic_mismatch_report_cleanly() {
        let mut bad_version = frame_bytes(FrameType::Hello, &[]);
        bad_version[4] = 2; // version 2
        let err = read_frame(&mut Cursor::new(&bad_version)).unwrap_err().to_string();
        assert!(err.contains("version 2"), "got: {err}");

        let mut bad_magic = frame_bytes(FrameType::Hello, &[]);
        bad_magic[0] ^= 0xFF;
        let err = read_frame(&mut Cursor::new(&bad_magic)).unwrap_err().to_string();
        assert!(err.contains("magic"), "got: {err}");

        let mut bad_type = frame_bytes(FrameType::Hello, &[]);
        bad_type[8] = 0xEE;
        let err = read_frame(&mut Cursor::new(&bad_type)).unwrap_err().to_string();
        assert!(err.contains("frame type"), "got: {err}");
    }

    #[test]
    fn hostile_length_is_capped_before_allocation() {
        let mut bytes = frame_bytes(FrameType::Hello, &[]);
        bytes[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("cap"), "got: {err}");
    }

    #[test]
    fn compile_request_roundtrip_and_rejection() {
        let tensors = vec![
            ("conv1".to_string(), vec![-3i64, 0, 7, 30]),
            ("fc".to_string(), vec![1i64, -1]),
        ];
        let payload = encode_compile_request(9, GroupConfig::R2C2, Method::Complete, &tensors);
        let req = decode_compile_request(&payload).unwrap();
        assert_eq!(req.chip_seed, 9);
        assert_eq!(req.cfg, GroupConfig::R2C2);
        assert_eq!(req.method, Method::Complete);
        assert_eq!(req.tensors, tensors);
        // Truncation anywhere inside the payload fails cleanly.
        for cut in 0..payload.len() {
            assert!(decode_compile_request(&payload[..cut]).is_err());
        }
        // Trailing garbage is rejected.
        let mut long = payload.clone();
        long.push(0);
        assert!(decode_compile_request(&long).is_err());
    }

    #[test]
    fn shard_job_roundtrip_reuses_cache_key_codec() {
        let chip = ChipFaults::new(77, FaultRates::paper_default());
        let tensors = vec![("t".to_string(), vec![5i64, -5])];
        let payload = encode_shard_job(
            &chip,
            GroupConfig::R2C2,
            PipelineOptions::default(),
            1,
            4,
            &tensors,
        );
        let spec = decode_shard_job(&payload).unwrap();
        assert_eq!(spec.chip, chip);
        assert_eq!(spec.cfg, GroupConfig::R2C2);
        assert_eq!(spec.pipeline, PipelineOptions::default());
        assert_eq!((spec.shard, spec.shards), (1, 4));
        assert_eq!(spec.tensors, tensors);
        // A shard index outside the plan is rejected.
        let bad = encode_shard_job(
            &chip,
            GroupConfig::R2C2,
            PipelineOptions::default(),
            4,
            4,
            &tensors,
        );
        assert!(decode_shard_job(&bad).is_err());
    }

    #[test]
    fn shard_snapshot_job_roundtrip_and_rejection() {
        let snapshot = vec![0x52u8, 0x43, 0x52, 0x47, 1, 2, 3, 4, 5];
        let payload = encode_shard_snapshot_job(2, 4, &snapshot);
        let spec = decode_shard_snapshot_job(&payload).unwrap();
        assert_eq!((spec.shard, spec.shards), (2, 4));
        assert_eq!(spec.snapshot, snapshot);
        // A shard index outside the plan is rejected.
        assert!(decode_shard_snapshot_job(&encode_shard_snapshot_job(4, 4, &snapshot)).is_err());
        assert!(decode_shard_snapshot_job(&encode_shard_snapshot_job(0, 0, &snapshot)).is_err());
        // An empty snapshot body is rejected.
        assert!(decode_shard_snapshot_job(&encode_shard_snapshot_job(0, 2, &[])).is_err());
        // Truncation inside the shard header is rejected.
        assert!(decode_shard_snapshot_job(&payload[..6]).is_err());
    }

    #[test]
    fn tensor_result_roundtrip() {
        let cells = GroupConfig::R2C2.cells();
        let res = TensorResult {
            name: "conv1".into(),
            errors: vec![0, 2],
            decomps: vec![
                Decomposition {
                    pos: Bitmap { cells: vec![1, 0, 2, 3] },
                    neg: Bitmap { cells: vec![0, 0, 0, 1] },
                },
                Decomposition {
                    pos: Bitmap { cells: vec![3, 3, 0, 0] },
                    neg: Bitmap { cells: vec![2, 0, 1, 0] },
                },
            ],
            fresh_solves: 11,
        };
        let payload = encode_tensor_result(&res, cells);
        let back = decode_tensor_result(&payload).unwrap();
        assert_eq!(back.name, res.name);
        assert_eq!(back.errors, res.errors);
        assert_eq!(back.decomps, res.decomps);
        assert_eq!(back.fresh_solves, 11);
        for cut in 0..payload.len() {
            assert!(decode_tensor_result(&payload[..cut]).is_err());
        }
    }

    #[test]
    fn summary_and_info_roundtrip() {
        let s = FabricSummary {
            tensors: 3,
            weights: 4_000,
            fresh_solves: 120,
            shards: 4,
            workers: 2,
            reassigned: 1,
        };
        assert_eq!(decode_summary(&encode_summary(&s)).unwrap(), s);
        let i = FabricInfo {
            workers: 2,
            sessions: 5,
            jobs: 9,
            distributed_jobs: 3,
            reassignments: 1,
        };
        assert_eq!(decode_info(&encode_info(&i)).unwrap(), i);
        assert!(decode_summary(&[1, 2, 3]).is_err());
        assert!(decode_info(&[]).is_err());
    }

    #[test]
    fn store_get_and_put_roundtrip_and_rejection() {
        use crate::coordinator::Stage;
        use crate::fault::FaultState;
        let cfg = GroupConfig::R2C2;
        let ctx = StoreCtx::new(cfg, PipelineOptions::default());
        let mut faulty = GroupFaults::free(cfg.cells());
        faulty.neg[1] = FaultState::Sa0;
        let patterns = vec![GroupFaults::free(cfg.cells()), faulty.clone()];

        let get = encode_store_get(&ctx, &patterns);
        let q = decode_store_get(&get).unwrap();
        assert_eq!(q.ctx, ctx);
        assert_eq!(q.patterns, patterns);
        for cut in 0..get.len() {
            assert!(decode_store_get(&get[..cut]).is_err(), "cut at {cut}");
        }

        let maxv = cfg.max_per_array();
        let table: Vec<Outcome> = (-maxv..=maxv)
            .map(|w| Outcome {
                decomposition: Decomposition::encode_ideal(w, &cfg),
                error: 0,
                stage: Stage::FastPath,
            })
            .collect();
        let entries = vec![(faulty, table)];
        let put = encode_store_put(&ctx, &entries);
        let b = decode_store_put(&put).unwrap();
        assert_eq!(b.ctx, ctx);
        assert_eq!(b.entries, entries);
        for cut in 0..put.len() {
            assert!(decode_store_put(&put[..cut]).is_err(), "cut at {cut}");
        }
        let mut long = put.clone();
        long.push(0);
        assert!(decode_store_put(&long).is_err());
    }

    #[test]
    fn stats_roundtrip_and_rejection() {
        use crate::obs::{bucket_index, HIST_BUCKETS};
        let mut h = Histogram::default();
        h.observe(0);
        h.observe(900);
        h.observe(u64::MAX);
        let snap = MetricsSnapshot {
            entries: vec![
                ("compile.weights".to_string(), MetricValue::Counter(4096)),
                ("fabric.queue_depth".to_string(), MetricValue::Gauge(-2)),
                ("fabric.shard.latency_us".to_string(), MetricValue::Histogram(h.clone())),
            ],
        };
        let payload = encode_stats(&snap);
        let back = decode_stats(&payload).unwrap();
        assert_eq!(back, snap);
        let hb = back.histogram("fabric.shard.latency_us").unwrap();
        assert_eq!(hb.count, 3);
        assert_eq!(hb.buckets[0], 1);
        assert_eq!(hb.buckets[bucket_index(900)], 1);
        assert_eq!(hb.buckets[HIST_BUCKETS - 1], 1);
        // An empty snapshot is a valid reply (a fresh coordinator).
        let empty = decode_stats(&encode_stats(&MetricsSnapshot::default())).unwrap();
        assert!(empty.is_empty());
        // Truncation anywhere fails cleanly; trailing garbage is rejected.
        for cut in 0..payload.len() {
            assert!(decode_stats(&payload[..cut]).is_err(), "cut at {cut}");
        }
        let mut long = payload.clone();
        long.push(0);
        assert!(decode_stats(&long).is_err());
        // An unknown metric kind is rejected.
        let mut bad_kind = encode_stats(&MetricsSnapshot {
            entries: vec![("x".to_string(), MetricValue::Counter(1))],
        });
        bad_kind[4 + 4 + 1] = 9;
        assert!(decode_stats(&bad_kind).is_err());
    }

    #[test]
    fn hello_and_chip_seed_payloads() {
        assert_eq!(decode_hello(&encode_hello(8)), 8);
        assert_eq!(decode_hello(&[]), 0);
        assert_eq!(decode_chip_seed(&encode_chip_seed(42)).unwrap(), 42);
        assert!(decode_chip_seed(&[1, 2]).is_err());
    }
}
