//! Real layer-shape tables for the paper's evaluation networks.
//!
//! Compile-time (Table II) and energy (Fig 11) experiments depend only on
//! layer *shapes* — weight counts, kernel geometry, output resolution —
//! not on trained values, so we reproduce the exact architectures:
//! ResNet-20 (CIFAR-10), ResNet-18/50 (ImageNet), VGG-16 (ImageNet).

/// One weight layer, conv or fully connected (`kh == kw == 1, oh == ow == 1`
/// for FC).
#[derive(Clone, Debug)]
pub struct LayerShape {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    /// Output spatial resolution (per-pixel array activations).
    pub oh: usize,
    pub ow: usize,
}

impl LayerShape {
    pub fn conv(name: &str, cin: usize, cout: usize, k: usize, out: usize) -> LayerShape {
        LayerShape { name: name.into(), cin, cout, kh: k, kw: k, oh: out, ow: out }
    }
    pub fn fc(name: &str, cin: usize, cout: usize) -> LayerShape {
        LayerShape { name: name.into(), cin, cout, kh: 1, kw: 1, oh: 1, ow: 1 }
    }
    /// Weight parameter count.
    pub fn params(&self) -> usize {
        self.cin * self.cout * self.kh * self.kw
    }
}

/// ResNet-20 for CIFAR-10 (16/32/64 channels, 3 stages × 3 blocks × 2 convs).
pub fn resnet20() -> Vec<LayerShape> {
    let mut l = vec![LayerShape::conv("conv1", 3, 16, 3, 32)];
    for (stage, (ch, out)) in [(16usize, 32usize), (32, 16), (64, 8)].iter().enumerate() {
        for block in 0..3 {
            let cin = if block == 0 && stage > 0 { ch / 2 } else { *ch };
            l.push(LayerShape::conv(&format!("s{stage}b{block}c1"), cin, *ch, 3, *out));
            l.push(LayerShape::conv(&format!("s{stage}b{block}c2"), *ch, *ch, 3, *out));
        }
        if stage > 0 {
            l.push(LayerShape::conv(&format!("s{stage}down"), ch / 2, *ch, 1, *out));
        }
    }
    l.push(LayerShape::fc("fc", 64, 10));
    l
}

/// ResNet-18 for ImageNet (BasicBlock ×2 per stage).
pub fn resnet18() -> Vec<LayerShape> {
    let mut l = vec![LayerShape::conv("conv1", 3, 64, 7, 112)];
    let stages: [(usize, usize); 4] = [(64, 56), (128, 28), (256, 14), (512, 7)];
    for (si, (ch, out)) in stages.iter().enumerate() {
        for block in 0..2 {
            let cin = if block == 0 && si > 0 { ch / 2 } else { *ch };
            l.push(LayerShape::conv(&format!("s{si}b{block}c1"), cin, *ch, 3, *out));
            l.push(LayerShape::conv(&format!("s{si}b{block}c2"), *ch, *ch, 3, *out));
        }
        if si > 0 {
            l.push(LayerShape::conv(&format!("s{si}down"), ch / 2, *ch, 1, *out));
        }
    }
    l.push(LayerShape::fc("fc", 512, 1000));
    l
}

/// ResNet-50 for ImageNet (Bottleneck; blocks 3/4/6/3).
pub fn resnet50() -> Vec<LayerShape> {
    let mut l = vec![LayerShape::conv("conv1", 3, 64, 7, 112)];
    let stages: [(usize, usize, usize); 4] =
        [(64, 256, 56), (128, 512, 28), (256, 1024, 14), (512, 2048, 7)];
    let blocks = [3usize, 4, 6, 3];
    let mut cin = 64usize;
    for (si, ((mid, outc, res), nb)) in stages.iter().zip(blocks).enumerate() {
        for b in 0..nb {
            l.push(LayerShape::conv(&format!("s{si}b{b}c1"), cin, *mid, 1, *res));
            l.push(LayerShape::conv(&format!("s{si}b{b}c2"), *mid, *mid, 3, *res));
            l.push(LayerShape::conv(&format!("s{si}b{b}c3"), *mid, *outc, 1, *res));
            if b == 0 {
                l.push(LayerShape::conv(&format!("s{si}down"), cin, *outc, 1, *res));
            }
            cin = *outc;
        }
    }
    l.push(LayerShape::fc("fc", 2048, 1000));
    l
}

/// VGG-16 for ImageNet.
pub fn vgg16() -> Vec<LayerShape> {
    let plan: [(usize, usize, usize); 13] = [
        (3, 64, 224),
        (64, 64, 224),
        (64, 128, 112),
        (128, 128, 112),
        (128, 256, 56),
        (256, 256, 56),
        (256, 256, 56),
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 28),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
    ];
    let mut l: Vec<LayerShape> = plan
        .iter()
        .enumerate()
        .map(|(i, (cin, cout, out))| LayerShape::conv(&format!("conv{i}"), *cin, *cout, 3, *out))
        .collect();
    l.push(LayerShape::fc("fc6", 512 * 7 * 7, 4096));
    l.push(LayerShape::fc("fc7", 4096, 4096));
    l.push(LayerShape::fc("fc8", 4096, 1000));
    l
}

/// Model registry by paper name.
pub fn by_name(name: &str) -> Option<Vec<LayerShape>> {
    match name.to_ascii_lowercase().as_str() {
        "resnet20" | "resnet-20" => Some(resnet20()),
        "resnet18" | "resnet-18" => Some(resnet18()),
        "resnet50" | "resnet-50" => Some(resnet50()),
        "vgg16" | "vgg-16" => Some(vgg16()),
        _ => None,
    }
}

pub fn total_params(layers: &[LayerShape]) -> usize {
    layers.iter().map(|l| l.params()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // Published weight counts (conv+fc, no BN): ResNet-20 ≈ 0.27M,
        // ResNet-18 ≈ 11.7M, ResNet-50 ≈ 25.5M, VGG-16 ≈ 138M.
        let r20 = total_params(&resnet20());
        assert!((260_000..300_000).contains(&r20), "resnet20: {r20}");
        let r18 = total_params(&resnet18());
        assert!((11_000_000..12_500_000).contains(&r18), "resnet18: {r18}");
        let r50 = total_params(&resnet50());
        assert!((23_000_000..27_000_000).contains(&r50), "resnet50: {r50}");
        let v16 = total_params(&vgg16());
        assert!((132_000_000..140_000_000).contains(&v16), "vgg16: {v16}");
    }

    #[test]
    fn registry_resolves() {
        for n in ["resnet20", "ResNet-18", "resnet50", "VGG16"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("alexnet").is_none());
    }

    #[test]
    fn fc_layers_are_1x1() {
        for l in vgg16() {
            if l.name.starts_with("fc") {
                assert_eq!((l.kh, l.kw, l.oh, l.ow), (1, 1, 1, 1));
            }
        }
    }
}
