//! Crossbar array model + convolution weight mapping (the ConvMapSIM /
//! NeuroSIM substrate of §VII "Hardware Evaluation").
//!
//! Mapping scheme: *kernel splitting* (NeuroSIM's default, the paper's
//! choice): each of the `kh × kw` kernel positions contributes a
//! `cin → cout` sub-matrix mapped to its own region. A grouping
//! configuration RrCc inflates it to `cin·r` physical rows × `cout·c`
//! physical columns per array sign; positive and negative arrays double
//! everything (sign decomposition).
//!
//! Two mapper policies:
//! * [`MapperPolicy::KernelSplit`] — the paper's: one kernel position per
//!   array (column-tiled if too wide, row-spanned if too tall). Known for
//!   energy efficiency but leaves rows idle when `cin·r ≪ rows` — exactly
//!   the utilization weakness Fig 11 discusses.
//! * [`MapperPolicy::PackedVertical`] — ablation: stack several kernel
//!   positions vertically in one array (their bit-line sums realize the
//!   convolution's accumulation in-array). Better utilization, fewer
//!   activations; used by the `bench_energy` ablation.

pub mod models;

use crate::grouping::GroupConfig;
use models::LayerShape;

/// Physical crossbar dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArrayDims {
    pub rows: usize,
    pub cols: usize,
}

impl ArrayDims {
    pub fn square(n: usize) -> ArrayDims {
        ArrayDims { rows: n, cols: n }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MapperPolicy {
    /// One kernel position per array (paper / NeuroSIM default).
    #[default]
    KernelSplit,
    /// Utilization-aware vertical packing (ablation).
    PackedVertical,
}

/// Mapping of one layer onto crossbars.
#[derive(Clone, Debug)]
pub struct LayerMapping {
    pub layer: String,
    /// Total arrays allocated (positive + negative).
    pub n_arrays: usize,
    /// MVM activations per inference (arrays × output pixels).
    pub activations: u64,
    /// ADC conversions per inference (used columns × activations).
    pub adc_conversions: u64,
    /// Wordline drives per inference (used rows × activations).
    pub row_drives: u64,
    /// Average row utilization across allocated arrays (0..1].
    pub row_utilization: f64,
    /// Average column utilization.
    pub col_utilization: f64,
    /// Physical cells allocated (both signs).
    pub cells_allocated: u64,
    /// Cells actually storing weights (both signs).
    pub cells_used: u64,
}

/// Map one layer under the given policy.
pub fn map_layer(
    layer: &LayerShape,
    dims: ArrayDims,
    cfg: &GroupConfig,
    policy: MapperPolicy,
) -> LayerMapping {
    let sub_rows = layer.cin * cfg.rows; // physical rows per kernel position
    let sub_cols = layer.cout * cfg.cols; // physical cols (per sign)
    let positions = layer.kh * layer.kw;

    // Vertical dimension: arrays needed to host all kernel positions, and
    // the used rows of each.
    let (arrays_v, used_rows_total) = match policy {
        MapperPolicy::KernelSplit => {
            if sub_rows <= dims.rows {
                (positions, (positions * sub_rows) as u64)
            } else {
                let span = sub_rows.div_ceil(dims.rows);
                (positions * span, (positions * sub_rows) as u64)
            }
        }
        MapperPolicy::PackedVertical => {
            if sub_rows <= dims.rows {
                let per = (dims.rows / sub_rows).max(1).min(positions.max(1));
                (positions.div_ceil(per), (positions * sub_rows) as u64)
            } else {
                let span = sub_rows.div_ceil(dims.rows);
                (positions * span, (positions * sub_rows) as u64)
            }
        }
    };

    // Horizontal tiling over output columns.
    let arrays_h = sub_cols.div_ceil(dims.cols);
    let used_cols_per_vslice = sub_cols as u64; // summed over the h tiles

    let pixels = (layer.oh * layer.ow) as u64;
    let arrays_per_sign = arrays_v * arrays_h;
    let n_arrays = arrays_per_sign * 2;
    let activations = n_arrays as u64 * pixels;

    // Every vertical slice digitizes all used columns once per pixel.
    let adc_conversions = 2 * arrays_v as u64 * used_cols_per_vslice * pixels;
    // Wordline drives: used rows across the layer, once per pixel, per sign
    // (column tiles share wordlines within an array but distinct arrays
    // re-drive them).
    let row_drives = 2 * used_rows_total * arrays_h as u64 * pixels;

    let cells_used = 2 * (layer.params() * cfg.rows * cfg.cols) as u64;
    let cells_allocated = n_arrays as u64 * (dims.rows * dims.cols) as u64;

    let row_utilization =
        (positions * sub_rows) as f64 / (arrays_per_sign.min(positions * arrays_h) * dims.rows).max(1) as f64;
    let col_utilization = sub_cols as f64 / (arrays_h * dims.cols) as f64;

    LayerMapping {
        layer: layer.name.clone(),
        n_arrays,
        activations,
        adc_conversions,
        row_drives,
        row_utilization: row_utilization.min(1.0),
        col_utilization: col_utilization.min(1.0),
        cells_allocated,
        cells_used,
    }
}

/// Map a whole network; returns per-layer mappings.
pub fn map_network(
    layers: &[LayerShape],
    dims: ArrayDims,
    cfg: &GroupConfig,
    policy: MapperPolicy,
) -> Vec<LayerMapping> {
    layers.iter().map(|l| map_layer(l, dims, cfg, policy)).collect()
}

/// Aggregate row utilization, weighted by allocated cells.
pub fn mean_row_utilization(mappings: &[LayerMapping]) -> f64 {
    let total: u64 = mappings.iter().map(|m| m.cells_allocated).sum();
    if total == 0 {
        return 0.0;
    }
    mappings
        .iter()
        .map(|m| m.row_utilization * m.cells_allocated as f64)
        .sum::<f64>()
        / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use models::resnet20;

    const KS: MapperPolicy = MapperPolicy::KernelSplit;

    #[test]
    fn kernel_split_one_array_per_position() {
        // conv 16→16 3×3, R1C4, 256×256: 9 positions → 9 arrays per sign.
        let l = LayerShape::conv("c", 16, 16, 3, 32);
        let m = map_layer(&l, ArrayDims::square(256), &GroupConfig::R1C4, KS);
        assert_eq!(m.n_arrays, 18);
        assert_eq!(m.activations, 18 * 1024);
        assert_eq!(m.adc_conversions, 2 * 9 * 64 * 1024);
    }

    #[test]
    fn r2c2_halves_adc_conversions_kernel_split() {
        let l = LayerShape::conv("c", 16, 16, 3, 32);
        let d = ArrayDims::square(256);
        let a = map_layer(&l, d, &GroupConfig::R1C4, KS);
        let b = map_layer(&l, d, &GroupConfig::R2C2, KS);
        assert_eq!(b.adc_conversions * 2, a.adc_conversions);
        assert!(b.row_utilization > a.row_utilization * 1.9);
    }

    #[test]
    fn packed_policy_reduces_arrays() {
        let l = LayerShape::conv("c", 16, 16, 3, 32);
        let d = ArrayDims::square(256);
        let ks = map_layer(&l, d, &GroupConfig::R1C4, KS);
        let pk = map_layer(&l, d, &GroupConfig::R1C4, MapperPolicy::PackedVertical);
        assert!(pk.n_arrays < ks.n_arrays);
        assert!(pk.activations < ks.activations);
        // Same cells stored either way.
        assert_eq!(pk.cells_used, ks.cells_used);
    }

    #[test]
    fn wide_layer_tiles_horizontally() {
        // cout 512, c=4 → 2048 cols → 8 tiles at 256 cols; 9 positions.
        let l = LayerShape::conv("c", 64, 512, 3, 7);
        let m = map_layer(&l, ArrayDims::square(256), &GroupConfig::R1C4, KS);
        assert_eq!(m.n_arrays, 2 * 9 * 8);
    }

    #[test]
    fn tall_position_spans_arrays() {
        // cin 4096 rows > 256 → 16-array vertical span (r=1), 1 position.
        let l = LayerShape::fc("fc", 4096, 10);
        let m = map_layer(&l, ArrayDims::square(256), &GroupConfig::R1C4, KS);
        assert_eq!(m.n_arrays, 2 * 16);
        assert_eq!(m.activations, 32);
    }

    #[test]
    fn utilization_bounded_across_grid() {
        for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
            for n in [64usize, 128, 256, 512] {
                for policy in [KS, MapperPolicy::PackedVertical] {
                    for m in map_network(&resnet20(), ArrayDims::square(n), &cfg, policy) {
                        assert!(m.row_utilization > 0.0 && m.row_utilization <= 1.0);
                        assert!(m.col_utilization > 0.0 && m.col_utilization <= 1.0);
                        assert!(m.cells_used <= m.cells_allocated);
                    }
                }
            }
        }
    }

    #[test]
    fn row_utilization_drops_with_array_size() {
        // The paper's observation: kernel splitting under-uses rows on
        // larger arrays (shallow layers especially).
        let net = resnet20();
        let u128 = mean_row_utilization(&map_network(&net, ArrayDims::square(128), &GroupConfig::R1C4, KS));
        let u512 = mean_row_utilization(&map_network(&net, ArrayDims::square(512), &GroupConfig::R1C4, KS));
        assert!(u512 < u128, "{u512} !< {u128}");
        // And hybrid grouping recovers utilization.
        let h512 = mean_row_utilization(&map_network(&net, ArrayDims::square(512), &GroupConfig::R2C2, KS));
        assert!(h512 > u512);
    }
}
