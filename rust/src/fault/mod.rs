//! Stuck-at-fault (SAF) model — §III of the paper.
//!
//! A ReRAM cell is either programmable (`Free`), stuck at its highest
//! conductance level (`SA0`, value locked to `L-1`), or stuck at its lowest
//! (`SA1`, value locked to `0`).
//!
//! Naming follows the paper (and the RRAM test literature it cites): SA0 =
//! stuck at the *low-resistance* state = maximum cell value; SA1 = stuck at
//! the *high-resistance* state = zero. This matches the paper's Fig 1b
//! worked example (SA0 in the MSB + SA1 in the 2nd LSB turn 52 into 240
//! for L=4, c=4).
//!
//! Fault maps are sampled i.i.d. per cell with published rates
//! (SA0 1.75%, SA1 9.04% — Chen et al., squeeze-search characterization),
//! uniformly across bit positions, exactly as the paper's experimental
//! setup describes.

pub mod bank;
pub mod detection;

use crate::util::prng::Rng;

/// Paper's default SA0 rate (fraction of all cells).
pub const DEFAULT_P_SA0: f64 = 0.0175;
/// Paper's default SA1 rate (fraction of all cells).
pub const DEFAULT_P_SA1: f64 = 0.0904;

/// Per-cell fault state.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum FaultState {
    /// Programmable cell (free variable in the decomposition problem).
    Free = 0,
    /// Stuck at low-resistance state: cell reads `L-1` regardless of writes.
    Sa0 = 1,
    /// Stuck at high-resistance state: cell reads `0` regardless of writes.
    Sa1 = 2,
}

impl FaultState {
    /// The value a cell reports when programmed to `v` under this state.
    #[inline]
    pub fn apply(self, v: u8, levels: u8) -> u8 {
        match self {
            FaultState::Free => v,
            FaultState::Sa0 => levels - 1,
            FaultState::Sa1 => 0,
        }
    }

    #[inline]
    pub fn is_fault(self) -> bool {
        !matches!(self, FaultState::Free)
    }

    /// Inverse of the `repr(u8)` discriminant (session cache deserializer).
    pub fn from_u8(b: u8) -> Option<FaultState> {
        match b {
            0 => Some(FaultState::Free),
            1 => Some(FaultState::Sa0),
            2 => Some(FaultState::Sa1),
            _ => None,
        }
    }
}

/// SA0/SA1 occurrence rates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultRates {
    pub p_sa0: f64,
    pub p_sa1: f64,
}

impl FaultRates {
    pub const fn paper_default() -> Self {
        FaultRates { p_sa0: DEFAULT_P_SA0, p_sa1: DEFAULT_P_SA1 }
    }

    /// No faults at all (ideal array).
    pub const fn none() -> Self {
        FaultRates { p_sa0: 0.0, p_sa1: 0.0 }
    }

    /// Scale total fault rate to `total`, keeping the paper's SA0:SA1 ratio
    /// of 1.75:9.04 — this is exactly the Fig 9 sweep protocol.
    pub fn scaled_to_total(total: f64) -> Self {
        let base = DEFAULT_P_SA0 + DEFAULT_P_SA1;
        FaultRates {
            p_sa0: total * DEFAULT_P_SA0 / base,
            p_sa1: total * DEFAULT_P_SA1 / base,
        }
    }

    pub fn total(&self) -> f64 {
        self.p_sa0 + self.p_sa1
    }

    /// Sample one cell's state.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> FaultState {
        let u = rng.f64();
        if u < self.p_sa0 {
            FaultState::Sa0
        } else if u < self.p_sa0 + self.p_sa1 {
            FaultState::Sa1
        } else {
            FaultState::Free
        }
    }
}

/// The fault map for one weight's grouped cells across the positive and
/// negative arrays. Cell layout matches `grouping::Bitmap`: column-major by
/// significance, `cells[col * rows + row]`, column 0 = MSB.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct GroupFaults {
    pub pos: Vec<FaultState>,
    pub neg: Vec<FaultState>,
}

impl GroupFaults {
    pub fn free(cells: usize) -> Self {
        GroupFaults { pos: vec![FaultState::Free; cells], neg: vec![FaultState::Free; cells] }
    }

    pub fn sample(cells: usize, rates: &FaultRates, rng: &mut Rng) -> Self {
        GroupFaults {
            pos: (0..cells).map(|_| rates.sample(rng)).collect(),
            neg: (0..cells).map(|_| rates.sample(rng)).collect(),
        }
    }

    pub fn num_faults(&self) -> usize {
        self.pos.iter().chain(&self.neg).filter(|f| f.is_fault()).count()
    }

    pub fn is_fault_free(&self) -> bool {
        self.pos.iter().chain(&self.neg).all(|f| !f.is_fault())
    }

    /// Dense bit-pattern key for interning and memoization: 2 bits per
    /// cell. Supports up to 32 cells total (r*c <= 16), which covers every
    /// configuration the paper evaluates (and then some). Two fault maps of
    /// the same shape share a key iff they are the same pattern, so this is
    /// the identity under which the pattern-class compiler
    /// (`coordinator::classes`) interns fault patterns.
    pub fn pattern_key(&self) -> PatternKey {
        debug_assert!(self.pos.len() + self.neg.len() <= 32);
        let mut key = 0u64;
        for f in self.pos.iter().chain(&self.neg) {
            key = (key << 2) | (*f as u64);
        }
        key
    }
}

/// Interning key of one fault pattern (see [`GroupFaults::pattern_key`]).
pub type PatternKey = u64;

/// The key of an all-free pattern: `Free` encodes as 0 in every 2-bit
/// slot, so a fault-free group of any shape always keys to 0.
pub const FREE_PATTERN_KEY: PatternKey = 0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_semantics() {
        assert_eq!(FaultState::Free.apply(2, 4), 2);
        assert_eq!(FaultState::Sa0.apply(2, 4), 3);
        assert_eq!(FaultState::Sa1.apply(2, 4), 0);
        assert_eq!(FaultState::Sa0.apply(0, 2), 1);
    }

    #[test]
    fn rates_sampling_statistics() {
        let rates = FaultRates::paper_default();
        let mut rng = Rng::new(99);
        let n = 200_000;
        let mut sa0 = 0;
        let mut sa1 = 0;
        for _ in 0..n {
            match rates.sample(&mut rng) {
                FaultState::Sa0 => sa0 += 1,
                FaultState::Sa1 => sa1 += 1,
                FaultState::Free => {}
            }
        }
        let r0 = sa0 as f64 / n as f64;
        let r1 = sa1 as f64 / n as f64;
        assert!((r0 - DEFAULT_P_SA0).abs() < 0.002, "sa0 rate {r0}");
        assert!((r1 - DEFAULT_P_SA1).abs() < 0.004, "sa1 rate {r1}");
    }

    #[test]
    fn scaled_rates_preserve_ratio() {
        let r = FaultRates::scaled_to_total(0.05);
        assert!((r.total() - 0.05).abs() < 1e-12);
        assert!((r.p_sa0 / r.p_sa1 - DEFAULT_P_SA0 / DEFAULT_P_SA1).abs() < 1e-12);
    }

    #[test]
    fn zero_rates_always_free() {
        let rates = FaultRates::none();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(rates.sample(&mut rng), FaultState::Free);
        }
    }

    #[test]
    fn pattern_key_distinct_and_stable() {
        let a = GroupFaults {
            pos: vec![FaultState::Free, FaultState::Sa0],
            neg: vec![FaultState::Sa1, FaultState::Free],
        };
        let b = GroupFaults {
            pos: vec![FaultState::Sa0, FaultState::Free],
            neg: vec![FaultState::Sa1, FaultState::Free],
        };
        assert_ne!(a.pattern_key(), b.pattern_key());
        assert_eq!(a.pattern_key(), a.clone().pattern_key());
    }

    #[test]
    fn free_pattern_keys_to_zero() {
        for cells in [2usize, 4, 8, 16] {
            assert_eq!(GroupFaults::free(cells).pattern_key(), FREE_PATTERN_KEY);
        }
        let mut g = GroupFaults::free(4);
        g.neg[3] = FaultState::Sa1;
        assert_ne!(g.pattern_key(), FREE_PATTERN_KEY);
    }

    #[test]
    fn fault_free_detection() {
        assert!(GroupFaults::free(8).is_fault_free());
        let mut g = GroupFaults::free(8);
        g.neg[3] = FaultState::Sa1;
        assert!(!g.is_fault_free());
        assert_eq!(g.num_faults(), 1);
    }
}
