//! Fault-map extraction: march-test + squeeze-search simulation.
//!
//! The paper assumes per-chip fault maps are known, citing the
//! squeeze-search scheme (Chen et al., TC'15) for obtaining them. This
//! module closes that loop in simulation: a [`PhysicalArray`] holds the
//! ground-truth cell states; [`march_detect`] plays the classical march
//! sequence (write-0/read, write-max/read) against it to classify every
//! cell, optionally with read noise, and returns the measured
//! [`FaultState`] map the compiler consumes.
//!
//! With zero read noise the procedure is exact (tests assert recovery of
//! the injected map); with noise, repeated reads + majority vote emulate
//! the "squeeze" refinement and the residual misclassification rate is
//! exposed so experiments can study compilation under *imperfect* fault
//! knowledge — an extension the paper leaves open.

use super::{FaultRates, FaultState};
use crate::util::prng::Rng;

/// Ground-truth array of cells for detection experiments.
#[derive(Clone, Debug)]
pub struct PhysicalArray {
    pub levels: u8,
    pub truth: Vec<FaultState>,
    /// Programmed values (what a write stored, before fault override).
    stored: Vec<u8>,
}

impl PhysicalArray {
    pub fn sample(cells: usize, levels: u8, rates: &FaultRates, rng: &mut Rng) -> Self {
        PhysicalArray {
            levels,
            truth: (0..cells).map(|_| rates.sample(rng)).collect(),
            stored: vec![0; cells],
        }
    }

    pub fn write(&mut self, idx: usize, v: u8) {
        self.stored[idx] = v.min(self.levels - 1);
    }

    /// Read with optional analog noise: the returned level flips to a
    /// neighbouring level with probability `noise`.
    pub fn read(&self, idx: usize, noise: f64, rng: &mut Rng) -> u8 {
        let ideal = self.truth[idx].apply(self.stored[idx], self.levels);
        if noise > 0.0 && rng.chance(noise) {
            if ideal == 0 {
                1.min(self.levels - 1)
            } else if rng.chance(0.5) {
                ideal - 1
            } else {
                (ideal + 1).min(self.levels - 1)
            }
        } else {
            ideal
        }
    }
}

/// Result of a detection pass.
#[derive(Clone, Debug)]
pub struct DetectionResult {
    pub measured: Vec<FaultState>,
    /// Cells whose measured state disagrees with ground truth.
    pub misclassified: usize,
}

/// March-style detection with `votes`-fold repeated reads (majority).
///
/// Sequence per cell: write 0 → read (expect 0; higher ⇒ SA0 candidate);
/// write L−1 → read (expect L−1; lower ⇒ SA1 candidate). A cell flagged in
/// both directions is impossible for a pure stuck-at and resolves to the
/// stronger deviation — with noise this is where the majority vote earns
/// its keep.
pub fn march_detect(
    array: &mut PhysicalArray,
    noise: f64,
    votes: usize,
    rng: &mut Rng,
) -> DetectionResult {
    let n = array.truth.len();
    let votes = votes.max(1) | 1; // odd
    let mut measured = Vec::with_capacity(n);
    for idx in 0..n {
        // Phase 1: write 0, read back.
        array.write(idx, 0);
        let mut high_votes = 0usize;
        for _ in 0..votes {
            if array.read(idx, noise, rng) == array.levels - 1 {
                high_votes += 1;
            }
        }
        // Phase 2: write L−1, read back.
        array.write(idx, array.levels - 1);
        let mut low_votes = 0usize;
        for _ in 0..votes {
            if array.read(idx, noise, rng) == 0 {
                low_votes += 1;
            }
        }
        let state = if high_votes * 2 > votes {
            FaultState::Sa0 // reads max even when programmed to 0
        } else if low_votes * 2 > votes {
            FaultState::Sa1 // reads 0 even when programmed to max
        } else {
            FaultState::Free
        };
        measured.push(state);
    }
    let misclassified = measured
        .iter()
        .zip(&array.truth)
        .filter(|(m, t)| m != t)
        .count();
    DetectionResult { measured, misclassified }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_detection_is_exact() {
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let mut arr = PhysicalArray::sample(500, 4, &FaultRates::paper_default(), &mut rng);
            let truth = arr.truth.clone();
            let det = march_detect(&mut arr, 0.0, 1, &mut rng);
            assert_eq!(det.misclassified, 0);
            assert_eq!(det.measured, truth);
        }
    }

    #[test]
    fn majority_vote_beats_single_read_under_noise() {
        let mut rng = Rng::new(9);
        let mut total_single = 0usize;
        let mut total_voted = 0usize;
        for trial in 0..10 {
            let mut arr =
                PhysicalArray::sample(2_000, 4, &FaultRates::paper_default(), &mut rng);
            let mut rng1 = Rng::new(100 + trial);
            let single = march_detect(&mut arr, 0.10, 1, &mut rng1);
            let mut rng2 = Rng::new(200 + trial);
            let voted = march_detect(&mut arr, 0.10, 7, &mut rng2);
            total_single += single.misclassified;
            total_voted += voted.misclassified;
        }
        assert!(
            total_voted * 3 < total_single.max(1),
            "voting {total_voted} vs single {total_single}"
        );
    }

    #[test]
    fn free_cells_survive_detection_noise() {
        // Noise can flip to a *neighbouring* level only; free-cell reads of
        // 0/max are never mistaken for the opposite rail under majority.
        let mut rng = Rng::new(11);
        let mut arr = PhysicalArray::sample(3_000, 4, &FaultRates::none(), &mut rng);
        let det = march_detect(&mut arr, 0.15, 5, &mut rng);
        assert_eq!(det.misclassified, 0);
    }

    #[test]
    fn two_level_cells_work() {
        // 1-bit cells (L=2): neighbouring-level noise *can* cross the rail,
        // so misclassification is possible but must stay below the noise
        // rate with voting.
        let mut rng = Rng::new(13);
        let mut arr = PhysicalArray::sample(5_000, 2, &FaultRates::paper_default(), &mut rng);
        let det = march_detect(&mut arr, 0.05, 9, &mut rng);
        assert!(
            (det.misclassified as f64) < 0.02 * 5_000.0,
            "misclassified {}",
            det.misclassified
        );
    }
}
