//! Chip-level fault map banks.
//!
//! A physical chip has one fixed SAF pattern; the compiler runs once per
//! chip (the paper's recurring per-chip compilation cost). `ChipFaults`
//! models a chip as a deterministic stream of per-group fault maps derived
//! from a chip seed, so "compile model M for chip 7" is reproducible and
//! different chips get different patterns — matching the paper's protocol
//! of averaging over independently sampled fault maps (10 trials for the
//! LM experiments, ± std for Table I).

use super::{FaultRates, GroupFaults};
use crate::util::prng::Rng;

/// One chip's fault universe: seeds + rates. Group fault maps are drawn
/// lazily per (tensor, group index), so arbitrarily large models never
/// materialize a full chip map.
#[derive(Clone, Debug, PartialEq)]
pub struct ChipFaults {
    pub chip_seed: u64,
    pub rates: FaultRates,
}

impl ChipFaults {
    pub fn new(chip_seed: u64, rates: FaultRates) -> Self {
        ChipFaults { chip_seed, rates }
    }

    /// RNG for one tensor's region of the chip.
    pub fn tensor_rng(&self, tensor_id: u64) -> Rng {
        let mut root = Rng::new(self.chip_seed);
        root.fork(tensor_id.wrapping_mul(0x0123_4567_89AB_CDEF) ^ 0xA5A5_A5A5)
    }

    /// Sample the fault maps for `n_groups` groups of `cells` cells each in
    /// tensor `tensor_id`. Deterministic in (chip_seed, tensor_id).
    pub fn sample_tensor(&self, tensor_id: u64, n_groups: usize, cells: usize) -> Vec<GroupFaults> {
        let mut rng = self.tensor_rng(tensor_id);
        (0..n_groups)
            .map(|_| GroupFaults::sample(cells, &self.rates, &mut rng))
            .collect()
    }

    /// Sample fault maps for a whole model at once: tensor `i` gets the
    /// same maps `sample_tensor(i, …)` would return. This is the chip-wide
    /// scan the pattern-class compiler runs so one registry / solve cache
    /// can dedupe (pattern, weight) pairs across every tensor of a chip.
    pub fn sample_model(&self, group_counts: &[usize], cells: usize) -> Vec<Vec<GroupFaults>> {
        group_counts
            .iter()
            .enumerate()
            .map(|(ti, &n)| self.sample_tensor(ti as u64, n, cells))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_chip_and_tensor() {
        let chip = ChipFaults::new(1234, FaultRates::paper_default());
        let a = chip.sample_tensor(5, 100, 8);
        let b = chip.sample_tensor(5, 100, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn different_tensors_differ() {
        let chip = ChipFaults::new(1234, FaultRates::paper_default());
        let a = chip.sample_tensor(1, 200, 8);
        let b = chip.sample_tensor(2, 200, 8);
        assert_ne!(a, b);
    }

    #[test]
    fn different_chips_differ() {
        let c1 = ChipFaults::new(1, FaultRates::paper_default());
        let c2 = ChipFaults::new(2, FaultRates::paper_default());
        assert_ne!(c1.sample_tensor(0, 200, 8), c2.sample_tensor(0, 200, 8));
    }

    #[test]
    fn sample_model_matches_per_tensor_sampling() {
        let chip = ChipFaults::new(31, FaultRates::paper_default());
        let counts = [50usize, 120, 7];
        let all = chip.sample_model(&counts, 8);
        assert_eq!(all.len(), counts.len());
        for (ti, maps) in all.iter().enumerate() {
            assert_eq!(maps, &chip.sample_tensor(ti as u64, counts[ti], 8));
        }
    }

    #[test]
    fn observed_rate_close_to_requested() {
        let chip = ChipFaults::new(77, FaultRates::paper_default());
        let groups = chip.sample_tensor(0, 20_000, 8);
        let cells: usize = groups.len() * 16;
        let faults: usize = groups.iter().map(|g| g.num_faults()).sum();
        let rate = faults as f64 / cells as f64;
        assert!((rate - 0.1079).abs() < 0.005, "rate={rate}");
    }
}
