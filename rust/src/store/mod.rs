//! Fleet-global, content-addressed pattern-solution store.
//!
//! A solved full-range pattern table depends on exactly three things —
//! the fault pattern itself, the [`GroupConfig`], and the pipeline
//! fingerprint ([`PipelineOptions`]) — and **not** on chip identity.
//! Every cache below this module is chip-scoped (the RCSS session cache
//! is keyed by chip seed + fault rates), so a fleet of a million chips
//! re-solves the same hot SAF patterns once per chip. This module is the
//! cross-chip dedupe layer:
//!
//! * [`StoreCtx`] + [`StoreCtx::content_hash`] — the content address: an
//!   FNV-1a hash over the canonical pattern bytes and the config/pipeline
//!   fingerprint, explicitly *excluding* chip seed and fault rates.
//! * [`SolutionStore`] — the in-process tier: a bounded-byte map from
//!   content hash to solved table with the same deterministic epoch-LRU
//!   discipline as [`crate::coordinator::SolveCache`].
//! * RCPS v1 blobs — the file tier: one sealed blob per distinct solution
//!   under `<dir>/<hash:016x>.rcps`, built from the same
//!   `coordinator::persist` codecs as RCSS/RCSF (trailing FNV-1a
//!   checksum verified before parsing; corrupt, truncated or
//!   version-mismatched blobs are rejected cleanly).
//! * [`StoreHandle`] — the shared `Arc<Mutex<…>>` wrapper a
//!   [`crate::coordinator::CompileService`] attaches to every chip's
//!   session, and the fabric coordinator serves over RCWP
//!   (`StoreGet`/`StorePut` frames).
//!
//! ## Determinism contract
//!
//! A store hit must be provably byte-identical to what a local solve
//! would produce. Three mechanisms enforce it:
//!
//! 1. Solutions enter the store only from an actual local solve
//!    ([`crate::coordinator::solve_full_range`] output installed
//!    verbatim), so every entry
//!    *is* a local solve's bytes.
//! 2. A lookup verifies full equality of the pattern and context against
//!    the stored entry — the content hash routes, equality decides — so a
//!    hash collision can never substitute a different pattern's solution.
//! 3. A file-tier read re-verifies the blob's trailing checksum before
//!    parsing and re-checks the decoded pattern, context, and table
//!    length against the request before serving it.
//!
//! Store scope is the `BatchTable` tier only: full-range tables are a
//! pure function of (pattern, config, pipeline), while `PerWeight`
//! pair maps are request-dependent partial state and are never published.

use crate::coordinator::persist::{
    push_i64, push_u32, read_pattern_solution, seal, table_len, unseal, write_pattern_solution,
    CacheKey, Reader,
};
use crate::coordinator::{Method, Outcome, PatternSolution, PipelineOptions};
use crate::fault::bank::ChipFaults;
use crate::fault::{FaultRates, GroupFaults};
use crate::grouping::GroupConfig;
use crate::util::failpoint;
use crate::util::fnv::FnvMap;
use crate::util::prop::{fnv1a_with, FNV1A_OFFSET};
use anyhow::{anyhow, bail, Context, Result};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, PoisonError};

/// Magic marker of the pattern-solution blob format ("RCPS").
pub const STORE_MAGIC: u32 = 0x5243_5053;
/// Current pattern-solution blob format version.
pub const STORE_VERSION: u32 = 1;

/// Default resident-memory budget of the in-process tier. Matches the
/// per-chip table budget default: the store is one more table cache, just
/// shared across chips.
pub const DEFAULT_STORE_MEMORY_BYTES: usize = 256 << 20;

/// The chip-independent half of a solution's identity: grouping config +
/// pipeline fingerprint. Together with a fault pattern this is everything
/// a full-range table is a function of.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StoreCtx {
    pub cfg: GroupConfig,
    pub pipeline: PipelineOptions,
}

impl StoreCtx {
    pub fn new(cfg: GroupConfig, pipeline: PipelineOptions) -> StoreCtx {
        StoreCtx { cfg, pipeline }
    }

    /// Cells per array under this context's config.
    pub fn cells(&self) -> usize {
        self.cfg.cells()
    }

    /// Dense-table length of a full-range solution under this context.
    pub fn table_len(&self) -> usize {
        table_len(&self.cfg)
    }

    /// A synthetic chip-less cache key (seed 0, zero fault rates) that
    /// lets the store reuse the RCSS per-pattern solution codecs, which
    /// only consume the config/pipeline half of the key.
    pub(crate) fn cache_key(&self) -> CacheKey {
        CacheKey::new(&ChipFaults::new(0, FaultRates::none()), self.cfg, self.pipeline)
    }

    /// Canonical context bytes, shared by the content hash, the RCPS blob
    /// header, and the RCWP store frames: `rows u32 · cols u32 ·
    /// levels u32 · method u8 · sparsest u8 · table_value_limit i64 ·
    /// cells u32` (all little-endian). This is the [`write_key`] layout
    /// minus the chip fields — chip seed and fault rates are *excluded*
    /// from a solution's identity by design.
    ///
    /// [`write_key`]: crate::coordinator::persist::write_key
    pub(crate) fn push_bytes(&self, buf: &mut Vec<u8>) {
        push_u32(buf, self.cfg.rows as u32);
        push_u32(buf, self.cfg.cols as u32);
        push_u32(buf, self.cfg.levels as u32);
        buf.push(self.pipeline.method.code());
        buf.push(self.pipeline.sparsest as u8);
        push_i64(buf, self.pipeline.table_value_limit);
        push_u32(buf, self.cfg.cells() as u32);
    }

    /// The content address of `pattern` under this context: FNV-1a over
    /// the canonical context bytes followed by the pattern's pos/neg
    /// fault-state bytes. Routing only — a lookup always re-verifies full
    /// equality before serving, so hash collisions cost a miss, never a
    /// wrong answer.
    pub fn content_hash(&self, pattern: &GroupFaults) -> u64 {
        let mut head = Vec::with_capacity(32);
        self.push_bytes(&mut head);
        let mut h = fnv1a_with(FNV1A_OFFSET, &head);
        for f in pattern.pos.iter().chain(&pattern.neg) {
            h = fnv1a_with(h, &[*f as u8]);
        }
        h
    }
}

/// Parse and validate the canonical context bytes written by
/// [`StoreCtx::push_bytes`], with the same bounds discipline as the RCSS
/// key parser: a corrupt header must produce a clean error, never an
/// absurd table allocation.
pub(crate) fn read_store_ctx(r: &mut Reader<'_>) -> Result<StoreCtx> {
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let levels = r.u32()?;
    if rows == 0 || cols == 0 || !(2..=255).contains(&levels) {
        bail!("bad grouping config R{rows}C{cols}@{levels} in store record");
    }
    let cfg = GroupConfig::new(rows, cols, levels as u8);
    let method =
        Method::from_code(r.u8()?).ok_or_else(|| anyhow!("bad method code in store record"))?;
    let sparsest = r.u8()? != 0;
    let table_value_limit = r.i64()?;
    let pipeline = PipelineOptions { method, table_value_limit, sparsest };
    let cells = r.u32()? as usize;
    if cells != cfg.cells() || cells == 0 || cells > 16 {
        bail!("cell count {cells} disagrees with config {cfg} in store record");
    }
    (levels as i64)
        .checked_pow(cols as u32)
        .and_then(|p| p.checked_sub(1))
        .and_then(|p| p.checked_mul(rows as i64))
        .filter(|&m| m > 0 && m <= (1 << 24))
        .ok_or_else(|| anyhow!("unreasonable weight range in store record"))?;
    Ok(StoreCtx { cfg, pipeline })
}

/// Serialize one solved pattern as an RCPS v1 blob: magic, version, the
/// canonical context bytes, the RCSS per-pattern framing (fault bytes +
/// tagged dense table), and the trailing FNV-1a checksum.
pub fn encode_blob(ctx: &StoreCtx, pattern: &GroupFaults, outcomes: &[Outcome]) -> Vec<u8> {
    debug_assert_eq!(outcomes.len(), ctx.table_len());
    let mut buf = Vec::new();
    push_u32(&mut buf, STORE_MAGIC);
    push_u32(&mut buf, STORE_VERSION);
    ctx.push_bytes(&mut buf);
    let solution = PatternSolution::Table(outcomes.to_vec());
    write_pattern_solution(&mut buf, pattern, Some(&solution));
    seal(buf)
}

/// Parse an RCPS v1 blob and verify it answers exactly the requested
/// (context, pattern): checksum first, then magic/version, then full
/// equality of the decoded context and pattern against the request.
/// Anything else — corruption, truncation, a version from a different
/// build, a hash-colliding foreign pattern — is an error, never a
/// silently adopted solution.
pub fn decode_blob(
    bytes: &[u8],
    ctx: &StoreCtx,
    pattern: &GroupFaults,
) -> Result<Vec<Outcome>> {
    let payload = unseal(bytes)?;
    let mut r = Reader::new(payload);
    let magic = r.u32()?;
    if magic != STORE_MAGIC {
        bail!("bad store blob magic {magic:#010x}");
    }
    let version = r.u32()?;
    if version != STORE_VERSION {
        bail!("unsupported store blob version {version} (this build reads {STORE_VERSION})");
    }
    let got_ctx = read_store_ctx(&mut r)?;
    if got_ctx != *ctx {
        bail!("store blob context {got_ctx:?} does not match the request");
    }
    let key = ctx.cache_key();
    let (got_pattern, solution) = read_pattern_solution(&mut r, &key, false)?;
    if r.remaining() != 0 {
        bail!("store blob has {} trailing bytes", r.remaining());
    }
    if got_pattern != *pattern {
        bail!("store blob pattern does not match the request (content-hash collision)");
    }
    match solution {
        Some(PatternSolution::Table(t)) if t.len() == ctx.table_len() => Ok(t),
        Some(PatternSolution::Table(t)) => bail!(
            "store blob table has {} entries, config {} needs {}",
            t.len(),
            ctx.cfg,
            ctx.table_len()
        ),
        _ => bail!("store blob does not carry a full-range table"),
    }
}

/// Lifetime counters of one [`SolutionStore`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Lookups answered, from memory or disk.
    pub hits: u64,
    /// Subset of `hits` that were re-read (and re-verified) from the file
    /// tier rather than served from memory.
    pub file_hits: u64,
    /// Lookups no tier could answer.
    pub misses: u64,
    /// Distinct solutions inserted (idempotent re-publishes don't count).
    pub publishes: u64,
    /// In-memory entries evicted to honor the byte budget.
    pub evictions: u64,
    /// Corrupt, truncated, or version-mismatched RCPS blobs rejected.
    pub rejected_blobs: u64,
    /// File-tier I/O failures (reads other than not-found, failed writes).
    pub io_errors: u64,
}

impl StoreCounters {
    /// Fraction of lookups answered, or `None` when nothing was looked up.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.hits + self.misses;
        (total > 0).then(|| self.hits as f64 / total as f64)
    }

    /// Mirror these lifetime totals into the global [`crate::obs`]
    /// registry as gauges. Called at scrape time (`StatsPull`, CLI
    /// summaries) rather than on the lookup hot path, so the store never
    /// takes the registry lock while solving.
    pub fn record_metrics(&self) {
        let m = crate::obs::metrics();
        m.gauge("store.hits", self.hits as i64);
        m.gauge("store.file_hits", self.file_hits as i64);
        m.gauge("store.misses", self.misses as i64);
        m.gauge("store.publishes", self.publishes as i64);
        m.gauge("store.evictions", self.evictions as i64);
        m.gauge("store.rejected_blobs", self.rejected_blobs as i64);
        m.gauge("store.io_errors", self.io_errors as i64);
    }
}

/// One resident solution: the full identity (for equality verification on
/// lookup) plus the solved table and LRU bookkeeping.
#[derive(Clone, Debug)]
struct StoreEntry {
    ctx: StoreCtx,
    pattern: GroupFaults,
    table: Vec<Outcome>,
    bytes: usize,
    last_used: u64,
}

/// Estimated resident bytes of one store entry (same estimate family as
/// `SolveCache`: a guard rail, not an allocator ledger).
fn entry_bytes(ctx: &StoreCtx) -> usize {
    let cells = ctx.cells();
    64 + 2 * cells + ctx.table_len() * (2 * (24 + cells) + 16)
}

/// The fleet-global pattern-solution store: in-process tier plus an
/// optional RCPS file tier. Use through a [`StoreHandle`] when shared
/// across sessions or threads.
#[derive(Debug)]
pub struct SolutionStore {
    dir: Option<PathBuf>,
    entries: FnvMap<u64, StoreEntry>,
    max_bytes: usize,
    resident_bytes: usize,
    epoch: u64,
    counters: StoreCounters,
}

impl SolutionStore {
    /// Memory-only store with a resident-byte budget.
    pub fn new(max_bytes: usize) -> SolutionStore {
        SolutionStore {
            dir: None,
            entries: FnvMap::default(),
            max_bytes: max_bytes.max(1),
            resident_bytes: 0,
            epoch: 0,
            counters: StoreCounters::default(),
        }
    }

    /// Store with an RCPS file tier rooted at `dir` (created if missing).
    pub fn with_dir(dir: &Path, max_bytes: usize) -> Result<SolutionStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create store directory {}", dir.display()))?;
        let mut s = SolutionStore::new(max_bytes);
        s.dir = Some(dir.to_path_buf());
        Ok(s)
    }

    /// File-tier root, when configured.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Resident entries in the in-process tier.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Estimated resident bytes of the in-process tier.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Lifetime counters snapshot.
    pub fn counters(&self) -> StoreCounters {
        self.counters
    }

    /// Blob path of one content hash under the file tier.
    fn blob_path(dir: &Path, hash: u64) -> PathBuf {
        dir.join(format!("{hash:016x}.rcps"))
    }

    /// Advance the LRU epoch and evict least-recently-used entries until
    /// the resident estimate fits the budget — deterministic order:
    /// (last-used epoch, content hash) ascending, earlier epochs only.
    /// Eviction never loses work (the file tier keeps its blob, and a
    /// re-solve is byte-identical by contract).
    pub fn begin_epoch(&mut self) {
        self.epoch += 1;
        if self.resident_bytes <= self.max_bytes {
            return;
        }
        let mut cands: Vec<(u64, u64)> = self
            .entries
            .iter()
            .filter(|(_, e)| e.last_used < self.epoch)
            .map(|(&k, e)| (e.last_used, k))
            .collect();
        cands.sort_unstable();
        for (_, key) in cands {
            if self.resident_bytes <= self.max_bytes {
                break;
            }
            if let Some(e) = self.entries.remove(&key) {
                self.resident_bytes -= e.bytes.min(self.resident_bytes);
                self.counters.evictions += 1;
            }
        }
    }

    /// Is (ctx, pattern) resident in the in-process tier? (No counter
    /// traffic — the fabric worker's publish-dedupe probe.)
    pub fn contains(&self, ctx: &StoreCtx, pattern: &GroupFaults) -> bool {
        self.entries
            .get(&ctx.content_hash(pattern))
            .is_some_and(|e| e.ctx == *ctx && e.pattern == *pattern)
    }

    /// Look up the full-range table of (ctx, pattern): memory first, then
    /// the file tier (a disk hit is re-verified and promoted to memory).
    /// Every hit went through full-equality verification against the
    /// stored identity — the returned table is provably the one a local
    /// solve of exactly this request produced.
    pub fn lookup_table(&mut self, ctx: &StoreCtx, pattern: &GroupFaults) -> Option<Vec<Outcome>> {
        let hash = ctx.content_hash(pattern);
        if let Some(e) = self.entries.get_mut(&hash) {
            if e.ctx == *ctx && e.pattern == *pattern {
                e.last_used = self.epoch;
                self.counters.hits += 1;
                return Some(e.table.clone());
            }
            // Hash-colliding foreign entry: fall through to a miss — never
            // serve a different pattern's solution.
        }
        if let Some(dir) = self.dir.clone() {
            let path = Self::blob_path(&dir, hash);
            // Chaos hook: the file tier's read fails (disk error, blob
            // vanished mid-read). Must count as an `io_errors` miss and
            // fall through to a local solve — never an error to the job.
            if failpoint::fires("store.blob_read_error") {
                self.counters.io_errors += 1;
                self.counters.misses += 1;
                return None;
            }
            match std::fs::read(&path) {
                Ok(bytes) => match decode_blob(&bytes, ctx, pattern) {
                    Ok(table) => {
                        self.install(hash, ctx, pattern, table.clone());
                        self.counters.file_hits += 1;
                        self.counters.hits += 1;
                        return Some(table);
                    }
                    Err(_) => self.counters.rejected_blobs += 1,
                },
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(_) => self.counters.io_errors += 1,
            }
        }
        self.counters.misses += 1;
        None
    }

    /// Publish a freshly solved full-range table. Idempotent: an entry
    /// already resident (necessarily byte-identical, by the determinism
    /// contract) is only LRU-refreshed, and an existing blob is never
    /// rewritten. The file write goes through a temp-file rename so a
    /// concurrent reader never sees a torn blob.
    pub fn publish_table(&mut self, ctx: &StoreCtx, pattern: &GroupFaults, outcomes: &[Outcome]) {
        if outcomes.len() != ctx.table_len() {
            return; // not a full-range table; out of store scope
        }
        let hash = ctx.content_hash(pattern);
        match self.entries.get_mut(&hash) {
            Some(e) if e.ctx == *ctx && e.pattern == *pattern => {
                e.last_used = self.epoch;
            }
            Some(_) => return, // hash-colliding foreign resident: keep it
            None => {
                self.install(hash, ctx, pattern, outcomes.to_vec());
                self.counters.publishes += 1;
            }
        }
        if let Some(dir) = self.dir.clone() {
            let path = Self::blob_path(&dir, hash);
            if !path.exists() {
                let tmp = path.with_extension("rcps.tmp");
                let blob = encode_blob(ctx, pattern, outcomes);
                // Chaos hook: a torn blob lands at the final path as if a
                // crash had bypassed the temp-file rename. Nothing notices
                // *here* — the next read must reject it (checksum) and
                // re-solve, which is what the chaos suite asserts.
                if let failpoint::Action::Truncate(n) =
                    failpoint::eval("store.torn_blob_write", None)
                {
                    let n = n.min(blob.len().saturating_sub(1));
                    let _ = std::fs::write(&path, &blob[..n]);
                    return;
                }
                let wrote = std::fs::write(&tmp, blob)
                    .and_then(|()| std::fs::rename(&tmp, &path));
                if wrote.is_err() {
                    self.counters.io_errors += 1;
                    let _ = std::fs::remove_file(&tmp);
                }
            }
        }
    }

    fn install(&mut self, hash: u64, ctx: &StoreCtx, pattern: &GroupFaults, table: Vec<Outcome>) {
        let bytes = entry_bytes(ctx);
        self.resident_bytes += bytes;
        self.entries.insert(
            hash,
            StoreEntry {
                ctx: *ctx,
                pattern: pattern.clone(),
                table,
                bytes,
                last_used: self.epoch,
            },
        );
    }
}

/// Cloneable shared handle to one [`SolutionStore`] — what a
/// `CompileService` attaches to every chip's session and the fabric
/// coordinator serves to workers. All methods lock internally; a poisoned
/// lock is recovered (the store holds only verified, re-derivable state).
#[derive(Clone)]
pub struct StoreHandle {
    inner: Arc<Mutex<SolutionStore>>,
}

impl fmt::Debug for StoreHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("StoreHandle(..)")
    }
}

impl StoreHandle {
    pub fn new(store: SolutionStore) -> StoreHandle {
        StoreHandle { inner: Arc::new(Mutex::new(store)) }
    }

    /// Memory-only store with the default budget.
    pub fn in_memory() -> StoreHandle {
        StoreHandle::new(SolutionStore::new(DEFAULT_STORE_MEMORY_BYTES))
    }

    /// Store with an RCPS file tier at `dir` and the default budget.
    pub fn with_dir(dir: &Path) -> Result<StoreHandle> {
        Ok(StoreHandle::new(SolutionStore::with_dir(dir, DEFAULT_STORE_MEMORY_BYTES)?))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SolutionStore> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// See [`SolutionStore::begin_epoch`].
    pub fn begin_epoch(&self) {
        self.lock().begin_epoch();
    }

    /// See [`SolutionStore::lookup_table`].
    pub fn lookup_table(&self, ctx: &StoreCtx, pattern: &GroupFaults) -> Option<Vec<Outcome>> {
        self.lock().lookup_table(ctx, pattern)
    }

    /// See [`SolutionStore::publish_table`].
    pub fn publish_table(&self, ctx: &StoreCtx, pattern: &GroupFaults, outcomes: &[Outcome]) {
        self.lock().publish_table(ctx, pattern, outcomes);
    }

    /// See [`SolutionStore::contains`].
    pub fn contains(&self, ctx: &StoreCtx, pattern: &GroupFaults) -> bool {
        self.lock().contains(ctx, pattern)
    }

    /// See [`SolutionStore::counters`].
    pub fn counters(&self) -> StoreCounters {
        self.lock().counters()
    }

    /// Resident entries in the in-process tier.
    pub fn entries(&self) -> usize {
        self.lock().entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Stage;
    use crate::fault::FaultState;
    use crate::grouping::Decomposition;

    fn ctx(cfg: GroupConfig) -> StoreCtx {
        StoreCtx::new(cfg, PipelineOptions::default())
    }

    fn full_table(cfg: &GroupConfig) -> Vec<Outcome> {
        let maxv = cfg.max_per_array();
        (-maxv..=maxv)
            .map(|w| Outcome {
                decomposition: Decomposition::encode_ideal(w, cfg),
                error: 0,
                stage: Stage::FastPath,
            })
            .collect()
    }

    fn faulty_pattern(cells: usize) -> GroupFaults {
        let mut g = GroupFaults::free(cells);
        g.pos[0] = FaultState::Sa1;
        g
    }

    #[test]
    fn content_hash_keys_by_pattern_and_context_only() {
        let cfg = GroupConfig::R2C2;
        let c = ctx(cfg);
        let free = GroupFaults::free(cfg.cells());
        let faulty = faulty_pattern(cfg.cells());
        assert_eq!(c.content_hash(&free), c.content_hash(&free.clone()));
        assert_ne!(c.content_hash(&free), c.content_hash(&faulty));
        // Same pattern bytes under a different config → different address
        // (the config/pipeline fingerprint is part of the identity). R2C2
        // and R1C4 both have 4 cells, so the pattern bytes are identical.
        let free4 = GroupFaults::free(4);
        assert_ne!(
            ctx(GroupConfig::R2C2).content_hash(&free4),
            ctx(GroupConfig::R1C4).content_hash(&free4)
        );
        let mut other_pipeline = PipelineOptions::default();
        other_pipeline.sparsest = !other_pipeline.sparsest;
        assert_ne!(
            c.content_hash(&free),
            StoreCtx::new(cfg, other_pipeline).content_hash(&free)
        );
    }

    #[test]
    fn blob_roundtrip_and_corruption_rejection() {
        let cfg = GroupConfig::R2C2;
        let c = ctx(cfg);
        let pattern = faulty_pattern(cfg.cells());
        let table = full_table(&cfg);
        let blob = encode_blob(&c, &pattern, &table);
        let back = decode_blob(&blob, &c, &pattern).expect("roundtrip");
        assert_eq!(back.len(), table.len());
        assert_eq!(back[0].decomposition, table[0].decomposition);
        // Every flipped byte is rejected before or during parsing.
        for i in 0..blob.len() {
            let mut bad = blob.clone();
            bad[i] ^= 0x20;
            assert!(decode_blob(&bad, &c, &pattern).is_err(), "flip at byte {i}");
        }
        // Truncation at any point is rejected.
        for cut in [0, 8, blob.len() / 2, blob.len() - 1] {
            assert!(decode_blob(&blob[..cut], &c, &pattern).is_err(), "cut at {cut}");
        }
        // A different requested pattern or context is rejected even with a
        // pristine blob (the full-equality half of the contract).
        assert!(decode_blob(&blob, &c, &GroupFaults::free(cfg.cells())).is_err());
        assert!(decode_blob(&blob, &ctx(GroupConfig::R1C4), &pattern).is_err());
    }

    #[test]
    fn blob_version_mismatch_rejected() {
        let cfg = GroupConfig::R2C2;
        let c = ctx(cfg);
        let pattern = faulty_pattern(cfg.cells());
        let blob = encode_blob(&c, &pattern, &full_table(&cfg));
        // Re-seal with a bumped version so only the version check fires.
        let payload = unseal(&blob).unwrap().to_vec();
        let mut bumped = payload.clone();
        bumped[4..8].copy_from_slice(&(STORE_VERSION + 1).to_le_bytes());
        let resealed = seal(bumped);
        let err = decode_blob(&resealed, &c, &pattern).unwrap_err().to_string();
        assert!(err.contains("version"), "got: {err}");
    }

    #[test]
    fn store_lookup_publish_and_counters() {
        let cfg = GroupConfig::R2C2;
        let c = ctx(cfg);
        let pattern = faulty_pattern(cfg.cells());
        let table = full_table(&cfg);
        let mut store = SolutionStore::new(1 << 20);
        store.begin_epoch();
        assert!(store.lookup_table(&c, &pattern).is_none());
        store.publish_table(&c, &pattern, &table);
        assert!(store.contains(&c, &pattern));
        let got = store.lookup_table(&c, &pattern).expect("published entry answers");
        assert_eq!(got.len(), table.len());
        // Idempotent republish: no double count, no byte growth.
        let bytes = store.resident_bytes();
        store.publish_table(&c, &pattern, &table);
        assert_eq!(store.resident_bytes(), bytes);
        let counters = store.counters();
        assert_eq!(counters.hits, 1);
        assert_eq!(counters.misses, 1);
        assert_eq!(counters.publishes, 1);
        assert_eq!(counters.hit_rate(), Some(0.5));
        // A short table (not full-range) is out of scope and ignored.
        let other = GroupFaults::free(cfg.cells());
        store.publish_table(&c, &other, &table[..3]);
        assert!(!store.contains(&c, &other));
    }

    #[test]
    fn eviction_is_lru_deterministic_and_budgeted() {
        let cfg = GroupConfig::R2C2;
        let c = ctx(cfg);
        let table = full_table(&cfg);
        let mut patterns = Vec::new();
        for i in 0..3 {
            let mut g = GroupFaults::free(cfg.cells());
            g.neg[i] = FaultState::Sa0;
            patterns.push(g);
        }
        let one = entry_bytes(&c);
        let mut store = SolutionStore::new(2 * one + one / 2);
        store.begin_epoch();
        store.publish_table(&c, &patterns[0], &table);
        store.begin_epoch();
        store.publish_table(&c, &patterns[1], &table);
        store.begin_epoch();
        // Touch [0] so [1] is the LRU victim.
        assert!(store.lookup_table(&c, &patterns[0]).is_some());
        store.publish_table(&c, &patterns[2], &table);
        store.begin_epoch();
        assert_eq!(store.counters().evictions, 1);
        assert!(store.contains(&c, &patterns[0]));
        assert!(!store.contains(&c, &patterns[1]), "LRU victim must be the untouched entry");
        assert!(store.contains(&c, &patterns[2]));
        assert!(store.resident_bytes() <= 2 * one + one / 2);
    }

    #[test]
    fn file_tier_shares_blobs_across_store_instances() {
        let cfg = GroupConfig::R2C2;
        let c = ctx(cfg);
        let pattern = faulty_pattern(cfg.cells());
        let table = full_table(&cfg);
        let dir = std::env::temp_dir().join(format!("rchg-store-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut a = SolutionStore::with_dir(&dir, 1 << 20).unwrap();
            a.publish_table(&c, &pattern, &table);
        }
        let hash = c.content_hash(&pattern);
        let path = dir.join(format!("{hash:016x}.rcps"));
        assert!(path.exists(), "publish must write the blob");
        // A brand-new store instance (fresh process, same dir) serves the
        // blob from disk after re-verification.
        let mut b = SolutionStore::with_dir(&dir, 1 << 20).unwrap();
        let got = b.lookup_table(&c, &pattern).expect("file-tier hit");
        assert_eq!(got.len(), table.len());
        assert_eq!(b.counters().file_hits, 1);
        // Corrupt the blob on disk: rejected cleanly, counted, and the
        // lookup degrades to a miss.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let mut d = SolutionStore::with_dir(&dir, 1 << 20).unwrap();
        assert!(d.lookup_table(&c, &pattern).is_none());
        assert_eq!(d.counters().rejected_blobs, 1);
        assert_eq!(d.counters().misses, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn handle_is_shared_and_cloneable() {
        let cfg = GroupConfig::R2C2;
        let c = ctx(cfg);
        let pattern = faulty_pattern(cfg.cells());
        let h = StoreHandle::in_memory();
        let h2 = h.clone();
        h.publish_table(&c, &pattern, &full_table(&cfg));
        assert!(h2.contains(&c, &pattern), "clones share one store");
        assert_eq!(h2.entries(), 1);
    }
}
