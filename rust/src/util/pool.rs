//! Scoped data-parallel helpers over std threads (no `rayon` offline).
//!
//! The coordinator compiles millions of independent weights; we split index
//! ranges across threads with `std::thread::scope`. Results are collected
//! per-chunk and stitched in order, so output is deterministic regardless
//! of thread count.

/// Number of worker threads to use: explicit override, else available
/// parallelism, else 1.
pub fn default_threads(requested: Option<usize>) -> usize {
    match requested {
        Some(n) if n > 0 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Split `n` items into at most `parts` contiguous ranges of near-equal size.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.max(1).min(n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Apply `f(range) -> Vec<T>` to each range on its own thread and
/// concatenate results in range order. `f` must produce exactly the items
/// for its range.
pub fn parallel_map_ranges<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> Vec<T> + Sync,
{
    let ranges = split_ranges(n, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().flat_map(&f).collect();
    }
    let mut slots: Vec<Option<Vec<T>>> = Vec::new();
    slots.resize_with(ranges.len(), || None);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for r in &ranges {
            let r = r.clone();
            let f = &f;
            handles.push(scope.spawn(move || f(r)));
        }
        for (slot, h) in slots.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("worker thread panicked"));
        }
    });
    slots.into_iter().flatten().flatten().collect()
}

/// Atomic-counter work-stealing map: `n` independent items are handed out
/// in chunks of `chunk` indices from a shared counter; idle workers steal
/// the next chunk as soon as they finish one. Results are stitched back in
/// index order, so output is byte-deterministic regardless of thread count
/// or scheduling — only wall-clock changes.
///
/// Prefer this over [`parallel_map_ranges`] when per-item cost is skewed
/// (e.g. the compiler's solve phase, where one pattern class may route to
/// ILP while thousands hit the fast path): static contiguous ranges leave
/// threads idle behind the slowest range, a shared counter does not.
pub fn parallel_work_steal<T, F>(n: usize, threads: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};

    if n == 0 {
        return Vec::new();
    }
    let chunk = chunk.max(1);
    let threads = threads.max(1).min(n.div_ceil(chunk));
    if threads == 1 {
        return (0..n).map(f).collect();
    }

    let counter = AtomicUsize::new(0);
    let mut chunks: Vec<(usize, Vec<T>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..threads {
            let counter = &counter;
            let f = &f;
            handles.push(scope.spawn(move || {
                let mut local: Vec<(usize, Vec<T>)> = Vec::new();
                loop {
                    let start = counter.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    local.push((start, (start..end).map(f).collect()));
                }
                local
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("worker thread panicked"))
            .collect()
    });
    chunks.sort_unstable_by_key(|(start, _)| *start);
    let mut out = Vec::with_capacity(n);
    for (_, mut c) in chunks {
        out.append(&mut c);
    }
    debug_assert_eq!(out.len(), n);
    out
}

/// Parallel fold: apply `f(range) -> A`, combine with `merge`.
pub fn parallel_fold<A, F, M>(n: usize, threads: usize, f: F, merge: M, init: A) -> A
where
    A: Send,
    F: Fn(std::ops::Range<usize>) -> A + Sync,
    M: Fn(A, A) -> A,
{
    let ranges = split_ranges(n, threads);
    if ranges.is_empty() {
        return init;
    }
    let mut partials: Vec<Option<A>> = Vec::new();
    partials.resize_with(ranges.len(), || None);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for r in &ranges {
            let r = r.clone();
            let f = &f;
            handles.push(scope.spawn(move || f(r)));
        }
        for (slot, h) in partials.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("worker thread panicked"));
        }
    });
    partials.into_iter().flatten().fold(init, merge)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_exactly() {
        for n in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 2, 3, 8, 200] {
                let rs = split_ranges(n, p);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    assert!(!r.is_empty());
                    next = r.end;
                }
            }
        }
    }

    #[test]
    fn map_matches_serial() {
        let out = parallel_map_ranges(1000, 4, |r| r.map(|i| i * i).collect::<Vec<_>>());
        let expect: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn map_single_thread() {
        let out = parallel_map_ranges(10, 1, |r| r.collect::<Vec<_>>());
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn fold_sums() {
        let s = parallel_fold(
            10_000,
            4,
            |r| r.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
            0u64,
        );
        assert_eq!(s, (10_000u64 * 9_999) / 2);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map_ranges(0, 4, |r| r.collect());
        assert!(out.is_empty());
    }

    #[test]
    fn work_steal_matches_serial_any_threads_and_chunks() {
        let expect: Vec<usize> = (0..1003).map(|i| i * 3 + 1).collect();
        for threads in [1usize, 2, 4, 8] {
            for chunk in [1usize, 7, 64, 5000] {
                let out = parallel_work_steal(1003, threads, chunk, |i| i * 3 + 1);
                assert_eq!(out, expect, "threads={threads} chunk={chunk}");
            }
        }
    }

    #[test]
    fn work_steal_empty_and_single() {
        let out: Vec<usize> = parallel_work_steal(0, 4, 64, |i| i);
        assert!(out.is_empty());
        let out = parallel_work_steal(1, 8, 64, |i| i + 9);
        assert_eq!(out, vec![9]);
    }

    #[test]
    fn work_steal_skewed_items_still_ordered() {
        // Make early items slow so later chunks finish first; order must
        // still be by index.
        let out = parallel_work_steal(64, 4, 4, |i| {
            if i < 8 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }
}
