//! Minimal JSON parser and writer.
//!
//! The offline build has no `serde`; artifact manifests, faultmap banks and
//! experiment reports need structured interchange with the python build
//! path, so we implement the subset of JSON we use: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so emission is
/// deterministic — useful for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs are not needed by our manifests;
                            // map lone surrogates to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c"), &Json::Null);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("name", Json::Str("mvm".into())),
            ("shape", Json::arr_usize(&[4, 8])),
            ("ok", Json::Bool(true)),
        ]);
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "quote\" slash\\ nl\n tab\t unicode→";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1 2"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn numbers_integer_format() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
        assert_eq!(Json::Num(-1e20).to_string(), "-100000000000000000000");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → wörld"));
    }
}
