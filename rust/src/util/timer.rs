//! Wall-clock timing + a tiny benchmark harness (no `criterion` offline).
//!
//! Used by the `rust/benches/*` targets (all `harness = false`) and by the
//! coordinator's per-stage breakdown counters (Fig 10b reproduction).

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulating stage clock: the coordinator charges wall time to named
/// stages (cond-check / FAWD / CVM) to reproduce the Fig 10b breakdown.
#[derive(Debug, Default, Clone)]
pub struct StageClock {
    entries: Vec<(String, f64)>,
}

impl StageClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, stage: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == stage) {
            e.1 += secs;
        } else {
            self.entries.push((stage.to_string(), secs));
        }
    }

    pub fn merge(&mut self, other: &StageClock) {
        for (n, s) in &other.entries {
            self.add(n, *s);
        }
    }

    pub fn get(&self, stage: &str) -> f64 {
        self.entries.iter().find(|(n, _)| n == stage).map(|(_, s)| *s).unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }
}

/// Benchmark statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} ±{:>9}  (n={})",
            self.name,
            fmt_dur(self.mean_s),
            fmt_dur(self.min_s),
            fmt_dur(self.max_s),
            fmt_dur(self.stddev_s),
            self.iters
        )
    }
}

/// Human-friendly duration formatting (ns → h scale).
pub fn fmt_dur(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else {
        format!("{:.2}h", s / 3600.0)
    }
}

/// Run `f` repeatedly: a few warmup iterations, then at least `min_iters`
/// timed iterations or until `min_time_s` elapsed, whichever is longer.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, min_time_s: f64, mut f: F) -> BenchStats {
    // Warmup.
    for _ in 0..2.min(min_iters) {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_time_s {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    stats_from(name, &samples)
}

pub fn stats_from(name: &str, samples: &[f64]) -> BenchStats {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min).min(mean),
        max_s: samples.iter().cloned().fold(0.0, f64::max).max(mean),
        stddev_s: var.sqrt(),
    }
}

/// Header line matching `BenchStats::report` columns.
pub fn bench_header() -> String {
    format!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "min", "max", "stddev"
    )
}

/// Black-box helper to stop the optimizer deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_clock_accumulates_and_merges() {
        let mut a = StageClock::new();
        a.add("fawd", 1.0);
        a.add("fawd", 0.5);
        a.add("cvm", 2.0);
        let mut b = StageClock::new();
        b.add("cvm", 1.0);
        b.merge(&a);
        assert_eq!(b.get("fawd"), 1.5);
        assert_eq!(b.get("cvm"), 3.0);
        assert!((b.total() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_enough_iters() {
        let mut count = 0usize;
        let st = bench("noop", 5, 0.0, || count += 1);
        assert!(st.iters >= 5);
        assert!(count >= st.iters);
        assert!(st.min_s <= st.mean_s && st.mean_s <= st.max_s);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(2e-9).ends_with("ns"));
        assert!(fmt_dur(2e-6).ends_with("µs"));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2.0).ends_with('s'));
        assert!(fmt_dur(200.0).ends_with('m'));
        assert!(fmt_dur(8000.0).ends_with('h'));
    }
}
