//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, timing/benchmarks, thread pooling, property
//! testing, deterministic failpoint injection, and binary tensor I/O.

pub mod cli;
pub mod failpoint;
pub mod fnv;
pub mod io;
pub mod json;
pub mod mem;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod timer;
