//! Minimal property-based testing harness.
//!
//! The offline environment lacks the `proptest` crate, so invariant tests
//! use this harness instead: run a closure over many seeded random cases;
//! on failure report the case seed so the exact input can be replayed by
//! constructing `Rng::new(seed)`. Used throughout `grouping`, `ilp`,
//! `decompose` and `coordinator` tests.

use super::prng::Rng;

/// Run `cases` random property checks. `f` receives a fresh deterministic
/// `Rng` per case and returns `Err(description)` on property violation.
/// Panics with the failing case seed.
pub fn prop_check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is fixed: test runs are reproducible across machines.
    let base = 0xC0FFEE_u64 ^ fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay with Rng::new({seed:#x})): {msg}"
            );
        }
    }
}

/// FNV-1a offset basis (the hash state before any byte is folded in).
pub const FNV1A_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a hash (for deriving per-property base seeds from names, and as
/// the checksum of the RCSS/RCSF file formats and RCWP wire frames).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_with(FNV1A_OFFSET, bytes)
}

/// Continue an FNV-1a hash from a prior state — `fnv1a_with(fnv1a(a), b)`
/// equals `fnv1a` of `a` and `b` concatenated, so multi-buffer inputs
/// (e.g. a frame header and its payload) hash without a joining copy.
pub fn fnv1a_with(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Assert helper returning `Err` instead of panicking, for use inside
/// `prop_check` closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Equality variant of [`prop_assert!`].
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        prop_check("sum-commutes", 200, |rng| {
            let a = rng.range_i64(-100, 100);
            let b = rng.range_i64(-100, 100);
            prop_assert!(a + b == b + a, "commutativity broke");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn panics_with_seed_on_failure() {
        prop_check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn fnv_distinct() {
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
        assert_ne!(fnv1a(b""), fnv1a(b"a"));
    }

    #[test]
    fn fnv_streams_across_buffers() {
        let whole = b"header-and-payload";
        let (a, b) = whole.split_at(7);
        assert_eq!(fnv1a_with(fnv1a(a), b), fnv1a(whole));
        assert_eq!(fnv1a_with(FNV1A_OFFSET, whole), fnv1a(whole));
        assert_eq!(fnv1a_with(fnv1a(whole), b""), fnv1a(whole));
    }

    #[test]
    fn rng_is_deterministic_per_case() {
        let mut first: Vec<i64> = Vec::new();
        prop_check("capture", 5, |rng| {
            first.push(rng.range_i64(0, 1_000_000));
            Ok(())
        });
        let mut second: Vec<i64> = Vec::new();
        prop_check("capture", 5, |rng| {
            second.push(rng.range_i64(0, 1_000_000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
