//! FNV-1a hashing for hot-path hash maps.
//!
//! The coordinator memoizes (fault-pattern, weight) pairs — small fixed
//! keys hashed millions of times. std's SipHash is DoS-resistant but ~4×
//! slower here; keys are internal (never attacker-controlled), so FNV-1a
//! is the right trade. §Perf: swapping the memo to `FnvMap` bought ~15%
//! end-to-end compile time on R2C2.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// FNV-1a streaming hasher.
#[derive(Default)]
pub struct FnvHasher(u64);

impl Hasher for FnvHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { 0xcbf2_9ce4_8422_2325 } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        self.0 = h;
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        let mut h = if self.0 == 0 { 0xcbf2_9ce4_8422_2325 } else { self.0 };
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01b3);
        // Extra avalanche for low-entropy keys (pattern bits cluster).
        h ^= h >> 29;
        self.0 = h;
    }

    #[inline]
    fn write_i64(&mut self, v: i64) {
        self.write_u64(v as u64);
    }
}

/// HashMap with FNV hashing.
pub type FnvMap<K, V> = HashMap<K, V, BuildHasherDefault<FnvHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FnvMap<(u64, i64), usize> = FnvMap::default();
        for i in 0..1000i64 {
            m.insert((i as u64 * 7, -i), i as usize);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000i64 {
            assert_eq!(m.get(&(i as u64 * 7, -i)), Some(&(i as usize)));
        }
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let bh: BuildHasherDefault<FnvHasher> = Default::default();
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            seen.insert(bh.hash_one((i, -(i as i64))));
        }
        assert!(seen.len() > 9_990);
    }
}
