//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement the
//! generators we need from scratch: SplitMix64 (for seeding) and
//! xoshiro256** (the workhorse). Both are public-domain algorithms
//! (Blackman & Vigna). Everything in the repo that samples — faultmaps,
//! synthetic datasets, weight init — goes through [`Rng`] with an explicit
//! seed so every experiment is exactly reproducible.

/// SplitMix64 step: used to expand a single `u64` seed into a full
/// xoshiro256** state. Also usable standalone as a cheap generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Not cryptographic; fast, high quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (e.g. per-thread, per-layer).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53 mantissa bits).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Standard normal via Box–Muller (pairs discarded; fine for our use).
    pub fn normal(&mut self) -> f64 {
        // Rejection-free polar-form-free Box–Muller.
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (reservoir when k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            all
        } else {
            // Floyd's algorithm.
            let mut chosen = std::collections::BTreeSet::new();
            for j in (n - k)..n {
                let t = self.index(j + 1);
                if !chosen.insert(t) {
                    chosen.insert(j);
                }
            }
            chosen.into_iter().collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn chance_statistics() {
        let mut r = Rng::new(11);
        let hits = (0..100_000).filter(|_| r.chance(0.0904)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.0904).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(19);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (10, 10), (1, 1)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(23);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn fork_independent() {
        let mut parent = Rng::new(31);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
