//! Binary tensor I/O shared with the python build path.
//!
//! Format (`.bin`, little-endian): the python side (`aot.py`) writes each
//! trained weight tensor as
//!
//! ```text
//! magic   u32 = 0x52434847  ("RCHG")
//! dtype   u32 (0 = f32, 1 = i32, 2 = u8)
//! ndim    u32
//! dims    u32 × ndim
//! data    dtype × prod(dims)
//! ```
//!
//! plus a JSON manifest listing tensors by name. Keeping the format trivial
//! means zero parsing dependencies on either side.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: u32 = 0x5243_4847;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I32 = 1,
    U8 = 2,
}

/// A raw tensor loaded from / destined for a `.bin` file.
#[derive(Clone, Debug)]
pub struct RawTensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub f32s: Vec<f32>,
    pub i32s: Vec<i32>,
    pub u8s: Vec<u8>,
}

impl RawTensor {
    pub fn from_f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        RawTensor { dtype: DType::F32, dims, f32s: data, i32s: vec![], u8s: vec![] }
    }
    pub fn from_i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        RawTensor { dtype: DType::I32, dims, f32s: vec![], i32s: data, u8s: vec![] }
    }
    pub fn from_u8(dims: Vec<usize>, data: Vec<u8>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        RawTensor { dtype: DType::U8, dims, f32s: vec![], i32s: vec![], u8s: data }
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::with_capacity(16 + self.len() * 4);
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&(self.dtype as u32).to_le_bytes());
        buf.extend_from_slice(&(self.dims.len() as u32).to_le_bytes());
        for &d in &self.dims {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match self.dtype {
            DType::F32 => {
                for v in &self.f32s {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            DType::I32 => {
                for v in &self.i32s {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            DType::U8 => buf.extend_from_slice(&self.u8s),
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<RawTensor> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes).with_context(|| format!("parse {}", path.display()))
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<RawTensor> {
        let mut pos = 0usize;
        let rd_u32 = |pos: &mut usize| -> Result<u32> {
            if *pos + 4 > bytes.len() {
                bail!("truncated header");
            }
            let v = u32::from_le_bytes(bytes[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let magic = rd_u32(&mut pos)?;
        if magic != MAGIC {
            bail!("bad magic {magic:#x}");
        }
        let dtype = match rd_u32(&mut pos)? {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U8,
            d => bail!("bad dtype {d}"),
        };
        let ndim = rd_u32(&mut pos)? as usize;
        if ndim > 8 {
            bail!("ndim {ndim} too large");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(rd_u32(&mut pos)? as usize);
        }
        let n: usize = dims.iter().product();
        let mut t = RawTensor { dtype, dims, f32s: vec![], i32s: vec![], u8s: vec![] };
        match dtype {
            DType::F32 => {
                if pos + n * 4 != bytes.len() {
                    bail!("payload size mismatch");
                }
                t.f32s = bytes[pos..]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
            }
            DType::I32 => {
                if pos + n * 4 != bytes.len() {
                    bail!("payload size mismatch");
                }
                t.i32s = bytes[pos..]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
            }
            DType::U8 => {
                if pos + n != bytes.len() {
                    bail!("payload size mismatch");
                }
                t.u8s = bytes[pos..].to_vec();
            }
        }
        Ok(t)
    }
}

/// Read a whole text file.
pub fn read_text(path: &Path) -> Result<String> {
    std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = RawTensor::from_f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-8, 7.25]);
        let dir = std::env::temp_dir().join("rchg_io_test");
        let p = dir.join("t.bin");
        t.save(&p).unwrap();
        let u = RawTensor::load(&p).unwrap();
        assert_eq!(u.dims, vec![2, 3]);
        assert_eq!(u.f32s, t.f32s);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_i32_u8() {
        let t = RawTensor::from_i32(vec![4], vec![-5, 0, 7, i32::MAX]);
        let bytes = {
            let dir = std::env::temp_dir().join("rchg_io_test2");
            let p = dir.join("t.bin");
            t.save(&p).unwrap();
            std::fs::read(&p).unwrap()
        };
        let u = RawTensor::from_bytes(&bytes).unwrap();
        assert_eq!(u.i32s, t.i32s);

        let b = RawTensor::from_u8(vec![3], vec![1, 2, 255]);
        let dir = std::env::temp_dir().join("rchg_io_test3");
        let p = dir.join("b.bin");
        b.save(&p).unwrap();
        assert_eq!(RawTensor::load(&p).unwrap().u8s, vec![1, 2, 255]);
    }

    #[test]
    fn rejects_corrupt() {
        assert!(RawTensor::from_bytes(&[]).is_err());
        assert!(RawTensor::from_bytes(&[1, 2, 3, 4, 5]).is_err());
        let t = RawTensor::from_f32(vec![2], vec![1.0, 2.0]);
        let dir = std::env::temp_dir().join("rchg_io_test4");
        let p = dir.join("t.bin");
        t.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.truncate(bytes.len() - 1);
        assert!(RawTensor::from_bytes(&bytes).is_err());
    }
}
