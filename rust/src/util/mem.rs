//! System-memory detection for the `auto` table-budget mode.
//!
//! The compile service can size its fleet-wide pattern-table budget from
//! the machine's physical RAM ([`crate::coordinator::TableBudget::Auto`]).
//! Detection is best-effort: on Linux it parses `MemTotal` from
//! `/proc/meminfo`; elsewhere (or on a malformed file) it reports `None`
//! and the caller falls back to a fixed default. No external crates — the
//! container has none to offer.

/// Physical memory of this machine in bytes, if detectable.
pub fn system_memory_bytes() -> Option<usize> {
    #[cfg(target_os = "linux")]
    {
        parse_meminfo(&std::fs::read_to_string("/proc/meminfo").ok()?)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Extract `MemTotal` (reported in kB) from `/proc/meminfo` content.
#[cfg_attr(not(target_os = "linux"), allow(dead_code))]
fn parse_meminfo(meminfo: &str) -> Option<usize> {
    let line = meminfo.lines().find(|l| l.starts_with("MemTotal:"))?;
    let kb: usize = line.split_whitespace().nth(1)?.parse().ok()?;
    kb.checked_mul(1024)
}

/// Parse a human byte-size string: a plain integer is bytes; `k`/`m`/`g`
/// or `kib`/`mib`/`gib` suffixes (case-insensitive) scale by 2^10/20/30.
/// Used by the CLI's `--table-budget` option.
pub fn parse_size_bytes(s: &str) -> Option<usize> {
    let s = s.trim().to_ascii_lowercase();
    let split = s.find(|c: char| !c.is_ascii_digit()).unwrap_or(s.len());
    let (digits, suffix) = s.split_at(split);
    let shift: u32 = match suffix {
        "" => 0,
        "k" | "kib" => 10,
        "m" | "mib" => 20,
        "g" | "gib" => 30,
        _ => return None,
    };
    let n: usize = digits.parse().ok()?;
    n.checked_shl(shift).filter(|&v| v >> shift == n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meminfo_parsing() {
        let sample = "MemTotal:       16384256 kB\nMemFree:         1234 kB\n";
        assert_eq!(parse_meminfo(sample), Some(16384256 * 1024));
        assert_eq!(parse_meminfo("garbage"), None);
        assert_eq!(parse_meminfo("MemTotal: not-a-number kB"), None);
    }

    #[test]
    fn size_strings() {
        assert_eq!(parse_size_bytes("1024"), Some(1024));
        assert_eq!(parse_size_bytes("64k"), Some(64 << 10));
        assert_eq!(parse_size_bytes("512MiB"), Some(512 << 20));
        assert_eq!(parse_size_bytes("2g"), Some(2 << 30));
        assert_eq!(parse_size_bytes("2GIB"), Some(2 << 30));
        assert_eq!(parse_size_bytes(""), None);
        assert_eq!(parse_size_bytes("12x"), None);
        assert_eq!(parse_size_bytes("auto"), None);
    }

    #[test]
    fn detection_is_sane_on_linux() {
        if let Some(bytes) = system_memory_bytes() {
            assert!(bytes > 1 << 20, "machines have more than a MiB of RAM");
        }
    }
}
