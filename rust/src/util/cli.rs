//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Each binary declares its options up front so `--help` is generated.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key-value options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub program: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

/// Declarative option spec used for help text and validation.
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// CLI definition for one (sub)command.
pub struct Cli {
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Cli { about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, default });
        self
    }

    pub fn help(&self, program: &str) -> String {
        let mut s = format!("{program} — {}\n\noptions:\n", self.about);
        for o in &self.opts {
            let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s.push_str("  --help               show this help\n");
        s
    }

    /// Parse `std::env::args()` (or any iterator). Exits on `--help` or on
    /// an unknown `--option`.
    pub fn parse(&self, argv: impl IntoIterator<Item = String>) -> Args {
        let mut it = argv.into_iter();
        let program = it.next().unwrap_or_else(|| "rchg".into());
        let mut args = Args { program: program.clone(), ..Default::default() };
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.flags.insert(o.name.to_string(), d.to_string());
            }
        }
        let known: Vec<&str> = self.opts.iter().map(|o| o.name).collect();
        let mut rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = std::mem::take(&mut rest[i]);
            if a == "--help" || a == "-h" {
                print!("{}", self.help(&program));
                std::process::exit(0);
            } else if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !known.contains(&key.as_str()) {
                    eprintln!("unknown option --{key}\n");
                    eprint!("{}", self.help(&program));
                    std::process::exit(2);
                }
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        // Value is the next token unless it looks like an option
                        // (then this is a boolean flag).
                        if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                            i += 1;
                            std::mem::take(&mut rest[i])
                        } else {
                            "true".to_string()
                        }
                    }
                };
                args.flags.insert(key, val);
            } else {
                args.positional.push(a);
            }
            i += 1;
        }
        args
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
    /// Thread-count option: `0` means auto-detect via
    /// [`crate::util::pool::default_threads`]; any positive value is taken
    /// literally. An unparsable value is an error (exit 2) rather than a
    /// silent fallback — auto-detecting on a typo would break protocols
    /// that rely on an explicit thread count (e.g. single-thread paper
    /// timing runs).
    pub fn get_threads(&self, key: &str) -> usize {
        let raw = self.get(key);
        match raw.and_then(|s| s.parse::<usize>().ok()) {
            Some(0) => crate::util::pool::default_threads(None),
            Some(n) => n,
            None => {
                eprintln!(
                    "invalid --{key} value {:?}: expected a number (0 = auto-detect)",
                    raw.unwrap_or("")
                );
                std::process::exit(2);
            }
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|s| s.split(',').map(|t| t.trim().to_string()).filter(|t| !t.is_empty()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(parts.iter().map(|s| s.to_string()))
            .collect()
    }

    fn cli() -> Cli {
        Cli::new("test")
            .opt("seed", "rng seed", Some("42"))
            .opt("config", "grouping config", Some("r2c2"))
            .opt("verbose", "chatty", None)
            .opt("rates", "fault rates", None)
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(argv(&[]));
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get_usize("seed", 0), 42);
    }

    #[test]
    fn key_value_both_styles() {
        let a = cli().parse(argv(&["--seed", "7", "--config=r1c4"]));
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("config"), Some("r1c4"));
    }

    #[test]
    fn boolean_flag() {
        let a = cli().parse(argv(&["--verbose", "--seed", "3"]));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_usize("seed", 0), 3);
    }

    #[test]
    fn positionals_collected() {
        let a = cli().parse(argv(&["run", "--seed", "1", "thing"]));
        assert_eq!(a.positional, vec!["run".to_string(), "thing".to_string()]);
    }

    #[test]
    fn list_option() {
        let a = cli().parse(argv(&["--rates", "0.01, 0.05,0.1"]));
        assert_eq!(a.get_list("rates"), vec!["0.01", "0.05", "0.1"]);
    }

    #[test]
    fn threads_zero_auto_detects() {
        let c = Cli::new("t").opt("threads", "threads (0 = auto)", Some("0"));
        let auto = c.parse(argv(&[]));
        assert!(auto.get_threads("threads") >= 1);
        let fixed = c.parse(argv(&["--threads", "3"]));
        assert_eq!(fixed.get_threads("threads"), 3);
        let explicit_auto = c.parse(argv(&["--threads", "0"]));
        assert!(explicit_auto.get_threads("threads") >= 1);
    }

    #[test]
    fn negative_number_value() {
        let a = cli().parse(argv(&["--rates", "-5"]));
        assert_eq!(a.get_f64("rates", 0.0), -5.0);
    }
}
