//! Deterministic failpoint injection for the fabric chaos suite.
//!
//! A *failpoint* is a named hook compiled into a fragile seam of the
//! fabric (frame writes, worker lifecycle, coordinator scheduling, store
//! I/O). In a normal build the hooks are zero-cost no-ops: the whole
//! registry is gated behind the `failpoints` cargo feature, and with the
//! feature off [`eval`] is an `#[inline(always)]` constant
//! [`Action::Nothing`] the optimizer deletes. With the feature on, a test
//! (or `rchg chaos`) arms points by name with a small spec string and the
//! site acts out the configured fault — deterministically, so a failing
//! chaos seed replays exactly.
//!
//! # Naming convention
//!
//! Failpoint names are `area.point`, where `area` is the subsystem that
//! hosts the hook (`net.frame`, `worker`, `server`, `store`) and `point`
//! names the seam. The full set this build compiles in:
//!
//! | name | site | effect when armed |
//! |---|---|---|
//! | `net.frame.stall` | [`write_frame`] | sleep before the write (timeout path) |
//! | `net.frame.truncate` | [`write_frame`] | send a prefix, then fail the write |
//! | `net.frame.corrupt` | [`write_frame`] | flip one byte of the wire frame |
//! | `net.frame.wrong_version` | [`write_frame`] | patch the version field (re-sealed) |
//! | `worker.crash_before_solve` | `run_worker` | drop the coordinator link pre-solve |
//! | `worker.crash_after_solve` | `run_worker` | solve, then drop the link unreported |
//! | `worker.drop_store_sync` | `sync_with_fleet` | skip the fleet-store sync |
//! | `server.drop_fragment` | `dispatch_one` | discard a valid fragment, drop worker |
//! | `server.requeue_race` | `drive_worker` | requeue an already-solved shard |
//! | `store.torn_blob_write` | `publish_table` | land a truncated blob, no rename |
//! | `store.blob_read_error` | `lookup_table` | fail the file-tier read |
//!
//! [`write_frame`]: crate::net::protocol::write_frame
//!
//! # Spec grammar
//!
//! A spec is `kind[=arg]` followed by `;`-separated modifiers:
//!
//! ```text
//! return                      fire the point's early-exit behavior
//! delay=MILLIS                sleep MILLIS before proceeding
//! truncate=N                  keep only the first N bytes
//! corrupt[=I]                 flip byte I (default: the last byte)
//! wrong_version               patch the protocol version field
//! off                         parse-checked no-op (placeholder)
//! ```
//!
//! Modifiers: `tag=T` fires only when the site's tag equals `T` (frame
//! sites tag with the [`FrameType`] debug name, e.g. `ShardResult`);
//! `skip=N` ignores the first N matching evaluations; `count=N` fires at
//! most N times (default: unlimited). Example:
//!
//! ```text
//! corrupt=17; tag=ShardResult; skip=1; count=2
//! ```
//!
//! flips byte 17 of the second and third `ShardResult` frames written by
//! this process, and nothing else.
//!
//! [`FrameType`]: crate::net::protocol::FrameType

#[cfg(feature = "failpoints")]
use anyhow::bail;
use anyhow::Result;
use std::time::Duration;

/// Whether this build compiled the failpoint registry in. `false` means
/// every [`eval`] call is a constant no-op.
pub const ENABLED: bool = cfg!(feature = "failpoints");

/// What an armed failpoint tells its site to do. Sites only honor the
/// variants that make sense for them (a store hook ignores
/// `WrongVersion`); everything else falls through to normal execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Not armed (or filtered out): proceed normally.
    Nothing,
    /// Take the site's early-exit path (crash, skip, drop, fail).
    Return,
    /// Sleep this long, then proceed normally.
    Delay(Duration),
    /// Keep only the first `n` bytes of whatever the site is writing.
    Truncate(usize),
    /// Flip byte `i` (site-defined wrap-around) of the site's buffer.
    Corrupt(usize),
    /// Patch the wire-protocol version field.
    WrongVersion,
}

#[cfg(feature = "failpoints")]
mod registry {
    use super::Action;
    use anyhow::{bail, Result};
    use std::collections::HashMap;
    use std::sync::{Mutex, MutexGuard, OnceLock};
    use std::time::Duration;

    pub(super) struct Entry {
        pub(super) raw: String,
        pub(super) action: Action,
        pub(super) tag: Option<String>,
        pub(super) skip_left: u64,
        pub(super) count_left: u64,
    }

    pub(super) fn table() -> MutexGuard<'static, HashMap<String, Entry>> {
        static TABLE: OnceLock<Mutex<HashMap<String, Entry>>> = OnceLock::new();
        // A panic while holding the lock poisons it; the registry is
        // plain data, so recover rather than cascade the panic.
        TABLE
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    /// Parse a spec string (see the module docs for the grammar).
    pub(super) fn parse(raw: &str) -> Result<Entry> {
        let mut action = None;
        let mut tag = None;
        let mut skip = 0u64;
        let mut count = u64::MAX;
        for (i, tok) in raw.split(';').map(str::trim).enumerate() {
            if tok.is_empty() {
                continue;
            }
            let (k, v) = match tok.split_once('=') {
                Some((k, v)) => (k.trim(), Some(v.trim())),
                None => (tok, None),
            };
            let int = |what: &str| -> Result<u64> {
                v.ok_or_else(|| anyhow::anyhow!("failpoint spec: {what} needs =N in {raw:?}"))?
                    .parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("failpoint spec: bad number for {what} in {raw:?}"))
            };
            if i == 0 {
                action = Some(match k {
                    "off" => Action::Nothing,
                    "return" => Action::Return,
                    "delay" => Action::Delay(Duration::from_millis(int("delay")?)),
                    "truncate" => Action::Truncate(int("truncate")? as usize),
                    "corrupt" => Action::Corrupt(match v {
                        Some(_) => int("corrupt")? as usize,
                        None => usize::MAX, // site wraps: flips the last byte
                    }),
                    "wrong_version" => Action::WrongVersion,
                    other => bail!("failpoint spec: unknown action {other:?} in {raw:?}"),
                });
                continue;
            }
            match k {
                "tag" => {
                    let t = v.ok_or_else(|| anyhow::anyhow!("failpoint spec: tag needs =NAME"))?;
                    tag = Some(t.to_string());
                }
                "skip" => skip = int("skip")?,
                "count" => count = int("count")?,
                other => bail!("failpoint spec: unknown modifier {other:?} in {raw:?}"),
            }
        }
        let action =
            action.ok_or_else(|| anyhow::anyhow!("failpoint spec: empty spec {raw:?}"))?;
        Ok(Entry { raw: raw.to_string(), action, tag, skip_left: skip, count_left: count })
    }
}

/// Arm failpoint `name` with `spec` (replacing any prior arming). Errors
/// on a malformed spec, and always errors in a build without the
/// `failpoints` feature — arming a point that cannot fire is a test bug.
#[cfg(feature = "failpoints")]
pub fn configure(name: &str, spec: &str) -> Result<()> {
    let entry = registry::parse(spec)?;
    registry::table().insert(name.to_string(), entry);
    Ok(())
}

/// Feature-off twin of [`configure`]: always an error, because arming a
/// point that cannot fire is a test bug.
#[cfg(not(feature = "failpoints"))]
pub fn configure(name: &str, spec: &str) -> Result<()> {
    let _ = (name, spec);
    anyhow::bail!("this binary was built without the `failpoints` feature")
}

/// Disarm failpoint `name` (no-op if it was not armed).
pub fn remove(name: &str) {
    #[cfg(feature = "failpoints")]
    registry::table().remove(name);
    #[cfg(not(feature = "failpoints"))]
    let _ = name;
}

/// Disarm every failpoint. Chaos scenarios call this between runs so a
/// leftover arming can never leak into the next scenario.
pub fn clear() {
    #[cfg(feature = "failpoints")]
    registry::table().clear();
}

/// The currently armed failpoints as `(name, spec)` pairs, sorted by
/// name (empty without the feature).
#[cfg(feature = "failpoints")]
pub fn list() -> Vec<(String, String)> {
    let mut v: Vec<(String, String)> =
        registry::table().iter().map(|(k, e)| (k.clone(), e.raw.clone())).collect();
    v.sort();
    v
}

/// Feature-off twin of [`list`]: nothing is ever armed.
#[cfg(not(feature = "failpoints"))]
pub fn list() -> Vec<(String, String)> {
    Vec::new()
}

/// Evaluate failpoint `name` at a site. `tag` is the site's dynamic
/// context (frame sites pass the frame-type name); an armed point with a
/// `tag=` filter fires only on a matching tag, and a non-matching
/// evaluation consumes neither `skip` nor `count`. Returns the armed
/// [`Action`] (consuming one `count`) or [`Action::Nothing`].
#[cfg(feature = "failpoints")]
pub fn eval(name: &str, tag: Option<&str>) -> Action {
    let mut table = registry::table();
    let Some(entry) = table.get_mut(name) else {
        return Action::Nothing;
    };
    if let Some(want) = &entry.tag {
        if tag != Some(want.as_str()) {
            return Action::Nothing;
        }
    }
    if entry.skip_left > 0 {
        entry.skip_left -= 1;
        return Action::Nothing;
    }
    if entry.count_left == 0 {
        return Action::Nothing;
    }
    entry.count_left -= 1;
    entry.action
}

/// No-op twin of [`eval`] for builds without the `failpoints` feature:
/// a constant the optimizer deletes along with the site's dead arms.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn eval(name: &str, tag: Option<&str>) -> Action {
    let _ = (name, tag);
    Action::Nothing
}

/// `bail!`-style helper: `Err` with a uniform message when the armed
/// action is [`Action::Return`], `Ok(())` otherwise. Sites whose crash
/// semantics are "return an error here" use this one-liner.
pub fn check(name: &str) -> Result<()> {
    #[cfg(feature = "failpoints")]
    if eval(name, None) == Action::Return {
        bail!("failpoint {name} triggered");
    }
    #[cfg(not(feature = "failpoints"))]
    let _ = name;
    Ok(())
}

/// `true` when the armed action for `name` is [`Action::Return`] —
/// for sites whose early exit is a silent skip rather than an error.
pub fn fires(name: &str) -> bool {
    eval(name, None) == Action::Return
}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// The registry is process-global; serialize the tests that mutate it.
    fn guard() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn unarmed_is_nothing() {
        let _g = guard();
        clear();
        assert_eq!(eval("no.such.point", None), Action::Nothing);
        assert!(check("no.such.point").is_ok());
        assert!(!fires("no.such.point"));
    }

    #[test]
    fn arm_fire_disarm() {
        let _g = guard();
        clear();
        configure("t.point", "return").unwrap();
        assert_eq!(list(), vec![("t.point".to_string(), "return".to_string())]);
        assert!(fires("t.point"));
        assert!(check("t.point").is_err());
        remove("t.point");
        assert_eq!(eval("t.point", None), Action::Nothing);
        assert!(list().is_empty());
    }

    #[test]
    fn skip_and_count_are_deterministic() {
        let _g = guard();
        clear();
        configure("t.count", "corrupt=3; skip=2; count=2").unwrap();
        assert_eq!(eval("t.count", None), Action::Nothing); // skip 1
        assert_eq!(eval("t.count", None), Action::Nothing); // skip 2
        assert_eq!(eval("t.count", None), Action::Corrupt(3)); // fire 1
        assert_eq!(eval("t.count", None), Action::Corrupt(3)); // fire 2
        assert_eq!(eval("t.count", None), Action::Nothing); // exhausted
        clear();
    }

    #[test]
    fn tag_filter_consumes_nothing() {
        let _g = guard();
        clear();
        configure("t.tag", "truncate=5; tag=ShardResult; count=1").unwrap();
        // Wrong / missing tags do not fire and do not burn the count.
        assert_eq!(eval("t.tag", Some("Hello")), Action::Nothing);
        assert_eq!(eval("t.tag", None), Action::Nothing);
        assert_eq!(eval("t.tag", Some("ShardResult")), Action::Truncate(5));
        assert_eq!(eval("t.tag", Some("ShardResult")), Action::Nothing);
        clear();
    }

    #[test]
    fn spec_parsing_accepts_the_grammar_and_rejects_junk() {
        let _g = guard();
        clear();
        configure("t.a", "off").unwrap();
        assert_eq!(eval("t.a", None), Action::Nothing);
        configure("t.b", "delay=40").unwrap();
        assert_eq!(eval("t.b", None), Action::Delay(Duration::from_millis(40)));
        configure("t.c", "corrupt").unwrap();
        assert_eq!(eval("t.c", None), Action::Corrupt(usize::MAX));
        configure("t.d", "wrong_version").unwrap();
        assert_eq!(eval("t.d", None), Action::WrongVersion);
        for bad in ["", "explode", "delay", "truncate=x", "return; bogus=1", "corrupt=-1"] {
            assert!(configure("t.bad", bad).is_err(), "spec {bad:?} should be rejected");
        }
        clear();
    }

    #[test]
    fn rearming_replaces_counters() {
        let _g = guard();
        clear();
        configure("t.rearm", "return; count=1").unwrap();
        assert!(fires("t.rearm"));
        assert!(!fires("t.rearm"));
        configure("t.rearm", "return; count=1").unwrap();
        assert!(fires("t.rearm"));
        clear();
    }
}
