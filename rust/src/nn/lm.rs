//! Language-model fault-injection perplexity evaluation (Table III).
//!
//! The OPT-like trunk's 2-D weight matrices are quantized + fault-compiled
//! and enter the graph as faulty floats; the tied LM head runs on the L1
//! Pallas crossbar kernel from faulty bit-planes. LayerNorm parameters,
//! biases and positional embeddings stay digital (the paper maps weight
//! matrices to IMC arrays; tiny 1-D parameters live in the digital logic).

use super::data::TokenStream;
use crate::coordinator::{CompileOptions, CompileStats, Method};
use crate::fault::bank::ChipFaults;
use crate::fault::FaultRates;
use crate::grouping::GroupConfig;
use crate::metrics;
use crate::quant::QuantizedMatrix;
use crate::runtime::{ArgValue, Executable, Runtime, WeightBank};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Result of one LM trial: perplexity per evaluation stream.
#[derive(Clone, Debug)]
pub struct LmEvalResult {
    pub cfg: GroupConfig,
    pub method: Method,
    pub ppl: Vec<(String, f64)>,
    pub compile: CompileStats,
}

pub struct LmEvaluator {
    pub cfg: GroupConfig,
    exe: Executable,
    bank: WeightBank,
    streams: Vec<TokenStream>,
    ctx: usize,
    batch: usize,
    vocab: usize,
    d_model: usize,
    pub max_windows: usize,
}

impl LmEvaluator {
    pub fn new(rt: &Runtime, art_dir: &Path, cfg: GroupConfig) -> Result<LmEvaluator> {
        let cfg_name = cfg.name().to_ascii_lowercase();
        let exe = rt.load(&format!("lm_{cfg_name}"))?;
        let bank = WeightBank::load(&art_dir.join("weights").join("lm"))?;
        let streams = TokenStream::load_all(art_dir)?;
        let meta = rt.meta();
        let lmc = meta.get("lm_config");
        let ctx = lmc.get("ctx").as_usize().unwrap_or(96);
        let vocab = lmc.get("vocab").as_usize().unwrap_or(256);
        let d_model = lmc.get("d_model").as_usize().unwrap_or(96);
        let batch = meta.get("lm_eval_batch").as_usize().unwrap_or(2);
        Ok(LmEvaluator {
            cfg,
            exe,
            bank,
            streams,
            ctx,
            batch,
            vocab,
            d_model,
            max_windows: 120,
        })
    }

    /// Which trunk parameters get quantized + fault-mapped (2-D matmul
    /// weights). Everything else stays digital/float.
    fn is_mapped(name: &str) -> bool {
        name.ends_with("qkv_w") || name.ends_with("o_w") || name.ends_with("fc1_w")
            || name.ends_with("fc2_w")
    }

    pub fn eval(
        &self,
        chip_seed: u64,
        rates: FaultRates,
        method: Method,
        threads: usize,
    ) -> Result<LmEvalResult> {
        let chip = ChipFaults::new(chip_seed, rates);
        let mut opts = CompileOptions::new(self.cfg, method);
        opts.threads = threads;
        let mut compile_total = CompileStats::default();
        // One chip-wide solve cache for the trunk and the LM head.
        let mut cc = super::ChipCompiler::new(&chip, &opts);

        // ---- trunk tensors ------------------------------------------------
        let mut trunk: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        for (ti, name) in self.bank.order.clone().iter().enumerate() {
            let t = self.bank.get(name)?;
            if Self::is_mapped(name) {
                let n = *t.dims.last().unwrap();
                let k = t.f32s.len() / n;
                let cm = cc.compile(&t.f32s, k, n, ti as u64);
                compile_total.merge_with_wall(&cm.stats);
                trunk.insert(name.clone(), cm.faulty_dequant(&self.cfg));
            } else {
                trunk.insert(name.clone(), t.f32s.clone());
            }
        }

        // ---- LM head: tied embedding transpose through the kernel --------
        let embed = self.bank.get("embed")?;
        let v = embed.dims[0];
        let d = embed.dims[1];
        debug_assert_eq!((v, d), (self.vocab, self.d_model));
        // head_w[d, vocab] = embed.T
        let mut head_w = vec![0f32; d * v];
        for vi in 0..v {
            for di in 0..d {
                head_w[di * v + vi] = embed.f32s[vi * d + di];
            }
        }
        let q = QuantizedMatrix::quantize_gptq_lite(&head_w, d, v, &self.cfg);
        let cm = cc.from_quantized(q, 5000);
        compile_total.merge_with_wall(&cm.stats);
        let planes = cm.planes(&self.cfg);
        let sigs: Vec<f32> = self.cfg.significances().iter().map(|&s| s as f32).collect();

        // ---- perplexity per stream ----------------------------------------
        let mut ppl = Vec::new();
        for stream in &self.streams {
            let windows = stream.windows(self.ctx, self.max_windows);
            if windows.is_empty() {
                bail!("stream {} too short", stream.name);
            }
            let mut total_nll = 0.0f64;
            let mut total_tok = 0usize;
            for chunk in windows.chunks(self.batch) {
                // Pad the final chunk by repeating the last window (its
                // duplicate NLL is not counted).
                let mut tokens: Vec<i32> = Vec::with_capacity(self.batch * self.ctx);
                for i in 0..self.batch {
                    let win = chunk.get(i).unwrap_or(chunk.last().unwrap());
                    tokens.extend_from_slice(&win[..self.ctx]);
                }
                let logits = self.run_batch(&tokens, &trunk, &planes, &sigs, &cm.q.scale)?;
                for (i, win) in chunk.iter().enumerate() {
                    let row = &logits[i * self.ctx * self.vocab..(i + 1) * self.ctx * self.vocab];
                    total_nll += metrics::sequence_nll(row, &win[1..], self.vocab);
                    total_tok += self.ctx;
                }
            }
            ppl.push((stream.name.clone(), metrics::perplexity(total_nll, total_tok)));
        }
        Ok(LmEvalResult { cfg: self.cfg, method, ppl, compile: compile_total })
    }

    fn run_batch(
        &self,
        tokens: &[i32],
        trunk: &BTreeMap<String, Vec<f32>>,
        planes: &super::packing::Planes,
        sigs: &[f32],
        head_scale: &[f32],
    ) -> Result<Vec<f32>> {
        let mut values: Vec<ArgValue> = Vec::with_capacity(self.exe.args.len());
        for spec in &self.exe.args {
            let v = match spec.name.as_str() {
                "tokens" => ArgValue::I32(tokens),
                "head_pos" => ArgValue::F32(&planes.pos),
                "head_neg" => ArgValue::F32(&planes.neg),
                "head_sigs" => ArgValue::F32(sigs),
                "head_scale" => ArgValue::F32(head_scale),
                name => match trunk.get(name) {
                    Some(buf) => ArgValue::F32(buf),
                    None => bail!("unexpected LM arg {name}"),
                },
            };
            values.push(v);
        }
        self.exe.run(&values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    #[test]
    fn lm_eval_fault_free_close_to_float_ppl() {
        let art = artifacts_dir();
        if !art.join("weights/lm/meta.json").exists() || !art.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&art).unwrap();
        let mut ev = LmEvaluator::new(&rt, &art, GroupConfig::R1C4).unwrap();
        ev.max_windows = 16; // keep the test fast
        let r = ev.eval(0, FaultRates::none(), Method::Complete, 1).unwrap();
        for (name, p) in &r.ppl {
            let float_p = ev.bank.meta.get("float_ppl").get(name).as_f64().unwrap_or(0.0);
            assert!(
                *p < float_p * 1.35 + 1.0,
                "stream {name}: quantized ppl {p} vs float {float_p}"
            );
        }
    }

    #[test]
    fn lm_faults_increase_ppl_and_mitigation_helps() {
        let art = artifacts_dir();
        if !art.join("weights/lm/meta.json").exists() || !art.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&art).unwrap();
        let mut ev = LmEvaluator::new(&rt, &art, GroupConfig::R1C4).unwrap();
        ev.max_windows = 10;
        let clean = ev.eval(0, FaultRates::none(), Method::Complete, 1).unwrap();
        let raw = ev.eval(3, FaultRates::paper_default(), Method::Unprotected, 1).unwrap();
        let fixed = ev.eval(3, FaultRates::paper_default(), Method::Complete, 1).unwrap();
        let avg = |r: &LmEvalResult| {
            r.ppl.iter().map(|(_, p)| p).sum::<f64>() / r.ppl.len() as f64
        };
        assert!(avg(&raw) > avg(&clean), "faults should hurt ppl");
        assert!(avg(&fixed) <= avg(&raw) * 1.05, "mitigation should help");
    }
}
