//! CNN fault-injection accuracy evaluation (Table I, Fig 8, Fig 9).
//!
//! For one (architecture, grouping config, chip, method):
//! 1. quantize every conv/fc weight tensor to the config's integer range;
//! 2. compile each tensor against the chip's fault maps (coordinator);
//! 3. reconstruct faulty floats for the conv trunk, pack faulty bit-planes
//!    for the FC head (which runs on the L1 Pallas kernel);
//! 4. execute the AOT graph over the test set via PJRT and score accuracy.

use super::data::CifarTest;
use crate::coordinator::{CompileOptions, CompileStats, Method};
use crate::fault::bank::ChipFaults;
use crate::fault::FaultRates;
use crate::grouping::GroupConfig;
use crate::metrics;
use crate::runtime::{ArgValue, Executable, Runtime, WeightBank};
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Result of one CNN fault-evaluation trial.
#[derive(Clone, Debug)]
pub struct CnnEvalResult {
    pub arch: String,
    pub cfg: GroupConfig,
    pub method: Method,
    pub accuracy: f64,
    /// Per-layer fault-induced ℓ1 error (dequantized domain) — Fig 8.
    pub layer_l1: Vec<(String, f64)>,
    /// Aggregated compile statistics across all tensors.
    pub compile: CompileStats,
}

/// Reusable evaluator: holds the compiled executable, weights and data.
pub struct CnnEvaluator {
    pub arch: String,
    pub cfg: GroupConfig,
    exe: Executable,
    bank: WeightBank,
    data: CifarTest,
    batch: usize,
    conv_layers: usize,
}

impl CnnEvaluator {
    pub fn new(rt: &Runtime, art_dir: &Path, arch: &str, cfg: GroupConfig) -> Result<CnnEvaluator> {
        let cfg_name = cfg.name().to_ascii_lowercase();
        let exe = rt.load(&format!("cnn_{arch}_{cfg_name}"))?;
        let bank = WeightBank::load(&art_dir.join("weights").join(arch))?;
        let data = CifarTest::load(art_dir)?;
        let batch = rt.meta().get("cnn_eval_batch").as_usize().unwrap_or(100);
        let conv_layers = bank.order.iter().filter(|n| n.ends_with("_w") && n.starts_with("conv")).count();
        if data.n % batch != 0 {
            bail!("test set size {} not divisible by eval batch {batch}", data.n);
        }
        Ok(CnnEvaluator { arch: arch.to_string(), cfg, exe, bank, data, batch, conv_layers })
    }

    /// Float-weight reference accuracy (no quantization, no faults) — used
    /// to sanity-check the PJRT path against the training-time accuracy.
    pub fn float_accuracy(&self) -> Result<f64> {
        // Pack "identity" planes representing the float fc via quantization
        // with zero faults and the true scale: easiest exact float path is
        // a fault-free, quantization-on evaluation at high precision —
        // callers use eval() with FaultRates::none() instead. Here we run
        // the quantized-but-fault-free path for R2C4 (9-bit, negligible
        // quantization).
        let r = self.eval(0, FaultRates::none(), Method::Complete, 1)?;
        Ok(r.accuracy)
    }

    /// One full trial.
    pub fn eval(
        &self,
        chip_seed: u64,
        rates: FaultRates,
        method: Method,
        threads: usize,
    ) -> Result<CnnEvalResult> {
        let chip = ChipFaults::new(chip_seed, rates);
        let mut opts = CompileOptions::new(self.cfg, method);
        opts.threads = threads;
        let mut compile_total = CompileStats::default();
        let mut layer_l1 = Vec::new();
        // All layers of one chip share a solve cache: (pattern, weight)
        // pairs recurring across layers are solved once per trial.
        let mut cc = super::ChipCompiler::new(&chip, &opts);

        // ---- compile conv tensors → faulty float weights -----------------
        let mut conv_args: Vec<Vec<f32>> = Vec::new();
        for li in 0..self.conv_layers {
            let wname = format!("conv{li}_w");
            let t = self.bank.get(&wname)?;
            let (dims, w) = (&t.dims, &t.f32s);
            // HWIO [3,3,cin,cout] → K = 3*3*cin rows, N = cout columns.
            let n = *dims.last().unwrap();
            let k = w.len() / n;
            let cm = cc.compile(w, k, n, li as u64);
            layer_l1.push((wname, cm.fault_l1(&self.cfg)));
            compile_total.merge_with_wall(&cm.stats);
            conv_args.push(cm.faulty_dequant(&self.cfg));
        }

        // ---- compile FC head → faulty bit-planes -------------------------
        let fc = self.bank.get("fc_w")?;
        let n = *fc.dims.last().unwrap();
        let k = fc.f32s.len() / n;
        let cm = cc.compile(&fc.f32s, k, n, 1000);
        layer_l1.push(("fc_w".to_string(), cm.fault_l1(&self.cfg)));
        compile_total.merge_with_wall(&cm.stats);
        let planes = cm.planes(&self.cfg);
        let sigs: Vec<f32> = self.cfg.significances().iter().map(|&s| s as f32).collect();
        let fc_b = &self.bank.get("fc_b")?.f32s;

        // ---- run the test set through PJRT --------------------------------
        let mut correct_logits: Vec<f32> = Vec::with_capacity(self.data.n * 10);
        let n_batches = self.data.n / self.batch;
        for b in 0..n_batches {
            let (bx, _) = self.data.batch(b, self.batch);
            let mut values: Vec<ArgValue> = Vec::with_capacity(self.exe.args.len());
            let mut conv_it = conv_args.iter();
            for spec in &self.exe.args {
                let v = match spec.name.as_str() {
                    "x" => ArgValue::F32(bx),
                    "fc_pos" => ArgValue::F32(&planes.pos),
                    "fc_neg" => ArgValue::F32(&planes.neg),
                    "fc_sigs" => ArgValue::F32(&sigs),
                    "fc_scale" => ArgValue::F32(&cm.q.scale),
                    "fc_b" => ArgValue::F32(fc_b),
                    name if name.ends_with("_w") => ArgValue::F32(
                        conv_it.next().ok_or_else(|| anyhow!("conv arg underflow"))?,
                    ),
                    name if name.ends_with("_b") => {
                        ArgValue::F32(&self.bank.get(name)?.f32s)
                    }
                    other => bail!("unexpected arg {other}"),
                };
                values.push(v);
            }
            let out = self.exe.run(&values)?;
            correct_logits.extend_from_slice(&out);
        }
        let accuracy = metrics::accuracy(&correct_logits, &self.data.y, 10);

        Ok(CnnEvalResult { arch: self.arch.clone(), cfg: self.cfg, method, accuracy, layer_l1, compile: compile_total })
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    #[test]
    fn cnn_eval_fault_free_matches_float_closely() {
        let art = artifacts_dir();
        if !art.join("manifest.json").exists() || !art.join("weights/cnn_s/meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&art).unwrap();
        let ev = CnnEvaluator::new(&rt, &art, "cnn_s", GroupConfig::R1C4).unwrap();
        let r = ev.eval(0, FaultRates::none(), Method::Complete, 1).unwrap();
        let float_acc = ev.bank.meta.get("float_acc").as_f64().unwrap_or(0.0);
        // 8-bit quantization should cost almost nothing.
        assert!(
            (r.accuracy - float_acc).abs() < 0.05,
            "quantized acc {} vs float {}",
            r.accuracy,
            float_acc
        );
        assert!(r.layer_l1.iter().all(|(_, e)| *e == 0.0));
    }

    #[test]
    fn cnn_eval_faults_hurt_and_mitigation_helps() {
        let art = artifacts_dir();
        if !art.join("manifest.json").exists() || !art.join("weights/cnn_s/meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&art).unwrap();
        let ev = CnnEvaluator::new(&rt, &art, "cnn_s", GroupConfig::R1C4).unwrap();
        let clean = ev.eval(0, FaultRates::none(), Method::Complete, 1).unwrap();
        let raw = ev.eval(1, FaultRates::paper_default(), Method::Unprotected, 1).unwrap();
        let fixed = ev.eval(1, FaultRates::paper_default(), Method::Complete, 1).unwrap();
        assert!(raw.accuracy <= clean.accuracy + 0.02);
        assert!(
            fixed.accuracy >= raw.accuracy - 0.02,
            "mitigated {} vs raw {}",
            fixed.accuracy,
            raw.accuracy
        );
        // Fault-induced ℓ1 must drop with mitigation.
        let l1 = |r: &CnnEvalResult| r.layer_l1.iter().map(|(_, e)| e).sum::<f64>();
        assert!(l1(&fixed) < l1(&raw));
    }
}
