//! Evaluation dataset loaders (written by `python/compile/train.py`).

use crate::util::io::RawTensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The synthetic-CIFAR test split.
pub struct CifarTest {
    /// `[N, 32, 32, 3]` flattened.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
}

impl CifarTest {
    pub fn load(art_dir: &Path) -> Result<CifarTest> {
        let xt = RawTensor::load(&art_dir.join("data/cifar_test_x.bin"))
            .context("cifar test images")?;
        let yt = RawTensor::load(&art_dir.join("data/cifar_test_y.bin"))
            .context("cifar test labels")?;
        if xt.dims.len() != 4 || xt.dims[1..] != [32, 32, 3] {
            bail!("unexpected cifar dims {:?}", xt.dims);
        }
        let n = xt.dims[0];
        if yt.dims != [n] {
            bail!("label count mismatch");
        }
        Ok(CifarTest { x: xt.f32s, y: yt.i32s, n })
    }

    /// Batch `b` of size `bs` (images flattened).
    pub fn batch(&self, b: usize, bs: usize) -> (&[f32], &[i32]) {
        let img = 32 * 32 * 3;
        let lo = b * bs;
        let hi = ((b + 1) * bs).min(self.n);
        (&self.x[lo * img..hi * img], &self.y[lo..hi])
    }
}

/// One LM evaluation token stream.
pub struct TokenStream {
    pub name: String,
    pub tokens: Vec<i32>,
}

impl TokenStream {
    pub fn load_all(art_dir: &Path) -> Result<Vec<TokenStream>> {
        let mut out = Vec::new();
        for name in ["jaxsrc", "npsrc", "pysrc"] {
            let path = art_dir.join(format!("data/lm_eval_{name}.bin"));
            let t = RawTensor::load(&path).with_context(|| format!("stream {name}"))?;
            out.push(TokenStream { name: name.to_string(), tokens: t.i32s });
        }
        Ok(out)
    }

    /// Non-overlapping windows of `ctx+1` tokens.
    pub fn windows(&self, ctx: usize, max_windows: usize) -> Vec<&[i32]> {
        let n_win = ((self.tokens.len().saturating_sub(1)) / ctx).min(max_windows);
        (0..n_win).map(|i| &self.tokens[i * ctx..i * ctx + ctx + 1]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    #[test]
    fn loads_when_built() {
        let art = artifacts_dir();
        if !art.join("data/cifar_test_x.bin").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = CifarTest::load(&art).unwrap();
        assert!(c.n >= 100);
        assert!(c.y.iter().all(|&y| (0..10).contains(&y)));
        let (bx, by) = c.batch(0, 50);
        assert_eq!(bx.len(), 50 * 32 * 32 * 3);
        assert_eq!(by.len(), 50);

        let streams = TokenStream::load_all(&art).unwrap();
        assert_eq!(streams.len(), 3);
        for s in &streams {
            assert!(s.tokens.iter().all(|&t| (0..256).contains(&t)));
            let w = s.windows(96, 10);
            assert!(w.len() <= 10);
            assert!(w.iter().all(|win| win.len() == 97));
        }
    }
}
