//! Model zoo + fault-injection evaluation drivers.
//!
//! Connects the pieces: load trained float weights (from `artifacts/`),
//! quantize to the grouping config's range, compile against a chip's fault
//! map via the coordinator, reconstruct the faulty weights, and execute
//! the AOT model graphs through the PJRT runtime to measure accuracy /
//! perplexity under SAFs.

pub mod cnn;
pub mod data;
pub mod lm;
pub mod packing;

use crate::coordinator::{CompileOptions, CompileSession, CompileStats};
use crate::fault::bank::ChipFaults;
use crate::fault::GroupFaults;
use crate::grouping::Decomposition;
use crate::quant::QuantizedMatrix;
use packing::Planes;

/// One weight matrix taken through the full quantize → fault-aware-compile
/// → reconstruct flow.
pub struct CompiledMatrix {
    pub q: QuantizedMatrix,
    pub decomps: Vec<Decomposition>,
    pub faults: Vec<GroupFaults>,
    pub stats: CompileStats,
}

impl CompiledMatrix {
    /// Quantize `[k, n]` float weights and compile them against the chip's
    /// fault map for tensor `tensor_id`.
    ///
    /// One-shot compat constructor: it runs a throwaway
    /// [`CompileSession`], so nothing is cached across calls. Compiling
    /// several matrices for one chip should go through [`ChipCompiler`]
    /// (or a [`CompileSession`] directly) instead.
    pub fn compile(
        w: &[f32],
        k: usize,
        n: usize,
        chip: &ChipFaults,
        tensor_id: u64,
        opts: &CompileOptions,
    ) -> CompiledMatrix {
        let q = QuantizedMatrix::quantize(w, k, n, &opts.cfg);
        Self::from_quantized(q, chip, tensor_id, opts)
    }

    pub fn from_quantized(
        q: QuantizedMatrix,
        chip: &ChipFaults,
        tensor_id: u64,
        opts: &CompileOptions,
    ) -> CompiledMatrix {
        let mut session = CompileSession::builder(opts.cfg).options(opts.clone()).chip(chip);
        Self::via_session(&mut session, q, tensor_id)
    }

    /// Quantized matrix through a caller's warm session.
    fn via_session(
        session: &mut CompileSession,
        q: QuantizedMatrix,
        tensor_id: u64,
    ) -> CompiledMatrix {
        let faults = session.sample_faults(tensor_id, q.w_int.len());
        let compiled = session.compile_with_faults(&q.w_int, &faults);
        CompiledMatrix { q, decomps: compiled.decomps, faults, stats: compiled.stats }
    }
}

/// Compiles a model's matrices for one chip — a thin adapter over a
/// chip-scoped [`CompileSession`], so (pattern, weight) pairs recurring
/// across layers are solved once per chip rather than once per tensor
/// (the session falls back to the legacy per-weight path when
/// `opts.dedupe` is off).
pub struct ChipCompiler {
    session: CompileSession,
}

impl ChipCompiler {
    pub fn new(chip: &ChipFaults, opts: &CompileOptions) -> ChipCompiler {
        ChipCompiler {
            session: CompileSession::builder(opts.cfg).options(opts.clone()).chip(chip),
        }
    }

    /// The underlying session (per-trial compile statistics, persistence).
    pub fn session(&self) -> &CompileSession {
        &self.session
    }

    /// Quantize and compile one `[k, n]` float matrix for tensor
    /// `tensor_id`, reusing the chip's solve cache.
    pub fn compile(&mut self, w: &[f32], k: usize, n: usize, tensor_id: u64) -> CompiledMatrix {
        let q = QuantizedMatrix::quantize(w, k, n, &self.session.options().cfg);
        self.from_quantized(q, tensor_id)
    }

    pub fn from_quantized(&mut self, q: QuantizedMatrix, tensor_id: u64) -> CompiledMatrix {
        CompiledMatrix::via_session(&mut self.session, q, tensor_id)
    }
}

impl CompiledMatrix {
    /// The faulty integer weights this compilation realizes on-chip.
    pub fn faulty_ints(&self, cfg: &crate::grouping::GroupConfig) -> Vec<i64> {
        self.decomps
            .iter()
            .zip(&self.faults)
            .map(|(d, f)| d.faulty_value(cfg, f))
            .collect()
    }

    /// Faulty dequantized float weights, `[k*n]` row-major.
    pub fn faulty_dequant(&self, cfg: &crate::grouping::GroupConfig) -> Vec<f32> {
        let ints: Vec<i64> = self
            .decomps
            .iter()
            .zip(&self.faults)
            .map(|(d, f)| d.faulty_value(cfg, f))
            .collect();
        self.q.dequant_values(&ints)
    }

    /// Ideal dequantized weights (quantization error only).
    pub fn ideal_dequant(&self) -> Vec<f32> {
        self.q.dequant()
    }

    /// Fault-induced ℓ1 error in the dequantized domain (the Fig 8 metric:
    /// fault error on top of quantization).
    pub fn fault_l1(&self, cfg: &crate::grouping::GroupConfig) -> f64 {
        let ideal = self.ideal_dequant();
        let faulty = self.faulty_dequant(cfg);
        ideal.iter().zip(&faulty).map(|(a, b)| (a - b).abs() as f64).sum()
    }

    /// Pack the (faulty) bit-planes for the L1 kernel.
    pub fn planes(&self, cfg: &crate::grouping::GroupConfig) -> Planes {
        Planes::pack(&self.decomps, Some(&self.faults), self.q.k, self.q.n, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;
    use crate::fault::FaultRates;
    use crate::grouping::GroupConfig;
    use crate::util::prng::Rng;

    #[test]
    fn compiled_matrix_flow() {
        let cfg = GroupConfig::R2C2;
        let mut rng = Rng::new(1);
        let (k, n) = (20, 6);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.5).collect();
        let chip = ChipFaults::new(42, FaultRates::paper_default());
        let opts = CompileOptions::new(cfg, Method::Complete);
        let cm = CompiledMatrix::compile(&w, k, n, &chip, 0, &opts);
        assert_eq!(cm.decomps.len(), k * n);
        // Faulty dequant differs from ideal only where residual errors exist.
        let ideal = cm.ideal_dequant();
        let faulty = cm.faulty_dequant(&cfg);
        let n_diff = ideal.iter().zip(&faulty).filter(|(a, b)| a != b).count();
        assert_eq!(n_diff, cm.stats.imperfect);
        // Planes reproduce exactly the faulty ints.
        let eff = cm.planes(&cfg).effective_weights(&cfg);
        let faulty_ints: Vec<i64> = cm
            .decomps
            .iter()
            .zip(&cm.faults)
            .map(|(d, f)| d.faulty_value(&cfg, f))
            .collect();
        assert_eq!(eff, faulty_ints);
    }

    #[test]
    fn chip_compiler_matches_standalone_and_reuses_cache() {
        let cfg = GroupConfig::R2C2;
        let mut rng = Rng::new(4);
        let (k, n) = (40, 8);
        let w0: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.5).collect();
        let w1: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.5).collect();
        let chip = ChipFaults::new(6, FaultRates::paper_default());
        let opts = CompileOptions::new(cfg, Method::Complete);

        let mut cc = ChipCompiler::new(&chip, &opts);
        let a0 = cc.compile(&w0, k, n, 0);
        let a1 = cc.compile(&w1, k, n, 1);
        let b0 = CompiledMatrix::compile(&w0, k, n, &chip, 0, &opts);
        let b1 = CompiledMatrix::compile(&w1, k, n, &chip, 1, &opts);
        assert_eq!(a0.decomps, b0.decomps);
        assert_eq!(a1.decomps, b1.decomps);
        assert_eq!(a0.faults, b0.faults);
        // Second matrix through the shared cache solves fewer fresh pairs
        // than the same matrix compiled standalone.
        assert!(a1.stats.unique_pairs <= b1.stats.unique_pairs);
    }

    #[test]
    fn fault_l1_zero_without_faults() {
        let cfg = GroupConfig::R1C4;
        let mut rng = Rng::new(2);
        let w: Vec<f32> = (0..50).map(|_| rng.normal_f32()).collect();
        let chip = ChipFaults::new(1, FaultRates::none());
        let opts = CompileOptions::new(cfg, Method::Complete);
        let cm = CompiledMatrix::compile(&w, 10, 5, &chip, 0, &opts);
        assert_eq!(cm.fault_l1(&cfg), 0.0);
    }
}
