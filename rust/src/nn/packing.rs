//! Bit-plane packing: turn per-weight decompositions into the `[C, K*r, N]`
//! plane tensors the L1 Pallas kernel consumes (layout contract documented
//! in `python/compile/kernels/crossbar_mvm.py` and mirrored by
//! `python/compile/packing.py`).

use crate::fault::GroupFaults;
use crate::grouping::{Decomposition, GroupConfig};

/// Packed plane pair for one weight matrix.
#[derive(Clone, Debug)]
pub struct Planes {
    /// `[C, K*r, N]` flattened row-major.
    pub pos: Vec<f32>,
    pub neg: Vec<f32>,
    pub slices: usize,
    pub phys_rows: usize,
    pub n: usize,
}

impl Planes {
    /// Pack decompositions (one per logical weight, row-major `[K, N]`)
    /// into plane tensors. When `faults` is given, the *faulty* cell values
    /// are packed (what the physical array actually reads); otherwise the
    /// programmed values.
    pub fn pack(
        decomps: &[Decomposition],
        faults: Option<&[GroupFaults]>,
        k: usize,
        n: usize,
        cfg: &GroupConfig,
    ) -> Planes {
        assert_eq!(decomps.len(), k * n);
        let (c, r) = (cfg.cols, cfg.rows);
        let kr = k * r;
        let mut pos = vec![0f32; c * kr * n];
        let mut neg = vec![0f32; c * kr * n];
        for ki in 0..k {
            for ni in 0..n {
                let idx = ki * n + ni;
                let d = &decomps[idx];
                let (pcells, ncells) = match faults {
                    Some(fs) => {
                        let f = &fs[idx];
                        (d.pos.inject(cfg, &f.pos).cells, d.neg.inject(cfg, &f.neg).cells)
                    }
                    None => (d.pos.cells.clone(), d.neg.cells.clone()),
                };
                for col in 0..c {
                    for row in 0..r {
                        let flat = col * kr * n + (ki * r + row) * n + ni;
                        pos[flat] = pcells[col * r + row] as f32;
                        neg[flat] = ncells[col * r + row] as f32;
                    }
                }
            }
        }
        Planes { pos, neg, slices: c, phys_rows: kr, n }
    }

    /// Collapse planes back into the effective logical integer weights —
    /// inverse of the kernel's shift-add (test/verification helper).
    pub fn effective_weights(&self, cfg: &GroupConfig) -> Vec<i64> {
        let r = cfg.rows;
        let k = self.phys_rows / r;
        let sigs = cfg.significances();
        let mut out = vec![0i64; k * self.n];
        for ki in 0..k {
            for ni in 0..self.n {
                let mut acc = 0i64;
                for (col, &sig) in sigs.iter().enumerate() {
                    for row in 0..r {
                        let flat = col * self.phys_rows * self.n + (ki * r + row) * self.n + ni;
                        acc += sig * (self.pos[flat] as i64 - self.neg[flat] as i64);
                    }
                }
                out[ki * self.n + ni] = acc;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultRates;
    use crate::util::prop::prop_check;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn pack_roundtrip_ideal() {
        prop_check("planes-roundtrip", 60, |rng| {
            let cfg = [GroupConfig::R1C4, GroupConfig::R2C2][rng.index(2)];
            let (k, n) = (1 + rng.index(6), 1 + rng.index(5));
            let ws: Vec<i64> = (0..k * n)
                .map(|_| rng.range_i64(-cfg.max_per_array(), cfg.max_per_array()))
                .collect();
            let decomps: Vec<Decomposition> =
                ws.iter().map(|&w| Decomposition::encode_ideal(w, &cfg)).collect();
            let planes = Planes::pack(&decomps, None, k, n, &cfg);
            prop_assert_eq!(planes.effective_weights(&cfg), ws);
            Ok(())
        });
    }

    #[test]
    fn faulty_pack_matches_faulty_value() {
        prop_check("planes-faulty", 60, |rng| {
            let cfg = GroupConfig::R2C2;
            let (k, n) = (3usize, 4usize);
            let ws: Vec<i64> = (0..k * n).map(|_| rng.range_i64(-30, 30)).collect();
            let decomps: Vec<Decomposition> =
                ws.iter().map(|&w| Decomposition::encode_ideal(w, &cfg)).collect();
            let faults: Vec<GroupFaults> = (0..k * n)
                .map(|_| {
                    GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.2, p_sa1: 0.2 }, rng)
                })
                .collect();
            let planes = Planes::pack(&decomps, Some(&faults), k, n, &cfg);
            let eff = planes.effective_weights(&cfg);
            for i in 0..k * n {
                prop_assert!(
                    eff[i] == decomps[i].faulty_value(&cfg, &faults[i]),
                    "packed faulty weight mismatch at {i}"
                );
            }
            Ok(())
        });
    }
}
