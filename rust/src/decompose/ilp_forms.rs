//! ILP formulations of FAWD (Eq. 12) and CVM (Eq. 13).
//!
//! Variables are created only for *free* cells (stuck cells contribute
//! constants — their programmed value is irrelevant and the ℓ1-minimal
//! choice is 0, exactly what Gurobi would return for the paper's full
//! formulation). Layout: positive-array free cells first, then negative;
//! CVM appends the auxiliary `t` variable last.

use crate::fault::{FaultState, GroupFaults};
use crate::grouping::{Bitmap, Decomposition, GroupConfig};
use crate::ilp::{IlpProblem, IlpStats};

/// Free-cell variable layout shared by both formulations.
struct VarMap {
    /// (array: 0 pos / 1 neg, cell idx, significance)
    vars: Vec<(u8, usize, i64)>,
    /// Constant component C = Σ stuck-at contributions (pos − neg).
    constant: i64,
}

fn build_varmap(cfg: &GroupConfig, faults: &GroupFaults) -> VarMap {
    let lm1 = cfg.levels as i64 - 1;
    let mut vars = Vec::new();
    let mut constant = 0i64;
    for (idx, f) in faults.pos.iter().enumerate() {
        match f {
            FaultState::Free => vars.push((0u8, idx, cfg.sig_of(idx))),
            FaultState::Sa0 => constant += cfg.sig_of(idx) * lm1,
            FaultState::Sa1 => {}
        }
    }
    for (idx, f) in faults.neg.iter().enumerate() {
        match f {
            FaultState::Free => vars.push((1u8, idx, cfg.sig_of(idx))),
            FaultState::Sa0 => constant -= cfg.sig_of(idx) * lm1,
            FaultState::Sa1 => {}
        }
    }
    VarMap { vars, constant }
}

fn decomposition_from(cfg: &GroupConfig, vm: &VarMap, values: &[i64]) -> Decomposition {
    let mut pos = Bitmap::zeros(cfg);
    let mut neg = Bitmap::zeros(cfg);
    for ((array, idx, _), &v) in vm.vars.iter().zip(values) {
        debug_assert!((0..cfg.levels as i64).contains(&v));
        if *array == 0 {
            pos.cells[*idx] = v as u8;
        } else {
            neg.cells[*idx] = v as u8;
        }
    }
    Decomposition { pos, neg }
}

/// ILP-FAWD (Eq. 12): minimize `‖X⁺‖₁ + ‖X⁻‖₁` subject to the faulty
/// decomposition reproducing `w` exactly. Returns `None` when no exact
/// (fault-masked) decomposition exists.
pub fn fawd_ilp(
    cfg: &GroupConfig,
    faults: &GroupFaults,
    w: i64,
    stats: &mut IlpStats,
) -> Option<Decomposition> {
    let vm = build_varmap(cfg, faults);
    let n = vm.vars.len();
    let mut p = IlpProblem::new(n);
    // Objective: Σ x (every stored level counts toward ℓ1 on both arrays).
    p.minimize(&vec![1i64; n]);
    for (j, _) in vm.vars.iter().enumerate() {
        p.bound(j, 0, cfg.levels as i64 - 1);
    }
    // d(X̃⁺) − d(X̃⁻) = w  ⇒  Σ ±sig·x = w − C.
    let coeffs: Vec<i64> = vm
        .vars
        .iter()
        .map(|(a, _, sig)| if *a == 0 { *sig } else { -*sig })
        .collect();
    p.add_eq(&coeffs, w - vm.constant);
    p.solve_with_stats(stats)
        .map(|s| decomposition_from(cfg, &vm, &s.values))
}

/// ILP-CVM (Eq. 13): minimize `t` with `−t ≤ w − w̃ ≤ t`. Always feasible.
/// Returns the decomposition and the achieved |error|.
pub fn cvm_ilp(
    cfg: &GroupConfig,
    faults: &GroupFaults,
    w: i64,
    stats: &mut IlpStats,
) -> (Decomposition, i64) {
    let vm = build_varmap(cfg, faults);
    let n = vm.vars.len();
    let mut p = IlpProblem::new(n + 1); // + t
    let mut obj = vec![0i64; n + 1];
    obj[n] = 1;
    p.minimize(&obj);
    for j in 0..n {
        p.bound(j, 0, cfg.levels as i64 - 1);
    }
    // t ∈ [0, 2·max]: |error| can never exceed the full span.
    p.bound(n, 0, 4 * cfg.max_per_array());
    // w − w̃ ≤ t  and  w − w̃ ≥ −t, where w̃ = Σ ±sig·x + C:
    //   −Σ ±sig·x − t ≤ C − w      (w − w̃ ≤ t)
    //    Σ ±sig·x − t ≤ w − C      (−t ≤ w − w̃)
    let mut up = vec![0i64; n + 1];
    let mut dn = vec![0i64; n + 1];
    for (j, (a, _, sig)) in vm.vars.iter().enumerate() {
        let s = if *a == 0 { *sig } else { -*sig };
        up[j] = -s;
        dn[j] = s;
    }
    up[n] = -1;
    dn[n] = -1;
    p.add_le(&up, vm.constant - w);
    p.add_le(&dn, w - vm.constant);
    let s = p
        .solve_with_stats(stats)
        .expect("CVM is always feasible (t unconstrained above)");
    let d = decomposition_from(cfg, &vm, &s.values[..n]);
    (d, s.objective)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::table::GroupTables;
    use crate::fault::FaultRates;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn fawd_ilp_exact_when_solvable() {
        prop_check("fawd-ilp", 120, |rng| {
            let cfg = GroupConfig::R2C2;
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.15, p_sa1: 0.15 }, rng);
            let w = rng.range_i64(-30, 30);
            let mut st = IlpStats::default();
            let tables = GroupTables::build(&cfg, &faults);
            match fawd_ilp(&cfg, &faults, w, &mut st) {
                Some(d) => {
                    prop_assert!(
                        d.faulty_value(&cfg, &faults) == w,
                        "ILP-FAWD inexact: {} != {w}",
                        d.faulty_value(&cfg, &faults)
                    );
                }
                None => {
                    prop_assert!(
                        tables.fawd(&cfg, &faults, w).is_none(),
                        "ILP says infeasible but table FAWD found a pair (w={w}, faults={faults:?})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fawd_ilp_l1_matches_table_l1() {
        prop_check("fawd-ilp-l1", 60, |rng| {
            let cfg = GroupConfig::R2C2;
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.1, p_sa1: 0.1 }, rng);
            let w = rng.range_i64(-30, 30);
            let mut st = IlpStats::default();
            let tables = GroupTables::build(&cfg, &faults);
            if let (Some(di), Some(dt)) = (fawd_ilp(&cfg, &faults, w, &mut st), tables.fawd(&cfg, &faults, w)) {
                prop_assert!(
                    di.l1() == dt.l1(),
                    "sparsest-solution mismatch: ilp {} vs table {}",
                    di.l1(),
                    dt.l1()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn cvm_ilp_matches_table_cvm_error() {
        prop_check("cvm-ilp", 80, |rng| {
            let cfg = [GroupConfig::R2C2, GroupConfig::new(1, 3, 4)][rng.index(2)];
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.25, p_sa1: 0.25 }, rng);
            let w = rng.range_i64(-cfg.max_per_array(), cfg.max_per_array());
            let mut st = IlpStats::default();
            let (d, err) = cvm_ilp(&cfg, &faults, w, &mut st);
            let tables = GroupTables::build(&cfg, &faults);
            let (_, table_err) = tables.cvm(&cfg, &faults, w);
            prop_assert!(
                err == table_err,
                "CVM error mismatch: ilp {err} vs table {table_err} (w={w}, faults={faults:?})"
            );
            prop_assert!(
                (w - d.faulty_value(&cfg, &faults)).abs() == err,
                "ILP-CVM witness error mismatch"
            );
            Ok(())
        });
    }

    #[test]
    fn cvm_zero_error_on_fault_free() {
        let cfg = GroupConfig::R1C4;
        let faults = GroupFaults::free(cfg.cells());
        let mut st = IlpStats::default();
        for w in [-255, -100, 0, 100, 255] {
            let (d, err) = cvm_ilp(&cfg, &faults, w, &mut st);
            assert_eq!(err, 0);
            assert_eq!(d.faulty_value(&cfg, &faults), w);
        }
    }
}
