//! Fault-aware weight decomposition algorithms.
//!
//! Four interchangeable solvers for the same problem — given a grouping
//! config, a per-group fault map and a target integer weight `w`, produce
//! bitmaps `(X⁺, X⁻)` whose *faulty* decode is as close to `w` as
//! possible:
//!
//! * [`table::GroupTables::fawd`] — table-based FAWD (exact, sparsest).
//! * [`ilp_forms::fawd_ilp`] — ILP FAWD (exact, sparsest; scales to
//!   configurations whose tables are intractable).
//! * [`table::GroupTables::cvm`] — direct closest-value matching.
//! * [`ilp_forms::cvm_ilp`] — ILP CVM (Eq. 13).
//!
//! plus the theorem-guided greedy ([`crate::grouping::FaultAnalysis::solve_exact`])
//! used by the complete pipeline for consecutive ranges.

pub mod ilp_forms;
pub mod table;

pub use ilp_forms::{cvm_ilp, fawd_ilp};
pub use table::{DiffTable, GroupTables, ValueTable};
