//! Table-based decomposition: achievable-value sets and the table-FAWD /
//! direct-CVM algorithms.
//!
//! For one array under a fault map, the set of achievable decoded values
//! `{d(f(X, F0, F1))}` is computed by dynamic programming over cells,
//! tracking the minimum ℓ1 cost per achievable value. This generalizes the
//! original Fault-Free "decomposition table": instead of enumerating
//! `(w⁺, w⁻)` pairs (quadratic), we intersect the two per-array sets along
//! the diagonal `w⁺ − w⁻ = w` (table-FAWD) or sweep for the closest pair
//! (direct CVM).
//!
//! Perf note (§Perf in EXPERIMENTS.md): values of one array live in the
//! dense range `[0, r(L^c−1)]`, so the DP runs over flat `Vec<u32>` cost
//! arrays with per-cell digit-choice tables for witness backtracking —
//! no maps, no per-state clones. This made CVM ~20× cheaper than the
//! original BTreeMap formulation and removed the R1C4 pipeline bottleneck.

use crate::fault::{FaultState, GroupFaults};
use crate::grouping::{Bitmap, Decomposition, GroupConfig};
use std::cell::RefCell;

const INF: u32 = u32::MAX;

thread_local! {
    /// Pooled DP scratch row for [`ValueTable::build`]. The builder runs
    /// once per fresh pattern; without pooling each build pays a transient
    /// `Vec<u32>` allocation for the rolling DP row. The row is taken at
    /// build start and returned at build end, so nested builds on one
    /// thread (there are none) would simply fall back to a fresh alloc.
    static DP_ROW: RefCell<Vec<u32>> = const { RefCell::new(Vec::new()) };
    /// Pooled scratch for [`GroupTables::diff_table`]: the packed-key
    /// merge buffer and the reversed dense negative-cost row.
    static DIFF_SCRATCH: RefCell<(Vec<u64>, Vec<u32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Achievable decoded values of one array: dense min-ℓ1-cost table plus
/// per-cell digit choices for witness reconstruction.
#[derive(Clone, Debug)]
pub struct ValueTable {
    /// `cost[v] == INF` ⇔ value `v` unachievable; else min ℓ1 cost.
    cost: Vec<u32>,
    /// `choice[cell * (maxv+1) + v]` = digit assigned to `cell` on the
    /// optimal path reaching value `v` after processing cells `0..=cell`.
    choice: Vec<u8>,
    /// Sorted achievable values (built once, reused by fawd/cvm sweeps).
    values: Vec<i64>,
    n_cells: usize,
}

impl ValueTable {
    /// DP over the cells of one array.
    pub fn build(cfg: &GroupConfig, faults: &[FaultState]) -> ValueTable {
        debug_assert_eq!(faults.len(), cfg.cells());
        let maxv = cfg.max_per_array() as usize;
        let n_cells = faults.len();
        let stride = maxv + 1;
        let mut cost = vec![INF; stride];
        cost[0] = 0;
        let mut choice = vec![0u8; n_cells * stride];
        let mut next = DP_ROW.with(|s| std::mem::take(&mut *s.borrow_mut()));
        next.clear();
        next.resize(stride, INF);

        for (idx, f) in faults.iter().enumerate() {
            let sig = cfg.sig_of(idx) as usize;
            next.fill(INF);
            let ch = &mut choice[idx * stride..(idx + 1) * stride];
            match f {
                FaultState::Free => {
                    for v in 0..stride {
                        let c = cost[v];
                        if c == INF {
                            continue;
                        }
                        // digit d contributes d·sig value and d cost.
                        let dmax = (cfg.levels - 1) as usize;
                        let mut val = v;
                        for d in 0..=dmax {
                            if val >= stride {
                                break;
                            }
                            let nc = c + d as u32;
                            if nc < next[val] {
                                next[val] = nc;
                                ch[val] = d as u8;
                            }
                            val += sig;
                        }
                    }
                }
                FaultState::Sa0 => {
                    let shift = sig * (cfg.levels - 1) as usize;
                    for v in 0..stride {
                        if cost[v] != INF && v + shift < stride + 1 {
                            let nv = v + shift;
                            if nv < stride && cost[v] < next[nv] {
                                next[nv] = cost[v];
                                ch[nv] = 0;
                            }
                        }
                    }
                }
                FaultState::Sa1 => {
                    for v in 0..stride {
                        if cost[v] != INF && cost[v] < next[v] {
                            next[v] = cost[v];
                            ch[v] = 0;
                        }
                    }
                }
            }
            std::mem::swap(&mut cost, &mut next);
        }
        // Return the rolling row to the pool (after the swaps, `next` may be
        // either original buffer — both are plain `Vec<u32>` of `stride`).
        DP_ROW.with(|s| *s.borrow_mut() = std::mem::take(&mut next));

        let values: Vec<i64> = (0..stride).filter(|&v| cost[v] != INF).map(|v| v as i64).collect();
        debug_assert!(!values.is_empty());
        ValueTable { cost, choice, values, n_cells }
    }

    #[inline]
    pub fn achievable(&self, v: i64) -> bool {
        v >= 0 && (v as usize) < self.cost.len() && self.cost[v as usize] != INF
    }

    #[inline]
    pub fn cost_of(&self, v: i64) -> Option<u32> {
        if self.achievable(v) {
            Some(self.cost[v as usize])
        } else {
            None
        }
    }

    pub fn min_value(&self) -> i64 {
        *self.values.first().unwrap()
    }
    pub fn max_value(&self) -> i64 {
        *self.values.last().unwrap()
    }
    /// Sorted achievable values.
    pub fn values(&self) -> &[i64] {
        &self.values
    }

    /// Achievable value closest to `v` (ties: smaller value).
    pub fn closest(&self, v: i64) -> i64 {
        match self.values.binary_search(&v) {
            Ok(_) => v,
            Err(i) => {
                if i == 0 {
                    self.values[0]
                } else if i == self.values.len() {
                    *self.values.last().unwrap()
                } else {
                    let (lo, hi) = (self.values[i - 1], self.values[i]);
                    if (v - lo) <= (hi - v) {
                        lo
                    } else {
                        hi
                    }
                }
            }
        }
    }

    /// Reconstruct the min-cost cell assignment reaching `v` (backtrack
    /// through the per-cell choice tables).
    pub fn witness(&self, v: i64, cfg: &GroupConfig) -> Bitmap {
        debug_assert!(self.achievable(v));
        let stride = self.cost.len();
        let mut cells = vec![0u8; self.n_cells];
        let mut val = v as usize;
        for idx in (0..self.n_cells).rev() {
            let d = self.choice[idx * stride + val];
            cells[idx] = d;
            // Remove this cell's read contribution to step back.
            let sig = cfg.sig_of(idx) as usize;
            // What did this cell *read*? Free: d·sig; SA0: (L−1)·sig was
            // applied as a shift with stored choice 0; SA1: 0. The choice
            // table stores the digit; for stuck cells the contribution is
            // implicit. We re-derive the contribution from the DP rules:
            // free → d·sig; Sa0 → (L−1)·sig; Sa1 → 0. The builder recorded
            // choice 0 for stuck cells, so we cannot distinguish here —
            // callers pass the faults via `witness_with_faults` when stuck
            // cells exist.
            val -= d as usize * sig;
        }
        debug_assert_eq!(val, 0, "witness backtrack must land on 0 for fault-free tables");
        Bitmap { cells }
    }

    /// Witness reconstruction in the presence of stuck cells.
    pub fn witness_with_faults(
        &self,
        v: i64,
        cfg: &GroupConfig,
        faults: &[FaultState],
    ) -> Bitmap {
        debug_assert!(self.achievable(v));
        let stride = self.cost.len();
        let mut cells = vec![0u8; self.n_cells];
        let mut val = v as usize;
        for idx in (0..self.n_cells).rev() {
            let sig = cfg.sig_of(idx) as usize;
            match faults[idx] {
                FaultState::Free => {
                    let d = self.choice[idx * stride + val];
                    cells[idx] = d;
                    val -= d as usize * sig;
                }
                FaultState::Sa0 => {
                    cells[idx] = 0; // stored value irrelevant; reads L−1
                    val -= sig * (cfg.levels - 1) as usize;
                }
                FaultState::Sa1 => {
                    cells[idx] = 0;
                }
            }
        }
        debug_assert_eq!(val, 0, "witness backtrack failed");
        Bitmap { cells }
    }
}

/// Dense best-pair table over every achievable difference `a − b` of one
/// group's two arrays — the batch-extraction companion of [`GroupTables`].
///
/// [`GroupTables::fawd`] and [`GroupTables::cvm`] sweep the positive
/// array's value list once *per target weight*. When a pattern class is
/// solved for its whole weight range (the compiler's `BatchTable` tier),
/// that per-target sweep is wasted work: one `O(|pos| · |neg|)` pass over
/// the cross product answers **every** target in `O(1)` afterwards. The
/// table records, per difference, the minimum combined ℓ1 cost and the
/// smallest positive-array value attaining it — exactly the pair the
/// per-target sweeps select (see `fawd_pair`/`cvm_pair` for the
/// tie-breaking proof sketch), so batch extraction is byte-identical to
/// the per-weight algorithms.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DiffTable {
    /// Smallest achievable difference (`pos.min − neg.max`).
    min_diff: i64,
    /// `cost[d − min_diff] == INF` ⇔ difference `d` unachievable; else the
    /// minimum combined ℓ1 cost over pairs on that diagonal.
    cost: Vec<u32>,
    /// Smallest positive-array value among the min-cost pairs of each
    /// difference (the value the per-target sweeps pick first).
    best_a: Vec<i64>,
    /// `prev[i]` = index of the nearest achievable difference ≤ `i`
    /// (`u32::MAX` when none).
    prev: Vec<u32>,
    /// `next[i]` = index of the nearest achievable difference ≥ `i`
    /// (`u32::MAX` when none).
    next: Vec<u32>,
}

const NO_DIFF: u32 = u32::MAX;

impl DiffTable {
    /// Smallest achievable difference.
    pub fn min_diff(&self) -> i64 {
        self.min_diff
    }

    /// Largest achievable difference.
    pub fn max_diff(&self) -> i64 {
        self.min_diff + self.cost.len() as i64 - 1
    }

    /// The pair `(a, b)` that [`GroupTables::fawd`] selects for target
    /// `w`: on the diagonal `a − b = w`, minimum combined ℓ1 cost, ties
    /// broken toward the smallest `a` (the sweep visits `a` ascending and
    /// only replaces on strictly lower cost). `None` when no exact pair
    /// exists.
    pub fn fawd_pair(&self, w: i64) -> Option<(i64, i64)> {
        if w < self.min_diff || w > self.max_diff() {
            return None;
        }
        let i = (w - self.min_diff) as usize;
        if self.cost[i] == INF {
            return None;
        }
        let a = self.best_a[i];
        Some((a, a - w))
    }

    /// The pair [`GroupTables::cvm`] selects for target `w`, plus its
    /// error `|w − (a − b)|`.
    ///
    /// Tie-breaking replicates the per-target sweep exactly. The sweep
    /// visits pairs in order of ascending `a`, and for one `a` considers
    /// the two neighbours of the ideal `b = a − w` — the `d > w` candidate
    /// before the `d ≤ w` one — keeping the first pair that minimizes
    /// `(err, cost)`. For the winning difference (nearest achievable to
    /// `w`) the sweep provably visits *every* pair on that diagonal, so
    /// the winner is: minimum error; then minimum cost; then smallest `a`;
    /// and at a full tie between the low and high neighbouring
    /// differences, the high side (visited first within an `a`).
    pub fn cvm_pair(&self, w: i64) -> (i64, i64, i64) {
        if let Some((a, b)) = self.fawd_pair(w) {
            return (a, b, 0);
        }
        let n = self.cost.len();
        let (lo, hi) = if w < self.min_diff {
            (None, Some(self.next[0] as usize))
        } else if w > self.max_diff() {
            (Some(self.prev[n - 1] as usize), None)
        } else {
            let i = (w - self.min_diff) as usize;
            let lo = if self.prev[i] == NO_DIFF { None } else { Some(self.prev[i] as usize) };
            let hi = if self.next[i] == NO_DIFF { None } else { Some(self.next[i] as usize) };
            (lo, hi)
        };
        let diff_of = |i: usize| self.min_diff + i as i64;
        let pick = |i: usize| {
            let d = diff_of(i);
            let a = self.best_a[i];
            (a, a - d, (w - d).abs())
        };
        match (lo, hi) {
            (Some(l), None) => pick(l),
            (None, Some(h)) => pick(h),
            (Some(l), Some(h)) => {
                let err_lo = w - diff_of(l);
                let err_hi = diff_of(h) - w;
                if err_lo < err_hi {
                    pick(l)
                } else if err_hi < err_lo {
                    pick(h)
                } else {
                    // Equal error: lower cost wins; then smaller `a`; at a
                    // full tie the high side is visited first per `a`.
                    let (cl, al) = (self.cost[l], self.best_a[l]);
                    let (ch, ah) = (self.cost[h], self.best_a[h]);
                    if ch < cl || (ch == cl && ah <= al) {
                        pick(h)
                    } else {
                        pick(l)
                    }
                }
            }
            (None, None) => unreachable!("a fault-map diff table is never empty"),
        }
    }
}

/// Per-group decomposition tables for both arrays.
#[derive(Clone, Debug)]
pub struct GroupTables {
    pub pos: ValueTable,
    pub neg: ValueTable,
}

impl GroupTables {
    pub fn build(cfg: &GroupConfig, faults: &GroupFaults) -> GroupTables {
        GroupTables {
            pos: ValueTable::build(cfg, &faults.pos),
            neg: ValueTable::build(cfg, &faults.neg),
        }
    }

    /// Table-based FAWD: a fault-masked pair on the diagonal `a − b = w`,
    /// minimizing combined ℓ1; `None` if no exact pair exists.
    pub fn fawd(
        &self,
        cfg: &GroupConfig,
        faults: &GroupFaults,
        w: i64,
    ) -> Option<Decomposition> {
        let mut best: Option<(u32, i64, i64)> = None;
        for &a in self.pos.values() {
            let b = a - w;
            if let Some(cb) = self.neg.cost_of(b) {
                let cost = self.pos.cost_of(a).unwrap() + cb;
                if best.map(|(c, _, _)| cost < c).unwrap_or(true) {
                    best = Some((cost, a, b));
                }
            }
        }
        best.map(|(_, a, b)| Decomposition {
            pos: self.pos.witness_with_faults(a, cfg, &faults.pos),
            neg: self.neg.witness_with_faults(b, cfg, &faults.neg),
        })
    }

    /// Direct CVM: the achievable pair `(a, b)` minimizing `|w − (a − b)|`
    /// (ties: min combined ℓ1). Always succeeds.
    pub fn cvm(&self, cfg: &GroupConfig, faults: &GroupFaults, w: i64) -> (Decomposition, i64) {
        let mut best_err = i64::MAX;
        let mut best_cost = u32::MAX;
        let mut best_pair = (0i64, 0i64);
        let nvals = self.neg.values();
        for &a in self.pos.values() {
            // Ideal b = a − w; its sorted neighbours bound the optimum.
            let target = a - w;
            let i = nvals.partition_point(|&b| b < target);
            for k in i.saturating_sub(1)..=(i.min(nvals.len() - 1)) {
                let b = nvals[k];
                let err = (w - (a - b)).abs();
                let cost = self.pos.cost_of(a).unwrap() + self.neg.cost_of(b).unwrap();
                if err < best_err || (err == best_err && cost < best_cost) {
                    best_err = err;
                    best_cost = cost;
                    best_pair = (a, b);
                }
            }
            if best_err == 0 && best_cost == 0 {
                break;
            }
        }
        let (a, b) = best_pair;
        (
            Decomposition {
                pos: self.pos.witness_with_faults(a, cfg, &faults.pos),
                neg: self.neg.witness_with_faults(b, cfg, &faults.neg),
            },
            best_err,
        )
    }

    /// Build the dense difference table for batch extraction: one
    /// `O(|pos| · |neg|)` pass that lets every subsequent FAWD/CVM query
    /// be answered in `O(1)` via [`GroupTables::fawd_from`] /
    /// [`GroupTables::cvm_from`].
    ///
    /// Vectorized formulation (byte-identical to
    /// [`GroupTables::diff_table_reference`], pinned by the
    /// `vectorized_diff_table_matches_reference` property test):
    ///
    /// * The negative array's costs are first scattered into a **dense
    ///   reversed row** over `[neg.min ..= neg.max]` — index `k` holds the
    ///   cost of `b = neg.max − k`, or the `UNREACHED` sentinel for holes.
    ///   This hoists the per-iteration `cost_of` bounds-check/lookup of the
    ///   scalar loop out of the cross product entirely.
    /// * For a fixed `a`, the differences `a − b` over that row are
    ///   **contiguous** in the table (`i = (a − pos.min) + k`), so the
    ///   inner pass is a branchless min-merge of two flat slices the
    ///   autovectorizer can chew on.
    /// * Each candidate is packed as `(cost << 32) | pos_index`. Costs are
    ///   bounded by `cells · (levels−1)` ≪ 2³⁰, so `u64::min` over packed
    ///   keys orders first by cost, then by the ascending position of `a`
    ///   in the sorted value list — exactly the strict-`<`-update /
    ///   smallest-`a` tie-break of the scalar loop. Sentinel entries carry
    ///   cost ≥ `UNREACHED` and therefore never beat a real pair.
    pub fn diff_table(&self) -> DiffTable {
        /// Cost sentinel for unachievable `b` values in the dense row —
        /// far above any real combined cost, far below `u32` overflow.
        const UNREACHED: u32 = 1 << 30;
        let pos_min = self.pos.min_value();
        let min_diff = pos_min - self.neg.max_value();
        let max_diff = self.pos.max_value() - self.neg.min_value();
        let n = (max_diff - min_diff + 1) as usize;
        let span = (self.neg.max_value() - self.neg.min_value() + 1) as usize;

        let (mut merged, mut neg_rev) =
            DIFF_SCRATCH.with(|s| std::mem::take(&mut *s.borrow_mut()));
        merged.clear();
        merged.resize(n, u64::MAX);
        neg_rev.clear();
        neg_rev.resize(span, UNREACHED);
        for &b in self.neg.values() {
            let cb = self.neg.cost_of(b).expect("neg value achievable");
            debug_assert!(cb < UNREACHED);
            neg_rev[(self.neg.max_value() - b) as usize] = cb;
        }

        for (ai, &a) in self.pos.values().iter().enumerate() {
            let ca = self.pos.cost_of(a).expect("pos value achievable");
            debug_assert!(ca < UNREACHED);
            let base = ((ca as u64) << 32) | ai as u64;
            // Diffs for this `a` occupy `[a − pos_min, a − pos_min + span)`.
            let window = &mut merged[(a - pos_min) as usize..(a - pos_min) as usize + span];
            for (slot, &cb) in window.iter_mut().zip(neg_rev.iter()) {
                let key = base + ((cb as u64) << 32);
                *slot = (*slot).min(key);
            }
        }

        let mut cost = vec![INF; n];
        let mut best_a = vec![0i64; n];
        for (i, &m) in merged.iter().enumerate() {
            let c = (m >> 32) as u32;
            if c < UNREACHED {
                cost[i] = c;
                best_a[i] = self.pos.values()[(m & 0xffff_ffff) as usize];
            }
        }
        DIFF_SCRATCH.with(|s| *s.borrow_mut() = (std::mem::take(&mut merged), std::mem::take(&mut neg_rev)));
        Self::finish_diff_table(min_diff, cost, best_a)
    }

    /// The original scalar cross-product construction, kept as the
    /// executable specification for [`GroupTables::diff_table`]: property
    /// tests pin the vectorized builder byte-identical to this, and
    /// `benches/bench_decompose.rs` measures the speedup against it.
    pub fn diff_table_reference(&self) -> DiffTable {
        let min_diff = self.pos.min_value() - self.neg.max_value();
        let max_diff = self.pos.max_value() - self.neg.min_value();
        let n = (max_diff - min_diff + 1) as usize;
        let mut cost = vec![INF; n];
        let mut best_a = vec![0i64; n];
        // `a` ascending with a strict `<` update keeps, per difference, the
        // minimum cost and the smallest `a` attaining it — the same pair
        // the per-target sweeps select.
        for &a in self.pos.values() {
            let ca = self.pos.cost_of(a).expect("pos value achievable");
            for &b in self.neg.values() {
                let i = (a - b - min_diff) as usize;
                let c = ca + self.neg.cost_of(b).expect("neg value achievable");
                if c < cost[i] {
                    cost[i] = c;
                    best_a[i] = a;
                }
            }
        }
        Self::finish_diff_table(min_diff, cost, best_a)
    }

    /// Shared tail of both builders: the prev/next nearest-achievable
    /// index fills.
    fn finish_diff_table(min_diff: i64, cost: Vec<u32>, best_a: Vec<i64>) -> DiffTable {
        let n = cost.len();
        let mut prev = vec![NO_DIFF; n];
        let mut last = NO_DIFF;
        for (i, p) in prev.iter_mut().enumerate() {
            if cost[i] != INF {
                last = i as u32;
            }
            *p = last;
        }
        let mut next = vec![NO_DIFF; n];
        let mut nxt = NO_DIFF;
        for (i, q) in next.iter_mut().enumerate().rev() {
            if cost[i] != INF {
                nxt = i as u32;
            }
            *q = nxt;
        }
        DiffTable { min_diff, cost, best_a, prev, next }
    }

    /// [`GroupTables::fawd`] answered from a prebuilt [`DiffTable`]:
    /// identical pair selection, `O(1)` per target plus witness
    /// backtracking.
    pub fn fawd_from(
        &self,
        dt: &DiffTable,
        cfg: &GroupConfig,
        faults: &GroupFaults,
        w: i64,
    ) -> Option<Decomposition> {
        let (a, b) = dt.fawd_pair(w)?;
        Some(Decomposition {
            pos: self.pos.witness_with_faults(a, cfg, &faults.pos),
            neg: self.neg.witness_with_faults(b, cfg, &faults.neg),
        })
    }

    /// [`GroupTables::cvm`] answered from a prebuilt [`DiffTable`]:
    /// identical pair selection, `O(1)` per target plus witness
    /// backtracking.
    pub fn cvm_from(
        &self,
        dt: &DiffTable,
        cfg: &GroupConfig,
        faults: &GroupFaults,
        w: i64,
    ) -> (Decomposition, i64) {
        let (a, b, err) = dt.cvm_pair(w);
        (
            Decomposition {
                pos: self.pos.witness_with_faults(a, cfg, &faults.pos),
                neg: self.neg.witness_with_faults(b, cfg, &faults.neg),
            },
            err,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultRates;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn fault_free_table_is_full_range() {
        let cfg = GroupConfig::R2C2;
        let t = ValueTable::build(&cfg, &vec![FaultState::Free; cfg.cells()]);
        assert_eq!(t.min_value(), 0);
        assert_eq!(t.max_value(), 30);
        assert_eq!(t.values().len(), 31); // consecutive
    }

    #[test]
    fn sa0_shifts_sa1_zeroes() {
        let cfg = GroupConfig::new(1, 2, 4); // sigs [4, 1]
        let t = ValueTable::build(&cfg, &[FaultState::Sa0, FaultState::Sa1]);
        // MSB always reads 3 → 12; LSB always 0 → exactly {12}.
        assert_eq!(t.values(), &[12]);
        assert_eq!(t.cost_of(12), Some(0)); // no programming cost
    }

    #[test]
    fn witness_cells_decode_to_value() {
        prop_check("table-witness", 200, |rng| {
            let cfg = GroupConfig::R2C2;
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.2, p_sa1: 0.2 }, rng);
            let t = ValueTable::build(&cfg, &faults.pos);
            for &v in t.values() {
                let bm = t.witness_with_faults(v, &cfg, &faults.pos);
                prop_assert!(
                    bm.decode_faulty(&cfg, &faults.pos) == v,
                    "witness decodes wrong for v={v}"
                );
                // Witness cost matches the DP's min cost.
                let l1: u32 = bm.cells.iter().map(|&c| c as u32).sum();
                prop_assert!(
                    l1 == t.cost_of(v).unwrap(),
                    "witness cost {l1} != dp cost {:?} at v={v}",
                    t.cost_of(v)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn table_matches_analysis_range() {
        prop_check("table-vs-analysis", 150, |rng| {
            let cfg = GroupConfig::R1C4;
            let faults = GroupFaults::sample(cfg.cells(), &FaultRates::paper_default(), rng);
            let tables = GroupTables::build(&cfg, &faults);
            let fa = crate::grouping::FaultAnalysis::new(&cfg, &faults);
            let (lo, hi) = fa.range();
            prop_assert!(
                tables.pos.max_value() - tables.neg.min_value() == hi,
                "hi mismatch"
            );
            prop_assert!(
                tables.pos.min_value() - tables.neg.max_value() == lo,
                "lo mismatch"
            );
            Ok(())
        });
    }

    #[test]
    fn fawd_zero_error_and_cvm_optimal() {
        prop_check("fawd-cvm", 250, |rng| {
            let cfg = [GroupConfig::R1C4, GroupConfig::R2C2][rng.index(2)];
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.15, p_sa1: 0.15 }, rng);
            let tables = GroupTables::build(&cfg, &faults);
            let w = rng.range_i64(-cfg.max_per_array(), cfg.max_per_array());
            // Brute-force optimum error over the cross product.
            let mut bf_err = i64::MAX;
            for &a in tables.pos.values() {
                for &b in tables.neg.values() {
                    bf_err = bf_err.min((w - (a - b)).abs());
                }
            }
            let (cvm_dec, cvm_err) = tables.cvm(&cfg, &faults, w);
            prop_assert!(cvm_err == bf_err, "cvm err {cvm_err} != brute force {bf_err}");
            prop_assert!(
                (w - cvm_dec.faulty_value(&cfg, &faults)).abs() == cvm_err,
                "cvm witness decodes to wrong error"
            );
            match tables.fawd(&cfg, &faults, w) {
                Some(d) => {
                    prop_assert!(
                        d.faulty_value(&cfg, &faults) == w,
                        "fawd result not exact"
                    );
                    prop_assert!(bf_err == 0, "fawd found pair but bf says impossible");
                }
                None => prop_assert!(bf_err > 0, "fawd missed an exact pair"),
            }
            Ok(())
        });
    }

    #[test]
    fn diff_table_matches_sweeps_for_every_target() {
        // The batch-extraction contract: for EVERY target in (and slightly
        // beyond) the representable range, the DiffTable-answered FAWD and
        // CVM must return byte-identical decompositions and errors to the
        // per-target sweeps — including tie-breaking.
        prop_check("diff-table-identity", 120, |rng| {
            let cfg = [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::new(2, 3, 4)]
                [rng.index(3)];
            let faults =
                GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.2, p_sa1: 0.2 }, rng);
            let tables = GroupTables::build(&cfg, &faults);
            let dt = tables.diff_table();
            prop_assert!(
                dt == tables.diff_table_reference(),
                "vectorized table differs from scalar reference (cfg {cfg}, faults {faults:?})"
            );
            let maxv = cfg.max_per_array();
            for w in -maxv - 2..=maxv + 2 {
                let sweep_fawd = tables.fawd(&cfg, &faults, w);
                let batch_fawd = tables.fawd_from(&dt, &cfg, &faults, w);
                prop_assert!(
                    sweep_fawd == batch_fawd,
                    "fawd diverged at w={w} (cfg {cfg}, faults {faults:?})"
                );
                let (sd, se) = tables.cvm(&cfg, &faults, w);
                let (bd, be) = tables.cvm_from(&dt, &cfg, &faults, w);
                prop_assert!(se == be, "cvm error diverged at w={w}: sweep {se} vs batch {be}");
                prop_assert!(
                    sd == bd,
                    "cvm decomposition diverged at w={w} (cfg {cfg}, faults {faults:?})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn vectorized_diff_table_matches_reference() {
        // The vectorized builder must be BYTE-identical to the scalar
        // reference — `cost`, `best_a`, `prev`, `next` and `min_diff` all
        // compared via `PartialEq` — over random, *independently* sampled
        // positive/negative ValueTables (sparser and more asymmetric than
        // anything one GroupFaults sample produces), across sparse and
        // dense fault regimes.
        prop_check("diff-table-vectorized-vs-reference", 300, |rng| {
            let cfg = [
                GroupConfig::R1C4,
                GroupConfig::R2C2,
                GroupConfig::new(2, 3, 4),
                GroupConfig::new(1, 2, 4),
            ][rng.index(4)];
            let rate = [0.0, 0.05, 0.3, 0.6][rng.index(4)];
            let fa = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: rate, p_sa1: rate },
                rng,
            );
            let fb = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: rate / 2.0, p_sa1: rate * 1.5 },
                rng,
            );
            // Independent pos/neg pair: pos from one sample, neg from the
            // other, exercising mismatched value-set shapes.
            let tables = GroupTables {
                pos: ValueTable::build(&cfg, &fa.pos),
                neg: ValueTable::build(&cfg, &fb.neg),
            };
            let vec_dt = tables.diff_table();
            let ref_dt = tables.diff_table_reference();
            prop_assert!(
                vec_dt == ref_dt,
                "vectorized != reference (cfg {cfg}, pos {:?}, neg {:?})",
                fa.pos,
                fb.neg
            );
            // And the full diff range answers identically through both.
            for w in vec_dt.min_diff() - 2..=vec_dt.max_diff() + 2 {
                prop_assert!(
                    vec_dt.fawd_pair(w) == ref_dt.fawd_pair(w),
                    "fawd_pair diverged at w={w}"
                );
                prop_assert!(
                    vec_dt.cvm_pair(w) == ref_dt.cvm_pair(w),
                    "cvm_pair diverged at w={w}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn diff_table_bounds_and_exactness() {
        let cfg = GroupConfig::R2C2;
        let free = GroupTables::build(&cfg, &GroupFaults::free(cfg.cells()));
        let dt = free.diff_table();
        // Fault-free R2C2: both arrays achieve 0..=30, so diffs span ±30
        // and every diff in between is achievable (FAWD always exact).
        assert_eq!(dt.min_diff(), -30);
        assert_eq!(dt.max_diff(), 30);
        for w in -30..=30 {
            let (a, b) = dt.fawd_pair(w).expect("fault-free diffs are dense");
            assert_eq!(a - b, w);
            let (ca, cb, err) = dt.cvm_pair(w);
            assert_eq!(err, 0);
            assert_eq!(ca - cb, w);
        }
        assert!(dt.fawd_pair(31).is_none());
        assert!(dt.fawd_pair(-31).is_none());
        // Out-of-range targets clamp to the nearest extreme.
        assert_eq!(dt.cvm_pair(35).2, 5);
        assert_eq!(dt.cvm_pair(-33).2, 3);
    }

    #[test]
    fn closest_picks_nearest() {
        let cfg = GroupConfig::new(1, 2, 4);
        let t = ValueTable::build(&cfg, &[FaultState::Free, FaultState::Sa1]);
        // Achievable: {0, 4, 8, 12}.
        assert_eq!(t.closest(5), 4);
        assert_eq!(t.closest(7), 8);
        assert_eq!(t.closest(-3), 0);
        assert_eq!(t.closest(100), 12);
        assert_eq!(t.closest(6), 4); // tie → smaller
    }

    #[test]
    fn dense_matches_bruteforce_enumeration() {
        // Cross-check the dense DP against direct enumeration of all cell
        // assignments (small configs).
        prop_check("dense-vs-enum", 100, |rng| {
            let cfg = GroupConfig::new(1 + rng.index(2), 1 + rng.index(2), 4);
            let faults = GroupFaults::sample(
                cfg.cells(),
                &FaultRates { p_sa0: 0.25, p_sa1: 0.25 },
                rng,
            );
            let t = ValueTable::build(&cfg, &faults.pos);
            // Enumerate.
            let n = cfg.cells();
            let mut best: std::collections::BTreeMap<i64, u32> = Default::default();
            let mut digits = vec![0u8; n];
            loop {
                let bm = Bitmap { cells: digits.clone() };
                let v = bm.decode_faulty(&cfg, &faults.pos);
                let c: u32 = digits
                    .iter()
                    .zip(&faults.pos)
                    .map(|(&d, f)| if f.is_fault() { 0 } else { d as u32 })
                    .sum();
                best.entry(v).and_modify(|e| *e = (*e).min(c)).or_insert(c);
                // odometer
                let mut k = 0;
                loop {
                    if k == n {
                        // done
                        let enum_vals: Vec<i64> = best.keys().cloned().collect();
                        prop_assert!(t.values() == enum_vals.as_slice(), "value sets differ");
                        for (&v, &c) in &best {
                            prop_assert!(
                                t.cost_of(v) == Some(c),
                                "cost mismatch at {v}: dp {:?} vs enum {c}",
                                t.cost_of(v)
                            );
                        }
                        return Ok(());
                    }
                    digits[k] += 1;
                    if digits[k] < cfg.levels {
                        break;
                    }
                    digits[k] = 0;
                    k += 1;
                }
            }
        });
    }
}
