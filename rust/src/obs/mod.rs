//! Unified observability: span/event tracing over the compile pipeline
//! and a process-global metrics registry, shared by the CLI, the fabric
//! coordinator, and workers.
//!
//! Two halves, one contract:
//!
//! * [`trace`] — RAII [`span`]s with explicit parent handles and
//!   structured fields, emitted as schema-stable JSON-lines
//!   (`rchg-trace-v1`) through a pluggable [`Sink`]. Zero-cost when no
//!   sink is installed.
//! * [`metrics`] — named counters/gauges/histograms behind one global
//!   [`metrics()`] handle, rendered as a stable text exposition and
//!   shipped over RCWP as `StatsPush` frames for `rchg submit --stats`
//!   and `rchg top`.
//!
//! The contract: observability never changes an output byte. Compiled
//! bitmaps and all RCSS/RCSF/RCPS persistence are byte-identical with
//! tracing on or off (pinned by `tests/obs.rs`), and timing values are
//! segregated by name ([`is_timing_key`]) so the deterministic skeleton
//! of a trace can be diffed across runs. See `docs/OBSERVABILITY.md`
//! for the span taxonomy, metric name inventory, and wire layout.

pub mod metrics;
pub mod trace;

pub use metrics::{
    bucket_index, metrics, Histogram, MetricValue, Metrics, MetricsSnapshot, HIST_BUCKETS,
};
pub use trace::{
    child_span, enabled, event, is_timing_key, set_sink, span, strip_timings, validate_trace,
    FileSink, MemorySink, Sink, Span, SpanHandle, TRACE_SCHEMA,
};
