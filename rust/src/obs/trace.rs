//! Span/event tracing core: RAII scopes over the compile pipeline,
//! emitted as JSON-lines through a pluggable [`Sink`].
//!
//! ## Model
//!
//! A [`Span`] is an RAII scope: [`span`] opens a root scope,
//! [`child_span`] parents one explicitly via a [`SpanHandle`] (no
//! thread-local ambient context — parenthood is always explicit, so a
//! span can be handed across helper functions without hidden state).
//! Structured `key=value` fields attach with [`Span::field_u64`] and
//! friends; the record is emitted when the span drops. [`event`] emits a
//! zero-duration record for point-in-time occurrences (a worker joining,
//! a blob rejected).
//!
//! ## Record stream
//!
//! One JSON object per line. The first record of every sink is the
//! header `{"ev":"trace","schema":"rchg-trace-v1","seq":0}`; every
//! subsequent record carries a monotonic per-process `seq` assigned at
//! emission, so `seq` equals the line index and a truncated trace is
//! detectable. Span records:
//!
//! ```text
//! {"dur_us":…,"ev":"span","fields":{…},"name":"compile.solve",
//!  "parent":1,"seq":3,"span":2,"start_us":…}
//! ```
//!
//! Spans close innermost-first, so a child's record precedes its
//! parent's — consumers rebuild the tree from `span`/`parent` ids, not
//! from line order.
//!
//! ## Timing segregation (the determinism contract)
//!
//! Exactly like `rchg bench`'s `is_timing_field` split, every wall-clock
//! leaf is named so tests can strip it: `start_us`, `dur_us`, `at_us`,
//! and any field key ending in `_us`, `_secs`, or `_per_sec` are timing
//! ([`is_timing_key`]); everything else — names, ids, counts, sequence
//! numbers — is the deterministic skeleton, byte-identical across two
//! runs of the same workload ([`strip_timings`] nulls the timing leaves
//! so tests can diff the rest). Tracing itself never feeds an output
//! byte: compiled bitmaps and RCSS/RCSF/RCPS bytes are identical with
//! tracing on or off.
//!
//! ## Cost when disabled
//!
//! With no sink installed, [`span`]/[`child_span`]/[`event`] are
//! `#[inline(always)]` early-returns behind one relaxed atomic load —
//! the runtime analogue of `util::failpoint`'s feature-gated no-ops
//! (tracing is a deploy-time switch, so it cannot be a compile-time
//! feature). No allocation, no lock, no clock read happens on the
//! disabled path; the `obs_overhead` bench criterion pins it.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

/// Schema tag stamped into every trace header record; bump on any
/// record-shape change.
pub const TRACE_SCHEMA: &str = "rchg-trace-v1";

/// Where trace records go, one JSON object per call. Implementations are
/// best-effort: a failing sink must not fail the traced workload.
pub trait Sink: Send {
    fn write_line(&mut self, line: &str);
    fn flush(&mut self) {}
}

/// JSON-lines file sink (`rchg compile --trace-out`). Write errors are
/// reported to stderr once and the sink goes quiet — tracing is
/// observability, never a reason to fail a compile.
pub struct FileSink {
    w: BufWriter<File>,
    failed: bool,
}

impl FileSink {
    pub fn create(path: &Path) -> std::io::Result<FileSink> {
        Ok(FileSink { w: BufWriter::new(File::create(path)?), failed: false })
    }
}

impl Sink for FileSink {
    fn write_line(&mut self, line: &str) {
        if self.failed {
            return;
        }
        if let Err(e) = writeln!(self.w, "{line}") {
            self.failed = true;
            eprintln!("obs: trace sink write failed ({e}); tracing disabled for this sink");
        }
    }

    fn flush(&mut self) {
        let _ = self.w.flush();
    }
}

/// Bounded in-memory ring-buffer sink for tests: install a clone via
/// [`set_sink`], keep the original to read the captured lines back.
#[derive(Clone)]
pub struct MemorySink {
    buf: Arc<Mutex<VecDeque<String>>>,
    cap: usize,
}

impl MemorySink {
    /// Ring buffer holding at most `cap` lines (oldest dropped first).
    pub fn new(cap: usize) -> MemorySink {
        MemorySink { buf: Arc::new(Mutex::new(VecDeque::new())), cap: cap.max(1) }
    }

    /// Captured lines, oldest first.
    pub fn lines(&self) -> Vec<String> {
        self.buf.lock().unwrap_or_else(PoisonError::into_inner).iter().cloned().collect()
    }
}

impl Sink for MemorySink {
    fn write_line(&mut self, line: &str) {
        let mut buf = self.buf.lock().unwrap_or_else(PoisonError::into_inner);
        if buf.len() == self.cap {
            buf.pop_front();
        }
        buf.push_back(line.to_string());
    }
}

struct SinkState {
    sink: Box<dyn Sink>,
    /// `start_us`/`at_us` origin: sink installation time.
    epoch: Instant,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Next record sequence number (== records emitted so far).
static SEQ: AtomicU64 = AtomicU64::new(0);
/// Last span id handed out (0 is reserved for "no parent").
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(0);
static SINK: Mutex<Option<SinkState>> = Mutex::new(None);

/// Install (or with `None`, remove) the process-global trace sink and
/// write the schema header record. The sequence and span-id counters
/// reset to zero on every call, so two traced runs in one process
/// produce comparable records. Returns the number of records emitted to
/// the *previous* sink (after its final flush).
pub fn set_sink(sink: Option<Box<dyn Sink>>) -> u64 {
    let mut guard = SINK.lock().unwrap_or_else(PoisonError::into_inner);
    if let Some(old) = guard.as_mut() {
        old.sink.flush();
    }
    let written = SEQ.load(Ordering::SeqCst);
    SEQ.store(0, Ordering::SeqCst);
    NEXT_SPAN_ID.store(0, Ordering::SeqCst);
    match sink {
        Some(s) => {
            let mut st = SinkState { sink: s, epoch: Instant::now() };
            let header = Json::obj(vec![
                ("ev", Json::Str("trace".into())),
                ("schema", Json::Str(TRACE_SCHEMA.into())),
                ("seq", Json::Num(SEQ.fetch_add(1, Ordering::SeqCst) as f64)),
            ]);
            st.sink.write_line(&header.to_string());
            *guard = Some(st);
            ENABLED.store(true, Ordering::SeqCst);
        }
        None => {
            *guard = None;
            ENABLED.store(false, Ordering::SeqCst);
        }
    }
    written
}

/// Is a sink installed? One relaxed load — the whole cost of every
/// disabled-path trace call.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Opaque reference to a live span, used to parent children explicitly.
/// `SpanHandle::NONE` (id 0) means "root".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanHandle(u64);

impl SpanHandle {
    pub const NONE: SpanHandle = SpanHandle(0);

    pub fn id(&self) -> u64 {
        self.0
    }
}

/// An RAII trace scope; emits one `"ev":"span"` record on drop. Dead
/// (tracing-disabled) spans carry no state and cost nothing beyond the
/// enabled check.
pub struct Span {
    live: bool,
    id: u64,
    parent: u64,
    name: &'static str,
    fields: Vec<(&'static str, Json)>,
    start: Option<Instant>,
}

/// Open a root span.
#[inline(always)]
pub fn span(name: &'static str) -> Span {
    child_span(name, SpanHandle::NONE)
}

/// Open a span parented under `parent` (see [`Span::handle`]).
#[inline(always)]
pub fn child_span(name: &'static str, parent: SpanHandle) -> Span {
    if !enabled() {
        return Span { live: false, id: 0, parent: 0, name, fields: Vec::new(), start: None };
    }
    Span {
        live: true,
        id: NEXT_SPAN_ID.fetch_add(1, Ordering::SeqCst) + 1,
        parent: parent.0,
        name,
        fields: Vec::new(),
        start: Some(Instant::now()),
    }
}

impl Span {
    /// Handle for parenting children under this span. A dead span hands
    /// out `SpanHandle::NONE`, so children of a disabled span are
    /// (dead) roots — consistent either way.
    pub fn handle(&self) -> SpanHandle {
        SpanHandle(self.id)
    }

    #[inline(always)]
    pub fn field_u64(&mut self, key: &'static str, v: u64) {
        if self.live {
            self.fields.push((key, Json::Num(v as f64)));
        }
    }

    #[inline(always)]
    pub fn field_i64(&mut self, key: &'static str, v: i64) {
        if self.live {
            self.fields.push((key, Json::Num(v as f64)));
        }
    }

    #[inline(always)]
    pub fn field_f64(&mut self, key: &'static str, v: f64) {
        if self.live {
            self.fields.push((key, Json::Num(v)));
        }
    }

    #[inline(always)]
    pub fn field_str(&mut self, key: &'static str, v: &str) {
        if self.live {
            self.fields.push((key, Json::Str(v.to_string())));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let start = self.start.take().expect("live span has a start instant");
        let dur_us = start.elapsed().as_micros() as u64;
        let fields = std::mem::take(&mut self.fields);
        emit_record("span", self.name, self.parent, fields, |rec, epoch| {
            let start_us = start.duration_since(epoch).as_micros() as u64;
            rec.push(("span", Json::Num(self.id as f64)));
            rec.push(("start_us", Json::Num(start_us as f64)));
            rec.push(("dur_us", Json::Num(dur_us as f64)));
        });
    }
}

/// Emit a zero-duration `"ev":"event"` record (point-in-time log line —
/// the queryable-event-log half of the trace stream).
#[inline(always)]
pub fn event(name: &'static str, parent: SpanHandle, fields: Vec<(&'static str, Json)>) {
    if !enabled() {
        return;
    }
    emit_record("event", name, parent.0, fields, |rec, epoch| {
        rec.push(("at_us", Json::Num(epoch.elapsed().as_micros() as f64)));
    });
}

/// Shared emission tail: take the sink lock, assign the record's `seq`,
/// assemble the JSON object (common keys + the caller's extras), write
/// one line. The sink may have been removed since the span opened — then
/// the record is silently dropped (the run is no longer being traced).
fn emit_record(
    ev: &str,
    name: &str,
    parent: u64,
    fields: Vec<(&'static str, Json)>,
    extra: impl FnOnce(&mut Vec<(&'static str, Json)>, Instant),
) {
    let mut guard = SINK.lock().unwrap_or_else(PoisonError::into_inner);
    let Some(st) = guard.as_mut() else { return };
    let seq = SEQ.fetch_add(1, Ordering::SeqCst);
    let fields_obj =
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect());
    let mut rec: Vec<(&'static str, Json)> = vec![
        ("ev", Json::Str(ev.to_string())),
        ("seq", Json::Num(seq as f64)),
        ("name", Json::Str(name.to_string())),
        ("parent", Json::Num(parent as f64)),
        ("fields", fields_obj),
    ];
    extra(&mut rec, st.epoch);
    st.sink.write_line(&Json::obj(rec).to_string());
}

/// Is this record/field key a wall-clock leaf? The trace analogue of
/// `rchg bench`'s `is_timing_field`: `_us`/`_secs`/`_per_sec` suffixes
/// (which cover the record-level `start_us`/`dur_us`/`at_us`).
pub fn is_timing_key(name: &str) -> bool {
    name.ends_with("_us") || name.ends_with("_secs") || name.ends_with("_per_sec")
}

/// Null every timing leaf of a parsed trace record (recursively), keeping
/// the deterministic skeleton — two traced runs of the same sequential
/// workload must agree on the result exactly.
pub fn strip_timings(v: &Json) -> Json {
    match v {
        Json::Obj(o) => Json::Obj(
            o.iter()
                .map(|(k, val)| {
                    let stripped =
                        if is_timing_key(k) { Json::Null } else { strip_timings(val) };
                    (k.clone(), stripped)
                })
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(strip_timings).collect()),
        other => other.clone(),
    }
}

/// Validate a JSON-lines trace dump against the `rchg-trace-v1` schema:
/// header first, every line a well-formed record of a known kind with
/// its required keys, `seq` equal to the line index. Returns the record
/// count. This is the `rchg trace-check` core and the CI smoke check.
pub fn validate_trace(text: &str) -> Result<u64, String> {
    let mut n = 0u64;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            return Err(format!("line {}: empty line inside the trace", i + 1));
        }
        let rec = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        let ev = rec
            .get("ev")
            .as_str()
            .ok_or_else(|| format!("line {}: missing \"ev\"", i + 1))?
            .to_string();
        let seq = rec
            .get("seq")
            .as_f64()
            .ok_or_else(|| format!("line {}: missing \"seq\"", i + 1))? as u64;
        if seq != i as u64 {
            return Err(format!("line {}: seq {seq} breaks the monotonic sequence", i + 1));
        }
        match (i, ev.as_str()) {
            (0, "trace") => {
                let schema = rec.get("schema").as_str().unwrap_or("");
                if schema != TRACE_SCHEMA {
                    return Err(format!(
                        "header schema {schema:?} (this build reads {TRACE_SCHEMA:?})"
                    ));
                }
            }
            (0, other) => return Err(format!("first record is {other:?}, not the header")),
            (_, "trace") => return Err(format!("line {}: duplicate header", i + 1)),
            (_, "span") => {
                for key in ["name", "parent", "fields", "span", "start_us", "dur_us"] {
                    if matches!(rec.get(key), Json::Null) {
                        return Err(format!("line {}: span record missing {key:?}", i + 1));
                    }
                }
            }
            (_, "event") => {
                for key in ["name", "parent", "fields", "at_us"] {
                    if matches!(rec.get(key), Json::Null) {
                        return Err(format!("line {}: event record missing {key:?}", i + 1));
                    }
                }
            }
            (_, other) => return Err(format!("line {}: unknown record kind {other:?}", i + 1)),
        }
        n += 1;
    }
    if n == 0 {
        return Err("empty trace (no header record)".to_string());
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is process-global, and instrumented code in *other* lib
    // tests (compiler batches, session save/load) emits records whenever
    // any sink is installed — so these tests serialize on this lock, use
    // distinctive span names, and assert only on records they emitted
    // themselves. The strict whole-trace determinism pins live in
    // `tests/obs.rs`, where the integration binary serializes emission.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    /// Parse the captured lines, keeping this test's own records (names
    /// starting with `prefix`) in emission order.
    fn ours(lines: &[String], prefix: &str) -> Vec<Json> {
        lines
            .iter()
            .map(|l| Json::parse(l).expect("trace line parses"))
            .filter(|r| r.get("name").as_str().map_or(false, |n| n.starts_with(prefix)))
            .collect()
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        set_sink(None);
        assert!(!enabled());
        let mut s = span("t_inert_noop");
        s.field_u64("n", 3);
        assert_eq!(s.handle(), SpanHandle::NONE);
        drop(s);
        event("t_inert_ping", SpanHandle::NONE, vec![]);
        // None of that reached the sink installed afterwards: the header
        // is there, our pre-sink spans and events are not.
        let mem = MemorySink::new(4096);
        set_sink(Some(Box::new(mem.clone())));
        assert!(set_sink(None) >= 1, "the header record was counted");
        let lines = mem.lines();
        assert!(lines[0].contains(TRACE_SCHEMA));
        assert!(ours(&lines, "t_inert_").is_empty());
    }

    #[test]
    fn span_records_validate_and_nest() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        let mem = MemorySink::new(4096);
        set_sink(Some(Box::new(mem.clone())));
        {
            let mut root = span("t_nest_root");
            root.field_u64("weights", 10);
            let mut child = child_span("t_nest_child", root.handle());
            child.field_str("what", "inner");
            event("t_nest_ping", root.handle(), vec![("n", Json::Num(1.0))]);
        }
        set_sink(None);
        let recs = ours(&mem.lines(), "t_nest_");
        assert_eq!(recs.len(), 3);
        // Emission order: event, then child (drops first), then root.
        let (ping, child, root) = (&recs[0], &recs[1], &recs[2]);
        assert_eq!(ping.get("ev").as_str(), Some("event"));
        assert!(ping.get("at_us").as_f64().is_some());
        assert_eq!(child.get("ev").as_str(), Some("span"));
        assert_eq!(child.get("name").as_str(), Some("t_nest_child"));
        assert_eq!(root.get("name").as_str(), Some("t_nest_root"));
        assert!(child.get("start_us").as_f64().is_some());
        assert!(child.get("dur_us").as_f64().is_some());
        assert_eq!(child.get("parent"), root.get("span"));
        assert_eq!(ping.get("parent"), root.get("span"));
        assert_eq!(root.get("parent").as_f64(), Some(0.0));
        assert_eq!(root.get("fields").get("weights").as_f64(), Some(10.0));
        assert_eq!(child.get("fields").get("what").as_str(), Some("inner"));
    }

    #[test]
    fn set_sink_resets_sequence_for_comparable_runs() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        let mut headers = Vec::new();
        let mut dumps = Vec::new();
        for _ in 0..2 {
            let mem = MemorySink::new(4096);
            set_sink(Some(Box::new(mem.clone())));
            {
                let root = span("t_reset_run");
                let _child = child_span("t_reset_step", root.handle());
            }
            set_sink(None);
            let lines = mem.lines();
            headers.push(Json::parse(&lines[0]).unwrap());
            dumps.push(ours(&lines, "t_reset_"));
        }
        // Installing a sink restarts the stream: the header is seq 0 both
        // times (the counter reset is what makes two runs comparable).
        for h in &headers {
            assert_eq!(h.get("seq").as_f64(), Some(0.0));
            assert_eq!(h.get("schema").as_str(), Some(TRACE_SCHEMA));
        }
        // Our records agree across runs once wall-clock leaves and the
        // ids concurrent emitters can shift are nulled; the id-exact pin
        // is in `tests/obs.rs`.
        let skeleton = |recs: &[Json]| -> Vec<Json> {
            recs.iter()
                .map(|r| {
                    let mut stripped = strip_timings(r);
                    if let Json::Obj(o) = &mut stripped {
                        for key in ["seq", "span", "parent"] {
                            if o.contains_key(key) {
                                o.insert(key.to_string(), Json::Null);
                            }
                        }
                    }
                    stripped
                })
                .collect()
        };
        assert_eq!(skeleton(&dumps[0]), skeleton(&dumps[1]));
    }

    #[test]
    fn validate_trace_rejects_malformed_dumps() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        assert!(validate_trace("").is_err());
        assert!(validate_trace("{\"ev\":\"span\",\"seq\":0}").is_err(), "no header");
        let header = format!("{{\"ev\":\"trace\",\"schema\":\"{TRACE_SCHEMA}\",\"seq\":0}}");
        assert!(validate_trace(&header).is_ok());
        let wrong_schema = "{\"ev\":\"trace\",\"schema\":\"rchg-trace-v0\",\"seq\":0}";
        assert!(validate_trace(wrong_schema).is_err());
        let bad_seq = format!("{header}\n{{\"ev\":\"event\",\"seq\":7}}");
        assert!(validate_trace(&bad_seq).is_err());
        let missing_keys = format!("{header}\n{{\"ev\":\"span\",\"seq\":1}}");
        assert!(validate_trace(&missing_keys).is_err());
        assert!(validate_trace(&format!("{header}\nnot json")).is_err());
    }

    #[test]
    fn timing_keys_are_segregated() {
        assert!(is_timing_key("start_us"));
        assert!(is_timing_key("dur_us"));
        assert!(is_timing_key("at_us"));
        assert!(is_timing_key("scan_secs"));
        assert!(is_timing_key("weights_per_sec"));
        assert!(!is_timing_key("seq"));
        assert!(!is_timing_key("weights"));
        assert!(!is_timing_key("name"));
        let rec = Json::parse(
            "{\"dur_us\":5,\"fields\":{\"n\":2,\"solve_secs\":0.1},\"seq\":1}",
        )
        .unwrap();
        let stripped = strip_timings(&rec);
        assert_eq!(stripped.get("dur_us"), &Json::Null);
        assert_eq!(stripped.get("fields").get("solve_secs"), &Json::Null);
        assert_eq!(stripped.get("fields").get("n").as_f64(), Some(2.0));
        assert_eq!(stripped.get("seq").as_f64(), Some(1.0));
    }
}
