//! Process-global metrics registry: named counters, gauges, and
//! fixed-layout log2 histograms behind one [`metrics()`] handle.
//!
//! This unifies the repo's pre-existing counter families — per-compile
//! `CompileStats` deltas, lifetime `StoreCounters`, coordinator
//! `FabricStats` — without touching how those structs feed deterministic
//! outputs. The mirroring convention (see each `record_metrics` impl):
//!
//! * **Per-compilation deltas** (`CompileStats`) are `inc`'d into
//!   counters once per batch, at the point the batch's stats are merged —
//!   never per weight or per lookup, so no hot solve path takes the
//!   registry lock.
//! * **Lifetime absolutes** (`StoreCounters`, `FabricStats`) are `gauge`'d
//!   at snapshot/report time: the source struct stays the single writer
//!   and the gauge is a scrape-time mirror, which keeps the registry off
//!   the store's lookup path entirely.
//!
//! Metrics are observability only: no compiled byte ever depends on a
//! registry value, and the registry itself is deterministic in *layout*
//! (BTreeMap-ordered names, fixed histogram buckets) though not in the
//! values timing-derived observations take.
//!
//! ## Histogram layout (pinned by `tests/obs.rs`)
//!
//! [`HIST_BUCKETS`] = 33 log2 buckets with [`bucket_index`]: bucket 0
//! holds exactly `{0}`, bucket `k` (1 ≤ k ≤ 31) holds `[2^(k-1), 2^k)`,
//! and bucket 32 is the overflow `[2^31, ∞)`. The layout is part of the
//! `StatsPush` wire contract — changing it is a protocol bump.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Number of histogram buckets: zero bucket + 31 log2 ranges + overflow.
pub const HIST_BUCKETS: usize = 33;

/// Log2 bucket for `v`: 0 for 0, otherwise `floor(log2(v)) + 1` capped
/// at the overflow bucket.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Fixed-layout log2 histogram (see module docs for the bucket scheme).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { count: 0, sum: 0, buckets: [0; HIST_BUCKETS] }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: u64) {
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.buckets[bucket_index(v)] += 1;
    }
}

/// One registered metric. The kind is implied by the first operation on
/// a name; mixing operations on one name replaces the value with the new
/// kind (a naming bug, not a panic — the registry is observability and
/// must never take a workload down).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    Histogram(Histogram),
}

/// The registry: a name-ordered map guarded by one mutex. All access is
/// through [`metrics()`]; the map order makes [`MetricsSnapshot`] and
/// [`MetricsSnapshot::render`] layout-deterministic.
pub struct Metrics {
    inner: Mutex<BTreeMap<String, MetricValue>>,
}

static GLOBAL: Metrics = Metrics { inner: Mutex::new(BTreeMap::new()) };

/// The process-global registry handle.
pub fn metrics() -> &'static Metrics {
    &GLOBAL
}

impl Metrics {
    fn lock(&self) -> MutexGuard<'_, BTreeMap<String, MetricValue>> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Add `by` to the counter `name` (creating it at 0).
    pub fn inc(&self, name: &str, by: u64) {
        let mut map = self.lock();
        match map.get_mut(name) {
            Some(MetricValue::Counter(c)) => *c = c.saturating_add(by),
            _ => {
                map.insert(name.to_string(), MetricValue::Counter(by));
            }
        }
    }

    /// Set the gauge `name` to `v`.
    pub fn gauge(&self, name: &str, v: i64) {
        self.lock().insert(name.to_string(), MetricValue::Gauge(v));
    }

    /// Record `v` into the histogram `name` (creating it empty).
    pub fn observe(&self, name: &str, v: u64) {
        let mut map = self.lock();
        match map.get_mut(name) {
            Some(MetricValue::Histogram(h)) => h.observe(v),
            _ => {
                let mut h = Histogram::default();
                h.observe(v);
                map.insert(name.to_string(), MetricValue::Histogram(h));
            }
        }
    }

    /// Point-in-time copy of every registered metric, name-sorted.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            entries: self.lock().iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        }
    }

    /// Drop every metric (tests only — the registry is process-global).
    pub fn reset(&self) {
        self.lock().clear();
    }
}

/// A name-sorted copy of the registry, as scraped locally or carried by
/// a `StatsPush` frame.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub entries: Vec<(String, MetricValue)>,
}

impl MetricsSnapshot {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries.iter().find(|(k, _)| k == name).map(|(_, v)| v)
    }

    /// Counter value, 0 when absent or not a counter.
    pub fn counter(&self, name: &str) -> u64 {
        match self.get(name) {
            Some(MetricValue::Counter(c)) => *c,
            _ => 0,
        }
    }

    /// Gauge value, 0 when absent or not a gauge.
    pub fn gauge(&self, name: &str) -> i64 {
        match self.get(name) {
            Some(MetricValue::Gauge(g)) => *g,
            _ => 0,
        }
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        match self.get(name) {
            Some(MetricValue::Histogram(h)) => Some(h),
            _ => None,
        }
    }

    /// Stable text exposition: one line per metric, name-sorted, kind
    /// prefix first, nonzero histogram buckets as `b<i>=<n>`. This is
    /// what `rchg submit --stats` and `rchg top` print.
    ///
    /// ```text
    /// counter compile.weights 4096
    /// gauge store.hits 17
    /// hist fabric.shard.latency_us count=3 sum=812 b9=2 b10=1
    /// ```
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, value) in &self.entries {
            match value {
                MetricValue::Counter(c) => {
                    out.push_str(&format!("counter {name} {c}\n"));
                }
                MetricValue::Gauge(g) => {
                    out.push_str(&format!("gauge {name} {g}\n"));
                }
                MetricValue::Histogram(h) => {
                    out.push_str(&format!("hist {name} count={} sum={}", h.count, h.sum));
                    for (i, b) in h.buckets.iter().enumerate() {
                        if *b != 0 {
                            out.push_str(&format!(" b{i}={b}"));
                        }
                    }
                    out.push('\n');
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and other lib tests (e.g. the
    // compiler's) mirror their own metrics into it concurrently, so these
    // tests serialize on this lock, use distinctive name prefixes, and
    // assert only on entries they created — never on the whole registry.
    // The strict whole-registry determinism pins live in `tests/obs.rs`,
    // where the integration binary serializes all emission.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn only(prefix: &str, snap: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            entries: snap.entries.iter().filter(|(k, _)| k.starts_with(prefix)).cloned().collect(),
        }
    }

    #[test]
    fn bucket_layout_is_pinned() {
        assert_eq!(HIST_BUCKETS, 33);
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index((1 << 31) - 1), 31);
        assert_eq!(bucket_index(1 << 31), 32);
        assert_eq!(bucket_index(u64::MAX), 32);
    }

    #[test]
    fn registry_ops_and_snapshot() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        let m = metrics();
        m.inc("t_ops.a.count", 2);
        m.inc("t_ops.a.count", 3);
        m.gauge("t_ops.b.depth", -4);
        m.gauge("t_ops.b.depth", 7);
        m.observe("t_ops.c.lat_us", 0);
        m.observe("t_ops.c.lat_us", 5);
        m.observe("t_ops.c.lat_us", 5);
        let snap = m.snapshot();
        assert_eq!(snap.counter("t_ops.a.count"), 5);
        assert_eq!(snap.gauge("t_ops.b.depth"), 7);
        let h = snap.histogram("t_ops.c.lat_us").unwrap();
        assert_eq!((h.count, h.sum), (3, 10));
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[bucket_index(5)], 2);
        // Missing names read as zero, not a panic.
        assert_eq!(snap.counter("nope"), 0);
        assert_eq!(snap.gauge("nope"), 0);
        assert!(snap.histogram("nope").is_none());
        m.reset();
        assert!(only("t_ops.", &m.snapshot()).is_empty());
    }

    #[test]
    fn render_is_name_sorted_and_stable() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        let m = metrics();
        m.observe("t_render.z.hist", 3);
        m.inc("t_render.m.count", 1);
        m.gauge("t_render.a.gauge", 9);
        let text = only("t_render.", &m.snapshot()).render();
        assert_eq!(
            text,
            "gauge t_render.a.gauge 9\ncounter t_render.m.count 1\n\
             hist t_render.z.hist count=1 sum=3 b2=1\n"
        );
        m.reset();
    }
}
