//! Evaluation metrics: classification accuracy, next-token NLL/perplexity,
//! and small aggregation helpers (mean ± std over trials).

/// Argmax classification accuracy. `logits`: `[n, classes]` row-major.
pub fn accuracy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut correct = 0usize;
    for (i, &y) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(j, _)| j)
            .unwrap_or(0);
        if pred == y as usize {
            correct += 1;
        }
    }
    correct as f64 / labels.len().max(1) as f64
}

/// Total next-token negative log-likelihood for one sequence's logits.
/// `logits`: `[t, vocab]`, `targets`: `[t]`. Numerically stable log-softmax.
pub fn sequence_nll(logits: &[f32], targets: &[i32], vocab: usize) -> f64 {
    assert_eq!(logits.len(), targets.len() * vocab);
    let mut total = 0.0f64;
    for (i, &tgt) in targets.iter().enumerate() {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logsum = m as f64 + row.iter().map(|&v| ((v - m) as f64).exp()).sum::<f64>().ln();
        total += logsum - row[tgt as usize] as f64;
    }
    total
}

/// exp(total_nll / tokens).
pub fn perplexity(total_nll: f64, tokens: usize) -> f64 {
    (total_nll / tokens.max(1) as f64).exp()
}

/// Mean and (population) standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        // logits rows: picks class 1, class 0.
        let logits = vec![0.1, 0.9, 0.8, 0.2];
        assert_eq!(accuracy(&logits, &[1, 0], 2), 1.0);
        assert_eq!(accuracy(&logits, &[0, 0], 2), 0.5);
    }

    #[test]
    fn nll_uniform() {
        // Uniform logits over 4 classes → nll = ln(4) per token.
        let logits = vec![0.0f32; 8];
        let nll = sequence_nll(&logits, &[0, 3], 4);
        assert!((nll - 2.0 * (4f64).ln()).abs() < 1e-6);
        assert!((perplexity(nll, 2) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn nll_confident() {
        let mut logits = vec![0.0f32; 4];
        logits[2] = 50.0; // near-certain class 2
        let nll = sequence_nll(&logits, &[2], 4);
        assert!(nll < 1e-6);
    }

    #[test]
    fn nll_stable_for_large_logits() {
        let logits = vec![1e4f32, -1e4, 0.0, 5.0];
        let nll = sequence_nll(&logits, &[0], 4);
        assert!(nll.is_finite() && nll < 1e-6);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert_eq!(s, 1.0);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
