//! Minimal dense f32 tensor used by the quantizers, mappers and evaluation
//! drivers. Row-major, owned storage. This is deliberately small: the heavy
//! numerics run inside the AOT-compiled XLA executables; the rust side only
//! needs reshapes, slicing, matmul for GPTQ-style calibration, and im2col
//! bookkeeping for the conv mappers.

use crate::util::prng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: &[usize]) -> Self {
        let n = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { dims: dims.to_vec(), data }
    }

    pub fn randn(dims: &[usize], rng: &mut Rng, std: f32) -> Self {
        let n: usize = dims.iter().product();
        Tensor {
            dims: dims.to_vec(),
            data: (0..n).map(|_| rng.normal_f32() * std).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn reshape(mut self, dims: &[usize]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims.to_vec();
        self
    }

    /// 2-D element access.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j] = v;
    }

    /// Matrix multiply: `[m,k] x [k,n] -> [m,n]`.
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.dims.len(), 2);
        assert_eq!(rhs.dims.len(), 2);
        let (m, k) = (self.dims[0], self.dims[1]);
        let (k2, n) = (rhs.dims[0], rhs.dims[1]);
        assert_eq!(k, k2, "matmul inner dims");
        let mut out = vec![0.0f32; m * n];
        // ikj loop order for cache friendliness.
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let row = &rhs.data[p * n..(p + 1) * n];
                let dst = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    dst[j] += a * row[j];
                }
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// Transpose a 2-D tensor.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.dims.len(), 2);
        let (m, n) = (self.dims[0], self.dims[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// ℓ1 norm of the difference — the Fig 8 metric.
    pub fn l1_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.dims, other.dims);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[4, 4], &mut rng, 1.0);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        let out = a.matmul(&eye);
        for (x, y) in out.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[3, 7], &mut rng, 1.0);
        let b = a.transpose2().transpose2();
        assert_eq!(a, b);
    }

    #[test]
    fn l1_diff_zero_for_self() {
        let mut rng = Rng::new(7);
        let a = Tensor::randn(&[10], &mut rng, 2.0);
        assert_eq!(a.l1_diff(&a), 0.0);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        let _ = a.matmul(&b);
    }
}
