//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the rust hot path (python never runs here).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifact names, argument order, shapes and dtypes come from
//! `artifacts/manifest.json` written by `python/compile/aot.py`.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Argument dtype (matches the manifest's "f32" / "i32").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One executable argument's spec.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Runtime argument value (borrowed buffers; shapes from the spec).
#[derive(Clone, Debug)]
pub enum ArgValue<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// The PJRT runtime: client + manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    art_dir: PathBuf,
    manifest: Json,
}

impl Runtime {
    /// Create a CPU PJRT client and read `manifest.json` from `art_dir`.
    pub fn new(art_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let manifest_path = art_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {}", manifest_path.display()))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        Ok(Runtime { client, art_dir: art_dir.to_path_buf(), manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of all executables in the manifest.
    pub fn executables(&self) -> Vec<String> {
        self.manifest
            .as_obj()
            .map(|o| o.keys().filter(|k| !k.starts_with('_')).cloned().collect())
            .unwrap_or_default()
    }

    /// The manifest's `_meta` section.
    pub fn meta(&self) -> &Json {
        self.manifest.get("_meta")
    }

    /// Compile one artifact into an executable.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let entry = self.manifest.get(name);
        let rel = entry
            .get("path")
            .as_str()
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.art_dir.join(rel);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;

        let args = entry
            .get("args")
            .as_arr()
            .ok_or_else(|| anyhow!("artifact '{name}' has no args"))?
            .iter()
            .map(|a| {
                let shape = a
                    .get("shape")
                    .as_arr()
                    .map(|xs| xs.iter().filter_map(|v| v.as_usize()).collect())
                    .unwrap_or_default();
                let dtype = match a.get("dtype").as_str() {
                    Some("i32") => DType::I32,
                    _ => DType::F32,
                };
                ArgSpec {
                    name: a.get("name").as_str().unwrap_or("?").to_string(),
                    shape,
                    dtype,
                }
            })
            .collect();
        Ok(Executable { exe, args, name: name.to_string() })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub args: Vec<ArgSpec>,
    pub name: String,
}

impl Executable {
    /// Execute with positional arguments (must match `self.args`).
    /// Returns the first tuple element flattened to f32.
    pub fn run(&self, values: &[ArgValue]) -> Result<Vec<f32>> {
        if values.len() != self.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.args.len(),
                values.len()
            );
        }
        let mut literals = Vec::with_capacity(values.len());
        for (spec, val) in self.args.iter().zip(values) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (spec.dtype, val) {
                (DType::F32, ArgValue::F32(data)) => {
                    if data.len() != spec.len() {
                        bail!(
                            "{}: arg '{}' wants {} elements, got {}",
                            self.name,
                            spec.name,
                            spec.len(),
                            data.len()
                        );
                    }
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshape {}: {e:?}", spec.name))?
                }
                (DType::I32, ArgValue::I32(data)) => {
                    if data.len() != spec.len() {
                        bail!(
                            "{}: arg '{}' wants {} elements, got {}",
                            self.name,
                            spec.name,
                            spec.len(),
                            data.len()
                        );
                    }
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshape {}: {e:?}", spec.name))?
                }
                _ => bail!(
                    "{}: arg '{}' dtype mismatch (spec {:?})",
                    self.name,
                    spec.name,
                    spec.dtype
                ),
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple {}: {e:?}", self.name))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {}: {e:?}", self.name))
    }

    /// Find an argument index by name.
    pub fn arg_index(&self, name: &str) -> Option<usize> {
        self.args.iter().position(|a| a.name == name)
    }
}

/// Locate the artifacts directory: $RCHG_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("RCHG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Load a named weight bank (`artifacts/weights/<model>/`): meta.json param
/// order + one .bin per parameter.
pub struct WeightBank {
    pub params: BTreeMap<String, crate::util::io::RawTensor>,
    pub order: Vec<String>,
    pub meta: Json,
}

impl WeightBank {
    pub fn load(dir: &Path) -> Result<WeightBank> {
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("read {}/meta.json", dir.display()))?;
        let meta = Json::parse(&meta_text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let mut params = BTreeMap::new();
        let mut order = Vec::new();
        for p in meta.get("params").as_arr().unwrap_or(&[]) {
            let name = p.get("name").as_str().ok_or_else(|| anyhow!("param sans name"))?;
            let t = crate::util::io::RawTensor::load(&dir.join(format!("{name}.bin")))?;
            let want: Vec<usize> = p
                .get("shape")
                .as_arr()
                .map(|xs| xs.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default();
            if t.dims != want {
                bail!("param {name}: file dims {:?} != meta {:?}", t.dims, want);
            }
            params.insert(name.to_string(), t);
            order.push(name.to_string());
        }
        Ok(WeightBank { params, order, meta })
    }

    pub fn get(&self, name: &str) -> Result<&crate::util::io::RawTensor> {
        self.params.get(name).ok_or_else(|| anyhow!("missing param {name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art() -> PathBuf {
        artifacts_dir()
    }

    fn have_artifacts() -> bool {
        art().join("manifest.json").exists()
    }

    #[test]
    fn runtime_loads_and_lists() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&art()).unwrap();
        assert_eq!(rt.platform().to_lowercase().contains("cpu"), true);
        let names = rt.executables();
        assert!(names.iter().any(|n| n.starts_with("imc_linear_")));
    }

    #[test]
    fn imc_linear_executes_and_matches_integer_matmul() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        use crate::grouping::{Decomposition, GroupConfig};
        let rt = Runtime::new(&art()).unwrap();
        let exe = rt.load("imc_linear_r2c2").unwrap();
        // Spec: x [8,64], planes [2,128,10], sigs [2].
        let cfg = GroupConfig::R2C2;
        let (k, n) = (64usize, 10usize);
        let mut rng = crate::util::prng::Rng::new(42);
        let w_int: Vec<i64> =
            (0..k * n).map(|_| rng.range_i64(-cfg.max_per_array(), cfg.max_per_array())).collect();
        // Pack planes (fault-free) in the kernel layout.
        let mut pos = vec![0f32; cfg.cols * k * cfg.rows * n];
        let mut neg = vec![0f32; cfg.cols * k * cfg.rows * n];
        let kr = k * cfg.rows;
        for ki in 0..k {
            for ni in 0..n {
                let d = Decomposition::encode_ideal(w_int[ki * n + ni], &cfg);
                for col in 0..cfg.cols {
                    for row in 0..cfg.rows {
                        let cell = d.pos.cells[col * cfg.rows + row] as f32;
                        let celln = d.neg.cells[col * cfg.rows + row] as f32;
                        let idx = col * kr * n + (ki * cfg.rows + row) * n + ni;
                        pos[idx] = cell;
                        neg[idx] = celln;
                    }
                }
            }
        }
        let x: Vec<f32> = (0..8 * k).map(|_| rng.normal_f32()).collect();
        let sigs: Vec<f32> = cfg.significances().iter().map(|&s| s as f32).collect();
        let out = exe
            .run(&[
                ArgValue::F32(&x),
                ArgValue::F32(&pos),
                ArgValue::F32(&neg),
                ArgValue::F32(&sigs),
            ])
            .unwrap();
        assert_eq!(out.len(), 8 * n);
        // Compare with x @ w_int.
        for b in 0..8 {
            for j in 0..n {
                let want: f32 =
                    (0..k).map(|i| x[b * k + i] * w_int[i * n + j] as f32).sum();
                let got = out[b * n + j];
                assert!(
                    (want - got).abs() <= 1e-2 * want.abs().max(1.0),
                    "mismatch at ({b},{j}): {got} vs {want}"
                );
            }
        }
    }
}
