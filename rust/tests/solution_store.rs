//! Fleet-store acceptance tests.
//!
//! * A store-backed compile is byte-identical to a store-less one —
//!   bitmaps, residual errors, AND the saved RCSS session bytes (the
//!   store's determinism contract).
//! * A second chip compiling the same model against a populated store
//!   reuses solutions across chips (`store_hits > 0`), and a re-compile
//!   of the *same* chip through a fresh session builds zero tables.
//! * The RCPS file tier answers a cold process from disk, and rejects
//!   corrupt, truncated, and version-mismatched blobs cleanly (a
//!   rejection is a miss, never a wrong answer or a crash).
//! * A pathologically small memory budget evicts constantly and still
//!   never changes a byte of output.
//! * Fabric end-to-end: tables a worker publishes after one chip's job
//!   are reused when a later chip's job is solved over the same fabric.

use rchg::coordinator::{CompileOptions, CompileSession, CompiledTensor, Method, ServiceOptions, TableBudget};
use rchg::experiments::compile_time::synthetic_model_tensors;
use rchg::fault::bank::ChipFaults;
use rchg::fault::FaultRates;
use rchg::grouping::GroupConfig;
use rchg::net::{run_worker, CompileClient, FabricServer, FabricStats, ServeOptions, TensorResult};
use rchg::store::{SolutionStore, StoreCtx, StoreHandle};
use rchg::util::prop::fnv1a;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

const CFG: GroupConfig = GroupConfig::R2C2;
const BIG: usize = 256 << 20;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rchg-store-{name}-{}", std::process::id()))
}

fn model(limit: usize) -> Vec<(String, Vec<i64>)> {
    synthetic_model_tensors("resnet20", &CFG, limit).unwrap()
}

/// The context every session in this file solves under (builder defaults
/// for the complete method) — what its publishes are keyed by.
fn store_ctx() -> StoreCtx {
    StoreCtx::new(CFG, CompileOptions::new(CFG, Method::Complete).pipeline)
}

/// Compile `tensors` for one chip through a fresh session, optionally
/// store-backed; returns the per-tensor outputs and the RCSS save bytes.
fn compile_chip(
    seed: u64,
    tensors: &[(String, Vec<i64>)],
    store: Option<StoreHandle>,
) -> (Vec<(String, CompiledTensor)>, Vec<u8>) {
    let chip = ChipFaults::new(seed, FaultRates::paper_default());
    let mut builder = CompileSession::builder(CFG).method(Method::Complete).threads(1);
    if let Some(store) = store {
        builder = builder.store(store);
    }
    let mut session = builder.chip(&chip);
    for (name, ws) in tensors {
        session.submit(name, ws.clone());
    }
    let out = session.drain();
    let bytes = session.to_bytes().unwrap();
    (out, bytes)
}

fn assert_outputs_match(got: &[(String, CompiledTensor)], want: &[(String, CompiledTensor)]) {
    assert_eq!(got.len(), want.len(), "tensor count");
    for ((gn, g), (wn, w)) in got.iter().zip(want) {
        assert_eq!(gn, wn);
        assert_eq!(g.decomps, w.decomps, "bitmaps of {gn}");
        assert_eq!(g.errors, w.errors, "residual errors of {gn}");
    }
}

fn sum_stat(out: &[(String, CompiledTensor)], f: impl Fn(&CompiledTensor) -> usize) -> usize {
    out.iter().map(|(_, t)| f(t)).sum()
}

#[test]
fn store_backed_compile_is_byte_identical_and_reuses_across_chips() {
    let tensors = model(4_000);
    let store = StoreHandle::in_memory();

    // Chip 1, cold store: identical output, no spurious hits.
    let (plain_a, bytes_a) = compile_chip(1, &tensors, None);
    let (store_a, store_bytes_a) = compile_chip(1, &tensors, Some(store.clone()));
    assert_outputs_match(&store_a, &plain_a);
    assert_eq!(store_bytes_a, bytes_a, "RCSS bytes must not depend on the store");
    let after_a = store.counters();
    assert_eq!(after_a.hits, 0, "an empty store must answer nothing");
    assert!(after_a.misses > 0, "a cold compile must consult the store");
    assert!(after_a.publishes > 0, "a cold compile must publish its solves");
    assert_eq!(sum_stat(&store_a, |t| t.stats.store_hits), 0);
    assert_eq!(sum_stat(&store_a, |t| t.stats.store_misses), after_a.misses as usize);

    // Chip 2, warm store: cross-chip reuse with byte-identical output.
    let (plain_b, bytes_b) = compile_chip(2, &tensors, None);
    let (store_b, store_bytes_b) = compile_chip(2, &tensors, Some(store.clone()));
    assert_outputs_match(&store_b, &plain_b);
    assert_eq!(store_bytes_b, bytes_b);
    let hits_b = sum_stat(&store_b, |t| t.stats.store_hits);
    assert!(hits_b > 0, "chips share hot SAF patterns; chip 2 must reuse chip 1's solves");
    assert_eq!(store.counters().hits, hits_b as u64);
    // Every store hit skipped exactly one table build.
    let plain_builds = sum_stat(&plain_b, |t| t.stats.pattern_tables_built);
    let store_builds = sum_stat(&store_b, |t| t.stats.pattern_tables_built);
    assert_eq!(store_builds + hits_b, plain_builds, "hits must replace builds one-for-one");

    // Chip 1 again through a *fresh* session: the store holds its whole
    // pattern set, so nothing is built locally and the RCSS bytes still
    // match the original store-less save.
    let (again_a, again_bytes_a) = compile_chip(1, &tensors, Some(store.clone()));
    assert_outputs_match(&again_a, &plain_a);
    assert_eq!(again_bytes_a, bytes_a);
    assert_eq!(
        sum_stat(&again_a, |t| t.stats.pattern_tables_built),
        0,
        "a fully warm store must build zero tables"
    );
    assert!(sum_stat(&again_a, |t| t.stats.store_hits) > 0);
    assert_eq!(sum_stat(&again_a, |t| t.stats.store_misses), 0);
}

#[test]
fn file_tier_answers_cold_processes_and_rejects_tampered_blobs() {
    let dir = tmp("blob-reject");
    let _ = std::fs::remove_dir_all(&dir);
    let tensors = model(1_200);
    let store = StoreHandle::new(SolutionStore::with_dir(&dir, BIG).unwrap());
    compile_chip(5, &tensors, Some(store.clone()));
    assert!(store.counters().publishes > 0);

    // The pattern set chip 5 drew (same sampling the compile used).
    let chip = ChipFaults::new(5, FaultRates::paper_default());
    let mut peek = CompileSession::builder(CFG).method(Method::Complete).chip(&chip);
    for (name, ws) in &tensors {
        peek.submit(name, ws.clone());
    }
    let patterns = peek.queued_patterns();
    assert!(!patterns.is_empty());
    let ctx = store_ctx();

    // A fresh store over the same dir — a cold process — answers every
    // pattern from disk, through full re-verification.
    let mut cold = SolutionStore::with_dir(&dir, BIG).unwrap();
    for p in &patterns {
        assert!(cold.lookup_table(&ctx, p).is_some(), "file tier must answer a cold process");
    }
    let c = cold.counters();
    assert_eq!(c.file_hits, patterns.len() as u64);
    assert_eq!(c.rejected_blobs, 0);
    assert_eq!(c.misses, 0);

    let blobs: Vec<(PathBuf, Vec<u8>)> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| {
            let path = e.unwrap().path();
            (path.extension().and_then(|x| x.to_str()) == Some("rcps"))
                .then(|| (path.clone(), std::fs::read(&path).unwrap()))
        })
        .collect();
    assert!(!blobs.is_empty());

    // Corruption: one flipped byte per blob → every lookup is a clean miss.
    for (path, bytes) in &blobs {
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x5A;
        std::fs::write(path, bad).unwrap();
    }
    let mut corrupt = SolutionStore::with_dir(&dir, BIG).unwrap();
    for p in &patterns {
        assert!(corrupt.lookup_table(&ctx, p).is_none(), "corrupt blob must not be served");
    }
    assert_eq!(corrupt.counters().rejected_blobs, patterns.len() as u64);
    assert_eq!(corrupt.counters().misses, patterns.len() as u64);

    // Truncation.
    for (path, bytes) in &blobs {
        std::fs::write(path, &bytes[..bytes.len() / 2]).unwrap();
    }
    let mut truncated = SolutionStore::with_dir(&dir, BIG).unwrap();
    for p in &patterns {
        assert!(truncated.lookup_table(&ctx, p).is_none());
    }
    assert_eq!(truncated.counters().rejected_blobs, patterns.len() as u64);

    // A blob from a future format version, re-sealed so its checksum is
    // valid — only the version gate can (and must) reject it.
    for (path, bytes) in &blobs {
        let mut payload = bytes[..bytes.len() - 8].to_vec();
        payload[4..8].copy_from_slice(&2u32.to_le_bytes());
        let sum = fnv1a(&payload);
        payload.extend_from_slice(&sum.to_le_bytes());
        std::fs::write(path, payload).unwrap();
    }
    let mut foreign = SolutionStore::with_dir(&dir, BIG).unwrap();
    for p in &patterns {
        assert!(foreign.lookup_table(&ctx, p).is_none(), "future-version blob must be refused");
    }
    assert_eq!(foreign.counters().rejected_blobs, patterns.len() as u64);

    // Restoring the valid bytes restores service.
    for (path, bytes) in &blobs {
        std::fs::write(path, bytes).unwrap();
    }
    let mut restored = SolutionStore::with_dir(&dir, BIG).unwrap();
    for p in &patterns {
        assert!(restored.lookup_table(&ctx, p).is_some());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tiny_memory_budget_evicts_constantly_and_never_changes_output() {
    let tensors = model(2_500);
    let store = StoreHandle::new(SolutionStore::new(1)); // evict everything, every epoch
    let (plain_a, bytes_a) = compile_chip(1, &tensors, None);
    let (starved_a, starved_bytes_a) = compile_chip(1, &tensors, Some(store.clone()));
    assert_outputs_match(&starved_a, &plain_a);
    assert_eq!(starved_bytes_a, bytes_a);
    let (plain_b, bytes_b) = compile_chip(2, &tensors, None);
    let (starved_b, starved_bytes_b) = compile_chip(2, &tensors, Some(store.clone()));
    assert_outputs_match(&starved_b, &plain_b);
    assert_eq!(starved_bytes_b, bytes_b);
    assert!(
        store.counters().evictions > 0,
        "a 1-byte budget must evict at epoch boundaries"
    );
}

// ---------------------------------------------------------------------
// Fabric end-to-end (idioms shared with tests/net_fabric.rs).
// ---------------------------------------------------------------------

fn serve_opts(shard_min_weights: usize) -> ServeOptions {
    let mut opts = CompileOptions::new(CFG, Method::Complete);
    opts.threads = 2;
    ServeOptions {
        service: ServiceOptions {
            opts,
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: None,
            store_dir: None, // memory-only fleet store on the coordinator
        },
        shard_min_weights,
        max_shards: 8,
        worker_timeout: Duration::from_secs(30),
        snapshot_dispatch: true,
    }
}

fn start_server(sopts: ServeOptions) -> (SocketAddr, thread::JoinHandle<FabricStats>) {
    let server = FabricServer::bind("127.0.0.1:0", sopts).unwrap();
    let addr = server.local_addr();
    let handle = thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

fn wait_for_workers(addr: SocketAddr, n: usize) {
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();
    for _ in 0..600 {
        if client.info().unwrap().workers as usize >= n {
            return;
        }
        thread::sleep(Duration::from_millis(10));
    }
    panic!("{n} workers never registered with the fabric at {addr}");
}

fn local_reference(chip_seed: u64, tensors: &[(String, Vec<i64>)]) -> Vec<(String, CompiledTensor)> {
    let chip = ChipFaults::new(chip_seed, FaultRates::paper_default());
    let mut session = CompileSession::builder(CFG).method(Method::Complete).chip(&chip);
    for (name, ws) in tensors {
        session.submit(name, ws.clone());
    }
    session.drain()
}

fn assert_results_match(got: &[TensorResult], want: &[(String, CompiledTensor)]) {
    assert_eq!(got.len(), want.len(), "tensor count");
    for (g, (name, w)) in got.iter().zip(want) {
        assert_eq!(&g.name, name);
        assert_eq!(g.errors, w.errors, "residual errors of {name}");
        assert_eq!(g.decomps, w.decomps, "bitmaps of {name}");
    }
}

#[test]
fn fabric_reuses_worker_published_solutions_across_jobs() {
    let tensors = model(2_000);
    let (addr, server) = start_server(serve_opts(1)); // always fan out
    let addr_s = addr.to_string();

    // Phase 1: one worker solves chip 21 cold and publishes its tables.
    let wa = addr_s.clone();
    let w1 = thread::spawn(move || run_worker(&wa, 1).unwrap());
    wait_for_workers(addr, 1);
    let mut client = CompileClient::connect(&addr_s).unwrap();
    let (r21, s21) = client.compile_model(21, CFG, Method::Complete, &tensors).unwrap();
    assert_eq!(s21.shards, 1);
    assert_eq!(s21.workers, 1);
    assert_results_match(&r21, &local_reference(21, &tensors));

    // Phase 2: a second worker joins with an *empty* replica; chip 22's
    // job fans out to both. Shared patterns are served by the fleet store
    // — the first worker's replica, or the coordinator's copy over
    // StoreGet — instead of being re-solved, and the output is still
    // byte-identical to a store-less local compile.
    let wb = addr_s.clone();
    let w2 = thread::spawn(move || run_worker(&wb, 1).unwrap());
    wait_for_workers(addr, 2);
    let (r22, s22) = client.compile_model(22, CFG, Method::Complete, &tensors).unwrap();
    assert_eq!(s22.shards, 2, "2 idle workers => a 2-way plan");
    assert_results_match(&r22, &local_reference(22, &tensors));

    client.shutdown_server().unwrap();
    server.join().unwrap();
    let rep1 = w1.join().unwrap();
    let rep2 = w2.join().unwrap();
    assert!(
        rep1.store_published > 0,
        "the cold chip-21 job must publish fresh tables to the coordinator"
    );
    assert!(
        rep1.store_hits + rep2.store_hits > 0,
        "chip 22 must reuse fleet-store tables published during chip 21's job"
    );
    assert!(
        rep2.store_published > 0 || rep2.store_hits > 0,
        "the late worker participates in the store either way"
    );
}
