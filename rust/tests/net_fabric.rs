//! Compile-fabric acceptance tests (RCWP v1 over localhost TCP).
//!
//! * A distributed compile — coordinator + 2 workers — produces compiled
//!   bitmaps AND fetched RCSS session bytes byte-identical to a local
//!   unsharded `CompileSession` compile (the default snapshot-dispatch
//!   path, where workers receive a sealed "RCRG" registry instead of the
//!   tensor set).
//! * Snapshot dispatch and tensor dispatch produce identical results and
//!   session bytes — the A/B pin of the two job flavors.
//! * Killing a worker mid-solve reassigns its pattern range to the live
//!   worker and the job still completes, byte-identically.
//! * Malformed, truncated, and wrong-version frames are rejected cleanly
//!   (and never take the server down).
//! * A workerless fabric degrades to local compilation, never failure.

use rchg::coordinator::{
    CompileOptions, CompileSession, CompiledTensor, Method, ServiceOptions, TableBudget,
};
use rchg::experiments::compile_time::synthetic_model_tensors;
use rchg::fault::bank::ChipFaults;
use rchg::fault::FaultRates;
use rchg::grouping::GroupConfig;
use rchg::net::protocol::{
    encode_hello, frame_bytes, read_frame, write_frame, FrameType, FRAME_HEADER_LEN,
};
use rchg::net::{run_worker, CompileClient, FabricServer, FabricStats, ServeOptions, TensorResult};
use std::io::{Cursor, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;
use std::time::Duration;

const CFG: GroupConfig = GroupConfig::R2C2;

fn model(limit: usize) -> Vec<(String, Vec<i64>)> {
    synthetic_model_tensors("resnet20", &CFG, limit).unwrap()
}

fn serve_opts(shard_min_weights: usize) -> ServeOptions {
    let mut opts = CompileOptions::new(CFG, Method::Complete);
    opts.threads = 2;
    ServeOptions {
        service: ServiceOptions {
            opts,
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: None,
            store_dir: None,
        },
        shard_min_weights,
        max_shards: 8,
        worker_timeout: Duration::from_secs(30),
        snapshot_dispatch: true,
    }
}

fn start_server(sopts: ServeOptions) -> (SocketAddr, thread::JoinHandle<FabricStats>) {
    let server = FabricServer::bind("127.0.0.1:0", sopts).unwrap();
    let addr = server.local_addr();
    let handle = thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

/// Poll the fabric until `n` workers sit idle in the pool.
fn wait_for_workers(addr: SocketAddr, n: usize) {
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();
    for _ in 0..600 {
        if client.info().unwrap().workers as usize >= n {
            return;
        }
        thread::sleep(Duration::from_millis(10));
    }
    panic!("{n} workers never registered with the fabric at {addr}");
}

/// The unsharded single-process reference: per-tensor outputs + the RCSS
/// bytes a local session saves after compiling the same tensor set.
fn local_reference(
    chip_seed: u64,
    tensors: &[(String, Vec<i64>)],
) -> (Vec<(String, CompiledTensor)>, Vec<u8>) {
    let chip = ChipFaults::new(chip_seed, FaultRates::paper_default());
    let mut session = CompileSession::builder(CFG).method(Method::Complete).chip(&chip);
    for (name, ws) in tensors {
        session.submit(name, ws.clone());
    }
    let out = session.drain();
    let bytes = session.to_bytes().unwrap();
    (out, bytes)
}

fn assert_results_match(got: &[TensorResult], want: &[(String, CompiledTensor)]) {
    assert_eq!(got.len(), want.len(), "tensor count");
    for (g, (name, w)) in got.iter().zip(want) {
        assert_eq!(&g.name, name);
        assert_eq!(g.errors, w.errors, "residual errors of {name}");
        assert_eq!(g.decomps, w.decomps, "bitmaps of {name}");
    }
}

#[test]
fn fabric_distributed_compile_is_byte_identical_to_local() {
    let tensors = model(2_500);
    let (addr, server) = start_server(serve_opts(1)); // force fan-out
    let addr_s = addr.to_string();
    let (wa, wb) = (addr_s.clone(), addr_s.clone());
    let w1 = thread::spawn(move || run_worker(&wa, 1).unwrap());
    let w2 = thread::spawn(move || run_worker(&wb, 1).unwrap());
    wait_for_workers(addr, 2);

    let mut client = CompileClient::connect(&addr_s).unwrap();
    let (results, summary) = client.compile_model(7, CFG, Method::Complete, &tensors).unwrap();
    assert_eq!(summary.shards, 2, "2 idle workers => a 2-way plan");
    assert_eq!(summary.workers, 2);
    assert_eq!(summary.reassigned, 0);
    assert!(summary.fresh_solves > 0, "a cold distributed job solves fresh work");

    // Acceptance: bitmaps AND RCSS session bytes byte-identical to a
    // local unsharded compile.
    let (want, want_bytes) = local_reference(7, &tensors);
    assert_results_match(&results, &want);
    let remote_bytes = client.fetch_session(7).unwrap();
    assert_eq!(remote_bytes, want_bytes, "fetched RCSS bytes must equal a local save");
    // The fetched bytes are a loadable session anywhere.
    let mut warm = CompileSession::from_bytes(&remote_bytes).unwrap();
    let again = warm.compile_tensor(&tensors[0].0, &tensors[0].1);
    assert_eq!(again.stats.unique_pairs, 0, "fetched cache must be warm");

    // A repeat job hits the retained warm session: local path, no solves.
    let (repeat, warm_summary) =
        client.compile_model(7, CFG, Method::Complete, &tensors).unwrap();
    assert_eq!(warm_summary.shards, 0, "warm jobs skip the fan-out");
    assert_eq!(warm_summary.fresh_solves, 0, "warm jobs solve nothing");
    assert_results_match(&repeat, &want);

    client.shutdown_server().unwrap();
    let stats = server.join().unwrap();
    assert_eq!(stats.jobs, 2);
    assert_eq!(stats.distributed_jobs, 1);
    assert_eq!(
        stats.snapshot_rounds, 1,
        "a table-tier distributed round must go out as a registry snapshot"
    );
    // Workers observe a clean EOF once the fabric stops.
    let r1 = w1.join().unwrap();
    let r2 = w2.join().unwrap();
    assert_eq!(r1.jobs + r2.jobs, 2, "each worker solved its range");
    assert!(r1.patterns_solved + r2.patterns_solved > 0);
}

/// The A/B pin of the two shard-job flavors: a fabric dispatching sealed
/// registry snapshots and one shipping tensor sets must produce
/// identical compiled outputs and identical fetched RCSS bytes — both
/// equal to the local unsharded reference.
#[test]
fn fabric_snapshot_and_tensor_dispatch_are_byte_identical() {
    let tensors = model(2_200);
    let chip_seed = 11;
    let mut fetched = Vec::new();
    for snapshot_dispatch in [true, false] {
        let mut sopts = serve_opts(1);
        sopts.snapshot_dispatch = snapshot_dispatch;
        let (addr, server) = start_server(sopts);
        let addr_s = addr.to_string();
        let (wa, wb) = (addr_s.clone(), addr_s.clone());
        let w1 = thread::spawn(move || run_worker(&wa, 1).unwrap());
        let w2 = thread::spawn(move || run_worker(&wb, 1).unwrap());
        wait_for_workers(addr, 2);

        let mut client = CompileClient::connect(&addr_s).unwrap();
        let (results, summary) =
            client.compile_model(chip_seed, CFG, Method::Complete, &tensors).unwrap();
        assert_eq!(summary.shards, 2);
        let (want, want_bytes) = local_reference(chip_seed, &tensors);
        assert_results_match(&results, &want);
        let bytes = client.fetch_session(chip_seed).unwrap();
        assert_eq!(bytes, want_bytes, "dispatch={snapshot_dispatch}: RCSS must equal local");
        fetched.push(bytes);

        client.shutdown_server().unwrap();
        let stats = server.join().unwrap();
        assert_eq!(
            stats.snapshot_rounds,
            if snapshot_dispatch { 1 } else { 0 },
            "snapshot_rounds must reflect the dispatch mode"
        );
        let (r1, r2) = (w1.join().unwrap(), w2.join().unwrap());
        assert!(r1.patterns_solved + r2.patterns_solved > 0);
    }
    assert_eq!(fetched[0], fetched[1], "the two dispatch modes must agree byte-for-byte");
}

#[test]
fn fabric_killed_worker_range_is_reassigned_to_a_live_worker() {
    let tensors = model(2_000);
    let (addr, server) = start_server(serve_opts(1));
    let addr_s = addr.to_string();

    // One real worker…
    let wa = addr_s.clone();
    let real = thread::spawn(move || run_worker(&wa, 1).unwrap());
    // …and one that registers, accepts a shard job, then dies mid-solve.
    let fake_addr = addr_s.clone();
    let fake = thread::spawn(move || {
        let mut s = TcpStream::connect(&fake_addr).unwrap();
        write_frame(&mut s, FrameType::Hello, &encode_hello(1)).unwrap();
        let ack = read_frame(&mut s).unwrap().unwrap();
        assert_eq!(ack.frame_type, FrameType::HelloAck);
        let _job = read_frame(&mut s); // swallow the assignment, then vanish
        drop(s);
    });
    wait_for_workers(addr, 2);

    let mut client = CompileClient::connect(&addr_s).unwrap();
    let (results, summary) = client.compile_model(9, CFG, Method::Complete, &tensors).unwrap();

    // The dead worker's range was requeued and solved by the live worker
    // — the job completed without local fallback changing a byte.
    assert_eq!(summary.shards, 2);
    assert!(summary.reassigned >= 1, "losing a worker must reassign its range");
    fake.join().unwrap();
    let (want, want_bytes) = local_reference(9, &tensors);
    assert_results_match(&results, &want);
    assert_eq!(client.fetch_session(9).unwrap(), want_bytes);

    client.shutdown_server().unwrap();
    let stats = server.join().unwrap();
    assert!(stats.reassignments >= 1);
    real.join().unwrap();
}

#[test]
fn fabric_workerless_coordinator_compiles_locally_and_restarts_warm() {
    let tensors = model(900);
    let dir = std::env::temp_dir().join(format!("rchg-fabric-restart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut sopts = serve_opts(1); // would shard, but no workers
    sopts.service.cache_dir = Some(dir.clone());
    let (addr, server) = start_server(sopts);
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();
    let (results, summary) = client.compile_model(3, CFG, Method::Complete, &tensors).unwrap();
    assert_eq!(summary.shards, 0);
    assert_eq!(summary.workers, 0);
    let (want, want_bytes) = local_reference(3, &tensors);
    assert_results_match(&results, &want);
    assert_eq!(client.fetch_session(3).unwrap(), want_bytes);
    client.shutdown_server().unwrap();
    server.join().unwrap();

    // A restarted coordinator over the same cache dir serves the warm
    // cache from disk — both for session fetches and for compiles
    // (which warm-start with zero fresh solves instead of re-solving).
    let mut sopts = serve_opts(1);
    sopts.service.cache_dir = Some(dir.clone());
    let (addr, server) = start_server(sopts);
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();
    assert_eq!(
        client.fetch_session(3).unwrap(),
        want_bytes,
        "restarted coordinator must serve the persisted warm cache"
    );
    let (again, warm_summary) = client.compile_model(3, CFG, Method::Complete, &tensors).unwrap();
    assert_eq!(warm_summary.fresh_solves, 0, "disk warm-start must solve nothing");
    assert_eq!(warm_summary.shards, 0);
    assert_results_match(&again, &want);
    client.shutdown_server().unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fabric_malformed_truncated_and_wrong_version_frames_are_rejected() {
    // Protocol-level rejection, no server involved: flip any byte of a
    // sealed frame and the reader must refuse it.
    let good = frame_bytes(FrameType::Info, &[]);
    for i in 0..good.len() {
        let mut bad = good.clone();
        bad[i] ^= 0x20;
        assert!(read_frame(&mut Cursor::new(&bad)).is_err(), "flip at {i} accepted");
    }
    // Every truncation of a frame errors; an empty stream is a clean EOF.
    for cut in 1..good.len() {
        assert!(read_frame(&mut Cursor::new(&good[..cut])).is_err());
    }
    assert!(read_frame(&mut Cursor::new(&[] as &[u8])).unwrap().is_none());

    // Server-level rejection: garbage and wrong-version frames get a
    // clean error and never take the fabric down.
    let (addr, server) = start_server(serve_opts(usize::MAX));
    let addr_s = addr.to_string();

    // Raw garbage: the connection is rejected. The server either answers
    // with an Error frame or hangs up (a reset is possible when it drops
    // the socket with bytes unread) — both are clean rejections, and the
    // load-bearing assertion is that the fabric survives, below.
    let mut garbage = TcpStream::connect(&addr_s).unwrap();
    garbage.write_all(&[0xFF; 64]).unwrap();
    garbage.flush().unwrap();
    if let Ok(Some(f)) = read_frame(&mut garbage) {
        assert_eq!(f.frame_type, FrameType::Error, "garbage must be answered with an error");
    }
    drop(garbage);

    // …a wrong-version frame is named as such…
    let mut stale = TcpStream::connect(&addr_s).unwrap();
    let mut v2 = frame_bytes(FrameType::Info, &[]);
    v2[4] = 2; // bump the version field
    stale.write_all(&v2).unwrap();
    stale.flush().unwrap();
    if let Ok(Some(f)) = read_frame(&mut stale) {
        assert_eq!(f.frame_type, FrameType::Error);
        assert!(
            String::from_utf8_lossy(&f.payload).contains("version"),
            "the rejection must name the version mismatch"
        );
    }
    drop(stale);

    // …a hostile payload length is capped before allocation…
    let mut huge = TcpStream::connect(&addr_s).unwrap();
    let mut oversized = frame_bytes(FrameType::Info, &[]);
    oversized[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
    huge.write_all(&oversized).unwrap();
    huge.flush().unwrap();
    drop(huge);

    // …and the fabric is still alive and serving valid clients.
    let mut client = CompileClient::connect(&addr_s).unwrap();
    let info = client.info().unwrap();
    assert_eq!(info.jobs, 0);
    client.shutdown_server().unwrap();
    server.join().unwrap();
}

#[test]
fn fabric_request_validation_answers_with_errors_not_hangups() {
    let (addr, server) = start_server(serve_opts(usize::MAX));
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();

    // Config mismatch: the server compiles R2C2.
    let small = vec![("t".to_string(), vec![1i64, -1])];
    let err = client
        .compile_model(1, GroupConfig::R1C4, Method::Complete, &small)
        .unwrap_err()
        .to_string();
    assert!(err.contains("R2C2") || err.contains("R1C4"), "got: {err}");

    // Out-of-range weights are named.
    let wild = vec![("t".to_string(), vec![1_000i64])];
    let err = client
        .compile_model(1, CFG, Method::Complete, &wild)
        .unwrap_err()
        .to_string();
    assert!(err.contains("outside"), "got: {err}");

    // Unknown chip for a session fetch.
    let err = client.fetch_session(999).unwrap_err().to_string();
    assert!(err.contains("no warm session"), "got: {err}");

    // The same connection still serves valid requests after each error.
    let (_, summary) = client.compile_model(2, CFG, Method::Complete, &small).unwrap();
    assert_eq!(summary.tensors, 1);
    client.shutdown_server().unwrap();
    server.join().unwrap();
}

#[test]
fn fabric_frame_header_layout_is_stable() {
    // The header is part of the wire contract: magic, version, type, len.
    let bytes = frame_bytes(FrameType::Hello, &[0xAA, 0xBB]);
    assert_eq!(FRAME_HEADER_LEN, 16);
    assert_eq!(&bytes[0..4], &0x5243_5750u32.to_le_bytes()); // "RCWP"
    assert_eq!(&bytes[4..8], &1u32.to_le_bytes()); // version
    assert_eq!(&bytes[8..12], &FrameType::Hello.code().to_le_bytes());
    assert_eq!(&bytes[12..16], &2u32.to_le_bytes()); // payload length
    assert_eq!(&bytes[16..18], &[0xAA, 0xBB]);
    assert_eq!(bytes.len(), 16 + 2 + 8); // header + payload + checksum
}
