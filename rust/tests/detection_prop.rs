//! Property tests for fault-map extraction (`fault::detection`).
//!
//! Every property here is *exact* — no statistical thresholds — so the
//! suite is deterministic by construction: `prop_check` derives each
//! case's `Rng` from a fixed base seed and reports the failing seed for
//! replay. The statistical behaviour of noisy detection (vote counts vs
//! misclassification rates) is covered by the module's unit tests; these
//! properties pin the contracts the rest of the repo leans on — exact
//! recovery at zero noise, honest bookkeeping, and same-seed determinism.

use rchg::fault::detection::{march_detect, PhysicalArray};
use rchg::fault::{FaultRates, FaultState};
use rchg::util::prng::Rng;
use rchg::util::prop::prop_check;
use rchg::{prop_assert, prop_assert_eq};

/// Random rates well above the paper defaults so every case sees all
/// three states; random geometry spans 1-bit cells to 3-bit cells.
fn random_case(rng: &mut Rng) -> (PhysicalArray, Vec<FaultState>) {
    let cells = 1 + rng.index(600);
    let levels = 2 + rng.index(7) as u8;
    let rates = FaultRates { p_sa0: 0.3 * rng.f64(), p_sa1: 0.3 * rng.f64() };
    let arr = PhysicalArray::sample(cells, levels, &rates, rng);
    let truth = arr.truth.clone();
    (arr, truth)
}

#[test]
fn prop_noiseless_march_recovers_any_injected_map_exactly() {
    prop_check("march-noiseless-exact", 200, |rng| {
        let (mut arr, truth) = random_case(rng);
        let votes = 1 + rng.index(9);
        let det = march_detect(&mut arr, 0.0, votes, rng);
        prop_assert_eq!(det.misclassified, 0);
        prop_assert_eq!(det.measured, truth);
        Ok(())
    });
}

#[test]
fn prop_misclassified_count_is_the_measured_truth_divergence() {
    // The reported counter must always equal an independent recount —
    // under noise too, where measured and truth genuinely diverge.
    prop_check("march-misclassified-recount", 150, |rng| {
        let (mut arr, truth) = random_case(rng);
        let noise = 0.4 * rng.f64();
        let votes = 1 + rng.index(7);
        let det = march_detect(&mut arr, noise, votes, rng);
        prop_assert_eq!(det.measured.len(), truth.len());
        let recount =
            det.measured.iter().zip(&truth).filter(|(m, t)| m != t).count();
        prop_assert_eq!(det.misclassified, recount);
        Ok(())
    });
}

#[test]
fn prop_same_seed_detection_replays_identically() {
    // The whole experiments layer assumes a (chip, seed) pair replays to
    // the same measured map; noise must come only from the passed Rng.
    prop_check("march-seeded-determinism", 100, |rng| {
        let (arr, _) = random_case(rng);
        let noise = 0.3 * rng.f64();
        let votes = 1 + rng.index(9);
        let replay_seed = rng.next_u64();
        let mut a = arr.clone();
        let det_a = march_detect(&mut a, noise, votes, &mut Rng::new(replay_seed));
        let mut b = arr.clone();
        let det_b = march_detect(&mut b, noise, votes, &mut Rng::new(replay_seed));
        prop_assert_eq!(det_a.measured, det_b.measured);
        prop_assert_eq!(det_a.misclassified, det_b.misclassified);
        Ok(())
    });
}

#[test]
fn prop_even_vote_counts_round_up_to_the_next_odd() {
    // `march_detect` normalises `votes` to `max(1) | 1` *before* any
    // randomness is consumed, so votes = 2k and votes = 2k+1 must be
    // byte-for-byte the same procedure under the same Rng seed.
    prop_check("march-votes-round-odd", 100, |rng| {
        let (arr, _) = random_case(rng);
        let noise = 0.3 * rng.f64();
        let even = 2 * (1 + rng.index(4)); // 2, 4, 6, 8
        let replay_seed = rng.next_u64();
        let mut a = arr.clone();
        let det_even = march_detect(&mut a, noise, even, &mut Rng::new(replay_seed));
        let mut b = arr.clone();
        let det_odd = march_detect(&mut b, noise, even + 1, &mut Rng::new(replay_seed));
        prop_assert_eq!(det_even.measured, det_odd.measured);
        Ok(())
    });
}

#[test]
fn prop_detection_is_independent_of_prior_array_contents() {
    // The march sequence writes before every read; whatever a previous
    // workload left programmed in the cells must not leak into the map.
    prop_check("march-ignores-prior-writes", 100, |rng| {
        let (arr, _) = random_case(rng);
        let noise = 0.2 * rng.f64();
        let replay_seed = rng.next_u64();
        let mut fresh = arr.clone();
        let mut dirty = arr.clone();
        for idx in 0..dirty.truth.len() {
            dirty.write(idx, rng.index(dirty.levels as usize) as u8);
        }
        let det_fresh = march_detect(&mut fresh, noise, 3, &mut Rng::new(replay_seed));
        let det_dirty = march_detect(&mut dirty, noise, 3, &mut Rng::new(replay_seed));
        prop_assert_eq!(det_fresh.measured, det_dirty.measured);
        Ok(())
    });
}

#[test]
fn prop_handcrafted_maps_classify_per_cell() {
    // Point-wise ground truth: overwrite the sampled map with a crafted
    // one mixing all three states at known positions, then check the
    // classification cell by cell at zero noise.
    prop_check("march-handcrafted-cells", 100, |rng| {
        let cells = 3 + rng.index(100);
        let levels = 2 + rng.index(7) as u8;
        let mut arr =
            PhysicalArray::sample(cells, levels, &FaultRates { p_sa0: 0.0, p_sa1: 0.0 }, rng);
        let mut truth = vec![FaultState::Free; cells];
        for slot in truth.iter_mut() {
            *slot = match rng.index(3) {
                0 => FaultState::Free,
                1 => FaultState::Sa0,
                _ => FaultState::Sa1,
            };
        }
        arr.truth = truth.clone();
        let det = march_detect(&mut arr, 0.0, 1, rng);
        for (idx, (m, t)) in det.measured.iter().zip(&truth).enumerate() {
            prop_assert!(m == t, "cell {idx}: measured {m:?}, injected {t:?} (L={levels})");
        }
        Ok(())
    });
}
