//! Pattern-class compiler acceptance tests: byte-equivalence with the
//! legacy per-weight path on a ResNet-20-shaped tensor at paper fault
//! rates, thread-count invariance, cached-context equivalence, chip-wide
//! cross-tensor reuse, and the dedup-counter accounting.

use rchg::coordinator::{
    decompose_one, decompose_with_ctx, CompileOptions, CompileSession, CompiledTensor, Method,
    PatternCtx, PipelineOptions,
};
use rchg::experiments::compile_time::synthetic_model_weights;
use rchg::fault::bank::ChipFaults;
use rchg::fault::{FaultRates, GroupFaults};
use rchg::grouping::GroupConfig;
use rchg::ilp::IlpStats;
use rchg::prop_assert;
use rchg::util::prop::prop_check;

/// One-shot compile against explicit fault maps (the removed free
/// function's surface, via a throwaway detached session).
fn compile_tensor(ws: &[i64], faults: &[GroupFaults], opts: &CompileOptions) -> CompiledTensor {
    CompileSession::builder(opts.cfg)
        .options(opts.clone())
        .detached()
        .compile_with_faults(ws, faults)
}

/// One-shot model compile for a chip (the removed free function's
/// surface, via a throwaway chip session).
fn compile_model(
    tensors: &[(String, Vec<i64>)],
    chip: &ChipFaults,
    opts: &CompileOptions,
) -> Vec<(String, CompiledTensor, Vec<GroupFaults>)> {
    CompileSession::builder(opts.cfg).options(opts.clone()).chip(chip).compile_model(tensors)
}

#[test]
fn resnet20_pattern_class_matches_legacy_across_threads() {
    // ResNet-20-shaped weights at the paper's published SAF rates: the
    // dedupe-first core must be byte-identical to the per-weight path for
    // threads ∈ {1, 4, 8}.
    for cfg in [GroupConfig::R2C2, GroupConfig::R1C4] {
        let ws = synthetic_model_weights("resnet20", &cfg, 25_000).unwrap();
        let chip = ChipFaults::new(1, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
        let mut legacy = CompileOptions::new(cfg, Method::Complete);
        legacy.dedupe = false;
        let base = compile_tensor(&ws, &faults, &legacy);
        for threads in [1usize, 4, 8] {
            let mut o = CompileOptions::new(cfg, Method::Complete);
            o.threads = threads;
            let out = compile_tensor(&ws, &faults, &o);
            assert_eq!(out.decomps, base.decomps, "{cfg} decomps diverged at threads={threads}");
            assert_eq!(out.errors, base.errors, "{cfg} errors diverged at threads={threads}");
            assert_eq!(out.stats.stage_counts, base.stats.stage_counts, "{cfg} stage census");
            assert_eq!(out.stats.unique_pairs + out.stats.dedup_hits, ws.len());
        }
    }
}

#[test]
fn resnet20_dedup_factor_exceeds_five() {
    // The scaling claim behind the refactor: at paper fault rates the
    // solver runs on ≥5x fewer unique (pattern, weight) pairs than there
    // are weights (R2C2's ±30 weight range keeps the pair space tiny).
    let cfg = GroupConfig::R2C2;
    let ws = synthetic_model_weights("resnet20", &cfg, 60_000).unwrap();
    let chip = ChipFaults::new(1, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
    let out = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
    assert!(out.stats.unique_patterns > 1);
    assert!(
        out.stats.dedup_ratio() >= 5.0,
        "dedup ratio {:.2} < 5 ({} weights, {} unique pairs)",
        out.stats.dedup_ratio(),
        ws.len(),
        out.stats.unique_pairs
    );
}

#[test]
fn cached_pattern_ctx_matches_fresh_build_per_weight() {
    // Property: a PatternCtx reused across many weights (analysis + tables
    // built once, cached) yields the same Outcome as a fresh
    // FaultAnalysis/GroupTables build per (pattern, weight).
    let opts = PipelineOptions::default();
    prop_check("cached-ctx-vs-fresh", 150, |rng| {
        let cfg = [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4][rng.index(3)];
        let faults =
            GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.12, p_sa1: 0.12 }, rng);
        let ctx = PatternCtx::new(cfg, faults.clone());
        for _ in 0..5 {
            let w = rng.range_i64(-cfg.max_per_array(), cfg.max_per_array());
            let mut s1 = IlpStats::default();
            let mut s2 = IlpStats::default();
            let cached = decompose_with_ctx(&ctx, w, &opts, &mut s1);
            let fresh = decompose_one(&cfg, &faults, w, &opts, &mut s2);
            prop_assert!(
                cached.decomposition == fresh.decomposition
                    && cached.error == fresh.error
                    && cached.stage == fresh.stage,
                "cached ctx diverged (cfg {cfg}, w {w}, stages {:?} vs {:?})",
                cached.stage,
                fresh.stage
            );
        }
        Ok(())
    });
}

#[test]
fn chip_wide_cache_shares_pairs_across_tensors() {
    // compile_model runs all tensors through one chip-wide SolveCache: the
    // later tensors' unique-pair counts must reflect cross-tensor reuse,
    // and outputs must equal the legacy per-tensor compilation.
    let cfg = GroupConfig::R2C2;
    let tensors: Vec<(String, Vec<i64>)> = (0..3)
        .map(|i| {
            (
                format!("layer{i}"),
                synthetic_model_weights("resnet20", &cfg, 8_000).unwrap(),
            )
        })
        .collect();
    let chip = ChipFaults::new(9, FaultRates::paper_default());
    let shared = compile_model(&tensors, &chip, &CompileOptions::new(cfg, Method::Complete));
    let mut legacy_opts = CompileOptions::new(cfg, Method::Complete);
    legacy_opts.dedupe = false;
    let legacy = compile_model(&tensors, &chip, &legacy_opts);
    for ((_, a, fa), (_, b, fb)) in shared.iter().zip(&legacy) {
        assert_eq!(fa, fb, "fault sampling must be identical");
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
    }
    // Later tensors solve fewer fresh pairs than the first (cache warm-up).
    let first = shared[0].1.stats.unique_pairs;
    let last = shared[2].1.stats.unique_pairs;
    assert!(
        last * 10 < first * 7,
        "chip-wide cache not reused: first tensor solved {first}, third solved {last}"
    );
    // Registry gauge is chip-wide: later tensors see at least as many
    // interned patterns as earlier ones.
    assert!(shared[2].1.stats.unique_patterns >= shared[0].1.stats.unique_patterns);
}

#[test]
fn dedup_invariant_under_thread_count_and_methods() {
    // unique_pairs is a property of the input, not of the schedule; and
    // every method (not just Complete) runs through the dedupe core.
    let cfg = GroupConfig::R1C4;
    let ws = synthetic_model_weights("resnet20", &cfg, 8_000).unwrap();
    let chip = ChipFaults::new(2, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
    let mut pair_counts = Vec::new();
    for threads in [1usize, 4, 8] {
        let mut o = CompileOptions::new(cfg, Method::Complete);
        o.threads = threads;
        pair_counts.push(compile_tensor(&ws, &faults, &o).stats.unique_pairs);
    }
    assert!(pair_counts.windows(2).all(|w| w[0] == w[1]), "{pair_counts:?}");

    for method in [Method::IlpOnly, Method::Unprotected] {
        let sample = &ws[..600];
        let fsample = &faults[..600];
        let a = compile_tensor(sample, fsample, &CompileOptions::new(cfg, method));
        let mut legacy = CompileOptions::new(cfg, method);
        legacy.dedupe = false;
        let b = compile_tensor(sample, fsample, &legacy);
        assert_eq!(a.decomps, b.decomps, "{method:?} diverged");
        assert_eq!(a.errors, b.errors);
        assert!(a.stats.unique_pairs <= sample.len());
    }
}
