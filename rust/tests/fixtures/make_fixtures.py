#!/usr/bin/env python3
"""Golden byte fixtures for every persisted/wire codec in the repo.

Generates one canonical binary per format, independently of the Rust
encoders, so `tests/golden_formats.rs` (and the RCRG unit test in
`coordinator/persist.rs`) can pin the byte layouts: a refactor that
changes any codec's bytes fails against these files, not only against
its own round-trip.

Formats (layouts transcribed from the Rust sources, all little-endian,
sealed with a trailing FNV-1a-64 checksum except the RCWP frame, whose
checksum covers header+payload):

* RCWP v1 frame      — net/protocol.rs   (rcwp_hello_v1.bin)
* RCSS v2 session    — coordinator/session.rs (rcss_v2_empty.bin)
* RCSF v1 fragment   — coordinator/shard.rs   (rcsf_v1_fragment.bin)
* RCRG v1 snapshot   — coordinator/persist.rs (rcrg_v1_snapshot.bin)
* RCPS v1 store blob — store/mod.rs           (rcps_v1_blob.bin)

Re-run this script to bless new bytes after an *intentional* format
change (then bump the relevant version constant and document the
migration): `python3 rust/tests/fixtures/make_fixtures.py`
"""

import os
import struct

OUT = os.path.dirname(os.path.abspath(__file__))

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK = (1 << 64) - 1


def fnv1a(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK
    return h


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def i64(v):
    return struct.pack("<q", v)


def f64_bits(v):
    return struct.pack("<d", v)  # same IEEE-754 bits Rust's to_bits() writes


def seal(payload: bytes) -> bytes:
    return payload + u64(fnv1a(payload))


# ---- shared constants (must mirror the Rust sources) --------------------

CHIP_SEED = 7
P_SA0 = 0.0175  # fault::DEFAULT_P_SA0
P_SA1 = 0.0904  # fault::DEFAULT_P_SA1
ROWS, COLS, LEVELS = 2, 2, 4  # GroupConfig::R2C2
CELLS = ROWS * COLS
MAX_PER_ARRAY = ROWS * (LEVELS**COLS - 1)  # 30
TABLE_LEN = 2 * MAX_PER_ARRAY + 1  # 61
METHOD_COMPLETE = 0
TABLE_VALUE_LIMIT = 4096  # PipelineOptions::default()
SPARSEST = 0

FREE, SA0, SA1 = 0, 1, 2  # FaultState codes
TAG_TABLE, TAG_PAIRS, TAG_EMPTY = 0, 1, 2


def cache_key() -> bytes:
    """persist::write_key — 50 bytes shared by RCSS/RCSF/RCRG."""
    return (
        u64(CHIP_SEED)
        + f64_bits(P_SA0)
        + f64_bits(P_SA1)
        + u32(ROWS)
        + u32(COLS)
        + u32(LEVELS)
        + bytes([METHOD_COMPLETE, SPARSEST])
        + i64(TABLE_VALUE_LIMIT)
        + u32(CELLS)
    )


def outcome(idx: int) -> bytes:
    """persist::push_outcome — error i64, stage u8, pos/neg cell bytes.

    Values vary with the table index so byte-identity checks are not
    trivially all-zero: cell levels stay < LEVELS, stage codes stay in
    the valid 0..=8 range.
    """
    pos = bytes([idx % LEVELS, 0, 0, 0])
    neg = bytes([0, (idx // LEVELS) % LEVELS, 0, 0])
    return i64(0) + bytes([idx % 3]) + pos + neg


def full_table() -> bytes:
    return bytes([TAG_TABLE]) + b"".join(outcome(i) for i in range(TABLE_LEN))


def pattern(pos, neg) -> bytes:
    assert len(pos) == len(neg) == CELLS
    return bytes(pos) + bytes(neg)


# ---- RCWP v1: one Hello frame (worker with 3 solve threads) -------------

def rcwp_hello() -> bytes:
    payload = u32(3)  # encode_hello(3)
    head = u32(0x52435750) + u32(1) + u32(1) + u32(len(payload))  # magic, ver, Hello
    body = head + payload
    return body + u64(fnv1a(body))


# ---- RCSS v2: an empty warm session (0 patterns) ------------------------
# The only session file whose decode -> re-encode is byte-stable by the
# format's own contract (save_parts drops never-hit warm entries).

def rcss_empty() -> bytes:
    payload = u32(0x52435353) + u32(2) + cache_key() + u32(0)
    return seal(payload)


# ---- RCSF v1: shard 1 of a 2-way plan over 6 patterns -------------------
# ShardPlan::new(2).range(1, 6) == 3..6, so the fragment carries 3 parts
# exercising all three solution tags: a dense table, a pairs map (sorted
# by weight, as the Rust encoder writes), and an empty (unsolved) slot.

def rcsf_fragment() -> bytes:
    parts = (
        pattern([FREE] * 4, [FREE] * 4)
        + full_table()
        + pattern([SA0, FREE, FREE, FREE], [FREE, SA1, FREE, FREE])
        + bytes([TAG_PAIRS])
        + u32(2)
        + i64(-2)
        + outcome(1)
        + i64(5)
        + outcome(2)
        + pattern([FREE, FREE, SA1, FREE], [SA0, FREE, FREE, FREE])
        + bytes([TAG_EMPTY])
    )
    payload = (
        u32(0x52435346)
        + u32(1)
        + cache_key()
        + u32(1)  # shard
        + u32(2)  # shards
        + u32(6)  # n_patterns
        + u32(3)  # start
        + u32(3)  # len
        + parts
    )
    return seal(payload)


# ---- RCRG v1: a 2-pattern registry snapshot -----------------------------

def rcrg_snapshot() -> bytes:
    payload = (
        u32(0x52435247)
        + u32(1)
        + cache_key()
        + u32(2)
        + pattern([FREE] * 4, [FREE] * 4)
        + pattern([SA0, FREE, FREE, FREE], [FREE, FREE, FREE, SA1])
    )
    return seal(payload)


# ---- RCPS v1: one store blob (full-range table for one pattern) ---------
# Header is StoreCtx::push_bytes — the cache key minus the chip fields
# (chip identity is excluded from a solution's identity by design).

def rcps_blob() -> bytes:
    ctx = (
        u32(ROWS)
        + u32(COLS)
        + u32(LEVELS)
        + bytes([METHOD_COMPLETE, SPARSEST])
        + i64(TABLE_VALUE_LIMIT)
        + u32(CELLS)
    )
    payload = (
        u32(0x52435053)
        + u32(1)
        + ctx
        + pattern([FREE, SA0, FREE, FREE], [FREE, FREE, FREE, SA1])
        + full_table()
    )
    return seal(payload)


FIXTURES = {
    "rcwp_hello_v1.bin": rcwp_hello,
    "rcss_v2_empty.bin": rcss_empty,
    "rcsf_v1_fragment.bin": rcsf_fragment,
    "rcrg_v1_snapshot.bin": rcrg_snapshot,
    "rcps_v1_blob.bin": rcps_blob,
}


def main():
    for name, build in FIXTURES.items():
        data = build()
        path = os.path.join(OUT, name)
        with open(path, "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes, fnv1a={fnv1a(data):016x}")


if __name__ == "__main__":
    main()
