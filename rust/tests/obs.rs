//! Observability acceptance tests: the determinism contract of the
//! tracing subsystem and the live fleet-telemetry path.
//!
//! * The histogram bucket layout is pinned — it is part of the
//!   `StatsPush` wire contract (changing it is a protocol bump).
//! * A traced sequential compile emits a schema-valid `rchg-trace-v1`
//!   stream whose timing-stripped skeleton is byte-identical across two
//!   runs — and tracing never changes a compiled output byte.
//! * A distributed (coordinator + workers) compile is byte-identical
//!   with tracing on vs off, and the multi-threaded trace stream is
//!   still schema-valid.
//! * `StatsPull` against a live fabric returns the coordinator's real
//!   registry: job counters, shard-latency histogram, store gauges.
//!
//! The trace sink and the metrics registry are process-global, so every
//! test that touches them holds `OBS_LOCK` (the `fabric_`-prefixed tests
//! additionally run under `--test-threads=1` in CI's bounded socket
//! step, like `tests/net_fabric.rs`).

use rchg::coordinator::{CompileSession, CompiledTensor, Method};
use rchg::experiments::compile_time::synthetic_model_tensors;
use rchg::fault::bank::ChipFaults;
use rchg::fault::FaultRates;
use rchg::grouping::GroupConfig;
use rchg::net::{run_worker, CompileClient, FabricServer, ServeOptions};
use rchg::obs;
use rchg::util::json::Json;
use std::net::SocketAddr;
use std::sync::{Mutex, PoisonError};
use std::thread;
use std::time::Duration;

const CFG: GroupConfig = GroupConfig::R2C2;

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn model(limit: usize) -> Vec<(String, Vec<i64>)> {
    synthetic_model_tensors("resnet20", &CFG, limit).unwrap()
}

fn serve_opts(shard_min_weights: usize) -> ServeOptions {
    use rchg::coordinator::{CompileOptions, ServiceOptions, TableBudget};
    let mut opts = CompileOptions::new(CFG, Method::Complete);
    opts.threads = 2;
    ServeOptions {
        service: ServiceOptions {
            opts,
            rates: FaultRates::paper_default(),
            table_budget: TableBudget::PerSession,
            cache_dir: None,
            store_dir: None,
        },
        shard_min_weights,
        max_shards: 8,
        worker_timeout: Duration::from_secs(30),
        snapshot_dispatch: true,
    }
}

fn start_server(sopts: ServeOptions) -> (SocketAddr, thread::JoinHandle<rchg::net::FabricStats>) {
    let server = FabricServer::bind("127.0.0.1:0", sopts).unwrap();
    let addr = server.local_addr();
    let handle = thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

fn wait_for_workers(addr: SocketAddr, n: usize) {
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();
    for _ in 0..600 {
        if client.info().unwrap().workers as usize >= n {
            return;
        }
        thread::sleep(Duration::from_millis(10));
    }
    panic!("{n} workers never registered with the fabric at {addr}");
}

/// One local sequential compile of `tensors` for `chip_seed`: the
/// compiled outputs plus the RCSS session bytes.
fn local_compile(
    chip_seed: u64,
    tensors: &[(String, Vec<i64>)],
) -> (Vec<(String, CompiledTensor)>, Vec<u8>) {
    let chip = ChipFaults::new(chip_seed, FaultRates::paper_default());
    let mut session = CompileSession::builder(CFG).method(Method::Complete).threads(2).chip(&chip);
    for (name, ws) in tensors {
        session.submit(name, ws.clone());
    }
    let out = session.drain();
    let bytes = session.to_bytes().unwrap();
    (out, bytes)
}

#[test]
fn obs_histogram_bucket_layout_is_pinned() {
    // Part of the StatsPush wire contract — see docs/OBSERVABILITY.md.
    assert_eq!(obs::HIST_BUCKETS, 33);
    let pins = [
        (0u64, 0usize),
        (1, 1),
        (2, 2),
        (3, 2),
        (4, 3),
        (1023, 10),
        (1024, 11),
        ((1 << 31) - 1, 31),
        (1 << 31, 32),
        (u64::MAX, 32),
    ];
    for (v, bucket) in pins {
        assert_eq!(obs::bucket_index(v), bucket, "bucket_index({v})");
    }
}

#[test]
fn obs_trace_schema_roundtrip_is_deterministic() {
    let _g = OBS_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let tensors = model(1_500);

    // Reference run with tracing off.
    obs::set_sink(None);
    let (want, want_bytes) = local_compile(5, &tensors);

    // Two identical traced runs.
    let mut dumps = Vec::new();
    for _ in 0..2 {
        let mem = obs::MemorySink::new(1 << 16);
        obs::set_sink(Some(Box::new(mem.clone())));
        let (got, got_bytes) = local_compile(5, &tensors);
        let written = obs::set_sink(None);

        // Tracing never changes an output byte.
        assert_eq!(got.len(), want.len());
        for ((gn, gt), (wn, wt)) in got.iter().zip(&want) {
            assert_eq!(gn, wn);
            assert_eq!(gt.decomps, wt.decomps, "bitmaps of {gn} changed under tracing");
            assert_eq!(gt.errors, wt.errors);
        }
        assert_eq!(got_bytes, want_bytes, "RCSS bytes changed under tracing");

        let lines = mem.lines();
        assert_eq!(lines.len() as u64, written, "set_sink(None) reports the record count");
        // The dump is schema-valid end to end.
        assert_eq!(obs::validate_trace(&lines.join("\n")).unwrap(), written);
        dumps.push(lines);
    }

    // The full timing-stripped skeletons — names, seq, span/parent ids,
    // deterministic fields — agree byte-for-byte across the two runs.
    let strip = |lines: &[String]| -> Vec<String> {
        lines
            .iter()
            .map(|l| obs::strip_timings(&Json::parse(l).unwrap()).to_string())
            .collect()
    };
    assert_eq!(strip(&dumps[0]), strip(&dumps[1]), "traced runs must have identical skeletons");

    // The span taxonomy over the compile pipeline is present.
    let names: Vec<String> = dumps[0]
        .iter()
        .filter_map(|l| Json::parse(l).unwrap().get("name").as_str().map(String::from))
        .collect();
    for expect in ["compile.batch", "compile.scan", "compile.solve", "compile.scatter", "session.save"]
    {
        assert!(names.iter().any(|n| n == expect), "missing span {expect:?} in {names:?}");
    }
}

#[test]
fn fabric_trace_on_vs_off_byte_identity() {
    let _g = OBS_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let tensors = model(2_000);
    let (want, want_bytes) = local_compile(7, &tensors);

    let mut fetched = Vec::new();
    for traced in [false, true] {
        let mem = obs::MemorySink::new(1 << 16);
        obs::set_sink(traced.then(|| Box::new(mem.clone()) as Box<dyn obs::Sink>));

        let (addr, server) = start_server(serve_opts(1)); // force fan-out
        let addr_s = addr.to_string();
        let (wa, wb) = (addr_s.clone(), addr_s.clone());
        let w1 = thread::spawn(move || run_worker(&wa, 1).unwrap());
        let w2 = thread::spawn(move || run_worker(&wb, 1).unwrap());
        wait_for_workers(addr, 2);

        let mut client = CompileClient::connect(&addr_s).unwrap();
        let (results, summary) =
            client.compile_model(7, CFG, Method::Complete, &tensors).unwrap();
        assert_eq!(summary.shards, 2, "traced={traced}: 2 idle workers => a 2-way plan");
        assert_eq!(results.len(), want.len());
        for (g, (name, w)) in results.iter().zip(&want) {
            assert_eq!(&g.name, name);
            assert_eq!(g.decomps, w.decomps, "traced={traced}: bitmaps of {name}");
            assert_eq!(g.errors, w.errors);
        }
        let bytes = client.fetch_session(7).unwrap();
        assert_eq!(bytes, want_bytes, "traced={traced}: fetched RCSS must equal a local save");
        fetched.push(bytes);

        client.shutdown_server().unwrap();
        server.join().unwrap();
        w1.join().unwrap();
        w2.join().unwrap();

        let written = obs::set_sink(None);
        if traced {
            // The concurrent (server + worker threads) stream is still
            // schema-valid: seq is assigned under the sink lock, so it
            // equals the line index even with many emitting threads.
            let lines = mem.lines();
            assert!(written > 1, "a traced distributed compile emits spans");
            assert_eq!(obs::validate_trace(&lines.join("\n")).unwrap(), written);
            let names: Vec<String> = lines
                .iter()
                .filter_map(|l| Json::parse(l).unwrap().get("name").as_str().map(String::from))
                .collect();
            for expect in ["fabric.distribute", "fabric.shard", "fabric.merge", "worker.job"] {
                assert!(names.iter().any(|n| n == expect), "missing span {expect:?}");
            }
        }
    }
    assert_eq!(fetched[0], fetched[1], "tracing on vs off must agree byte-for-byte");
}

#[test]
fn fabric_stats_pull_reports_live_metrics() {
    let _g = OBS_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    obs::set_sink(None);
    obs::metrics().reset();

    let tensors = model(2_000);
    let (addr, server) = start_server(serve_opts(1));
    let addr_s = addr.to_string();
    let (wa, wb) = (addr_s.clone(), addr_s.clone());
    let w1 = thread::spawn(move || run_worker(&wa, 1).unwrap());
    let w2 = thread::spawn(move || run_worker(&wb, 1).unwrap());
    wait_for_workers(addr, 2);

    let mut client = CompileClient::connect(&addr_s).unwrap();

    // A scrape before any job still answers (zeroed gauges, no panic).
    let cold = client.stats().unwrap();
    assert_eq!(cold.gauge("fabric.jobs"), 0);
    assert_eq!(cold.gauge("fabric.workers_joined"), 2);

    let (_, summary) = client.compile_model(7, CFG, Method::Complete, &tensors).unwrap();
    assert_eq!(summary.shards, 2);

    let snap = client.stats().unwrap();
    // Coordinator-side gauges reflect the finished job.
    assert_eq!(snap.gauge("fabric.jobs"), 1);
    assert_eq!(snap.gauge("fabric.distributed_jobs"), 1);
    assert_eq!(snap.gauge("fabric.workers_joined"), 2);
    assert!(snap.gauge("fabric.shards_dispatched") >= 2);
    assert_eq!(snap.gauge("fabric.sessions_warm"), 1);
    // The per-shard latency histogram recorded every dispatched range.
    let lat = snap.histogram("fabric.shard.latency_us").expect("shard latency histogram");
    assert!(lat.count >= 2, "2 shard ranges => 2 latency observations, got {}", lat.count);
    assert_eq!(lat.buckets.iter().sum::<u64>(), lat.count);
    // Compile counters were mirrored once per batch on the coordinator.
    assert!(snap.counter("compile.batches") >= 1);
    assert!(snap.counter("compile.weights") > 0);
    // Store gauges are present even for a storeless fabric (all zero).
    for name in ["store.hits", "store.misses", "store.io_errors", "store.rejected_blobs"] {
        assert!(snap.get(name).is_some(), "missing {name} in the scrape");
    }
    // In-process workers share the registry, so their counters show too.
    assert!(snap.counter("worker.jobs") >= 2);

    // The text exposition carries every scraped entry, name-sorted.
    let text = snap.render();
    assert_eq!(text.lines().count(), snap.len());
    let mut lines: Vec<&str> = text.lines().collect();
    lines.sort_by_key(|l| l.split_whitespace().nth(1).unwrap_or("").to_string());
    assert_eq!(lines, text.lines().collect::<Vec<_>>(), "render must be name-sorted");

    client.shutdown_server().unwrap();
    server.join().unwrap();
    w1.join().unwrap();
    w2.join().unwrap();
    obs::metrics().reset();
}
