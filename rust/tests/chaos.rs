//! Chaos suite: deterministic fault schedules against localhost fleets.
//!
//! Compiled (and meaningful) only with the `failpoints` feature; CI runs
//! it as its own bounded step:
//!
//! ```text
//! cargo test --test chaos --features failpoints -- --test-threads=1
//! ```
//!
//! Every scenario asserts the spine invariant: the faulted job either
//! completes **byte-identical** to a fault-free local compile (bitmaps +
//! fetched RCSS session bytes) or fails with a **typed error** while the
//! fabric stays alive — never a hang (watchdog-bounded), never a panic,
//! never silently wrong bytes. Scripted scenarios cover each named
//! failpoint; the seeded schedules compose them randomly and replay
//! exactly from their seed (repro: `rchg chaos --seed <N>`).
#![cfg(feature = "failpoints")]

use rchg::coordinator::Method;
use rchg::net::chaos::{
    self, check_results, local_reference, model, random_scenario, run_scenario, run_seed,
    scratch_dir, Scenario, CFG,
};
use rchg::net::{CompileClient, FabricServer};
use rchg::store::{SolutionStore, StoreCounters, StoreHandle};
use rchg::util::failpoint;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::thread;

/// Failpoints are process-global; serialize the suite so scenarios never
/// see each other's armed points even without `--test-threads=1`.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Weights per chaos job: big enough to fan out (shard_min_weights = 1
/// anyway) and to hit a few hundred distinct patterns, small enough that
/// a dozen scenarios stay inside the CI step's bound.
const WEIGHTS: usize = 700;

/// Run one scripted scenario and assert the invariant plus the expected
/// ending kind (`Some(true)` = must complete, `Some(false)` = must be a
/// typed error, `None` = either ending is fine).
fn scripted(scenario: Scenario, chip_seed: u64, must_complete: Option<bool>) {
    let _g = serial();
    let outcome = run_scenario(&scenario, chip_seed, WEIGHTS)
        .unwrap_or_else(|e| panic!("scenario {}: invariant violated: {e:#}", scenario.name));
    if let Some(want) = must_complete {
        assert_eq!(
            outcome.completed, want,
            "scenario {}: expected completed={want}, got {outcome:?}",
            scenario.name
        );
    }
}

// ---- protocol failpoints -----------------------------------------------

#[test]
fn chaos_frame_truncate_mid_shard_result() {
    // The worker crashes mid-way through writing its result frame: the
    // coordinator sees a torn frame + EOF, requeues, and the job still
    // completes byte-identically (the other worker or local fallback).
    scripted(
        Scenario::new(
            "frame-truncate-shard-result",
            &[("net.frame.truncate", "truncate=10; tag=ShardResult; count=1")],
        ),
        1,
        Some(true),
    );
}

#[test]
fn chaos_frame_corrupt_shard_result() {
    // One flipped payload byte on a result frame: the checksum rejects
    // it, the worker is dropped, the range re-solves elsewhere.
    scripted(
        Scenario::new(
            "frame-corrupt-shard-result",
            &[("net.frame.corrupt", "corrupt=20; tag=ShardResult; count=1")],
        ),
        2,
        Some(true),
    );
}

#[test]
fn chaos_frame_corrupt_compile_result_is_a_typed_client_error() {
    // Corrupting the server→client result stream cannot be healed by
    // requeueing — the client must surface a typed error, and the fabric
    // must survive to serve the recovery job.
    scripted(
        Scenario::new(
            "frame-corrupt-compile-result",
            &[("net.frame.corrupt", "corrupt=16; tag=CompileResult; count=1")],
        ),
        3,
        Some(false),
    );
}

#[test]
fn chaos_frame_wrong_version_on_snapshot_job() {
    // A version-patched (re-sealed) job frame: the worker rejects it on
    // the version check and drops the link; the range requeues.
    scripted(
        Scenario::new(
            "frame-wrong-version-snapshot-job",
            &[("net.frame.wrong_version", "wrong_version; tag=ShardSnapshotJob; count=1")],
        ),
        4,
        Some(true),
    );
}

#[test]
fn chaos_frame_stall_converts_into_worker_timeout() {
    // The worker sits on its result past the coordinator's deadline: the
    // read times out, the range is reassigned, the job completes. The
    // late frame lands on a dropped connection and goes nowhere.
    let mut s = Scenario::new(
        "frame-stall-shard-result",
        &[("net.frame.stall", "delay=3000; tag=ShardResult; count=1")],
    );
    s.worker_timeout_ms = 1_000;
    scripted(s, 5, Some(true));
}

// ---- worker lifecycle failpoints ---------------------------------------

#[test]
fn chaos_worker_crash_before_solve() {
    scripted(
        Scenario::new("worker-crash-before-solve", &[("worker.crash_before_solve", "return; count=1")]),
        6,
        Some(true),
    );
}

#[test]
fn chaos_worker_crash_after_solve() {
    // The costliest loss: the range was solved but never reported, so it
    // is solved twice. Dedupe and determinism keep the bytes identical.
    scripted(
        Scenario::new("worker-crash-after-solve", &[("worker.crash_after_solve", "return; count=1")]),
        7,
        Some(true),
    );
}

#[test]
fn chaos_worker_crash_with_no_spare_falls_back_to_local() {
    // A single-worker fleet losing its only worker must degrade to the
    // coordinator's local fallback, not to failure.
    let mut s = Scenario::new(
        "worker-crash-no-spare",
        &[("worker.crash_before_solve", "return")], // unlimited: the fleet dies
    );
    s.workers = 1;
    scripted(s, 8, Some(true));
}

#[test]
fn chaos_worker_dropped_store_sync_changes_no_bytes() {
    // Workers silently skip the fleet-store sync: every pattern solves
    // locally. Slower, byte-identical — the store determinism contract.
    scripted(
        Scenario::new("worker-drop-store-sync", &[("worker.drop_store_sync", "return")]),
        9,
        Some(true),
    );
}

// ---- coordinator scheduling failpoints ---------------------------------

#[test]
fn chaos_server_drops_a_valid_fragment() {
    // The late-fragment case: a fully valid fragment is discarded after
    // validation, the worker dropped, the range re-solved.
    scripted(
        Scenario::new("server-drop-fragment", &[("server.drop_fragment", "return; count=1")]),
        10,
        Some(true),
    );
}

#[test]
fn chaos_server_requeue_race_merges_duplicates_idempotently() {
    // A solved range is requeued as if lost: two byte-identical
    // fragments for the same range reach the merge. Must stay invisible.
    scripted(
        Scenario::new("server-requeue-race", &[("server.requeue_race", "return; count=1")]),
        11,
        Some(true),
    );
}

// ---- store failpoints (unit-level + restart scenario) ------------------

#[test]
fn chaos_store_torn_blob_is_rejected_on_reread() {
    let _g = serial();
    failpoint::clear();
    let dir = scratch_dir("torn-unit");
    let _ = std::fs::remove_dir_all(&dir);
    let tensors = model(400);
    let (want, _) = local_reference(20, &tensors);

    // Publish through a store whose every file write lands torn.
    failpoint::configure("store.torn_blob_write", "truncate=9").unwrap();
    let writer = StoreHandle::new(SolutionStore::with_dir(&dir, 64 << 20).unwrap());
    let chip = rchg::fault::bank::ChipFaults::new(20, rchg::fault::FaultRates::paper_default());
    let mut session = rchg::coordinator::CompileSession::builder(CFG)
        .method(Method::Complete)
        .store(writer.clone())
        .chip(&chip);
    for (name, ws) in &tensors {
        session.submit(name, ws.clone());
    }
    let first = session.drain();
    failpoint::clear();
    assert!(writer.counters().publishes > 0, "the job must publish fresh tables");

    // A fresh store over the same directory sees only torn blobs: every
    // file-tier read must be rejected (checksum), counted, and answered
    // with a miss — and the re-solve must reproduce the reference bytes.
    let reader = StoreHandle::new(SolutionStore::with_dir(&dir, 64 << 20).unwrap());
    let mut session = rchg::coordinator::CompileSession::builder(CFG)
        .method(Method::Complete)
        .store(reader.clone())
        .chip(&chip);
    for (name, ws) in &tensors {
        session.submit(name, ws.clone());
    }
    let second = session.drain();
    let c: StoreCounters = reader.counters();
    assert!(c.rejected_blobs > 0, "torn blobs must be rejected, got {c:?}");
    assert_eq!(c.file_hits, 0, "no torn blob may ever serve a file-tier hit: {c:?}");
    for ((na, a), (nb, b)) in first.iter().zip(&second) {
        assert_eq!(na, nb);
        assert_eq!(a.decomps, b.decomps, "torn store changed compiled bytes of {na}");
    }
    for ((na, a), (nb, b)) in second.iter().zip(&want) {
        assert_eq!(na, nb);
        assert_eq!(&a.decomps, &b.decomps, "store path changed bytes of {na}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chaos_store_read_errors_count_and_miss() {
    let _g = serial();
    failpoint::clear();
    let dir = scratch_dir("read-err-unit");
    let _ = std::fs::remove_dir_all(&dir);
    let tensors = model(400);

    // Warm the file tier cleanly…
    let writer = StoreHandle::new(SolutionStore::with_dir(&dir, 64 << 20).unwrap());
    let chip = rchg::fault::bank::ChipFaults::new(21, rchg::fault::FaultRates::paper_default());
    let mut session = rchg::coordinator::CompileSession::builder(CFG)
        .method(Method::Complete)
        .store(writer.clone())
        .chip(&chip);
    for (name, ws) in &tensors {
        session.submit(name, ws.clone());
    }
    let first = session.drain();

    // …then read it back through a store whose file reads all fail.
    failpoint::configure("store.blob_read_error", "return").unwrap();
    let reader = StoreHandle::new(SolutionStore::with_dir(&dir, 64 << 20).unwrap());
    let mut session = rchg::coordinator::CompileSession::builder(CFG)
        .method(Method::Complete)
        .store(reader.clone())
        .chip(&chip);
    for (name, ws) in &tensors {
        session.submit(name, ws.clone());
    }
    let second = session.drain();
    failpoint::clear();
    let c = reader.counters();
    assert!(c.io_errors > 0, "failed reads must be counted: {c:?}");
    assert_eq!(c.file_hits, 0, "a failing file tier cannot produce hits: {c:?}");
    for ((na, a), (nb, b)) in first.iter().zip(&second) {
        assert_eq!(na, nb);
        assert_eq!(a.decomps, b.decomps, "read errors changed compiled bytes of {na}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chaos_restart_between_jobs_over_a_torn_store() {
    // Coordinator restart between jobs, with the store directory full of
    // torn blobs from the first life: the second coordinator must reject
    // every torn blob, re-solve, and still produce byte-identical output.
    let _g = serial();
    failpoint::clear();
    let dir = scratch_dir("restart-store");
    let _ = std::fs::remove_dir_all(&dir);
    let tensors = model(WEIGHTS);
    let chip_seed = 30;
    let (want, want_bytes) = local_reference(chip_seed, &tensors);

    // Life 1: every blob the coordinator's store writes lands torn.
    let mut scenario = Scenario::new("restart-life1", &[]);
    scenario.workers = 1;
    let sopts = chaos::chaos_serve_opts(&scenario, Some(dir.clone()));
    let server = FabricServer::bind("127.0.0.1:0", sopts).unwrap();
    let addr = server.local_addr();
    let server = thread::spawn(move || server.run().unwrap());
    let a = addr.to_string();
    let worker = thread::spawn(move || rchg::net::run_worker(&a, 1));
    chaos::wait_for_workers(addr, 1).unwrap();
    failpoint::configure("store.torn_blob_write", "truncate=9").unwrap();
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();
    let (results, _) = client.compile_model(chip_seed, CFG, Method::Complete, &tensors).unwrap();
    failpoint::clear();
    check_results(&results, &want).unwrap();
    client.shutdown_server().unwrap();
    server.join().unwrap();
    let _ = worker.join().unwrap();

    // Life 2: a fresh coordinator over the same store directory, no
    // failpoints. Torn blobs must be rejected silently; the job must
    // complete byte-identically (including the fetched session bytes).
    let scenario2 = {
        let mut s = Scenario::new("restart-life2", &[]);
        s.workers = 1;
        s
    };
    let server = FabricServer::bind("127.0.0.1:0", chaos::chaos_serve_opts(&scenario2, Some(dir.clone()))).unwrap();
    let addr = server.local_addr();
    let server = thread::spawn(move || server.run().unwrap());
    let a = addr.to_string();
    let worker = thread::spawn(move || rchg::net::run_worker(&a, 1));
    chaos::wait_for_workers(addr, 1).unwrap();
    let mut client = CompileClient::connect(&addr.to_string()).unwrap();
    let (results, _) = client.compile_model(chip_seed, CFG, Method::Complete, &tensors).unwrap();
    check_results(&results, &want).unwrap();
    assert_eq!(
        client.fetch_session(chip_seed).unwrap(),
        want_bytes,
        "restarted coordinator over a torn store must still serve byte-identical sessions"
    );
    client.shutdown_server().unwrap();
    server.join().unwrap();
    let _ = worker.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- composed scenarios -------------------------------------------------

#[test]
fn chaos_double_fault_crash_plus_drop_fragment() {
    // Two independent faults in one round: a worker dies on its first
    // job AND the coordinator discards one valid fragment.
    scripted(
        Scenario::new(
            "double-crash+drop",
            &[
                ("worker.crash_before_solve", "return; count=1"),
                ("server.drop_fragment", "return; count=1"),
            ],
        ),
        12,
        Some(true),
    );
}

#[test]
fn chaos_randomized_seeded_schedules() {
    // The CI seed set. A failure names the (seed, scenario) pair; replay
    // locally with `cargo run --features failpoints -- chaos --seed <N>`.
    let _g = serial();
    for seed in [1u64, 2, 3] {
        match run_seed(seed, 3, 500) {
            Ok(report) => {
                assert_eq!(report.scenarios, 3);
                assert_eq!(report.completed + report.typed_errors, report.scenarios);
            }
            Err(e) => panic!(
                "chaos seed {seed} violated the invariant: {e:#}\n\
                 replay: cargo run --features failpoints -- chaos --seed {seed} --scenarios 3 --weights 500"
            ),
        }
    }
}

#[test]
fn chaos_scenario_derivation_is_deterministic() {
    // Same (seed, idx) must always derive the same scenario — the whole
    // replay story rests on this.
    for seed in [1u64, 7, 99] {
        for idx in 0..4 {
            let a = random_scenario(seed, idx);
            let b = random_scenario(seed, idx);
            assert_eq!(a.name, b.name);
            assert_eq!(a.failpoints, b.failpoints);
            assert_eq!(a.workers, b.workers);
            assert_eq!(a.store_dir, b.store_dir);
        }
    }
    // And the menu really is sampled: across a few seeds every named
    // failpoint family shows up at least once.
    let mut seen = std::collections::BTreeSet::new();
    for seed in 0..40u64 {
        for idx in 0..4 {
            for (name, _) in random_scenario(seed, idx).failpoints {
                seen.insert(name);
            }
        }
    }
    for name in chaos::MENU {
        assert!(seen.contains(*name), "menu entry {name} never sampled");
    }
}
