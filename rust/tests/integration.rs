//! Cross-module integration tests: the full quantize → fault-compile →
//! pack → execute flow, cross-method agreement at model scale, chip
//! determinism, and failure injection on the runtime loading path.

use rchg::coordinator::{CompileOptions, CompileSession, CompiledTensor, Method, Stage};
use rchg::fault::bank::ChipFaults;
use rchg::fault::{FaultRates, GroupFaults};
use rchg::grouping::{Decomposition, FaultAnalysis, GroupConfig};
use rchg::nn::packing::Planes;
use rchg::nn::CompiledMatrix;
use rchg::quant::QuantizedMatrix;
use rchg::util::prng::Rng;

fn random_weights(n: usize, max: i64, seed: u64) -> Vec<i64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.range_i64(-max, max)).collect()
}

/// One-shot compile against explicit fault maps (the removed free
/// function's surface, via a throwaway detached session).
fn compile_tensor(ws: &[i64], faults: &[GroupFaults], opts: &CompileOptions) -> CompiledTensor {
    CompileSession::builder(opts.cfg)
        .options(opts.clone())
        .detached()
        .compile_with_faults(ws, faults)
}

#[test]
fn every_method_agrees_on_residual_error() {
    // Complete, ILP-only and (r=1) original FF must produce identical
    // per-weight |error| — they solve the same optimization problem.
    let cfg = GroupConfig::R1C4;
    let ws = random_weights(300, cfg.max_per_array(), 3);
    let chip = ChipFaults::new(11, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());

    let run = |m: Method| {
        compile_tensor(&ws, &faults, &CompileOptions::new(cfg, m)).errors
    };
    let complete = run(Method::Complete);
    let ilp = run(Method::IlpOnly);
    let ff = run(Method::OriginalFf);
    assert_eq!(complete, ilp);
    assert_eq!(complete, ff);
}

#[test]
fn full_weight_range_exactness_census() {
    // For a fixed fault map, sweep EVERY representable weight and verify
    // the pipeline achieves zero error exactly on the achievable set.
    let cfg = GroupConfig::R2C2;
    let mut rng = Rng::new(5);
    for _ in 0..20 {
        let faults = GroupFaults::sample(
            cfg.cells(),
            &FaultRates { p_sa0: 0.2, p_sa1: 0.2 },
            &mut rng,
        );
        let fa = FaultAnalysis::new(&cfg, &faults);
        let achievable: std::collections::BTreeSet<i64> =
            fa.enumerate_values().into_iter().collect();
        let ws: Vec<i64> = (-cfg.max_per_array()..=cfg.max_per_array()).collect();
        let fs = vec![faults.clone(); ws.len()];
        let out = compile_tensor(&ws, &fs, &CompileOptions::new(cfg, Method::Complete));
        for (w, err) in ws.iter().zip(&out.errors) {
            if achievable.contains(w) {
                assert_eq!(*err, 0, "w={w} achievable but error={err} (faults {faults:?})");
            } else {
                assert!(*err > 0, "w={w} unachievable but error=0");
            }
        }
    }
}

#[test]
fn chip_compilation_is_deterministic() {
    let cfg = GroupConfig::R2C2;
    let ws = random_weights(2_000, cfg.max_per_array(), 9);
    let chip = ChipFaults::new(77, FaultRates::paper_default());
    let faults = chip.sample_tensor(3, ws.len(), cfg.cells());
    let mut opts = CompileOptions::new(cfg, Method::Complete);
    opts.threads = 2;
    let a = compile_tensor(&ws, &faults, &opts);
    let b = compile_tensor(&ws, &faults, &opts);
    assert_eq!(a.decomps, b.decomps);
    assert_eq!(a.errors, b.errors);
}

#[test]
fn quantize_compile_pack_roundtrip_model_scale() {
    // A "layer" of float weights goes through the full path; the packed
    // planes must decode to exactly the faulty ints the compiler reported,
    // and the dequantized error must be bounded by scale × integer error.
    let cfg = GroupConfig::R2C2;
    let (k, n) = (48usize, 12usize);
    let mut rng = Rng::new(21);
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.4).collect();
    let chip = ChipFaults::new(5, FaultRates::paper_default());
    let opts = CompileOptions::new(cfg, Method::Complete);
    let cm = CompiledMatrix::compile(&w, k, n, &chip, 0, &opts);

    let eff = cm.planes(&cfg).effective_weights(&cfg);
    assert_eq!(eff, cm.faulty_ints(&cfg));

    let ideal = cm.ideal_dequant();
    let faulty = cm.faulty_dequant(&cfg);
    for col in 0..n {
        for row in 0..k {
            let i = row * n + col;
            let int_err = (cm.q.w_int[i] - cm.faulty_ints(&cfg)[i]).abs() as f32;
            let float_err = (ideal[i] - faulty[i]).abs();
            assert!(
                (float_err - cm.q.scale[col] * int_err).abs() < 1e-4,
                "float/int error inconsistent at {i}"
            );
        }
    }
}

#[test]
fn unprotected_is_never_beaten_by_itself_with_mitigation_census() {
    // Aggregate fault error strictly improves with mitigation across a
    // sweep of chips and configs (failure-mode census).
    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
        for chip_seed in [1u64, 2, 3] {
            let ws = random_weights(1_500, cfg.max_per_array(), chip_seed ^ 0xAB);
            let chip = ChipFaults::new(chip_seed, FaultRates::paper_default());
            let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
            let raw = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Unprotected));
            let fixed = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
            assert!(fixed.stats.total_abs_error < raw.stats.total_abs_error);
            // Per-weight: never worse.
            for (a, b) in fixed.errors.iter().zip(&raw.errors) {
                assert!(a <= b);
            }
        }
    }
}

#[test]
fn stage_census_matches_theorem_predictions() {
    // At paper rates on R2C2, inconsecutivity is rare (Fig 6) → the CVM
    // stage should be nearly unused; fault-free groups ≈ (1-p)^(2 cells).
    let cfg = GroupConfig::R2C2;
    let ws = random_weights(40_000, cfg.max_per_array(), 13);
    let chip = ChipFaults::new(2, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
    let mut opts = CompileOptions::new(cfg, Method::Complete);
    opts.memoize = false;
    let out = compile_tensor(&ws, &faults, &opts);
    let n = ws.len() as f64;
    let fast = out.stats.count_of(Stage::FastPath) as f64 / n;
    let cvm = out.stats.count_of(Stage::TableCvm) as f64 / n;
    let expected_fault_free = (1.0 - 0.1079f64).powi(8);
    assert!((fast - expected_fault_free).abs() < 0.02, "fast-path {fast}");
    assert!(cvm < 0.002, "CVM share {cvm} should be negligible on R2C2");
}

#[test]
fn planes_respect_cell_bounds_under_faults() {
    let cfg = GroupConfig::R2C4;
    let (k, n) = (10usize, 10usize);
    let ws = random_weights(k * n, cfg.max_per_array(), 31);
    let chip = ChipFaults::new(8, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
    let out = compile_tensor(&ws, &faults, &CompileOptions::new(cfg, Method::Complete));
    let decomps: Vec<Decomposition> = out.decomps;
    let planes = Planes::pack(&decomps, Some(&faults), k, n, &cfg);
    for v in planes.pos.iter().chain(planes.neg.iter()) {
        assert!(*v >= 0.0 && *v <= (cfg.levels - 1) as f32);
    }
}

#[test]
fn quantizer_then_pipeline_respects_range_invariant() {
    // Quantized ints always fit the config range; compile must never panic
    // across configs (the debug_assert in decompose_one guards this).
    let mut rng = Rng::new(77);
    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::new(1, 2, 2)] {
        let (k, n) = (30usize, 7usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 3.0).collect();
        let q = QuantizedMatrix::quantize(&w, k, n, &cfg);
        let chip = ChipFaults::new(3, FaultRates::paper_default());
        let faults = chip.sample_tensor(0, q.w_int.len(), cfg.cells());
        let _ = compile_tensor(&q.w_int, &faults, &CompileOptions::new(cfg, Method::Complete));
    }
}

// ---------------------------------------------------------------------
// Failure injection on the runtime path.
// ---------------------------------------------------------------------

mod runtime_failures {
    use rchg::runtime::Runtime;
    use std::path::PathBuf;

    fn scratch(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rchg_it_{name}"));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let d = scratch("nomanifest");
        let err = match Runtime::new(&d) { Err(e) => e.to_string(), Ok(_) => panic!("expected error") };
        assert!(err.contains("manifest.json"), "{err}");
    }

    #[test]
    fn corrupt_manifest_is_a_clean_error() {
        let d = scratch("badmanifest");
        std::fs::write(d.join("manifest.json"), "{not json").unwrap();
        assert!(Runtime::new(&d).is_err());
    }

    #[test]
    fn unknown_executable_is_a_clean_error() {
        let d = scratch("emptymanifest");
        std::fs::write(d.join("manifest.json"), "{}").unwrap();
        let rt = Runtime::new(&d).unwrap();
        let err = match rt.load("nope") { Err(e) => e.to_string(), Ok(_) => panic!("expected error") };
        assert!(err.contains("not in manifest"), "{err}");
    }

    #[test]
    fn corrupt_hlo_is_a_clean_error() {
        let d = scratch("badhlo");
        std::fs::write(d.join("bad.hlo.txt"), "this is not hlo").unwrap();
        std::fs::write(
            d.join("manifest.json"),
            r#"{"bad": {"path": "bad.hlo.txt", "args": [{"name":"x","shape":[1],"dtype":"f32"}]}}"#,
        )
        .unwrap();
        let rt = Runtime::new(&d).unwrap();
        assert!(rt.load("bad").is_err());
    }

    #[test]
    fn wrong_arg_count_and_size_rejected() {
        // Against the real artifacts if present.
        let art = rchg::runtime::artifacts_dir();
        if !art.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&art).unwrap();
        let exe = rt.load("imc_linear_r2c2").unwrap();
        // Too few args.
        let err = exe.run(&[]).unwrap_err().to_string();
        assert!(err.contains("expected"), "{err}");
        // Wrong element count.
        let bad = vec![0f32; 3];
        let vals: Vec<rchg::runtime::ArgValue> =
            exe.args.iter().map(|_| rchg::runtime::ArgValue::F32(&bad)).collect();
        assert!(exe.run(&vals).is_err());
    }
}

mod weightbank_failures {
    use rchg::runtime::WeightBank;
    use std::path::PathBuf;

    fn scratch(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rchg_wb_{name}"));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn missing_meta_is_clean_error() {
        let d = scratch("nometa");
        assert!(WeightBank::load(&d).is_err());
    }

    #[test]
    fn shape_mismatch_detected() {
        let d = scratch("shapemismatch");
        std::fs::write(
            d.join("meta.json"),
            r#"{"params": [{"name": "w", "shape": [2, 2]}]}"#,
        )
        .unwrap();
        // Write a 3-element tensor where meta says 2x2.
        crate::rchg_io_save(&d.join("w.bin"), &[1.0, 2.0, 3.0]);
        let err = match WeightBank::load(&d) { Err(e) => e.to_string(), Ok(_) => panic!("expected error") };
        assert!(err.contains("dims"), "{err}");
    }
}

/// Helper for the failure tests: write a RawTensor f32 file.
fn rchg_io_save(path: &std::path::Path, data: &[f32]) {
    rchg::util::io::RawTensor::from_f32(vec![data.len()], data.to_vec())
        .save(path)
        .unwrap();
}
