//! CompileSession acceptance tests: byte-identity with the caller-owned
//! SolveCache path at threads {1, 4, 8}, save → load → recompile
//! round-trips (warm-start performs zero fresh solves and matches cold
//! output byte-for-byte), clean rejection of corrupted, v1, or
//! version-mismatched cache files, submit/drain batch equivalence, and
//! the multi-chip compile service.

use rchg::coordinator::{
    compile_batch_with_cache, CompileOptions, CompileService, CompileSession, Method,
    ServiceOptions, SolveCache, TableBudget, TensorJob,
};
use rchg::experiments::compile_time::synthetic_model_tensors;
use rchg::fault::bank::ChipFaults;
use rchg::fault::FaultRates;
use rchg::grouping::GroupConfig;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rchg_session_test_{name}"))
}

#[test]
fn session_matches_caller_cache_path_across_threads() {
    // Acceptance: CompileSession compiles ResNet-20-shaped tensors
    // byte-identically to the caller-threaded SolveCache path at threads
    // {1, 4, 8}.
    let cfg = GroupConfig::R2C2;
    let tensors = synthetic_model_tensors("resnet20", &cfg, 12_000).unwrap();
    let chip = ChipFaults::new(1, FaultRates::paper_default());
    for threads in [1usize, 4, 8] {
        let mut opts = CompileOptions::new(cfg, Method::Complete);
        opts.threads = threads;
        let mut cache = SolveCache::new(cfg);
        let mut reference = Vec::new();
        for (i, (_, ws)) in tensors.iter().enumerate() {
            let faults = chip.sample_tensor(i as u64, ws.len(), cfg.cells());
            reference.push(
                compile_batch_with_cache(
                    &[TensorJob { weights: ws, faults: &faults }],
                    &opts,
                    &mut cache,
                )
                .pop()
                .unwrap(),
            );
        }
        let mut session = CompileSession::builder(cfg)
            .method(Method::Complete)
            .threads(threads)
            .chip(&chip);
        let out = session.compile_model(&tensors);
        assert_eq!(out.len(), reference.len());
        for ((name, s, _), r) in out.iter().zip(&reference) {
            assert_eq!(s.decomps, r.decomps, "{name} decomps diverged at threads={threads}");
            assert_eq!(s.errors, r.errors, "{name} errors diverged at threads={threads}");
            assert_eq!(s.stats.unique_pairs, r.stats.unique_pairs);
            assert_eq!(s.stats.stage_counts, r.stats.stage_counts);
        }
        assert_eq!(session.solved_pairs(), cache.solved_pairs());
    }
}

#[test]
fn save_load_warm_start_zero_fresh_solves_byte_identical() {
    // Acceptance: a save/load warm-start recompile of the same model
    // performs zero fresh solves while matching cold output byte-for-byte.
    let cfg = GroupConfig::R2C2;
    let tensors = synthetic_model_tensors("resnet20", &cfg, 10_000).unwrap();
    let chip = ChipFaults::new(7, FaultRates::paper_default());
    let mut cold = CompileSession::builder(cfg).chip(&chip);
    let cold_out = cold.compile_model(&tensors);
    let path = tmp("warm_roundtrip.rcs");
    cold.save(&path).unwrap();

    let mut warm = CompileSession::load(&path).unwrap();
    assert!(warm.matches(&chip, cold.options()));
    assert_eq!(warm.solved_pairs(), cold.solved_pairs());
    let warm_out = warm.compile_model(&tensors);
    for ((_, a, fa), (_, b, fb)) in cold_out.iter().zip(&warm_out) {
        assert_eq!(fa, fb, "fault sampling must be identical after reload");
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
        assert_eq!(b.stats.unique_pairs, 0, "warm recompile must perform zero fresh solves");
        assert_eq!(b.stats.dedup_hits, b.stats.weights);
    }
    // The cache grew by nothing.
    assert_eq!(warm.solved_pairs(), cold.solved_pairs());
    std::fs::remove_file(&path).ok();
}

#[test]
fn warm_start_survives_a_second_generation() {
    // save → load → compile a *revised* model (one tensor changed) → save
    // → load again: only the revision costs solves, and the second
    // generation still matches a cold compile byte-for-byte.
    let cfg = GroupConfig::R2C2;
    let mut tensors = synthetic_model_tensors("resnet20", &cfg, 8_000).unwrap();
    let chip = ChipFaults::new(13, FaultRates::paper_default());
    let mut gen0 = CompileSession::builder(cfg).chip(&chip);
    let _ = gen0.compile_model(&tensors);
    let path = tmp("generations.rcs");
    gen0.save(&path).unwrap();

    // Revise one tensor (weights shifted into the config's range).
    for w in tensors[1].1.iter_mut() {
        *w = (*w + 1).clamp(-cfg.max_per_array(), cfg.max_per_array());
    }
    let mut gen1 = CompileSession::load(&path).unwrap();
    let revised = gen1.compile_model(&tensors);
    let unchanged_solves: usize = revised
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != 1)
        .map(|(_, (_, t, _))| t.stats.unique_pairs)
        .sum();
    assert_eq!(unchanged_solves, 0, "unchanged tensors must be pure cache hits");
    gen1.save(&path).unwrap();

    let mut cold = CompileSession::builder(cfg).chip(&chip);
    let cold_out = cold.compile_model(&tensors);
    for ((_, a, _), (_, b, _)) in revised.iter().zip(&cold_out) {
        assert_eq!(a.decomps, b.decomps);
        assert_eq!(a.errors, b.errors);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupted_or_mismatched_cache_files_rejected() {
    let cfg = GroupConfig::R2C2;
    let tensors = synthetic_model_tensors("resnet20", &cfg, 3_000).unwrap();
    let chip = ChipFaults::new(2, FaultRates::paper_default());
    let mut s = CompileSession::builder(cfg).chip(&chip);
    let _ = s.compile_model(&tensors);
    let good = s.to_bytes().unwrap();
    assert!(CompileSession::from_bytes(&good).is_ok());

    // Truncation at any interesting boundary.
    assert!(CompileSession::from_bytes(&[]).is_err());
    assert!(CompileSession::from_bytes(&good[..8]).is_err());
    assert!(CompileSession::from_bytes(&good[..good.len() - 3]).is_err());
    assert!(CompileSession::from_bytes(&good[..good.len() / 2]).is_err());

    // A flipped bit mid-payload fails the checksum.
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    assert!(CompileSession::from_bytes(&flipped).is_err());

    // Wrong magic (checksum recomputed so only the magic is at fault).
    let refresh = |mut bytes: Vec<u8>| -> Vec<u8> {
        let n = bytes.len();
        let sum = rchg::util::prop::fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        bytes
    };
    let mut magic = good.clone();
    magic[0] ^= 0xFF;
    assert!(CompileSession::from_bytes(&refresh(magic)).is_err());

    // Future format version is rejected, not misparsed.
    let mut vers = good.clone();
    vers[4] = 99;
    assert!(CompileSession::from_bytes(&refresh(vers)).is_err());

    // v1 pair-cache files are rejected with a clean version error, not
    // misparsed as v2 pattern tables.
    let mut v1 = good.clone();
    v1[4..8].copy_from_slice(&1u32.to_le_bytes());
    let err = match CompileSession::from_bytes(&refresh(v1)) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("v1 file must be rejected"),
    };
    assert!(err.contains("version 1"), "{err}");
}

#[test]
fn submit_drain_batch_matches_sequential_compiles() {
    let cfg = GroupConfig::R2C2;
    let tensors = synthetic_model_tensors("resnet20", &cfg, 8_000).unwrap();
    let chip = ChipFaults::new(4, FaultRates::paper_default());

    let mut batched = CompileSession::builder(cfg).threads(4).chip(&chip);
    for (name, ws) in &tensors {
        batched.submit(name, ws.clone());
    }
    assert_eq!(batched.pending(), tensors.len());
    let out = batched.drain();
    assert_eq!(batched.pending(), 0);
    assert_eq!(out.len(), tensors.len());

    let mut sequential = CompileSession::builder(cfg).threads(1).chip(&chip);
    let total: usize = tensors.iter().map(|(_, w)| w.len()).sum();
    for ((name, ws), (bname, bt)) in tensors.iter().zip(&out) {
        assert_eq!(name, bname);
        let st = sequential.compile_tensor(name, ws);
        assert_eq!(st.decomps, bt.decomps, "batched drain diverged on {name}");
        assert_eq!(st.errors, bt.errors);
        assert_eq!(st.stats.stage_counts, bt.stats.stage_counts);
        assert_eq!(st.stats.unique_pairs, bt.stats.unique_pairs);
    }
    // Session-level accounting covers the whole batch.
    assert_eq!(batched.stats().weights, total);
    assert_eq!(batched.tensors_compiled(), tensors.len());
    assert_eq!(batched.solved_pairs(), sequential.solved_pairs());
}

#[test]
fn service_batches_many_chips_and_warm_starts_from_cache_dir() {
    let cfg = GroupConfig::R2C2;
    let tensors = synthetic_model_tensors("resnet20", &cfg, 6_000).unwrap();
    let seeds = [11u64, 12, 13];
    let dir = tmp("service_cache_dir");
    std::fs::remove_dir_all(&dir).ok();
    let mut opts = CompileOptions::new(cfg, Method::Complete);
    opts.threads = 4;

    let mut service = CompileService::new(ServiceOptions {
        opts: opts.clone(),
        rates: FaultRates::paper_default(),
        table_budget: TableBudget::PerSession,
        cache_dir: Some(dir.clone()),
        store_dir: None,
    });
    for &seed in &seeds {
        for (name, ws) in &tensors {
            service.enqueue(seed, name, ws.clone());
        }
    }
    let round1 = service.run().unwrap();
    assert_eq!(round1.len(), seeds.len() * tensors.len());
    assert!(round1.windows(2).all(|w| w[0].job_id < w[1].job_id), "enqueue order");

    // Each chip's results equal a standalone per-chip session.
    for (ci, &seed) in seeds.iter().enumerate() {
        let chip = ChipFaults::new(seed, FaultRates::paper_default());
        let mut standalone = CompileSession::builder(cfg).chip(&chip);
        for (ti, (name, ws)) in tensors.iter().enumerate() {
            let want = standalone.compile_tensor(name, ws);
            let got = &round1[ci * tensors.len() + ti];
            assert_eq!(got.chip_seed, seed);
            assert_eq!(&got.name, name);
            assert_eq!(got.tensor.decomps, want.decomps, "chip {seed} tensor {name}");
            assert_eq!(got.tensor.errors, want.errors);
        }
    }

    // A *fresh* service over the same cache dir starts warm: zero fresh
    // solves, byte-identical output.
    let mut fresh = CompileService::new(ServiceOptions {
        opts,
        rates: FaultRates::paper_default(),
        table_budget: TableBudget::PerSession,
        cache_dir: Some(dir.clone()),
        store_dir: None,
    });
    for &seed in &seeds {
        for (name, ws) in &tensors {
            fresh.enqueue(seed, name, ws.clone());
        }
    }
    let round2 = fresh.run().unwrap();
    let fresh_solves: usize = round2.iter().map(|r| r.tensor.stats.unique_pairs).sum();
    assert_eq!(fresh_solves, 0, "cache-dir warm start must skip every solve");
    for (a, b) in round1.iter().zip(&round2) {
        assert_eq!(a.tensor.decomps, b.tensor.decomps);
        assert_eq!(a.tensor.errors, b.tensor.errors);
    }
    std::fs::remove_dir_all(&dir).ok();
}
