//! Golden byte fixtures for every codec: RCWP frames, RCSS sessions,
//! RCSF fragments, and RCPS store blobs (the `pub(crate)` RCRG snapshot
//! codec has its golden test inside `coordinator/persist.rs`).
//!
//! The fixtures under `tests/fixtures/` are generated *independently of
//! the Rust encoders* by `make_fixtures.py`, so these tests pin the
//! actual byte layouts — a refactor that changes any format's bytes
//! fails here even if its own round-trip still passes. After an
//! intentional format change, bump the version constant, re-run the
//! generator to bless new bytes, and document the migration (see
//! `docs/TESTING.md`).

use rchg::coordinator::session::{SESSION_MAGIC, SESSION_VERSION};
use rchg::coordinator::{
    CompileSession, Method, PipelineOptions, ShardFragment, FRAGMENT_VERSION,
};
use rchg::fault::bank::ChipFaults;
use rchg::fault::{FaultRates, FaultState, GroupFaults};
use rchg::grouping::GroupConfig;
use rchg::net::protocol::{frame_bytes, read_frame, WIRE_VERSION};
use rchg::net::FrameType;
use rchg::store::{decode_blob, encode_blob, StoreCtx};
use rchg::util::prop::fnv1a;
use std::io::Cursor;

const RCWP: &[u8] = include_bytes!("fixtures/rcwp_hello_v1.bin");
const RCSS: &[u8] = include_bytes!("fixtures/rcss_v2_empty.bin");
const RCSF: &[u8] = include_bytes!("fixtures/rcsf_v1_fragment.bin");
const RCPS: &[u8] = include_bytes!("fixtures/rcps_v1_blob.bin");

/// The fixtures' shared identity: chip 7, paper rates, R2C2, default
/// pipeline (Complete, table limit 4096, not sparsest).
const CHIP_SEED: u64 = 7;
const CFG: GroupConfig = GroupConfig::R2C2;

/// Flip every byte of a sealed fixture one at a time and require the
/// decoder to reject each mutant — corruption anywhere (payload or
/// checksum) must be caught before parsing.
fn assert_rejects_every_flip(bytes: &[u8], what: &str, decode: impl Fn(&[u8]) -> bool) {
    for i in 0..bytes.len() {
        let mut bad = bytes.to_vec();
        bad[i] ^= 0xff;
        assert!(!decode(&bad), "{what}: flipped byte {i} must be rejected");
    }
}

/// Truncate a fixture at every length below its full size and require
/// rejection (offset 0 is excluded where an empty input is a legal
/// clean-EOF, as for wire frames).
fn assert_rejects_every_truncation(
    bytes: &[u8],
    from: usize,
    what: &str,
    decode: impl Fn(&[u8]) -> bool,
) {
    for len in from..bytes.len() {
        assert!(!decode(&bytes[..len]), "{what}: truncation to {len} bytes must be rejected");
    }
}

/// Patch one byte of a sealed payload and re-seal so the checksum passes
/// — the way to prove a *semantic* validation fires, not the checksum.
fn reseal_with(bytes: &[u8], at: usize, value: u8) -> Vec<u8> {
    let mut payload = bytes[..bytes.len() - 8].to_vec();
    payload[at] = value;
    let sum = fnv1a(&payload);
    payload.extend_from_slice(&sum.to_le_bytes());
    payload
}

// ---- RCWP v1 wire frame -------------------------------------------------

#[test]
fn golden_rcwp_hello_frame() {
    let frame = read_frame(&mut Cursor::new(RCWP))
        .expect("golden frame must parse")
        .expect("golden frame is not a clean EOF");
    assert_eq!(frame.frame_type, FrameType::Hello);
    assert_eq!(frame.payload, 3u32.to_le_bytes(), "a 3-thread hello");
    assert_eq!(
        frame_bytes(frame.frame_type, &frame.payload),
        RCWP,
        "the frame encoder no longer produces the golden RCWP bytes"
    );
}

#[test]
fn golden_rcwp_rejects_corruption_truncation_and_wrong_version() {
    let parses = |b: &[u8]| matches!(read_frame(&mut Cursor::new(b)), Ok(Some(_)));
    assert_rejects_every_flip(RCWP, "RCWP", parses);
    // Truncating to 0 bytes is a clean EOF (Ok(None)), every other prefix
    // is a mid-frame cut and must error.
    assert!(matches!(read_frame(&mut Cursor::new(&RCWP[..0])), Ok(None)));
    assert_rejects_every_truncation(RCWP, 1, "RCWP", parses);
    // Version patched and re-sealed: the version check itself must fire.
    let mut bumped = RCWP.to_vec();
    bumped[4..8].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
    let body = bumped.len() - 8;
    let sum = fnv1a(&bumped[..body]);
    bumped[body..].copy_from_slice(&sum.to_le_bytes());
    let err = read_frame(&mut Cursor::new(&bumped[..])).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
}

// ---- RCSS v2 session cache ----------------------------------------------

#[test]
fn golden_rcss_empty_session_roundtrips() {
    let session = CompileSession::from_bytes(RCSS).expect("golden session must parse");
    assert_eq!(session.chip().expect("persisted sessions carry a chip").chip_seed, CHIP_SEED);
    // An empty session is the one session whose decode -> re-encode is
    // byte-stable by contract (save_parts drops never-hit warm entries).
    assert_eq!(
        session.to_bytes().unwrap(),
        RCSS,
        "the session encoder no longer produces the golden RCSS bytes"
    );
    // And a session built from scratch through the public API must land
    // on the same bytes — generator and encoder agree on the layout.
    let chip = ChipFaults::new(CHIP_SEED, FaultRates::paper_default());
    let built = CompileSession::builder(CFG).method(Method::Complete).chip(&chip);
    assert_eq!(built.to_bytes().unwrap(), RCSS);
}

#[test]
fn golden_rcss_rejects_corruption_truncation_and_bad_header() {
    let parses = |b: &[u8]| CompileSession::from_bytes(b).is_ok();
    assert_rejects_every_flip(RCSS, "RCSS", parses);
    assert_rejects_every_truncation(RCSS, 0, "RCSS", parses);
    assert_eq!(&RCSS[0..4], SESSION_MAGIC.to_le_bytes().as_slice());
    // Semantic rejections, re-sealed so the checksum passes: bad magic,
    // unsupported version (a v1 pair cache must not half-parse).
    let err = CompileSession::from_bytes(&reseal_with(RCSS, 0, b'X')).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");
    let err =
        CompileSession::from_bytes(&reseal_with(RCSS, 4, SESSION_VERSION as u8 - 1)).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
}

// ---- RCSF v1 shard fragment ---------------------------------------------

#[test]
fn golden_rcsf_fragment_roundtrips_all_three_tags() {
    let frag = ShardFragment::from_bytes(RCSF).expect("golden fragment must parse");
    assert_eq!(frag.chip_seed(), CHIP_SEED);
    // Shard 1 of a 2-way plan over 6 patterns: ids 3..6.
    assert_eq!(frag.range(), 3..6);
    assert_eq!(frag.total_patterns(), 6);
    // Three parts: one dense table, one pairs map, one empty slot.
    assert_eq!(frag.solved_patterns(), 2);
    let parts: Vec<_> = frag.parts().collect();
    assert_eq!(parts.len(), 3);
    assert!(parts[0].1.is_some() && parts[1].1.is_some() && parts[2].1.is_none());
    assert_eq!(parts[1].0.pos[0], FaultState::Sa0);
    assert_eq!(parts[1].0.neg[1], FaultState::Sa1);
    assert_eq!(
        frag.to_bytes(),
        RCSF,
        "the fragment encoder no longer produces the golden RCSF bytes"
    );
}

#[test]
fn golden_rcsf_rejects_corruption_truncation_and_bad_framing() {
    let parses = |b: &[u8]| ShardFragment::from_bytes(b).is_ok();
    assert_rejects_every_flip(RCSF, "RCSF", parses);
    for len in [0, 8, 15, 16, 57, RCSF.len() / 2, RCSF.len() - 1] {
        assert!(!parses(&RCSF[..len]), "truncation to {len} bytes must be rejected");
    }
    // Re-sealed semantic rejections: version from a future build, and a
    // shard index outside its own plan (offset 58 = magic+version+key).
    let err =
        ShardFragment::from_bytes(&reseal_with(RCSF, 4, FRAGMENT_VERSION as u8 + 1)).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
    let err = ShardFragment::from_bytes(&reseal_with(RCSF, 58, 5)).unwrap_err();
    assert!(err.to_string().contains("shard"), "{err}");
}

// ---- RCPS v1 store blob -------------------------------------------------

/// The identity the golden RCPS blob answers for.
fn rcps_identity() -> (StoreCtx, GroupFaults) {
    let ctx = StoreCtx::new(CFG, PipelineOptions::default());
    let mut pattern = GroupFaults::free(CFG.cells());
    pattern.pos[1] = FaultState::Sa0;
    pattern.neg[3] = FaultState::Sa1;
    (ctx, pattern)
}

#[test]
fn golden_rcps_blob_roundtrips() {
    let (ctx, pattern) = rcps_identity();
    let table = decode_blob(RCPS, &ctx, &pattern).expect("golden blob must parse");
    assert_eq!(table.len(), ctx.table_len(), "a full-range R2C2 table has 61 entries");
    assert_eq!(
        encode_blob(&ctx, &pattern, &table),
        RCPS,
        "the store blob encoder no longer produces the golden RCPS bytes"
    );
}

#[test]
fn golden_rcps_rejects_corruption_and_foreign_identities() {
    let (ctx, pattern) = rcps_identity();
    let parses = |b: &[u8]| decode_blob(b, &ctx, &pattern).is_ok();
    assert_rejects_every_flip(RCPS, "RCPS", parses);
    assert_rejects_every_truncation(RCPS, 0, "RCPS", parses);
    // A valid blob answering a *different* request must be refused — the
    // hash-collision guard: never adopt a foreign pattern's solution.
    let other_pattern = GroupFaults::free(CFG.cells());
    assert!(decode_blob(RCPS, &ctx, &other_pattern).is_err());
    let other_pipeline =
        PipelineOptions { table_value_limit: 512, ..PipelineOptions::default() };
    let other_ctx = StoreCtx::new(CFG, other_pipeline);
    assert!(decode_blob(RCPS, &other_ctx, &pattern).is_err());
}
