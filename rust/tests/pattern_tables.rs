//! Pattern-solution-table acceptance tests: the `BatchTable` tier (solve
//! once per pattern, dense full-range tables) must be byte-identical to
//! the per-weight pipeline for every method on R2C2/R1C4 across threads
//! {1, 4, 8}; a single pattern table must decode every representable
//! weight correctly under random fault states; and the memory bound must
//! evict deterministically without changing one output byte.

use rchg::coordinator::{
    solve_full_range, CompileOptions, CompileSession, CompiledTensor, Method, PatternCtx,
    PipelineOptions, SolveTier,
};
use rchg::experiments::compile_time::synthetic_model_weights;
use rchg::fault::bank::ChipFaults;
use rchg::fault::{FaultRates, GroupFaults};
use rchg::grouping::GroupConfig;
use rchg::prop_assert;
use rchg::util::prop::prop_check;

fn compile(ws: &[i64], faults: &[GroupFaults], opts: &CompileOptions) -> CompiledTensor {
    CompileSession::builder(opts.cfg)
        .options(opts.clone())
        .detached()
        .compile_with_faults(ws, faults)
}

#[test]
fn batch_table_matches_per_weight_for_all_methods_and_threads() {
    // Acceptance: BatchTable output is byte-identical to the per-weight
    // pipeline for every method on R2C2/R1C4 at threads {1, 4, 8}. For
    // the baselines the tier gate routes both runs to per-weight solving
    // (the paper's cost model) — identity still must hold.
    for cfg in [GroupConfig::R2C2, GroupConfig::R1C4] {
        let chip = ChipFaults::new(3, FaultRates::paper_default());
        let methods: &[(Method, usize)] = if cfg == GroupConfig::R1C4 {
            &[
                (Method::Complete, 20_000),
                (Method::IlpOnly, 400),
                (Method::OriginalFf, 300),
                (Method::Unprotected, 2_000),
            ]
        } else {
            &[(Method::Complete, 20_000), (Method::IlpOnly, 400), (Method::Unprotected, 2_000)]
        };
        for &(method, n) in methods {
            let ws = synthetic_model_weights("resnet20", &cfg, n).unwrap();
            let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
            let mut pw = CompileOptions::new(cfg, method);
            pw.tier = SolveTier::PerWeight;
            let base = compile(&ws, &faults, &pw);
            for threads in [1usize, 4, 8] {
                let mut bt = CompileOptions::new(cfg, method);
                bt.tier = SolveTier::BatchTable;
                bt.threads = threads;
                let out = compile(&ws, &faults, &bt);
                assert_eq!(
                    out.decomps, base.decomps,
                    "{cfg} {method:?} decomps diverged at threads={threads}"
                );
                assert_eq!(
                    out.errors, base.errors,
                    "{cfg} {method:?} errors diverged at threads={threads}"
                );
                assert_eq!(
                    out.stats.stage_counts, base.stats.stage_counts,
                    "{cfg} {method:?} stage census diverged at threads={threads}"
                );
            }
        }
    }
}

#[test]
fn every_weight_decodes_from_one_pattern_table() {
    // Acceptance: one pattern table answers the FULL weight range
    // [-max, +max] correctly under random fault states — each entry
    // decodes to within its recorded error, and the error equals the
    // per-weight pipeline's.
    prop_check("pattern-table-full-range", 80, |rng| {
        let cfg = [GroupConfig::R2C2, GroupConfig::R1C4][rng.index(2)];
        let faults =
            GroupFaults::sample(cfg.cells(), &FaultRates { p_sa0: 0.15, p_sa1: 0.15 }, rng);
        let ctx = PatternCtx::new(cfg, faults.clone());
        let popts = PipelineOptions::default();
        let (table, _clock) = solve_full_range(&ctx, &popts, false);
        let maxv = cfg.max_per_array();
        prop_assert!(table.len() as i64 == 2 * maxv + 1, "table must span the whole range");
        for w in -maxv..=maxv {
            let out = &table[(w + maxv) as usize];
            let decoded = out.decomposition.faulty_value(&cfg, &faults);
            prop_assert!(
                (w - decoded).abs() == out.error,
                "w={w} decodes to {decoded} but the table recorded error {} (cfg {cfg})",
                out.error
            );
        }
        Ok(())
    });
}

#[test]
fn memory_bound_evicts_without_changing_outputs() {
    // The ROADMAP cache-bound item: a tiny table budget forces evictions
    // across batches, yet every output stays byte-identical to the
    // unbounded run and the resident estimate respects the budget at
    // batch boundaries.
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(17, FaultRates::paper_default());
    let tensors: Vec<Vec<i64>> = (0..4)
        .map(|i| synthetic_model_weights("resnet20", &cfg, 4_000 + 7 * i).unwrap())
        .collect();

    let mut unbounded = CompileSession::builder(cfg).chip(&chip);
    let mut bounded = CompileSession::builder(cfg).table_memory_bytes(64 << 10).chip(&chip);
    let mut evictions_seen = 0u64;
    for (i, ws) in tensors.iter().enumerate() {
        let name = format!("t{i}");
        let a = unbounded.compile_tensor(&name, ws);
        let b = bounded.compile_tensor(&name, ws);
        assert_eq!(a.decomps, b.decomps, "eviction changed outputs on {name}");
        assert_eq!(a.errors, b.errors);
        evictions_seen = evictions_seen.max(b.stats.table_evictions);
    }
    assert!(evictions_seen > 0, "a 64 KiB budget must evict on resnet20-scale work");
    assert_eq!(unbounded.stats().table_evictions, 0, "default budget must not evict here");
    // The bounded session re-solves what it evicted: more fresh solves in
    // total, never fewer.
    assert!(bounded.stats().unique_pairs >= unbounded.stats().unique_pairs);
}
