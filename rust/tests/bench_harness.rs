//! Perf-harness contract tests: the `rchg bench` report schema is stable,
//! its non-timing fields are a deterministic function of the seeded
//! workload, and the committed `BENCH_*.json` trajectory files at the
//! repository root stay schema-valid.
//!
//! These run the tiny suite (seconds, no sockets); the real numbers come
//! from `rchg bench` / the CI smoke step.

use rchg::experiments::bench::{
    run, seeded_cases, skeleton, strip_timings, validate, BenchOptions, BENCH_SCHEMA,
};
use rchg::grouping::GroupConfig;
use rchg::util::json::Json;

fn tiny_report() -> Json {
    run(&BenchOptions::tiny(), true, 6).expect("tiny bench suite runs")
}

#[test]
fn report_schema_round_trips() {
    let doc = tiny_report();
    validate(&doc).expect("fresh report validates");
    let text = doc.pretty();
    let parsed = Json::parse(&text).expect("report serializes to parseable JSON");
    assert_eq!(parsed, doc, "pretty → parse must round-trip exactly");
    validate(&parsed).expect("parsed report still validates");
    assert_eq!(doc.get("schema").as_str(), Some(BENCH_SCHEMA));
}

#[test]
fn report_matches_skeleton_key_tree() {
    // The measured report and the no-toolchain skeleton must have byte-for-
    // byte identical key trees — that is the whole schema-stability story.
    let doc = tiny_report();
    let sk = skeleton(6);
    fn key_tree(j: &Json) -> Json {
        match j {
            Json::Obj(m) => Json::Obj(m.iter().map(|(k, v)| (k.clone(), key_tree(v))).collect()),
            _ => Json::Null,
        }
    }
    assert_eq!(key_tree(&doc), key_tree(&sk));
    validate(&sk).expect("skeleton validates");
}

#[test]
fn non_timing_fields_are_deterministic() {
    let a = strip_timings(&tiny_report());
    let b = strip_timings(&tiny_report());
    assert_eq!(
        a.pretty(),
        b.pretty(),
        "two runs of the seeded suite must agree on every non-timing field"
    );
}

#[test]
fn seeded_case_pool_is_shared_and_stable() {
    // The harness and benches/bench_decompose.rs draw from this generator;
    // pin its determinism so the two can never silently diverge.
    for cfg in [GroupConfig::R2C2, GroupConfig::R1C4] {
        assert_eq!(seeded_cases(&cfg, 128), seeded_cases(&cfg, 128));
        // A prefix of a longer pool is the shorter pool (same stream).
        let long = seeded_cases(&cfg, 128);
        let short = seeded_cases(&cfg, 64);
        assert_eq!(&long[..64], &short[..]);
    }
}

#[test]
fn committed_trajectory_files_validate() {
    // Every BENCH_<n>.json at the repo root must parse and match the
    // current schema (skeletons with null leaves included).
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let mut seen = 0;
    for entry in std::fs::read_dir(&root).expect("repo root readable") {
        let path = entry.expect("dir entry").path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if !(name.starts_with("BENCH_") && name.ends_with(".json")) {
            continue;
        }
        let text = std::fs::read_to_string(&path).expect("trajectory file readable");
        let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e}"));
        validate(&doc).unwrap_or_else(|e| panic!("{name}: schema mismatch: {e}"));
        seen += 1;
    }
    assert!(seen >= 1, "expected at least BENCH_6.json at the repo root");
}
